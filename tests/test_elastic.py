"""Elastic resize: checkpoint written under one mesh restores under another.

Runs in a subprocess (needs 8 fake devices before jax init)."""

import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, tempfile
import jax, numpy as np
from repro.configs import get_reduced
from repro.launch import shardings as sh
from repro.models.model import build_model
from repro.optim.adamw import AdamWConfig, init_state
from repro.checkpoint import ckpt as ckpt_lib
from repro.runtime.elastic import reshard_restore, survivors_mesh
from repro.sharding import use_mesh

import jax.numpy as jnp
from repro import atomics

cfg = get_reduced("gemma_2b")
model = build_model(cfg, attn_impl="ref", remat_policy="none", loss_chunk=64)
opt_cfg = AdamWConfig()

# write under an 8-chip mesh (data=4, model=2)
mesh_a = jax.make_mesh((4, 2), ("data", "model"))
rules_a = sh.arch_rules(cfg, mesh_a, "train")
with use_mesh(mesh_a, rules_a):
    params = model.init(jax.random.PRNGKey(0))
    opt = init_state(params, opt_cfg)
    counters = atomics.make_table(64, jnp.int32, fill=9)  # live RMW state
d = tempfile.mkdtemp()
ckpt_lib.save(d, 5, {"params": params, "opt": opt, "counters": counters})

# restore under a shrunken mesh (lost half the data shards): 2x2
mesh_b = survivors_mesh({"data": 4, "model": 2}, lost_data_shards=2)
like = {"params": params, "opt": opt, "counters": counters}
state, _ = reshard_restore(d, 5, like, cfg, mesh_b)

# bitwise identical content, new placement — AtomicTable included
# (its owner-major layout re-derived under mesh_b, not the writer's mesh)
ok = True
for a, b in zip(jax.tree.leaves(like), jax.tree.leaves(state)):
    if not np.array_equal(np.asarray(a, np.float32),
                          np.asarray(b, np.float32)):
        ok = False
tbl = state["counters"]
ok &= isinstance(tbl, atomics.AtomicTable)
ok &= tbl.data.sharding.mesh.shape.get("data", 0) == 2
# and the restored params still produce the same loss on the new mesh
from repro.data.pipeline import DataConfig, synthetic_batch
dc = DataConfig(seq_len=16, global_batch=4, vocab_size=cfg.vocab_size)
batch = synthetic_batch(dc, 0)
loss_a = float(model.loss(params, batch))
rules_b = sh.arch_rules(cfg, mesh_b, "train")
with use_mesh(mesh_b, rules_b):
    loss_b = float(jax.jit(model.loss)(state["params"], batch))
print("RESULT:" + json.dumps({"bitwise": ok, "loss_a": loss_a,
                              "loss_b": loss_b}))
"""


def test_elastic_reshard_roundtrip():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT:")][0]
    res = json.loads(line[len("RESULT:"):])
    assert res["bitwise"]
    assert abs(res["loss_a"] - res["loss_b"]) / abs(res["loss_a"]) < 1e-3
