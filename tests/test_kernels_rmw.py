"""Pallas RMW kernel vs pure-jnp oracle: shape/dtype/alignment sweeps."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.rmw.kernel import rmw_table
from repro.kernels.rmw.ops import histogram, rmw_apply
from repro.kernels.rmw.ref import histogram_ref, rmw_table_ref

RNG = np.random.default_rng(7)

SWEEP = [
    # (table, n_ops, table_tile, block)
    (512, 1024, 512, 1024),
    (1024, 512, 256, 256),
    (700, 3000, 512, 1024),     # needs padding
    (96, 64, 512, 1024),        # tiny, heavy padding
    (4096, 8192, 128, 2048),
]


@pytest.mark.parametrize("op", ["faa", "min", "max", "swp"])
@pytest.mark.parametrize("m,n,tile,block", SWEEP)
def test_kernel_matches_ref(op, m, n, tile, block):
    table = jnp.asarray(RNG.normal(size=m), jnp.float32)
    idx = jnp.asarray(RNG.integers(0, m + 7, n), jnp.int32)  # some dropped
    vals = jnp.asarray(RNG.normal(size=n), jnp.float32)
    got = rmw_apply(table, idx, vals, op, table_tile=tile, block=block)
    want = rmw_table_ref(table, idx, vals, op)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("tile", [96, 384])  # off the 128-lane grid
def test_misaligned_tiles_still_correct(tile):
    """Unaligned tiles cost more (benchmarks/unaligned.py) but stay exact."""
    table = jnp.asarray(RNG.normal(size=960), jnp.float32)
    idx = jnp.asarray(RNG.integers(0, 960, 2048), jnp.int32)
    vals = jnp.asarray(RNG.normal(size=2048), jnp.float32)
    got = rmw_apply(table, idx, vals, "faa", table_tile=tile, block=512)
    np.testing.assert_allclose(got, rmw_table_ref(table, idx, vals, "faa"),
                               rtol=1e-5, atol=1e-5)


def test_out_of_range_dropped():
    table = jnp.zeros((128,), jnp.float32)
    idx = jnp.asarray([0, 127, 128, 10_000], jnp.int32)
    vals = jnp.ones((4,), jnp.float32)
    got = rmw_apply(table, idx, vals, "faa", table_tile=128, block=128)
    assert float(got.sum()) == 2.0


def test_direct_kernel_entry_alignment_asserts():
    with pytest.raises(AssertionError):
        rmw_table(jnp.zeros((100,), jnp.float32),
                  jnp.zeros((128,), jnp.int32),
                  jnp.zeros((128,), jnp.float32), "faa",
                  table_tile=512, block=128)


def test_histogram_is_faa_counter():
    idx = jnp.asarray(RNG.integers(0, 64, 5000), jnp.int32)
    got = histogram(idx, 64)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(histogram_ref(idx, 64)))
    assert float(got.sum()) == 5000
