"""SSD kernel + jnp chunked path vs sequential reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd.ops import ssd, ssd_chunked_jnp, ssd_decode_step
from repro.kernels.ssd.ref import ssd_ref

RNG = np.random.default_rng(13)


def _inputs(b, s, h, p, n):
    x = jnp.asarray(RNG.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(b, s, h)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    B = jnp.asarray(RNG.normal(size=(b, s, h, n)), jnp.float32)
    C = jnp.asarray(RNG.normal(size=(b, s, h, n)), jnp.float32)
    return x, dt, A, B, C


SWEEP = [(2, 64, 3, 16, 8, 16), (1, 100, 2, 8, 4, 32), (1, 32, 1, 4, 4, 32),
         (2, 48, 4, 8, 16, 8)]


@pytest.mark.parametrize("b,s,h,p,n,chunk", SWEEP)
def test_pallas_kernel_matches_ref(b, s, h, p, n, chunk):
    args = _inputs(b, s, h, p, n)
    got = ssd(*args, chunk=chunk, use_kernel=True)
    want = ssd_ref(*args)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("b,s,h,p,n,chunk", SWEEP)
def test_jnp_chunked_matches_ref(b, s, h, p, n, chunk):
    args = _inputs(b, s, h, p, n)
    got = ssd_chunked_jnp(*args, chunk=chunk)
    want = ssd_ref(*args)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


def test_final_state_consistency():
    args = _inputs(1, 40, 2, 8, 4)
    y1, h1 = ssd(*args, chunk=8, use_kernel=True, return_final_state=True)
    y2, h2 = ssd_chunked_jnp(*args, chunk=8, return_final_state=True)
    np.testing.assert_allclose(h1, h2, rtol=3e-4, atol=3e-4)
    # continuing with the state matches running the longer sequence
    x, dt, A, B, C = _inputs(1, 41, 2, 8, 4)
    y_full = ssd_ref(x, dt, A, B, C)
    y_pre, h_pre = ssd_chunked_jnp(x[:, :40], dt[:, :40], A, B[:, :40],
                                   C[:, :40], chunk=8,
                                   return_final_state=True)
    h_step, y_last = ssd_decode_step(h_pre, x[:, 40], dt[:, 40], A,
                                     B[:, 40], C[:, 40])
    np.testing.assert_allclose(y_last, y_full[:, -1], rtol=1e-3, atol=1e-4)


def test_grad_through_jnp_path():
    args = _inputs(1, 32, 2, 8, 4)
    g = jax.grad(lambda x: ssd_chunked_jnp(x, *args[1:], chunk=8).sum()
                 )(args[0])
    assert np.isfinite(np.asarray(g)).all()


def test_dt_zero_is_identity_step():
    """dt=0 => exp(0)*h + 0: state unchanged (padding correctness)."""
    x, dt, A, B, C = _inputs(1, 16, 2, 8, 4)
    h0 = jnp.asarray(RNG.normal(size=(1, 2, 4, 8)), jnp.float32)
    h1, y = ssd_decode_step(h0, x[:, 0], jnp.zeros_like(dt[:, 0]), A,
                            B[:, 0], C[:, 0])
    np.testing.assert_allclose(h1, h0, rtol=1e-6)
