"""Unified `repro.atomics` front-end: the ONE public RMW surface.

The acceptance contract of the API redesign (ISSUE 3), post shim removal
(ISSUE 5 deleted the PR-3 deprecation shims after their one-release window):

* `atomics.execute` is bit-identical to the serialized oracle for
  FAA/SWP/MIN/MAX, uniform-expected CAS *and* per-op-expected CAS,
  single-device and on an 8-fake-device mesh (subprocess half, same
  pattern as tests/test_rmw_sharded.py).
* the legacy entry points (``rmw_run``/``rmw.rmw``, ``rmw_execute``,
  ``rmw_sharded.rmw_sharded``, both old ``arrival_rank`` spellings) are
  GONE — `test_legacy_shims_are_deleted` pins that they never come back.
* typed constructors validate shapes; `AtomicTable` handles are pytrees
  carrying the mesh contract; `make_table` wires the ``"rmw_table"``
  logical-sharding rule; a sharded table outside shard_map fails with
  guidance; `select_exchange` honours the dynamic contention hint.
"""

import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import atomics
from repro.core.rmw import rmw_serialized

RNG = np.random.default_rng(5)

OPS = atomics.OP_KINDS


def _batch(n=300, m=17):
    idx = jnp.asarray(RNG.integers(-2, m + 3, n), jnp.int32)  # incl. OOR
    idx = jnp.clip(idx, 0, m - 1)  # local tier: keep in range
    vals = jnp.asarray(RNG.integers(-6, 7, n), jnp.int32)
    table = jnp.asarray(RNG.integers(-5, 6, m), jnp.int32)
    return table, idx, vals


def _assert_result(res, ref, what, table_only=False):
    np.testing.assert_array_equal(np.asarray(res.table.data),
                                  np.asarray(ref.table),
                                  err_msg=f"{what}: table")
    if not table_only:
        np.testing.assert_array_equal(np.asarray(res.fetched),
                                      np.asarray(ref.fetched),
                                      err_msg=f"{what}: fetched")
        np.testing.assert_array_equal(np.asarray(res.success),
                                      np.asarray(ref.success),
                                      err_msg=f"{what}: success")


# ---------------------------------------------------------------------------
# local tier: bit-identical to the oracle and to the legacy entries
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("op", ["faa", "swp", "min", "max"])
def test_execute_equals_oracle(op):
    table, idx, vals = _batch()
    ref = rmw_serialized(table, idx, vals, op)
    res = atomics.execute(table, OPS[op](idx, vals))
    _assert_result(res, ref, f"atomics:{op}")
    # ... and the raw-array engine entry (the internal tier) agrees
    from repro.core.rmw_engine import execute_backend
    raw = execute_backend(table, idx, vals, op)
    _assert_result(res, raw, f"engine:{op}")


def test_execute_cas_uniform_equals_oracle():
    m, n = 11, 300
    idx = jnp.asarray(RNG.integers(0, m, n), jnp.int32)
    vals = jnp.asarray(RNG.integers(-1, 2, n), jnp.int32)
    table = jnp.asarray(RNG.integers(-1, 2, m), jnp.int32)
    ref = rmw_serialized(table, idx, vals, "cas", jnp.zeros((n,), jnp.int32))
    res = atomics.execute(table, atomics.Cas(idx, vals, expected=0))
    _assert_result(res, ref, "cas-uniform")


def test_execute_cas_perop_equals_oracle():
    """Per-op expected locally: auto-routes to the serialized oracle."""
    m, n = 11, 200
    idx = jnp.asarray(RNG.integers(0, m, n), jnp.int32)
    vals = jnp.asarray(RNG.integers(-1, 2, n), jnp.int32)
    exp = jnp.asarray(RNG.integers(-1, 2, n), jnp.int32)
    table = jnp.asarray(RNG.integers(-1, 2, m), jnp.int32)
    ref = rmw_serialized(table, idx, vals, "cas", exp)
    res = atomics.execute(table, atomics.Cas(idx, vals, expected=exp))
    _assert_result(res, ref, "cas-perop")


def test_execute_table_only_and_backend_override():
    table, idx, vals = _batch()
    ref = rmw_serialized(table, idx, vals, "faa")
    for backend in ("auto", "sort", "onehot", "serialized"):
        res = atomics.execute(table, atomics.Faa(idx, vals),
                              backend=backend, need_fetched=False)
        _assert_result(res, ref, f"table-only:{backend}", table_only=True)


def test_execute_op_sequence_folds_in_order():
    table, idx, vals = _batch()
    ref1 = rmw_serialized(table, idx, vals, "faa")
    ref2 = rmw_serialized(ref1.table, idx, vals, "max")
    res = atomics.execute(table, [atomics.Faa(idx, vals),
                                  atomics.Max(idx, vals)])
    np.testing.assert_array_equal(np.asarray(res.table.data),
                                  np.asarray(ref2.table))
    assert isinstance(res.fetched, tuple) and len(res.fetched) == 2
    np.testing.assert_array_equal(np.asarray(res.fetched[0]),
                                  np.asarray(ref1.fetched))
    np.testing.assert_array_equal(np.asarray(res.fetched[1]),
                                  np.asarray(ref2.fetched))


# ---------------------------------------------------------------------------
# sharded tier in-process (1-device mesh): detection + legacy parity
# ---------------------------------------------------------------------------

def _one_dev_shard_map(fn, mesh, n_in, n_out):
    from jax.sharding import PartitionSpec as P
    from repro.sharding import shard_map_compat
    return shard_map_compat(fn, mesh, (P(),) * n_in, (P(),) * n_out)


def test_execute_sharded_detection_and_parity_one_device():
    mesh = jax.make_mesh((1,), ("x",))
    table, idx, vals = _batch()
    ref = rmw_serialized(table, idx, vals, "faa")

    def fn(t, i, v):
        tbl = atomics.AtomicTable(t, axis="x")
        res = atomics.execute(tbl, atomics.Faa(i, v))
        return res.table.data, res.fetched, res.success

    tab, fetched, success = _one_dev_shard_map(fn, mesh, 3, 3)(
        table, idx, vals)
    np.testing.assert_array_equal(np.asarray(tab), np.asarray(ref.table))
    np.testing.assert_array_equal(np.asarray(fetched),
                                  np.asarray(ref.fetched))


def test_sharded_table_outside_shard_map_raises_with_guidance():
    table, idx, vals = _batch()
    tbl = atomics.AtomicTable(table, axis="model")
    with pytest.raises(ValueError, match="shard_map"):
        atomics.execute(tbl, atomics.Faa(idx, vals))


def test_local_table_rejects_sharded_tier_arguments():
    """Naming an exchange strategy (or hint) against a local table is almost
    always a migration that forgot AtomicTable(axis=...) — error, don't
    silently run the local tier and drop the exchange."""
    table, idx, vals = _batch()
    with pytest.raises(ValueError, match="AtomicTable"):
        atomics.execute(table, atomics.Faa(idx, vals), strategy="oneshot")
    with pytest.raises(ValueError, match="AtomicTable"):
        atomics.execute(table, atomics.Faa(idx, vals), distinct_slots=8)


def test_sharded_perop_cas_rejects_non_oracle_backend():
    """Sharded per-op CAS mirrors the local tier: an explicit non-oracle
    backend override raises instead of being silently ignored."""
    mesh = jax.make_mesh((1,), ("x",))
    m, n = 8, 16
    table = jnp.zeros((m,), jnp.int32)
    idx = jnp.zeros((n,), jnp.int32)
    vals = jnp.ones((n,), jnp.int32)
    exp = jnp.zeros((n,), jnp.int32)

    def fn(t, i, v, e):
        tbl = atomics.AtomicTable(t, axis="x")
        res = atomics.execute(tbl, atomics.Cas(i, v, expected=e),
                              backend="onehot")
        return res.table.data

    with pytest.raises(ValueError, match="serialized oracle"):
        _one_dev_shard_map(fn, mesh, 4, 1)(table, idx, vals, exp)


# ---------------------------------------------------------------------------
# typed constructors + table handle
# ---------------------------------------------------------------------------

def test_op_constructors_validate():
    i2 = jnp.zeros((2,), jnp.int32)
    v3 = jnp.zeros((3,), jnp.int32)
    with pytest.raises(ValueError, match="batch size"):
        atomics.Faa(i2, v3)
    with pytest.raises(ValueError, match="1-D"):
        atomics.Swp(jnp.zeros((2, 2), jnp.int32), jnp.zeros((4,), jnp.int32))
    with pytest.raises(ValueError, match="expected"):
        atomics.Cas(i2, i2, expected=None)
    with pytest.raises(ValueError, match="per-op expected"):
        atomics.Cas(i2, i2, expected=v3)
    assert atomics.Cas(i2, i2, expected=0).uniform_expected
    assert not atomics.Cas(i2, i2, expected=i2).uniform_expected


def test_execute_rejects_untyped_ops():
    table, idx, vals = _batch()
    with pytest.raises(TypeError, match="atomics.Faa"):
        atomics.execute(table, (idx, vals, "faa"))
    with pytest.raises(ValueError, match="empty"):
        atomics.execute(table, [])


def test_atomic_table_is_pytree_through_jit():
    tbl = atomics.AtomicTable(jnp.zeros((8,), jnp.int32), axis="model",
                              replica_axes=("data",))
    out = jax.jit(lambda t: t.with_data(t.data + 1))(tbl)
    assert isinstance(out, atomics.AtomicTable)
    assert out.axis == "model" and out.replica_axes == ("data",)
    assert int(out.data.sum()) == 8
    # ops are pytrees too
    op = atomics.Cas(jnp.zeros((2,), jnp.int32), jnp.ones((2,), jnp.int32),
                     expected=jnp.zeros((2,), jnp.int32))
    leaves = jax.tree_util.tree_leaves(op)
    assert len(leaves) == 3


def test_make_table_without_mesh_is_local():
    tbl = atomics.make_table(16, jnp.float32, fill=2.5)
    assert not tbl.is_sharded and tbl.axis is None
    assert tbl.dtype == jnp.float32 and float(tbl.data[3]) == 2.5


def test_replica_axes_without_axis_rejected():
    """A 'replicated but unsharded' table would silently drop the
    replica-major write contract — both constructors must refuse it."""
    with pytest.raises(ValueError, match="replica_axes requires axis"):
        atomics.AtomicTable(jnp.zeros((8,), jnp.int32),
                            replica_axes=("data",))
    # make_table: no mesh -> the rmw_table rule resolves to nothing
    with pytest.raises(ValueError, match="replica_axes"):
        atomics.make_table(16, jnp.int32, replica_axes=("data",))


# ---------------------------------------------------------------------------
# the PR-3 shims completed their one-release window and are deleted —
# pin the removal so they cannot quietly come back
# ---------------------------------------------------------------------------

def test_legacy_shims_are_deleted():
    import repro.core as core
    from repro.core import rmw_engine, rmw_sharded
    from repro.core import rmw as rmw_mod
    for holder, name in ((rmw_mod, "rmw"), (rmw_mod, "arrival_rank"),
                         (rmw_engine, "rmw_execute"),
                         (rmw_engine, "arrival_rank"),
                         (rmw_sharded, "rmw_sharded"),
                         (core, "rmw_run"), (core, "rmw_execute"),
                         (core, "arrival_rank"), (core, "RmwConfig")):
        assert not hasattr(holder, name), \
            f"{holder.__name__}.{name} shim resurrected"
    # ... and the internal raw-array entries remain
    assert callable(rmw_engine.execute_backend)
    assert callable(rmw_sharded.execute_sharded)


def test_arrival_rank_canonical_spellings_agree():
    keys = jnp.asarray(RNG.integers(0, 5, 64), jnp.int32)
    want = atomics.arrival_rank(keys, 5)          # sort-free
    np.testing.assert_array_equal(np.asarray(atomics.arrival_rank(keys)),
                                  np.asarray(want))  # argsort fallback


# ---------------------------------------------------------------------------
# dynamic contention hint (select_exchange)
# ---------------------------------------------------------------------------

def _hint_spec():
    from repro.core import perf_model
    from repro.core.placement import Tier
    base = perf_model.cpu_default_spec()
    return dataclasses.replace(
        base,
        tier_bandwidth_Bps={**base.tier_bandwidth_Bps,
                            Tier.DCN_REMOTE_POD: 1e8},
        collective_launch_s=1e-4)


def _hint_axes():
    from repro.core.placement import Tier
    from repro.core.rmw_sharded import MeshAxis
    return (MeshAxis("pod", 2, Tier.DCN_REMOTE_POD),
            MeshAxis("dev", 4, Tier.ICI_NEIGHBOR))


def test_contention_hint_shifts_exchange_crossover():
    """Static caps say 'big contended batch -> hierarchical'; an observed
    distinct-slot estimate of a *skewed* batch (few slots -> tiny combined
    payload) flips the pick to one-shot, because the DCN savings no longer
    pay for the extra level's launches.  Wide estimates must not flip."""
    from repro.core.rmw_sharded import select_exchange
    spec, axes = _hint_spec(), _hint_axes()
    assert select_exchange("faa", 65536, 1 << 19, axes,
                           spec=spec) == "hierarchical"
    assert select_exchange("faa", 65536, 1 << 19, axes, spec=spec,
                           distinct_slots=64) == "oneshot"
    assert select_exchange("faa", 65536, 1 << 19, axes, spec=spec,
                           distinct_slots=65536) == "hierarchical"


def test_contention_hint_never_changes_results():
    """The hint reaches only the selector: execution with an absurd hint is
    still bit-identical (1-device mesh exercises the full dispatch path)."""
    mesh = jax.make_mesh((1,), ("x",))
    table, idx, vals = _batch()
    ref = rmw_serialized(table, idx, vals, "faa")

    def fn(t, i, v):
        tbl = atomics.AtomicTable(t, axis="x")
        res = atomics.execute(tbl, atomics.Faa(i, v), distinct_slots=1)
        return res.table.data, res.fetched

    tab, fetched = _one_dev_shard_map(fn, mesh, 3, 2)(table, idx, vals)
    np.testing.assert_array_equal(np.asarray(tab), np.asarray(ref.table))
    np.testing.assert_array_equal(np.asarray(fetched),
                                  np.asarray(ref.fetched))


# ---------------------------------------------------------------------------
# 8-fake-device subprocess: per-op-expected CAS across shards + make_table
# ---------------------------------------------------------------------------

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro import atomics
from repro.core.rmw import rmw_serialized
from repro.sharding import DEFAULT_RULES, shard_map_compat, use_mesh

rng = np.random.default_rng(13)
mesh = jax.make_mesh((2, 4), ("pod", "dev"))
NDEV = 8
SPEC = P(("pod", "dev"))
out = {}

def check_perop_cas(tag, dist, replica_axes=(), n_per=48, m=64):
    axis = ("pod", "dev") if not replica_axes else "dev"
    if dist == "hot":
        idx = rng.integers(0, max(2, m // 8), (NDEV, n_per))
    else:
        idx = rng.integers(-2, m + 3, (NDEV, n_per))   # includes OOR
    vals = rng.integers(-1, 2, (NDEV, n_per))
    exps = rng.integers(-1, 2, (NDEV, n_per))          # PER-OP expected
    table0 = rng.integers(-1, 2, m)
    idx_j = jnp.asarray(idx, jnp.int32)
    vals_j = jnp.asarray(vals, jnp.int32)
    exps_j = jnp.asarray(exps, jnp.int32)
    tab_j = jnp.asarray(table0, jnp.int32)
    tab_spec = SPEC if not replica_axes else P("dev")

    def fn(t, i, v, e):
        tbl = atomics.AtomicTable(t, axis=axis, replica_axes=replica_axes)
        res = atomics.execute(tbl, atomics.Cas(i[0], v[0], expected=e[0]))
        return res.table.data, res.fetched[None], res.success[None]

    tabs, fetched, success = shard_map_compat(
        fn, mesh, (tab_spec, SPEC, SPEC, SPEC), (tab_spec, SPEC, SPEC))(
        tab_j, idx_j, vals_j, exps_j)

    # oracle: device-rank-ordered concatenation, per-op expected concatenated
    flat_i = idx_j.reshape(-1); flat_v = vals_j.reshape(-1)
    flat_e = exps_j.reshape(-1)
    valid = (flat_i >= 0) & (flat_i < m)
    pad_tab = jnp.concatenate([tab_j, jnp.zeros((1,), jnp.int32)])
    ref = rmw_serialized(pad_tab, jnp.where(valid, flat_i, m), flat_v,
                         "cas", flat_e)
    ok = bool(np.array_equal(np.asarray(tabs).reshape(-1)[:m],
                             np.asarray(ref.table)[:m]))
    ok &= bool(np.array_equal(
        np.asarray(fetched).reshape(-1),
        np.asarray(jnp.where(valid, ref.fetched, 0))))
    ok &= bool(np.array_equal(np.asarray(success).reshape(-1),
                              np.asarray(ref.success & valid)))
    out[tag] = ok

check_perop_cas("perop_cas/hot", "hot")
check_perop_cas("perop_cas/uniform_with_oor", "uniform")
check_perop_cas("perop_cas/hot/replicated", "hot", replica_axes="pod")
check_perop_cas("perop_cas/uniform/replicated", "uniform",
                replica_axes="pod")

# table-only per-op CAS agrees on the table
idx = jnp.asarray(rng.integers(0, 64, (NDEV, 40)), jnp.int32)
vals = jnp.asarray(rng.integers(-1, 2, (NDEV, 40)), jnp.int32)
exps = jnp.asarray(rng.integers(-1, 2, (NDEV, 40)), jnp.int32)
tab0 = jnp.asarray(rng.integers(-1, 2, 64), jnp.int32)
def fn_to(t, i, v, e):
    tbl = atomics.AtomicTable(t, axis=("pod", "dev"))
    res = atomics.execute(tbl, atomics.Cas(i[0], v[0], expected=e[0]),
                          need_fetched=False)
    return res.table.data
tabs = shard_map_compat(fn_to, mesh, (SPEC, SPEC, SPEC, SPEC), SPEC)(
    tab0, idx, vals, exps)
ref = rmw_serialized(tab0, idx.reshape(-1), vals.reshape(-1), "cas",
                     exps.reshape(-1))
out["perop_cas/table_only"] = bool(np.array_equal(
    np.asarray(tabs).reshape(-1), np.asarray(ref.table)))

# make_table wires the "rmw_table" logical rule to the model axis
mesh2 = jax.make_mesh((2, 4), ("pod", "model"))
with use_mesh(mesh2, dict(DEFAULT_RULES)):
    tbl = atomics.make_table(4096, jnp.int32)
out["make_table/axis_is_model"] = tbl.axis == "model"
# sharded 4-ways over model (4 distinct slices), replicated over pod
out["make_table/sharded_over_4"] = (
    len(set(str(s.index) for s in tbl.data.addressable_shards)) == 4)
# non-divisible tables fall back to local (the divisibility-aware rule)
with use_mesh(mesh2, dict(DEFAULT_RULES)):
    tbl_odd = atomics.make_table(13, jnp.int32)
out["make_table/non_divisible_local"] = tbl_odd.axis is None

print("RESULT:" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def atomics_sharded_result():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT:")][0]
    return json.loads(line[len("RESULT:"):])


def test_perop_cas_across_shards_matches_oracle(atomics_sharded_result):
    bad = [k for k, v in atomics_sharded_result.items() if v is not True]
    assert not bad, f"mismatches: {bad}"
