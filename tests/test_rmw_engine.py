"""RMW engine: every backend agrees bit-exactly with the serialized oracle.

Property-style over collision-heavy index distributions (tiny tables, zipf-y
hot slots, runs of repeats) — the regimes where combining bugs hide.  Also
covers the Pallas kernel's new fetched-value / uniform-CAS outputs and the
cost-model backend selector.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image: fall back to the local shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.atomics import arrival_rank
from repro.core import perf_model
from repro.core.rmw import rmw_serialized
from repro.core.rmw_engine import (BACKENDS, execute_backend, rmw_onehot,
                                   select_backend)
from repro.kernels.rmw.ops import rmw_apply_fetched
from repro.kernels.rmw.ref import rmw_table_fetched_ref

SET = settings(max_examples=25, deadline=None)

RNG = np.random.default_rng(11)


def _collision_heavy(rng, n, m):
    """Mix of hot-slot, uniform, and run-repeated indices."""
    hot = rng.integers(0, max(1, m // 8) or 1, n)
    uni = rng.integers(0, m, n)
    runs = np.repeat(rng.integers(0, m, n // 4 + 1), 4)[:n]
    mix = np.where(rng.random(n) < 0.5, hot, uni)
    mix = np.where(rng.random(n) < 0.25, runs, mix)
    return mix.astype(np.int32)


def batches(max_table=8, max_ops=48, lo=-4, hi=4):
    return st.tuples(
        st.integers(1, max_table),
        st.lists(st.tuples(st.integers(0, max_table - 1),
                           st.integers(lo, hi)), min_size=1,
                 max_size=max_ops))


def _assert_same(a, b, what):
    np.testing.assert_array_equal(np.asarray(a.table), np.asarray(b.table),
                                  err_msg=f"{what}: table")
    np.testing.assert_array_equal(np.asarray(a.fetched), np.asarray(b.fetched),
                                  err_msg=f"{what}: fetched")
    np.testing.assert_array_equal(np.asarray(a.success), np.asarray(b.success),
                                  err_msg=f"{what}: success")


# ---------------------------------------------------------------------------
# onehot backend vs oracle (int dtypes: bit-exact)
# ---------------------------------------------------------------------------

@SET
@given(batches(), st.sampled_from(["faa", "swp", "min", "max"]))
def test_onehot_equals_serialized(batch, op):
    m, ops = batch
    idx = jnp.asarray([i % m for i, _ in ops], jnp.int32)
    vals = jnp.asarray([v for _, v in ops], jnp.int32)
    table = jnp.arange(m, dtype=jnp.int32) - m // 2
    a = rmw_serialized(table, idx, vals, op)
    b = rmw_onehot(table, idx, vals, op, block=16)
    _assert_same(a, b, f"onehot:{op}")
    # table-only mode agrees on the table
    c = rmw_onehot(table, idx, vals, op, block=16, need_fetched=False)
    np.testing.assert_array_equal(np.asarray(a.table), np.asarray(c.table))


@SET
@given(batches(max_table=4, lo=-2, hi=2), st.integers(-2, 2))
def test_onehot_cas_uniform_equals_serialized(batch, expected):
    m, ops = batch
    idx = jnp.asarray([i % m for i, _ in ops], jnp.int32)
    vals = jnp.asarray([v for _, v in ops], jnp.int32)
    table = jnp.asarray([(i % 5) - 2 for i in range(m)], jnp.int32)
    exp_arr = jnp.full((len(ops),), expected, jnp.int32)
    a = rmw_serialized(table, idx, vals, "cas", exp_arr)
    b = rmw_onehot(table, idx, vals, "cas", jnp.int32(expected), block=16)
    _assert_same(a, b, "onehot:cas")
    c = rmw_onehot(table, idx, vals, "cas", jnp.int32(expected), block=16,
                   need_fetched=False)
    np.testing.assert_array_equal(np.asarray(a.table), np.asarray(c.table))


@pytest.mark.parametrize("op", ["faa", "swp", "min", "max"])
@pytest.mark.parametrize("backend", ["sort", "onehot", "serialized"])
def test_backends_agree_collision_heavy(backend, op):
    """Larger batches, blocks straddled, hot slots: all backends identical."""
    m, n = 37, 500
    idx = jnp.asarray(_collision_heavy(RNG, n, m))
    vals = jnp.asarray(RNG.integers(-6, 7, n), jnp.int32)
    table = jnp.asarray(RNG.integers(-5, 6, m), jnp.int32)
    a = rmw_serialized(table, idx, vals, op)
    b = execute_backend(table, idx, vals, op, backend=backend)
    _assert_same(a, b, f"{backend}:{op}")


@pytest.mark.parametrize("backend", ["sort", "onehot"])
def test_backends_cas_collision_heavy(backend):
    m, n = 11, 300
    idx = jnp.asarray(_collision_heavy(RNG, n, m))
    # values drawn from {-1, 0, 1} with expected 0 => live/dead chains mix
    vals = jnp.asarray(RNG.integers(-1, 2, n), jnp.int32)
    table = jnp.asarray(RNG.integers(-1, 2, m), jnp.int32)
    a = rmw_serialized(table, idx, vals, "cas", jnp.zeros((n,), jnp.int32))
    b = execute_backend(table, idx, vals, "cas", jnp.int32(0), backend=backend)
    _assert_same(a, b, f"{backend}:cas")


def test_float_faa_close_across_backends():
    """Float FAA is exact up to reassociation on every backend."""
    m, n = 64, 2048
    idx = jnp.asarray(_collision_heavy(RNG, n, m))
    vals = jnp.asarray(RNG.normal(size=n), jnp.float32)
    table = jnp.asarray(RNG.normal(size=m), jnp.float32)
    ref = rmw_serialized(table, idx, vals, "faa")
    for backend in ("sort", "onehot", "pallas"):
        got = execute_backend(table, idx, vals, "faa", backend=backend)
        np.testing.assert_allclose(np.asarray(got.table),
                                   np.asarray(ref.table),
                                   rtol=1e-4, atol=1e-4, err_msg=backend)
        np.testing.assert_allclose(np.asarray(got.fetched),
                                   np.asarray(ref.fetched),
                                   rtol=1e-4, atol=1e-4, err_msg=backend)


# ---------------------------------------------------------------------------
# Pallas kernel: fetched values + uniform CAS vs the drop-aware oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("op", ["faa", "min", "max", "swp"])
@pytest.mark.parametrize("m,n,tile,block", [
    (128, 256, 128, 128),
    (256, 384, 128, 128),   # multiple tiles AND multiple blocks
    (96, 130, 128, 128),    # padding on both axes
])
def test_pallas_fetched_matches_oracle(op, m, n, tile, block):
    """Integer-valued fp32 => sums exact => bit-exact comparison is valid."""
    table = jnp.asarray(RNG.integers(-8, 9, m), jnp.float32)
    idx = jnp.asarray(_collision_heavy(RNG, n, m + 9))  # some dropped
    vals = jnp.asarray(RNG.integers(-4, 5, n), jnp.float32)
    t, f, s = rmw_apply_fetched(table, idx, vals, op, table_tile=tile,
                                block=block)
    tr, fr, sr = rmw_table_fetched_ref(table, idx, vals, op)
    np.testing.assert_array_equal(np.asarray(t), np.asarray(tr))
    np.testing.assert_array_equal(np.asarray(f), np.asarray(fr))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(sr))


@pytest.mark.parametrize("m,n,tile,block", [
    (128, 256, 128, 128),
    (200, 300, 128, 128),
])
def test_pallas_cas_uniform_matches_oracle(m, n, tile, block):
    # expected = 0 with a table and values full of zeros: dense chain action
    table = jnp.asarray(RNG.integers(-1, 2, m), jnp.float32)
    idx = jnp.asarray(_collision_heavy(RNG, n, m + 5))
    vals = jnp.asarray(RNG.integers(-1, 2, n), jnp.float32)
    t, f, s = rmw_apply_fetched(table, idx, vals, "cas",
                                expected=jnp.float32(0.0),
                                table_tile=tile, block=block)
    tr, fr, sr = rmw_table_fetched_ref(table, idx, vals, "cas",
                                       jnp.float32(0.0))
    np.testing.assert_array_equal(np.asarray(t), np.asarray(tr))
    np.testing.assert_array_equal(np.asarray(f), np.asarray(fr))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(sr))


def test_pallas_fetched_drops_out_of_range():
    table = jnp.zeros((128,), jnp.float32)
    idx = jnp.asarray([0, 0, 127, 128, 10_000], jnp.int32)
    vals = jnp.asarray([1, 2, 3, 4, 5], jnp.float32)
    t, f, s = rmw_apply_fetched(table, idx, vals, "faa", table_tile=128,
                                block=128)
    assert float(t.sum()) == 6.0
    np.testing.assert_array_equal(np.asarray(f), [0.0, 1.0, 0.0, 0.0, 0.0])
    np.testing.assert_array_equal(np.asarray(s), [True, True, True, False,
                                                  False])


# ---------------------------------------------------------------------------
# arrival_rank (sort-free) and the selector
# ---------------------------------------------------------------------------

@SET
@given(st.lists(st.integers(0, 5), min_size=1, max_size=60))
def test_arrival_rank_sortfree_is_faa_fetch(keys):
    k = jnp.asarray(keys, jnp.int32)
    ser = rmw_serialized(jnp.zeros((6,), jnp.int32), k,
                         jnp.ones((len(keys),), jnp.int32), "faa")
    np.testing.assert_array_equal(np.asarray(arrival_rank(k, 6)),
                                  np.asarray(ser.fetched))


def test_arrival_rank_blocked_path_matches_dense():
    # force the blocked (rmw_onehot) path with a big key space
    n, k = 512, 1 << 14
    keys = jnp.asarray(RNG.integers(0, 64, n), jnp.int32)  # still collides
    dense = arrival_rank(keys, 64)
    blocked = arrival_rank(keys, k, block=64)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(blocked))


def test_selector_prefers_sortfree_on_big_batches():
    """The tentpole regime: FAA batches >= 4k, tables <= 64k slots."""
    for n in (4096, 16384, 65536):
        for m in (256, 4096, 65536):
            assert select_backend("faa", n, m) == "onehot", (n, m)


def test_selector_respects_semantics():
    # general (per-op) expected CAS only has the oracle
    assert select_backend("cas", 10_000, 64,
                          uniform_expected=False) == "serialized"
    # int tables never go to the fp32 pallas kernel
    assert select_backend("swp", 4096, 256, dtype=jnp.int32) != "pallas"


def test_selector_tracks_spec_costs():
    spec = perf_model.cpu_default_spec()
    name = select_backend("faa", 8192, 1024, spec)
    backend = BACKENDS[name]
    others = [b for b in BACKENDS.values()
              if b.supports("faa", dtype=jnp.float32)]
    best = min(o.cost(spec, "faa", 8192, 1024, True) for o in others)
    assert backend.cost(spec, "faa", 8192, 1024, True) == best


def test_execute_validates():
    t = jnp.zeros((4,), jnp.int32)
    i = jnp.zeros((2,), jnp.int32)
    with pytest.raises(ValueError):
        execute_backend(t, i, i, "xor")
    with pytest.raises(ValueError):
        execute_backend(t, i, i, "cas")
    with pytest.raises(ValueError):
        execute_backend(t, i, i, "faa", backend="nope")
    # per-op expected arrays on a uniform-only backend must be rejected,
    # not silently mis-executed
    with pytest.raises(ValueError):
        execute_backend(t, i, i, "cas", jnp.zeros((2,), jnp.int32),
                    backend="onehot")


def test_execute_backend_modes_match_oracle():
    """The raw-array engine entry answers identically across backends (the
    facade shim this used to exercise is deleted)."""
    from repro.core.rmw_engine import execute_backend
    table = jnp.zeros((16,), jnp.int32)
    idx = jnp.asarray([1, 1, 2, 15, 1], jnp.int32)
    vals = jnp.asarray([1, 2, 3, 4, 5], jnp.int32)
    ref = rmw_serialized(table, idx, vals, "faa")
    for mode in ("auto", "onehot", "sort", "serialized"):
        got = execute_backend(table, idx, vals, "faa", backend=mode)
        _assert_same(ref, got, mode)
