"""MoE routing + RMW-semantics dispatch tests (local path)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig, MoEConfig
from repro.models.moe import (_capacity, _priority_rank, moe_ffn, moe_init)

KEY = jax.random.PRNGKey(3)


def _cfg(policy="cas_keep_top_gate", cap=1.0, e=4, k=2):
    return ModelConfig(
        name="t", family="moe", n_layers=1, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=64, vocab_size=64, max_seq_len=32,
        moe=MoEConfig(n_experts=e, top_k=k, d_ff_expert=32,
                      capacity_factor=cap, overflow_policy=policy))


def test_priority_rank_swp_is_arrival_order():
    ids = jnp.asarray([[0, 1], [0, 1], [0, 2]], jnp.int32)
    gates = jnp.asarray([[0.9, 0.1], [0.5, 0.5], [0.2, 0.8]], jnp.float32)
    r = _priority_rank(ids, gates, "swp_drop_newest")
    # expert 0 receives ops at flat positions 0, 2, 4 -> ranks 0,1,2
    np.testing.assert_array_equal(np.asarray(r), [0, 0, 1, 1, 2, 0])


def test_priority_rank_cas_is_gate_order():
    ids = jnp.asarray([[0], [0], [0]], jnp.int32)
    gates = jnp.asarray([[0.1], [0.9], [0.5]], jnp.float32)
    r = _priority_rank(ids, gates, "cas_keep_top_gate")
    # highest gate gets rank 0 (the CAS winner keeps the slot)
    np.testing.assert_array_equal(np.asarray(r), [2, 0, 1])


@pytest.mark.parametrize("policy", ["swp_drop_newest", "cas_keep_top_gate"])
def test_moe_forward_finite_and_shaped(policy):
    cfg = _cfg(policy)
    params = moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 8, 32), jnp.float32)
    out, aux = moe_ffn(params, x, cfg)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) >= 0


def test_capacity_drop_actually_drops():
    """With capacity_factor≈0, all tokens overflow -> zero routed output."""
    cfg = _cfg(cap=1e-6)
    params = moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (1, 16, 32), jnp.float32)
    out, _ = moe_ffn(params, x, cfg)
    # capacity 1 per expert: at most E tokens routed; most outputs zero
    nonzero_rows = np.abs(np.asarray(out)).sum(-1) > 1e-6
    assert nonzero_rows.sum() <= cfg.moe.n_experts * 1 * cfg.moe.top_k


def test_gate_priority_keeps_highest_gate_under_overflow():
    cfg_swp = _cfg("swp_drop_newest", cap=1e-6)
    cfg_cas = _cfg("cas_keep_top_gate", cap=1e-6)
    params = moe_init(KEY, cfg_cas, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(9), (1, 32, 32), jnp.float32)
    out_cas, _ = moe_ffn(params, x, cfg_cas)
    out_swp, _ = moe_ffn(params, x, cfg_swp)
    # both drop the same COUNT but keep different tokens in general
    assert not np.allclose(np.asarray(out_cas), np.asarray(out_swp))


def test_gradients_flow_to_router_and_experts():
    cfg = _cfg(cap=2.0)
    params = moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (1, 8, 32), jnp.float32)

    def loss(p):
        out, aux = moe_ffn(p, x, cfg)
        return (out ** 2).mean() + aux

    g = jax.grad(loss)(params)
    assert float(jnp.abs(g["router"]).sum()) > 0
    assert float(jnp.abs(g["w1"]).sum()) > 0


def test_capacity_formula():
    m = _cfg().moe
    assert _capacity(64, m, 1) == int(64 * m.top_k / m.n_experts
                                      * m.capacity_factor + 0.999)
    assert _capacity(1, m, 1) >= 1
