"""Per-arch smoke tests (assignment requirement): each architecture's reduced
config runs one forward/train step on CPU — output shapes + no NaNs — and a
prefill->decode consistency check for one arch per family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_reduced
from repro.data.pipeline import DataConfig, batch_kwargs_for, synthetic_batch
from repro.models.model import build_model

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=16, seed=0):
    dc = DataConfig(seq_len=s, global_batch=b, vocab_size=cfg.vocab_size,
                    seed=seed)
    return synthetic_batch(dc, 0, **batch_kwargs_for(cfg))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_loss(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg, attn_impl="ref", remat_policy="none",
                        loss_chunk=64)
    params = model.init(KEY)
    loss = model.loss(params, _batch(cfg))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    from repro.launch.steps import make_train_step
    from repro.optim.adamw import AdamWConfig, init_state
    cfg = get_reduced(arch)
    model = build_model(cfg, attn_impl="ref", remat_policy="none",
                        loss_chunk=64)
    params = model.init(KEY)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=4)
    opt = init_state(params, opt_cfg)
    step = jax.jit(make_train_step(model, opt_cfg))
    p1, o1, m1 = step(params, opt, _batch(cfg))
    assert bool(jnp.isfinite(m1["loss"])), arch
    assert int(o1["step"]) == 1
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in
                zip(jax.tree.leaves(params), jax.tree.leaves(p1)))
    assert delta > 0


@pytest.mark.parametrize("arch", ["gemma_2b", "deepseek_v3_671b",
                                  "mamba2_780m", "jamba_1_5_large_398b",
                                  "whisper_small"])
def test_prefill_decode_matches_teacher_forced(arch):
    cfg = get_reduced(arch).replace(dtype="float32")
    if cfg.moe is not None:
        cfg = cfg.replace(moe=cfg.moe.__class__(
            **{**cfg.moe.__dict__, "capacity_factor": 4.0}))
    model = build_model(cfg, attn_impl="ref", remat_policy="none",
                        loss_chunk=64)
    params = model.init(KEY)
    B, S = 2, 12
    batch = _batch(cfg, b=B, s=S)
    enc_out = model._encode(params, batch["frames"]) \
        if cfg.encoder is not None else None
    x = model._embed_in(params, batch, 0)
    h, _, _ = model._backbone(params, x, caches=None, enc_out=enc_out,
                              positions3=None)
    full = h.astype(jnp.float32) @ model._head(params).astype(jnp.float32)

    pre = dict(batch)
    key = "embeds" if cfg.embeds_input else "tokens"
    pre[key] = batch[key][:, :8]
    if "positions3" in pre:
        pre["positions3"] = batch["positions3"][:, :, :8]
    cache, logits = model.prefill(params, pre, s_max=S)
    np.testing.assert_allclose(logits, full[:, 7], rtol=1e-3, atol=1e-3)
    for t in range(8, S):
        step_in = {key: batch[key][:, t:t + 1]}
        if "positions3" in batch:
            step_in["positions3"] = batch["positions3"][:, :, t:t + 1]
        cache, logits = model.decode_step(params, cache, step_in)
        np.testing.assert_allclose(logits, full[:, t], rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_stage_plan_covers_all_layers(arch):
    cfg = get_reduced(arch)
    from repro.models.transformer import plan_stages
    stages = plan_stages(cfg)
    assert sum(len(sigs) * reps for sigs, reps in stages) == cfg.n_layers


def test_param_count_formula_close_to_actual():
    for arch in ("gemma_2b", "mamba2_780m", "phi3_medium_14b"):
        cfg = get_reduced(arch)
        model = build_model(cfg, attn_impl="ref", remat_policy="none")
        params = model.init(KEY)
        actual = sum(x.size for x in jax.tree.leaves(params))
        predicted = cfg.param_count()
        assert abs(actual - predicted) / actual < 0.30, (arch, actual,
                                                         predicted)
