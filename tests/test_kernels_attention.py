"""Flash-attention Pallas kernel vs reference: shape/GQA/padding sweeps."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import attention
from repro.kernels.flash_attention.ref import attention_ref

RNG = np.random.default_rng(11)

CASES = [
    # (B, Hq, Hkv, Sq, Skv, D, causal, bq, bk)
    (2, 4, 2, 128, 128, 64, True, 64, 64),
    (1, 8, 1, 100, 100, 32, True, 64, 64),     # MQA + padding
    (2, 4, 4, 64, 192, 64, True, 64, 64),      # cached decode-style kv
    (1, 2, 2, 50, 70, 16, True, 32, 32),
    (1, 4, 2, 96, 96, 64, False, 32, 64),
    (1, 3, 3, 33, 47, 8, False, 32, 32),
    (1, 1, 1, 1, 64, 32, True, 32, 32),        # single-query decode
]


@pytest.mark.parametrize("b,hq,hkv,sq,skv,d,causal,bq,bk", CASES)
def test_attention_matches_ref(b, hq, hkv, sq, skv, d, causal, bq, bk):
    q = jnp.asarray(RNG.normal(size=(b, hq, sq, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, hkv, skv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, hkv, skv, d)), jnp.float32)
    got = attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_bf16_tolerance():
    q = jnp.asarray(RNG.normal(size=(1, 4, 64, 64)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(1, 2, 64, 64)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(1, 2, 64, 64)), jnp.bfloat16)
    got = attention(q, k, v, causal=True, block_q=32, block_k=32)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_scale_override():
    q = jnp.asarray(RNG.normal(size=(1, 2, 32, 16)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 2, 32, 16)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 2, 32, 16)), jnp.float32)
    got = attention(q, k, v, causal=False, scale=0.5, block_q=32, block_k=32)
    want = attention_ref(q, k, v, causal=False, scale=0.5)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)
