"""Sharded RMW subsystem: 8-fake-device oracle equivalence + selector props.

The subprocess half (same pattern as tests/test_distributed.py: XLA_FLAGS
must predate jax init) checks the distributed engine against the
single-device serialized oracle under the documented arrival order — the
concatenation of per-device batches by device rank — for FAA/SWP/MIN and
uniform-CAS, fetched and table-only, across every exchange strategy, with
out-of-range drops and the replicated-writer mode.  The in-process half
covers the exchange selector (hierarchical-vs-one-shot crossover), the
hierarchical contention model, the calibrated-spec loader, and the
`repro.core.rmw` namespace fix.
"""

import dataclasses
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro import atomics
from repro.core.rmw import rmw_serialized
from repro.core.bfs import bfs, bfs_sharded, kronecker_graph

rng = np.random.default_rng(7)
mesh = jax.make_mesh((2, 4), ("pod", "dev"))
NDEV = 8
SPEC = P(("pod", "dev"))

def shard_map(fn, in_specs, out_specs):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as sm
    return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=False)

out = {}

def check(op, strategy, need_fetched, dist, axis, replica_axes=(),
          n_per=48, m=64, expected=0):
    n_rep = 2 if replica_axes else 1
    if dist == "hot":
        idx = rng.integers(0, max(2, m // 8), (NDEV, n_per))
    else:
        idx = rng.integers(-2, m + 3, (NDEV, n_per))   # includes OOR
    vals = rng.integers(-5, 6, (NDEV, n_per))
    table0 = rng.integers(-2, 3, m)
    if op == "cas":
        vals = rng.integers(-1, 2, (NDEV, n_per))
        table0 = rng.integers(-1, 2, m)
    idx_j = jnp.asarray(idx, jnp.int32)
    vals_j = jnp.asarray(vals, jnp.int32)
    tab_j = jnp.asarray(table0, jnp.int32)
    tab_spec = SPEC if not replica_axes else P("dev")

    def fn(t, i, v):
        tbl = atomics.AtomicTable(t, axis=axis, replica_axes=replica_axes)
        if op == "cas":
            aop = atomics.Cas(i[0], v[0], expected=jnp.int32(expected))
        else:
            aop = atomics.OP_KINDS[op](i[0], v[0])
        res = atomics.execute(tbl, aop, strategy=strategy,
                              need_fetched=need_fetched)
        return res.table.data, res.fetched[None], res.success[None]

    tabs, fetched, success = shard_map(
        fn, (tab_spec, SPEC, SPEC), (tab_spec, SPEC, SPEC))(
        tab_j, idx_j, vals_j)

    # oracle: concatenated batches in device-rank order, drops to a pad row
    flat_i = idx_j.reshape(-1); flat_v = vals_j.reshape(-1)
    valid = (flat_i >= 0) & (flat_i < m)
    pad_tab = jnp.concatenate([tab_j, jnp.zeros((1,), jnp.int32)])
    ref = rmw_serialized(pad_tab, jnp.where(valid, flat_i, m), flat_v, op,
                         None if op != "cas"
                         else jnp.full((flat_i.shape[0],), expected,
                                       jnp.int32))
    ok = bool(np.array_equal(np.asarray(tabs).reshape(-1)[:m],
                             np.asarray(ref.table)[:m]))
    if need_fetched:
        ok &= bool(np.array_equal(
            np.asarray(fetched).reshape(-1),
            np.asarray(jnp.where(valid, ref.fetched, 0))))
        ok &= bool(np.array_equal(np.asarray(success).reshape(-1),
                                  np.asarray(ref.success & valid)))
    tag = f"{op}/{strategy}/nf={int(need_fetched)}/{dist}/rep={n_rep>1}"
    out[tag] = ok

for op in ("faa", "swp", "cas", "min"):
    for strategy in ("oneshot", "hierarchical", "naive"):
        check(op, strategy, True, "hot", axis=("pod", "dev"))
    check(op, "oneshot", True, "uniform", axis=("pod", "dev"))
    check(op, "oneshot", False, "uniform", axis=("pod", "dev"))
check("faa", "hierarchical", True, "uniform", axis=("pod", "dev"))
check("faa", "dense", False, "hot", axis=("pod", "dev"))
check("faa", "dense", False, "uniform", axis=("pod", "dev"))
# replicated-writer mode: table sharded over dev, replicated over pod;
# arrival order = (pod major, dev minor) = flat device order
for op in ("faa", "swp", "cas"):
    check(op, "oneshot", True, "hot", axis="dev", replica_axes="pod")
check("faa", "dense", False, "hot", axis="dev", replica_axes="pod")

# reverse_ranks: oracle on the batches concatenated in DESCENDING device
# rank (every strategy realizes the same reversed order).  perop=True
# drives the _execute_cas_perop owner-oracle path, which carries its own
# per-level un-flip loop.
def check_reverse(op, strategy, replica_axes=(), n_per=48, m=64,
                  perop=False):
    axis = ("pod", "dev") if not replica_axes else "dev"
    idx = rng.integers(-2, m + 3, (NDEV, n_per))       # includes OOR
    vals = rng.integers(-5, 6, (NDEV, n_per))
    table0 = rng.integers(-2, 3, m)
    if op == "cas":
        vals = rng.integers(-1, 2, (NDEV, n_per))
        table0 = rng.integers(-1, 2, m)
    exps = rng.integers(-1, 2, (NDEV, n_per))          # per-op expected
    idx_j = jnp.asarray(idx, jnp.int32)
    vals_j = jnp.asarray(vals, jnp.int32)
    exps_j = jnp.asarray(exps, jnp.int32)
    tab_j = jnp.asarray(table0, jnp.int32)
    tab_spec = SPEC if not replica_axes else P("dev")

    def fn(t, i, v, e):
        tbl = atomics.AtomicTable(t, axis=axis, replica_axes=replica_axes)
        if op == "cas":
            aop = atomics.Cas(i[0], v[0],
                              expected=e[0] if perop else jnp.int32(0))
        else:
            aop = atomics.OP_KINDS[op](i[0], v[0])
        res = atomics.execute(tbl, aop, strategy=strategy,
                              reverse_ranks=True)
        return res.table.data, res.fetched[None], res.success[None]

    tabs, fetched, success = shard_map(
        fn, (tab_spec, SPEC, SPEC, SPEC), (tab_spec, SPEC, SPEC))(
        tab_j, idx_j, vals_j, exps_j)
    flat_i = idx_j[::-1].reshape(-1)
    flat_v = vals_j[::-1].reshape(-1)
    valid = (flat_i >= 0) & (flat_i < m)
    pad_tab = jnp.concatenate([tab_j, jnp.zeros((1,), jnp.int32)])
    exp_ref = None
    if op == "cas":
        exp_ref = (exps_j[::-1].reshape(-1) if perop
                   else jnp.zeros((flat_i.shape[0],), jnp.int32))
    ref = rmw_serialized(pad_tab, jnp.where(valid, flat_i, m), flat_v, op,
                         exp_ref)
    ok = bool(np.array_equal(np.asarray(tabs).reshape(-1)[:m],
                             np.asarray(ref.table)[:m]))
    ok &= bool(np.array_equal(
        np.asarray(fetched)[::-1].reshape(-1),
        np.asarray(jnp.where(valid, ref.fetched, 0))))
    ok &= bool(np.array_equal(np.asarray(success)[::-1].reshape(-1),
                              np.asarray(ref.success & valid)))
    tag = "cas_perop" if perop else op
    out[f"reverse/{tag}/{strategy}/rep={bool(replica_axes)}"] = ok

for strategy in ("oneshot", "hierarchical", "naive"):
    check_reverse("swp", strategy)
check_reverse("faa", "oneshot")
check_reverse("cas", "oneshot")
check_reverse("swp", "oneshot", replica_axes="pod")
check_reverse("cas", "oneshot", perop=True)            # owner-oracle path
check_reverse("cas", "oneshot", replica_axes="pod", perop=True)

# sharded BFS == single-device BFS (same arrival order => same parents),
# CAS protocol and the SWP+revert protocol (reversed second pass)
src, dst = kronecker_graph(scale=7, edgefactor=8, seed=3)
s = np.concatenate([src, dst]); d = np.concatenate([dst, src])
root = int(s[0])
r_local = bfs(s, d, 128, root=root, op="cas")
r_shard = bfs_sharded(s, d, 128, root=root)
out["bfs_parents_equal"] = bool(np.array_equal(
    np.asarray(r_local.parent), np.asarray(r_shard.parent)))
out["bfs_levels"] = [int(r_local.levels), int(r_shard.levels)]
r_local_swp = bfs(s, d, 128, root=root, op="swp")
r_shard_swp = bfs_sharded(s, d, 128, root=root, op="swp")
out["bfs_swp_parents_equal"] = bool(np.array_equal(
    np.asarray(r_local_swp.parent), np.asarray(r_shard_swp.parent)))
print("RESULT:" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def sharded_result():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT:")][0]
    return json.loads(line[len("RESULT:"):])


def test_sharded_matches_serialized_oracle(sharded_result):
    bad = [k for k, v in sharded_result.items()
           if k not in ("bfs_parents_equal", "bfs_levels") and v is not True]
    assert not bad, f"oracle mismatches: {bad}"


def test_sharded_bfs_matches_local(sharded_result):
    assert sharded_result["bfs_parents_equal"] is True
    lvls = sharded_result["bfs_levels"]
    assert lvls[0] == lvls[1]


# ---------------------------------------------------------------------------
# in-process: exchange selector, contention model, loader, namespace
# ---------------------------------------------------------------------------

def _geo_spec():
    """Pods linked by a slow shared WAN pipe — the hierarchy's home turf."""
    from repro.core import perf_model
    from repro.core.placement import Tier
    base = perf_model.cpu_default_spec()
    return dataclasses.replace(
        base,
        tier_bandwidth_Bps={**base.tier_bandwidth_Bps,
                            Tier.DCN_REMOTE_POD: 1e8},
        collective_launch_s=1e-6)


def _axes(outer=2, inner=4):
    from repro.core.placement import Tier
    from repro.core.rmw_sharded import MeshAxis
    return (MeshAxis("pod", outer, Tier.DCN_REMOTE_POD),
            MeshAxis("dev", inner, Tier.ICI_NEIGHBOR))


def test_selector_hierarchical_on_contended_slow_dcn():
    """Contended regime (caps bound by the table): the per-pod tree cuts the
    shared-DCN bytes by the pod fan-in and must win."""
    from repro.core.rmw_sharded import select_exchange
    spec = _geo_spec()
    assert select_exchange("faa", 65536, 1 << 19, _axes(),
                           spec=spec) == "hierarchical"
    assert select_exchange("faa", 65536, 4096, _axes(),
                           spec=spec) == "hierarchical"


def test_selector_oneshot_when_uncombinable_or_flat():
    from repro.core.rmw_sharded import select_exchange
    spec = _geo_spec()
    # small batch against a huge table: nothing to combine, extra level loses
    assert select_exchange("faa", 4096, 1 << 19, _axes(),
                           spec=spec) == "oneshot"
    # a single-axis mesh has no hierarchy to exploit
    assert select_exchange("faa", 65536, 1 << 19, _axes()[1:],
                           spec=spec) == "oneshot"


def test_selector_dense_for_table_only_faa():
    from repro.core.rmw_sharded import select_exchange
    assert select_exchange("faa", 65536, 4096, _axes(), spec=_geo_spec(),
                           need_fetched=False) == "dense"


def test_selector_model_mirrors_benchmark_acceptance():
    """The cost model itself must predict hierarchical < naive on contended
    shapes (the committed benchmark checks the measured version)."""
    from repro.core.rmw_sharded import (cost_exchange_hierarchical,
                                        cost_exchange_naive)
    spec = _geo_spec()
    hier = cost_exchange_hierarchical(spec, "faa", 65536, 4096, _axes())
    naive = cost_exchange_naive(spec, "faa", 65536, 4096, _axes())
    assert hier < naive


def test_selector_rejects_per_op_expected_cas():
    from repro.core.rmw_sharded import select_exchange
    with pytest.raises(ValueError):
        select_exchange("cas", 1024, 4096, _axes(), uniform_expected=False)


def test_contention_hierarchical_beats_flat_tree_over_dcn():
    from repro.core import contention, perf_model
    from repro.core.placement import Tier
    spec = perf_model.TPU_V5E
    flat = contention.contended_bandwidth_combining(
        spec, "faa", 64, remote_tier=Tier.DCN_REMOTE_POD)
    hier = contention.contended_bandwidth_hierarchical(spec, "faa", 4, 16)
    assert hier > flat
    assert contention.hierarchical_crossover_pods(spec, "faa", 16) >= 2


def test_default_spec_loads_calibration(tmp_path, monkeypatch):
    from repro.core import perf_model, rmw_engine
    spec = dataclasses.replace(perf_model.cpu_default_spec(),
                               gather_elem_s=7.5e-9)
    payload = {"jax_backend": "cpu", "spec": perf_model.spec_to_dict(spec)}
    path = tmp_path / "calibrated_spec.json"
    path.write_text(json.dumps(payload))
    monkeypatch.setenv("REPRO_CALIBRATED_SPEC", str(path))
    rmw_engine._reset_spec_cache()
    try:
        assert rmw_engine.default_spec().gather_elem_s == 7.5e-9
    finally:
        rmw_engine._reset_spec_cache()
    # corrupt files must fall back to the priors, never raise
    path.write_text("{not json")
    rmw_engine._reset_spec_cache()
    try:
        assert rmw_engine.default_spec().gather_elem_s \
            == perf_model.cpu_default_spec().gather_elem_s
    finally:
        rmw_engine._reset_spec_cache()


def test_core_rmw_namespace_contract():
    """Post shim removal: `from repro.core import rmw` AND
    `from repro.core import rmw_sharded` both yield plain modules (the PR-2
    function alias for the latter left with the PR-3 shims), and neither
    is callable."""
    import sys
    import types
    import pytest as _pytest
    from repro.core import rmw, rmw_sharded
    assert isinstance(rmw, types.ModuleType)
    assert type(rmw) is types.ModuleType          # not a callable subclass
    assert rmw_sharded is sys.modules["repro.core.rmw_sharded"]
    assert isinstance(rmw_sharded, types.ModuleType)
    with _pytest.raises(TypeError):
        rmw(None)                     # modules are not callable
    with _pytest.raises(TypeError):
        rmw_sharded(None)
