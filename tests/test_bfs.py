"""BFS with RMW combiners (paper §6.1): validity + equivalence properties."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image: fall back to the local shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.bfs import bfs, kronecker_graph, validate_parents


def _undirected(src, dst):
    return np.concatenate([src, dst]), np.concatenate([dst, src])


@pytest.mark.parametrize("op", ["cas", "swp", "faa"])
def test_kronecker_bfs_valid(op):
    src, dst = kronecker_graph(scale=8, edgefactor=8, seed=0)
    s, d = _undirected(src, dst)
    root = int(s[0])
    r = bfs(s, d, 256, root=root, op=op)
    assert validate_parents(s, d, np.asarray(r.parent), root)
    assert r.levels >= 1


def test_all_ops_reach_same_vertex_set():
    """Semantics differ in WHICH parent wins, never in reachability."""
    src, dst = kronecker_graph(scale=9, edgefactor=8, seed=1)
    s, d = _undirected(src, dst)
    root = int(s[0])
    reached = [np.asarray(bfs(s, d, 512, root=root, op=op).parent) >= 0
               for op in ("cas", "swp", "faa")]
    np.testing.assert_array_equal(reached[0], reached[1])
    np.testing.assert_array_equal(reached[0], reached[2])


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_random_graph_bfs_matches_python(seed):
    rng = np.random.default_rng(seed)
    n = 24
    m = rng.integers(10, 60)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    s, d = _undirected(src, dst)
    root = int(rng.integers(0, n))
    r = bfs(s, d, n, root=root, op="cas")
    # python BFS reference for the reachable set + level structure
    adj = {}
    for a, b in zip(s.tolist(), d.tolist()):
        adj.setdefault(a, set()).add(b)
    seen = {root}
    frontier = {root}
    while frontier:
        frontier = {v for u in frontier for v in adj.get(u, ())} - seen
        seen |= frontier
    got_reached = set(np.nonzero(np.asarray(r.parent) >= 0)[0].tolist())
    assert got_reached == seen
    assert validate_parents(s, d, np.asarray(r.parent), root)


def test_kronecker_shapes():
    src, dst = kronecker_graph(scale=6, edgefactor=4, seed=2)
    assert len(src) == len(dst) == 4 * 64
    assert src.max() < 64 and dst.max() < 64
