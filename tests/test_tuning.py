"""Guarded self-tuning (`repro.tuning`): controller, estimator, chaos.

The acceptance contract of ISSUE 9's robustness tentpole:

* the live-spec indirection swaps the selection cost model under all
  three tiers at once, and the controller's guardrails (clamp, deadband,
  cooldown, rollback, quarantine, validated persistence) make the
  feedback loop safe to leave on;
* the ``spec_perturb`` chaos site poisons the loop deterministically and
  the controller converges back / rolls back / quarantines — never
  silently;
* the load-bearing invariant: tuned runs are **bit-identical** to
  untuned runs (the spec steers selection only), asserted both on the
  chaos-matrix workload and on real train() metrics.
"""

import dataclasses
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import atomics, telemetry
from repro.checkpoint import ckpt
from repro.core import perf_model, rmw_engine
from repro.runtime.chaos import FaultPlan, SiteSpec
from repro.runtime.fault_tolerance import (FaultConfig, declare_donation,
                                           run_with_recovery)
from repro.tuning import (TUNABLE_FIELDS, TUNING_ENV, ContentionEstimator,
                          SpecController, TuningConfig, active_controller,
                          from_env, site_key)

#: the test-sized guardrail config: tiny windows, no cooldown
CFG = TuningConfig(min_events=8, min_samples=2, cooldown_updates=0)

P0 = 1e-5     # base predicted wall per synthetic drift event


@pytest.fixture(autouse=True)
def _tuning_hygiene():
    """No live spec / controller / stream state may leak across tests."""
    yield
    ctrl = active_controller()
    if ctrl is not None:
        ctrl.stop()
    rmw_engine.clear_live_spec()
    assert not telemetry.enabled()


def _feed_window(ctrl, true_factor, *, events=None):
    """Emit one full drift window through the live stream, closed-loop:
    predictions come from the *active* spec (scaled off the base field),
    measurements from the 'true' hardware (``base * true_factor``), then
    run one controller step and return its outcome."""
    k = ctrl.active.loop_step_s / ctrl.base.loop_step_s
    for _ in range(events if events is not None else ctrl.cfg.min_events):
        telemetry.record("atomics.execute", tier="local",
                         backend="serialized", op="faa", n=256,
                         predicted_s=P0 * k, measured_s=P0 * true_factor)
    return ctrl.step()


def _events(buf, name):
    return [e for e in buf.events if e.get("event") == name]


def _perturb_u(seed):
    """The deterministic spec_perturb parameter draw of ``seed``'s first
    firing — what the controller's `_maybe_perturb` will see."""
    plan = FaultPlan(seed, {"spec_perturb": SiteSpec(prob=1.0)})
    assert plan.fire("spec_perturb")
    return plan.param("spec_perturb")


def _seed_where(pred):
    for seed in range(256):
        if pred(_perturb_u(seed)):
            return seed
    raise AssertionError("no seed in 0..255 draws the wanted parameter")


# ---------------------------------------------------------------------------
# the live-spec indirection (rmw_engine)
# ---------------------------------------------------------------------------

def test_live_spec_indirection_covers_default_spec():
    cal = rmw_engine.calibrated_spec()
    assert rmw_engine.live_spec() is None
    assert rmw_engine.default_spec() == cal
    e0 = rmw_engine.spec_epoch()
    tuned = dataclasses.replace(cal, loop_step_s=cal.loop_step_s * 2)
    rmw_engine.set_live_spec(tuned)
    assert rmw_engine.default_spec() == tuned
    assert rmw_engine.live_spec() == tuned
    assert rmw_engine.spec_epoch() == e0 + 1
    rmw_engine.clear_live_spec()
    assert rmw_engine.default_spec() == cal
    assert rmw_engine.spec_epoch() == e0 + 2
    rmw_engine.clear_live_spec()            # idempotent: no spurious bump
    assert rmw_engine.spec_epoch() == e0 + 2


def test_set_live_spec_rejects_non_spec():
    with pytest.raises(TypeError, match="HardwareSpec"):
        rmw_engine.set_live_spec({"loop_step_s": 1.0})


# ---------------------------------------------------------------------------
# the update cycle: apply / confirm / clamp-walk / rollback / deadband
# ---------------------------------------------------------------------------

def test_window_fills_then_applies():
    with telemetry.capture() as buf:
        with SpecController(CFG) as ctrl:
            assert ctrl.step() is None          # empty window: fast path
            out = _feed_window(ctrl, 2.0, events=CFG.min_events - 1)
            assert out is None                  # still below min_events
            out = _feed_window(ctrl, 2.0, events=1)
            assert out == "apply"
            assert ctrl.active.loop_step_s == pytest.approx(
                ctrl.base.loop_step_s * 2.0)
            # installed process-wide, under every tier's default
            assert rmw_engine.default_spec() == ctrl.active
        assert rmw_engine.live_spec() is None   # stop() clears the override
    (apply,) = _events(buf, "tuning.apply")
    assert "loop_step_s" in apply["fields"]
    assert apply["fields"]["loop_step_s"]["to"] == pytest.approx(
        ctrl.base.loop_step_s * 2.0)


def test_clamp_walks_large_corrections_then_converges():
    """A 4x-miscalibrated constant is corrected over two clamped applies
    (max_update_factor=2), then held once converged."""
    with telemetry.capture() as buf:
        with SpecController(CFG) as ctrl:
            assert _feed_window(ctrl, 4.0) == "apply"     # clamped to 2x
            assert _feed_window(ctrl, 4.0) == "apply"     # walks to 4x
            assert _feed_window(ctrl, 4.0) == "hold"      # converged
            assert ctrl.active.loop_step_s == pytest.approx(
                ctrl.base.loop_step_s * 4.0)
            assert ctrl.n_applied == 2 and ctrl.n_rollbacks == 0
    first = _events(buf, "tuning.apply")[0]
    assert "loop_step_s" in first["clamped"]              # the clamp spoke up
    assert len(_events(buf, "tuning.confirm")) == 2       # both swaps upheld
    (hold,) = [e for e in _events(buf, "tuning.skip")
               if e["reason"] == "deadband"]
    assert hold["n"] == CFG.min_events


def test_rollback_reinstalls_the_previous_spec():
    with telemetry.capture() as buf:
        with SpecController(CFG) as ctrl:
            assert _feed_window(ctrl, 2.0) == "apply"
            # post-swap window wildly worse than the pre-swap score:
            # the swap must be judged harmful and undone
            assert _feed_window(ctrl, 64.0) == "rollback"
            assert ctrl.active == ctrl.base               # bit-equal restore
            assert rmw_engine.default_spec() == ctrl.base
            assert ctrl.n_rollbacks == 1
    (rb,) = _events(buf, "tuning.rollback")
    assert rb["score"] > rb["pre_swap_score"] + CFG.rollback_margin
    assert not _events(buf, "tuning.confirm")


def test_cooldown_sits_out_a_window_after_a_swap():
    cfg = dataclasses.replace(CFG, cooldown_updates=1)
    with telemetry.capture() as buf:
        with SpecController(cfg) as ctrl:
            assert _feed_window(ctrl, 2.0) == "apply"
            # the post-swap window still runs the rollback check (and
            # confirms), but fitting sits out the cooldown
            assert _feed_window(ctrl, 2.0) == "cooldown"
            assert _feed_window(ctrl, 2.0) == "hold"      # converged by now
    assert len(_events(buf, "tuning.confirm")) == 1
    assert [e["reason"] for e in _events(buf, "tuning.skip")] == \
        ["cooldown", "deadband"]


def test_deadband_holds_sub_threshold_moves():
    with telemetry.capture() as buf:
        with SpecController(CFG) as ctrl:
            assert _feed_window(ctrl, math.exp(0.02)) == "hold"
            assert ctrl.active == ctrl.base
            assert ctrl.n_applied == 0
    (skip,) = _events(buf, "tuning.skip")
    assert skip["reason"] == "deadband"


def test_per_field_sample_floors_surface_skipped_fields():
    cfg = dataclasses.replace(
        CFG, min_samples=2, min_samples_per_field={"sort_elem_pass_s": 99})
    with telemetry.capture() as buf:
        with SpecController(cfg) as ctrl:
            for _ in range(6):
                telemetry.record("atomics.execute", tier="local",
                                 backend="serialized", op="faa", n=256,
                                 predicted_s=P0, measured_s=P0 * 2)
            for _ in range(2):
                telemetry.record("atomics.execute", tier="local",
                                 backend="sort", op="faa", n=256,
                                 predicted_s=P0, measured_s=P0 * 3)
            assert ctrl.step() == "apply"
            assert ctrl.active.loop_step_s == pytest.approx(
                ctrl.base.loop_step_s * 2)
            # the sort pool had drift evidence but sat below its floor:
            # surfaced, not silently dropped
            assert ctrl.active.sort_elem_pass_s == ctrl.base.sort_elem_pass_s
    (apply,) = _events(buf, "tuning.apply")
    assert apply["skipped"]["sort_elem_pass_s"] == {"n": 2,
                                                    "min_samples": 99}


def test_only_one_controller_per_process():
    with telemetry.capture():
        with SpecController(CFG):
            with pytest.raises(RuntimeError, match="already running"):
                SpecController(CFG).start()
        with SpecController(CFG):           # released on stop
            pass


def test_stats_reports_counters_and_tuned_fields():
    with telemetry.capture():
        with SpecController(CFG) as ctrl:
            _feed_window(ctrl, 2.0)
            stats = ctrl.stats()
    assert stats["applied"] == 1 and stats["updates"] == 1
    assert stats["last_outcome"] == "apply"
    assert set(stats["tuned_fields"]) == {"loop_step_s"}
    assert stats["tuned_fields"]["loop_step_s"]["active"] == pytest.approx(
        stats["tuned_fields"]["loop_step_s"]["calibrated"] * 2)


def test_from_env(monkeypatch, tmp_path):
    monkeypatch.delenv(TUNING_ENV, raising=False)
    assert from_env() is None
    monkeypatch.setenv(TUNING_ENV, "off")
    assert from_env() is None
    monkeypatch.setenv(TUNING_ENV, "on")
    ctrl = from_env()
    assert isinstance(ctrl, SpecController) and ctrl.state_path is None
    path = str(tmp_path / "tuned.json")
    monkeypatch.setenv(TUNING_ENV, path)
    assert from_env().state_path == path


# ---------------------------------------------------------------------------
# chaos: the spec_perturb site
# ---------------------------------------------------------------------------

def test_spec_perturb_draws_are_deterministic():
    assert _perturb_u(3) == _perturb_u(3)
    # the parameter space is actually exercised: all three perturb kinds
    # are reachable from some seed
    _seed_where(lambda u: u < 0.5)               # skew
    _seed_where(lambda u: 0.5 <= u < 0.75)       # NaN poison
    _seed_where(lambda u: u >= 0.75)             # negative poison


def test_skewed_window_is_walked_back_by_honest_windows():
    """spec_perturb (skew) poisons the live spec through its own feedback
    loop — subsequent honest windows must converge it back to base."""
    seed = _seed_where(
        lambda u: u < 0.5 and abs(4.0 * u - 1.0) * math.log(8.0) > 0.3)
    plan = FaultPlan(seed, {"spec_perturb": SiteSpec(prob=1.0, count=1)})
    with telemetry.capture() as buf:
        with SpecController(CFG, chaos=plan) as ctrl:
            assert _feed_window(ctrl, 1.0) == "apply"     # the skewed swap
            skewed = ctrl.active.loop_step_s
            assert skewed != ctrl.base.loop_step_s
            _feed_window(ctrl, 1.0)                       # honest: walk back
            _feed_window(ctrl, 1.0)
            assert abs(math.log(ctrl.active.loop_step_s
                                / ctrl.base.loop_step_s)) < CFG.deadband
            assert ctrl.n_perturbs == 1
    (pert,) = _events(buf, "tuning.perturb")
    assert pert["kind"] == "skew"


@pytest.mark.parametrize("kind,pick", [
    ("nan", lambda u: 0.5 <= u < 0.75),
    ("negative", lambda u: u >= 0.75),
])
def test_poisoned_proposals_are_quarantined(kind, pick):
    plan = FaultPlan(_seed_where(pick),
                     {"spec_perturb": SiteSpec(prob=1.0, count=1)})
    with telemetry.capture() as buf:
        with SpecController(CFG, chaos=plan) as ctrl:
            assert _feed_window(ctrl, 3.0) == "quarantine"
            assert ctrl.active == ctrl.base       # nothing installed
            assert ctrl.n_quarantined == 1
            # and the loop keeps working: the next honest window applies
            assert _feed_window(ctrl, 3.0) == "apply"
    (q,) = _events(buf, "tuning.quarantine")
    (name, info), = q["fields"].items()
    assert name in TUNABLE_FIELDS
    assert info["reason"] == "non-finite or non-positive"
    (pert,) = _events(buf, "tuning.perturb")
    assert pert["kind"] == "poison" and pert["poison"] == kind


def test_out_of_envelope_proposal_falls_back_to_calibrated():
    """A finite but absurd proposal (outside envelope_factor of the
    calibrated spec) quarantines; a tuned field resets to calibrated."""
    with telemetry.capture() as buf:
        with SpecController(CFG) as ctrl:
            assert _feed_window(ctrl, 2.0) == "apply"     # now tuned 2x
            # bypass the fitter: hand _guard a pathological proposal
            applied, _clamped, quarantined = ctrl._guard(
                {"loop_step_s": ctrl.base.loop_step_s
                 * CFG.envelope_factor * 10})
            assert "loop_step_s" in quarantined
            assert quarantined["loop_step_s"]["reason"] == \
                "outside calibrated envelope"
            # the tuned (cur != cal) field falls back to calibrated
            assert applied == {"loop_step_s": ctrl.base.loop_step_s}
    assert buf  # capture kept alive past stop for symmetry with the others


# ---------------------------------------------------------------------------
# validated persistence
# ---------------------------------------------------------------------------

EST_KEY = ("cas", "local", "2^4", "2^3")


def test_state_roundtrip_restores_spec_and_estimator(tmp_path):
    path = str(tmp_path / "tuned.json")
    with telemetry.capture():
        with SpecController(CFG, state_path=path) as ctrl:
            _feed_window(ctrl, 2.0)
            ctrl.estimator.update(EST_KEY, 4)
            tuned = ctrl.active
    assert json.load(open(path))["jax_backend"] == jax.default_backend()
    with telemetry.capture() as buf:
        with SpecController(CFG, state_path=path) as ctrl2:
            assert ctrl2.active == tuned
            assert rmw_engine.default_spec() == tuned     # re-installed
            assert ctrl2.estimator.raw(EST_KEY) == 4.0
    (restore,) = _events(buf, "tuning.restore")
    assert restore["accepted"] and not restore["quarantined"]
    assert restore["estimator_sites"] == 1


def test_restore_rejects_backend_mismatch(tmp_path):
    path = tmp_path / "tuned.json"
    base = rmw_engine.calibrated_spec()
    path.write_text(json.dumps({
        "version": 1, "jax_backend": "not-this-backend",
        "spec": perf_model.spec_to_dict(
            dataclasses.replace(base, loop_step_s=base.loop_step_s * 2))}))
    with telemetry.capture() as buf:
        with SpecController(CFG, state_path=str(path)) as ctrl:
            assert ctrl.active == ctrl.base               # nothing installed
    (restore,) = _events(buf, "tuning.restore")
    assert restore["accepted"] is False
    assert "backend mismatch" in restore["reason"]


def test_restore_quarantines_out_of_envelope_fields(tmp_path):
    path = tmp_path / "tuned.json"
    base = rmw_engine.calibrated_spec()
    poisoned = dataclasses.replace(
        base,
        loop_step_s=base.loop_step_s * CFG.envelope_factor * 100,
        gather_elem_s=base.gather_elem_s * 1.5)           # this one is fine
    path.write_text(json.dumps({
        "version": 1, "jax_backend": jax.default_backend(),
        "spec": perf_model.spec_to_dict(poisoned)}))
    with telemetry.capture() as buf:
        with SpecController(CFG, state_path=str(path)) as ctrl:
            # suspect field reset to calibrated, sane field kept
            assert ctrl.active.loop_step_s == base.loop_step_s
            assert ctrl.active.gather_elem_s == pytest.approx(
                base.gather_elem_s * 1.5)
    (restore,) = _events(buf, "tuning.restore")
    assert restore["accepted"] and \
        set(restore["quarantined"]) == {"loop_step_s"}


def test_restore_rejects_unreadable_state(tmp_path):
    path = tmp_path / "tuned.json"
    path.write_text("not json {{{")
    with telemetry.capture() as buf:
        with SpecController(CFG, state_path=str(path)) as ctrl:
            assert ctrl.active == ctrl.base
    (restore,) = _events(buf, "tuning.restore")
    assert restore["accepted"] is False


# ---------------------------------------------------------------------------
# the contention estimator
# ---------------------------------------------------------------------------

def test_estimator_ewma_and_pow2_hint():
    est = ContentionEstimator(alpha=0.5)
    key = site_key("cas", "local", 16, 8)
    assert est.hint(key) is None
    est.update(key, 2)
    est.update(key, 6)                        # ewma: 2 + .5*(6-2) = 4
    assert est.raw(key) == pytest.approx(4.0)
    assert est.hint(key) == 4                 # already a power of two
    est.update(key, 6)                        # ewma 5 -> rounds to 4
    assert est.hint(key) in (4, 8)
    assert math.log2(est.hint(key)).is_integer()
    # junk observations carry no signal and are ignored
    est.update(key, 0)
    est.update(key, -3)
    est.update(key, float("nan"))
    assert est.raw(key) == pytest.approx(5.0)
    with pytest.raises(ValueError, match="alpha"):
        ContentionEstimator(alpha=0.0)


def test_estimator_snapshot_restore_drops_malformed():
    est = ContentionEstimator()
    est.update(EST_KEY, 4)
    snap = est.snapshot()
    snap["sites"]["bad|key"] = 2.0            # wrong arity
    snap["sites"]["a|b|c|d"] = float("nan")   # non-finite
    snap["sites"]["e|f|g|h"] = 0.5            # below 1: no signal
    fresh = ContentionEstimator()
    assert fresh.restore(snap) == 1
    assert fresh.raw(EST_KEY) == 4.0
    assert len(fresh) == 1


def test_execute_until_feeds_the_estimator():
    """A contended CAS loop under a running controller must observe its
    own collision counts — round-0 distinct slots AND the CAS
    round-histogram winners — into the estimator, keyed by call site."""
    with telemetry.capture(sync=True) as buf:
        with SpecController(CFG) as ctrl:
            table = atomics.AtomicTable(jnp.zeros((8,), jnp.int32))

            def make_ops(slots, observed):
                if slots is None:             # all six ops fight slot 0
                    return atomics.Cas(jnp.zeros(6, jnp.int32),
                                       jnp.ones(6, jnp.int32),
                                       expected=jnp.int32(0))
                return observed + 1           # lock-free fetch-increment

            res = atomics.execute_until(table, make_ops, max_rounds=8)
            assert res.success.all()
            assert int(res.table.data[0]) == 6
            key = site_key("cas", "local", 8, 6)
            # both observations say "1 distinct slot": round-0 unique
            # count and first-attempt winners agree
            assert ctrl.estimator.raw(key) == pytest.approx(1.0)
            assert ctrl.estimator.hint(key) == 1
    rounds = [e for e in buf.events
              if e.get("event") == "atomics.retry.round"]
    assert rounds[0]["distinct_observed"] == 1


def test_execute_until_without_controller_is_unchanged():
    table = atomics.AtomicTable(jnp.zeros((8,), jnp.int32))

    def make_ops(slots, observed):
        if slots is None:
            return atomics.Cas(jnp.arange(4, dtype=jnp.int32),
                               jnp.ones(4, jnp.int32),
                               expected=jnp.int32(0))
        return observed + 1

    res = atomics.execute_until(table, make_ops, max_rounds=4)
    assert res.success.all() and res.n_rounds == 1
    assert active_controller() is None


# ---------------------------------------------------------------------------
# integration: wrap_step, the chaos matrix, train()
# ---------------------------------------------------------------------------

def test_wrap_step_preserves_donation_and_runs_the_cycle():
    def step(i, state):
        return state

    donating = declare_donation(step, (1,))
    with telemetry.capture():
        with SpecController(CFG) as ctrl:
            wrapped = ctrl.wrap_step(donating)
            assert tuple(wrapped.donate_argnums) == (1,)
            for _ in range(CFG.min_events):
                telemetry.record("atomics.execute", tier="local",
                                 backend="serialized", op="faa", n=256,
                                 predicted_s=P0, measured_s=P0 * 2)
            wrapped(0, None)                  # the wrapped call steps
            assert ctrl.last_outcome == "apply"


N_STEPS = 12
M_SLOTS = 16


def _matrix_step(step, state):
    """Deterministic per (step, state): an FAA batch against a live table
    plus a fetched-sum accumulator (fetched values are load-bearing)."""
    table, acc = state
    idx = jnp.asarray((np.arange(8) * (step + 3)) % M_SLOTS, jnp.int32)
    vals = jnp.asarray(np.arange(8) + step, jnp.int32)
    res = atomics.execute(table, atomics.Faa(idx, vals))
    return res.table, acc + jnp.sum(res.fetched)


def _run_matrix(tmp_path, tag, chaos, controller):
    from repro.runtime.elastic import reshard_tables
    ckpt_dir = str(tmp_path / tag)
    table0 = atomics.AtomicTable(jnp.zeros((M_SLOTS,), jnp.int32))
    like = {"table": table0, "acc": jnp.int32(0)}
    step_fn = (_matrix_step if controller is None
               else controller.wrap_step(_matrix_step))

    def save_fn(step, state):
        ckpt.save(ckpt_dir, step, {"table": state[0], "acc": state[1]})

    def restore_fn():
        got = ckpt.restore_latest_valid(ckpt_dir, like)
        if got is None:
            return None
        step, tree, _ = got
        return step, (tree["table"], tree["acc"])

    cfg = FaultConfig(max_failures=60, checkpoint_every=4,
                      backoff_base_s=0.0)
    res = run_with_recovery(step_fn, (table0, jnp.int32(0)), N_STEPS, cfg,
                            save_fn, restore_fn, chaos=chaos,
                            reshard_fn=lambda s: reshard_tables(s, None))
    assert res.steps_done == N_STEPS
    final = ckpt.restore_latest_valid(ckpt_dir, like)
    assert final[0] == N_STEPS
    return np.asarray(final[1]["table"].data), int(final[1]["acc"])


def test_tuned_chaos_matrix_bit_identical_to_untuned(tmp_path):
    """The tentpole invariant, under fire: >= 5 seeds of recovery faults
    PLUS spec_perturb poison, with a live controller actually retuning
    the spec mid-run — and the final table + fetched-sum accumulator are
    bit-equal to the untuned fault-free run, every seed."""
    base_table, base_acc = _run_matrix(tmp_path, "base", FaultPlan.null(),
                                       None)
    assert base_table.any()
    sites = {"step": SiteSpec(prob=0.2, count=2),
             "ckpt_save": SiteSpec(prob=0.2, count=2),
             "ckpt_restore": SiteSpec(prob=0.2, count=1),
             "reshard": SiteSpec(prob=0.2, count=1),
             "spec_perturb": SiteSpec(prob=0.5)}
    cfg = TuningConfig(min_events=6, min_samples=1, cooldown_updates=0)
    updates = perturbs = fired = 0
    for seed in range(1, 6):
        plan = FaultPlan(seed, sites)
        ctrl = SpecController(cfg, chaos=plan)
        with ctrl:
            table, acc = _run_matrix(tmp_path, f"seed{seed}", plan, ctrl)
        np.testing.assert_array_equal(
            table, base_table,
            err_msg=f"seed {seed}: tuned run diverged from untuned")
        assert acc == base_acc, f"seed {seed}: accumulator diverged"
        updates += ctrl.n_updates
        perturbs += ctrl.n_perturbs
        fired += plan.total_fired
    assert updates >= 5           # the controller really retuned mid-run
    assert perturbs >= 1          # and the spec_perturb site really fired
    assert fired >= 5             # alongside a real recovery-fault storm


def test_train_metrics_bit_equal_tuned_vs_untuned(monkeypatch):
    """Real train() steps: a live controller (telemetry sync on, spec
    swaps mid-run) must not move a single loss bit."""
    from repro.launch.train import train
    monkeypatch.delenv(TUNING_ENV, raising=False)
    kw = dict(steps=4, seq_len=16, global_batch=2, lr=1e-3, log_every=1,
              seed=7)
    base = train("gemma_2b", **kw)
    ctrl = SpecController(TuningConfig(min_events=4, min_samples=1,
                                       cooldown_updates=0))
    tuned = train("gemma_2b", **kw, tuning=ctrl)
    assert "tuning" in tuned and tuned["tuning"]["updates"] >= 0
    assert [h["loss"] for h in base["history"]] == \
        [h["loss"] for h in tuned["history"]]
    assert [h["grad_norm"] for h in base["history"]] == \
        [h["grad_norm"] for h in tuned["history"]]
    assert rmw_engine.live_spec() is None     # train() stopped the controller
