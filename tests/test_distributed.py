"""Distributed integration: real (not just compiled) steps on 8 fake devices.

Runs in a subprocess because XLA_FLAGS must be set before jax initializes —
the main pytest process keeps its single device (per the assignment, only
the dry-run may use placeholder devices)."""

import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.configs import get_reduced, ShapeCell
from repro.launch import shardings as sh
from repro.launch.steps import abstract_train_state, make_train_step
from repro.launch.dryrun import input_specs
from repro.models.model import build_model
from repro.optim.adamw import AdamWConfig, init_state
from repro.data.pipeline import DataConfig, batch_kwargs_for, synthetic_batch
from repro.sharding import use_mesh

out = {}
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
for arch in ["gemma_2b", "deepseek_v3_671b", "jamba_1_5_large_398b"]:
    cfg = get_reduced(arch)
    rules = sh.arch_rules(cfg, mesh, "train")
    model = build_model(cfg, attn_impl="chunked", remat_policy="full",
                        loss_chunk=64)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    dc = DataConfig(seq_len=32, global_batch=8, vocab_size=cfg.vocab_size)
    bkw = batch_kwargs_for(cfg)
    with use_mesh(mesh, rules):
        params = model.init(jax.random.PRNGKey(0))
        params_sh = sh.params_shardings(
            cfg, jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                              params), mesh, rules)
        params = jax.tree.map(lambda x, s: jax.device_put(x, s), params,
                              params_sh)
        opt = init_state(params, opt_cfg)
        step = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0, 1))
        losses = []
        for i in range(3):
            batch = synthetic_batch(dc, i, **bkw)
            params, opt, metrics = step(params, opt, batch)
            losses.append(float(metrics["loss"]))
        out[arch] = losses

# single-device equivalence: sharded loss == unsharded loss (same seed)
cfg = get_reduced("gemma_2b")
model = build_model(cfg, attn_impl="chunked", remat_policy="full",
                    loss_chunk=64)
dc = DataConfig(seq_len=32, global_batch=8, vocab_size=cfg.vocab_size)
params = model.init(jax.random.PRNGKey(0))
batch = synthetic_batch(dc, 0)
loss_local = float(model.loss(params, batch))
rules = sh.arch_rules(cfg, mesh, "train")
with use_mesh(mesh, rules):
    loss_sharded = float(jax.jit(model.loss)(params, batch))
out["equivalence"] = [loss_local, loss_sharded]
print("RESULT:" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def dist_result():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT:")][0]
    return json.loads(line[len("RESULT:"):])


def test_sharded_train_steps_finite(dist_result):
    import math
    for arch in ("gemma_2b", "deepseek_v3_671b", "jamba_1_5_large_398b"):
        losses = dist_result[arch]
        assert len(losses) == 3
        assert all(math.isfinite(x) for x in losses), (arch, losses)


def test_sharded_matches_local_loss(dist_result):
    local, sharded = dist_result["equivalence"]
    assert abs(local - sharded) / abs(local) < 5e-2, (local, sharded)
