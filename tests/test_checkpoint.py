"""Checkpointing: roundtrip, atomicity, async, keep-k, reshard-on-load."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "b": {"c": jnp.arange(5, dtype=jnp.int32),
                  "d": jnp.float32(3.5)}}


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 7, t, extra={"note": "x"})
    restored, extra = ckpt.restore(str(tmp_path), 7, t)
    assert extra["note"] == "x"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_list(tmp_path):
    t = _tree()
    for s in (3, 10, 5):
        ckpt.save(str(tmp_path), s, t)
    assert ckpt.list_steps(str(tmp_path)) == [3, 5, 10]
    assert ckpt.latest_step(str(tmp_path)) == 10
    assert ckpt.latest_step(str(tmp_path / "missing")) is None


def test_atomic_no_torn_checkpoints(tmp_path):
    """A leftover tmp- dir must never be listed as a valid step."""
    os.makedirs(tmp_path / "tmp-99")
    ckpt.save(str(tmp_path), 1, _tree())
    assert ckpt.list_steps(str(tmp_path)) == [1]


def test_async_and_gc(tmp_path):
    saver = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    for s in range(5):
        saver.save_async(s, _tree(s))
    saver.wait()
    saver.gc()
    assert ckpt.list_steps(str(tmp_path)) == [3, 4]


def test_structure_mismatch_rejected(tmp_path):
    ckpt.save(str(tmp_path), 1, _tree())
    with pytest.raises(AssertionError):
        ckpt.restore(str(tmp_path), 1, {"only": jnp.zeros(3)})


def test_reshard_on_load_hook(tmp_path):
    """sharding_fn places restored leaves under the current device layout —
    the elastic-resize path (runtime/elastic)."""
    t = _tree()
    ckpt.save(str(tmp_path), 2, t)
    placed = []

    def sharding_fn(key, ref):
        placed.append(key)
        return jax.devices()[0]  # Device works as a Sharding target

    restored, _ = ckpt.restore(str(tmp_path), 2, t, sharding_fn=sharding_fn)
    assert len(placed) == len(jax.tree.leaves(t))
    for leaf in jax.tree.leaves(restored):
        assert leaf.device == jax.devices()[0]


# ---------------------------------------------------------------------------
# Integrity hardening: checksums, corrupt-checkpoint fallback, tolerant gc
# ---------------------------------------------------------------------------

def _corrupt_payload(tmp_path, step, needle):
    """Flip a byte inside the actual array payload of arrays.npz (NOT the
    zip structure padding, which is genuinely meaningless)."""
    p = tmp_path / f"step-{step:08d}" / "arrays.npz"
    b = bytearray(p.read_bytes())
    at = b.find(needle)
    assert at >= 0, "payload bytes not found — test setup broken"
    b[at] ^= 0xFF
    p.write_bytes(bytes(b))


def test_corrupt_payload_detected(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 3, t)
    assert ckpt.validate_step(str(tmp_path), 3)
    _corrupt_payload(tmp_path, 3, np.arange(5, dtype=np.int32).tobytes())
    assert not ckpt.validate_step(str(tmp_path), 3)
    with pytest.raises(ckpt.CheckpointCorruptError):
        ckpt.restore(str(tmp_path), 3, t)


def test_truncated_npz_detected(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    p = tmp_path / "step-00000001" / "arrays.npz"
    p.write_bytes(p.read_bytes()[: p.stat().st_size // 2])
    with pytest.raises(ckpt.CheckpointCorruptError, match="arrays.npz"):
        ckpt.restore(str(tmp_path), 1, t)


def test_sha256_catches_valid_zip_wrong_bytes(tmp_path):
    """Rewrite arrays.npz wholesale with *valid* (but wrong) arrays: the
    zip CRC is clean, only the manifest sha256 can catch it — and
    validate=False is the explicit escape hatch."""
    t = _tree()
    ckpt.save(str(tmp_path), 2, t)
    path = tmp_path / "step-00000002"
    with np.load(path / "arrays.npz") as npz:
        zeroed = {k: np.zeros_like(npz[k]) for k in npz.files}
    np.savez(path / "arrays.npz", **zeroed)
    with pytest.raises(ckpt.CheckpointCorruptError, match="sha256"):
        ckpt.restore(str(tmp_path), 2, t)
    restored, _ = ckpt.restore(str(tmp_path), 2, t, validate=False)
    assert float(np.abs(np.asarray(restored["a"])).sum()) == 0.0


def test_checksum_less_manifest_still_restores(tmp_path):
    """Pre-hardening checkpoints (no "checksums" key) restore cleanly."""
    import json
    t = _tree()
    ckpt.save(str(tmp_path), 5, t)
    mpath = tmp_path / "step-00000005" / "manifest.json"
    m = json.loads(mpath.read_text())
    del m["checksums"]
    mpath.write_text(json.dumps(m))
    assert ckpt.validate_step(str(tmp_path), 5)
    restored, _ = ckpt.restore(str(tmp_path), 5, t)
    np.testing.assert_array_equal(np.asarray(restored["b"]["c"]),
                                  np.arange(5, dtype=np.int32))


def test_restore_latest_valid_walks_back(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), s, _tree(s))
    # newest: torn npz; next: manifest gone (skipped by list_steps)
    p4 = tmp_path / "step-00000004" / "arrays.npz"
    p4.write_bytes(p4.read_bytes()[:64])
    os.remove(tmp_path / "step-00000003" / "manifest.json")
    got = ckpt.restore_latest_valid(str(tmp_path), t)
    assert got is not None
    step, tree, _extra = got
    assert step == 2
    np.testing.assert_array_equal(np.asarray(tree["a"]),
                                  np.asarray(_tree(2)["a"]))
    # the corrupt steps are kept on disk as post-mortem evidence
    assert (tmp_path / "step-00000004").is_dir()


def test_restore_latest_valid_none_when_nothing_restores(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    p = tmp_path / "step-00000001" / "arrays.npz"
    p.write_bytes(b"not a zip")
    assert ckpt.restore_latest_valid(str(tmp_path), t) is None
    assert ckpt.restore_latest_valid(str(tmp_path / "missing"), t) is None


def test_list_steps_tolerates_mangled_entries(tmp_path):
    ckpt.save(str(tmp_path), 7, _tree())
    os.makedirs(tmp_path / "step-garbage")          # non-integer suffix
    os.makedirs(tmp_path / "step-00000009")         # manifest-less dir
    assert ckpt.list_steps(str(tmp_path)) == [7]
    assert ckpt.latest_step(str(tmp_path)) == 7


def test_gc_never_drops_newest_valid_step(tmp_path):
    """Corrupt every step inside the keep window: gc must still preserve
    the newest step that validates, even though it fell outside keep=2."""
    for s in range(5):
        ckpt.save(str(tmp_path), s, _tree(s))
    for s in (3, 4):
        p = tmp_path / f"step-{s:08d}" / "arrays.npz"
        p.write_bytes(p.read_bytes()[:64])
    saver = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    saver.gc()
    steps = ckpt.list_steps(str(tmp_path))
    assert 2 in steps                                # newest valid survives
    assert steps == [2, 3, 4]                        # keep window + survivor
    got = ckpt.restore_latest_valid(str(tmp_path), _tree())
    assert got is not None and got[0] == 2
