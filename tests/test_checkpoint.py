"""Checkpointing: roundtrip, atomicity, async, keep-k, reshard-on-load."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "b": {"c": jnp.arange(5, dtype=jnp.int32),
                  "d": jnp.float32(3.5)}}


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 7, t, extra={"note": "x"})
    restored, extra = ckpt.restore(str(tmp_path), 7, t)
    assert extra["note"] == "x"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_list(tmp_path):
    t = _tree()
    for s in (3, 10, 5):
        ckpt.save(str(tmp_path), s, t)
    assert ckpt.list_steps(str(tmp_path)) == [3, 5, 10]
    assert ckpt.latest_step(str(tmp_path)) == 10
    assert ckpt.latest_step(str(tmp_path / "missing")) is None


def test_atomic_no_torn_checkpoints(tmp_path):
    """A leftover tmp- dir must never be listed as a valid step."""
    os.makedirs(tmp_path / "tmp-99")
    ckpt.save(str(tmp_path), 1, _tree())
    assert ckpt.list_steps(str(tmp_path)) == [1]


def test_async_and_gc(tmp_path):
    saver = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    for s in range(5):
        saver.save_async(s, _tree(s))
    saver.wait()
    saver.gc()
    assert ckpt.list_steps(str(tmp_path)) == [3, 4]


def test_structure_mismatch_rejected(tmp_path):
    ckpt.save(str(tmp_path), 1, _tree())
    with pytest.raises(AssertionError):
        ckpt.restore(str(tmp_path), 1, {"only": jnp.zeros(3)})


def test_reshard_on_load_hook(tmp_path):
    """sharding_fn places restored leaves under the current device layout —
    the elastic-resize path (runtime/elastic)."""
    t = _tree()
    ckpt.save(str(tmp_path), 2, t)
    placed = []

    def sharding_fn(key, ref):
        placed.append(key)
        return jax.devices()[0]  # Device works as a Sharding target

    restored, _ = ckpt.restore(str(tmp_path), 2, t, sharding_fn=sharding_fn)
    assert len(placed) == len(jax.tree.leaves(t))
    for leaf in jax.tree.leaves(restored):
        assert leaf.device == jax.devices()[0]
