"""Planner + collective model + contention model sanity."""

import math

import pytest

from repro.core.collective_model import (MeshAxis, collective_bytes_on_wire,
                                         collective_time_s,
                                         cross_pod_hierarchical,
                                         grad_sync_strategies)
from repro.core.contention import (contended_bandwidth_combining,
                                   contended_bandwidth_serialized,
                                   hot_expert_capacity)
from repro.core.perf_model import TPU_V5E
from repro.core.placement import Tier
from repro.core.planner import (default_axes, plan_fsdp_gather_dtype,
                                plan_grad_sync, plan_moe_dispatch)

ICI = MeshAxis("data", 16, Tier.ICI_NEIGHBOR)
DCN = MeshAxis("pod", 2, Tier.DCN_REMOTE_POD)


def test_collective_times_positive_and_ordered():
    nbytes = 1 << 30
    ar = collective_time_s(TPU_V5E, "all_reduce", nbytes, ICI)
    ag = collective_time_s(TPU_V5E, "all_gather", nbytes, ICI)
    rs = collective_time_s(TPU_V5E, "reduce_scatter", nbytes, ICI)
    assert ar > ag > 0 and ar > rs > 0
    assert ar == pytest.approx(ag + rs, rel=1e-6)


def test_single_member_axis_free():
    one = MeshAxis("x", 1, Tier.ICI_NEIGHBOR)
    assert collective_time_s(TPU_V5E, "all_reduce", 1 << 20, one) == 0.0


def test_wire_bytes_formulas():
    assert collective_bytes_on_wire("all_gather", 1600, 16) == 1500
    assert collective_bytes_on_wire("all_reduce", 1600, 16) == 3000
    assert collective_bytes_on_wire("collective_permute", 1600, 16) == 1600


def test_unknown_collective_rejected():
    with pytest.raises(ValueError):
        collective_time_s(TPU_V5E, "gossip", 10, ICI)


def test_grad_sync_zero_beats_nothing():
    table = grad_sync_strategies(TPU_V5E, 1 << 30, ICI)
    assert set(table) == {"all_reduce", "zero", "zero_int8"}
    assert table["zero_int8"] < table["zero"]


def test_plan_grad_sync_picks_compressed_or_zero():
    d = plan_grad_sync(1 << 30, ICI, DCN)
    assert d.choice in ("zero", "zero_int8")
    assert d.priced["all_reduce"] > 0


def test_plan_fsdp_gather_prefers_bf16():
    d = plan_fsdp_gather_dtype(1 << 28, ICI)
    assert d.choice == "bf16"
    assert d.priced["bf16"] < d.priced["fp32"]


def test_hierarchical_cross_pod_shrinks_dcn_leg():
    """The hierarchical schedule's value: the slow DCN axis carries only
    1/ici_n of the payload (the ICI RS/AG legs are needed by DP anyway)."""
    nbytes = 1 << 28
    dcn_leg_hier = collective_time_s(TPU_V5E, "all_reduce",
                                     nbytes // ICI.size, DCN)
    dcn_leg_flat = collective_time_s(TPU_V5E, "all_reduce", nbytes, DCN)
    assert dcn_leg_hier < dcn_leg_flat / 4
    # and the composed schedule is never *worse* than ICI legs + flat DCN
    hier = cross_pod_hierarchical(TPU_V5E, nbytes, ICI, DCN)
    flat_total = (collective_time_s(TPU_V5E, "reduce_scatter", nbytes, ICI)
                  + dcn_leg_flat
                  + collective_time_s(TPU_V5E, "all_gather", nbytes, ICI))
    assert hier <= flat_total


# ------------------------------------------------------------- contention

def test_contended_serialized_collapses():
    b1 = contended_bandwidth_serialized(TPU_V5E, "faa", 1)
    b16 = contended_bandwidth_serialized(TPU_V5E, "faa", 16)
    assert b16 < b1 / 10  # the paper's Fig. 8 collapse


def test_combining_scales_then_saturates():
    b2 = contended_bandwidth_combining(TPU_V5E, "faa", 2)
    b64 = contended_bandwidth_combining(TPU_V5E, "faa", 64)
    assert b64 > b2


def test_combining_beats_serialized_under_contention():
    for n in (4, 16, 64):
        assert contended_bandwidth_combining(TPU_V5E, "faa", n) > \
            contended_bandwidth_serialized(TPU_V5E, "faa", n)


def test_hot_expert_capacity_bounds():
    cap = hot_expert_capacity(TPU_V5E, tokens_per_step=1 << 20, n_experts=256,
                              top_k=8, n_writers=16, step_budget_s=1e-3)
    assert cap >= 1.0


def test_plan_moe_dispatch():
    d = plan_moe_dispatch(tokens_per_step=1 << 20, n_experts=256, top_k=8,
                          ep_degree=16, step_budget_s=1e-3)
    assert "capacity_factor" in d.priced
    assert 1.0 <= d.priced["capacity_factor"] <= 4.0
    assert d.priced["contended_combining_Bps"] > \
        d.priced["contended_serialized_Bps"]


def test_default_axes():
    axes = default_axes({"pod": 2, "data": 16, "model": 16})
    assert axes["pod"].tier == Tier.DCN_REMOTE_POD
    assert axes["data"].size == 16
