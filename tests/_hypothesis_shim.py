"""Minimal stand-in for `hypothesis` when it isn't installed.

The container image doesn't ship hypothesis (it's in requirements-dev.txt for
dev machines), so the property tests import it with a fallback:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_shim import given, settings, strategies as st

The shim runs each property over a deterministic seeded sample (seed derived
from the test name, so every test sees a stable but distinct stream).  It
implements only the surface this repo uses: ``given``, ``settings``
(max_examples / deadline), and the ``integers`` / ``booleans`` /
``sampled_from`` / ``lists`` / ``tuples`` strategies — no shrinking, no
example database.
"""

from __future__ import annotations

import zlib
from types import SimpleNamespace
from typing import Any, Callable

import numpy as np

DEFAULT_MAX_EXAMPLES = 25


class SearchStrategy:
    """A strategy is just a draw function rng -> value."""

    def __init__(self, draw: Callable[[np.random.Generator], Any]):
        self._draw = draw

    def example_from(self, rng: np.random.Generator) -> Any:
        return self._draw(rng)

    def map(self, f: Callable[[Any], Any]) -> "SearchStrategy":
        return SearchStrategy(lambda rng: f(self._draw(rng)))


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: int(rng.integers(min_value, max_value + 1)))


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: bool(rng.integers(0, 2)))


def sampled_from(elements) -> SearchStrategy:
    elements = list(elements)
    return SearchStrategy(lambda rng: elements[rng.integers(0, len(elements))])


def lists(elements: SearchStrategy, *, min_size: int = 0,
          max_size: int = 10) -> SearchStrategy:
    def draw(rng):
        size = int(rng.integers(min_size, max_size + 1))
        return [elements.example_from(rng) for _ in range(size)]
    return SearchStrategy(draw)


def tuples(*strategies: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: tuple(s.example_from(rng) for s in strategies))


strategies = SimpleNamespace(integers=integers, booleans=booleans,
                             sampled_from=sampled_from, lists=lists,
                             tuples=tuples,
                             SearchStrategy=SearchStrategy)


class settings:
    """Decorator recording max_examples on the (already-wrapped) test."""

    def __init__(self, max_examples: int = DEFAULT_MAX_EXAMPLES,
                 deadline=None, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._shim_max_examples = self.max_examples
        return fn


def given(*strategies_: SearchStrategy):
    """Run the test body over a deterministic sample of drawn examples.

    The wrapper takes no parameters so pytest doesn't mistake the property
    arguments for fixtures.
    """

    def deco(fn):
        def wrapper():
            n = getattr(wrapper, "_shim_max_examples", DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(fn.__qualname__.encode("utf-8"))
            rng = np.random.default_rng(seed)
            for _ in range(n):
                args = tuple(s.example_from(rng) for s in strategies_)
                try:
                    fn(*args)
                except Exception as e:  # noqa: BLE001 - re-raise with example
                    raise AssertionError(
                        f"property failed for drawn example {args!r}: {e}"
                    ) from e

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco
