"""Validate the dry-run deliverable artifacts (no compilation here).

The actual 512-device compiles run via `python -m repro.launch.dryrun --all`;
these tests check the recorded results satisfy the deliverable contract:
every (arch x shape) cell on both meshes compiled, with memory/cost/
collective records present.  Skipped when the sweep hasn't been run.
"""

import glob
import json
import os

import pytest

from repro.configs import ARCH_IDS, cells_for

DRYRUN_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "dryrun")


def _cells():
    files = glob.glob(os.path.join(DRYRUN_DIR, "*.baseline.json"))
    if not files:
        pytest.skip("dry-run sweep artifacts not present")
    return {os.path.basename(f): json.load(open(f)) for f in files}


def test_every_cell_present_and_ok():
    recs = _cells()
    missing, failed = [], []
    for arch in ARCH_IDS:
        for cell in cells_for(arch):
            for mesh in ("single", "multi"):
                name = f"{arch}.{cell.name}.{mesh}.baseline.json"
                if name not in recs:
                    missing.append(name)
                elif recs[name].get("status") != "ok":
                    failed.append(name)
    # allow in-progress sweeps: only assert on what exists
    assert not failed, failed
    if missing:
        pytest.skip(f"sweep incomplete: {len(missing)} cells pending")


def test_records_have_roofline_inputs():
    recs = _cells()
    for name, r in recs.items():
        if r.get("status") != "ok":
            continue
        assert r.get("dot_flops", 0) > 0, name
        assert "total_wire_bytes" in r, name
        assert r.get("per_device_peak_bytes", 0) > 0, name
        assert r.get("model_flops_global", 0) > 0, name


def test_multi_pod_uses_512_chips():
    recs = _cells()
    for name, r in recs.items():
        if r.get("status") != "ok":
            continue
        assert r["chips"] == (512 if r["mesh"] == "multi" else 256), name


def test_roofline_rows_render():
    from benchmarks.roofline import roofline_row
    recs = _cells()
    for name, r in recs.items():
        if r.get("status") != "ok":
            continue
        row = roofline_row(r)
        assert row is not None
        assert row["dominant"] in ("compute", "memory", "collective")
        assert row["roofline_fraction"] >= 0
