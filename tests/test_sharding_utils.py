"""Divisibility-aware logical sharding rules + HLO stats parser."""

import jax.numpy as jnp

from repro.launch.hlo_stats import (analyze_hlo, split_computations,
                                    _trip_count)
from repro.sharding import DEFAULT_RULES, hint, logical_to_physical


def test_hint_noop_without_mesh():
    x = jnp.ones((4, 8))
    assert hint(x, "batch", None) is x


def test_logical_to_physical_without_mesh_is_empty():
    from jax.sharding import PartitionSpec as P
    assert logical_to_physical(["batch", None], (4, 8)) == P()


HLO = """
HloModule test

%cond.1 (p: (s32[], f32[8])) -> pred[] {
  %p = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body.1 (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p = (s32[], f32[8]) parameter(0)
  %x = f32[8]{0} get-tuple-element(%p), index=1
  %ag = f32[16]{0} all-gather(%x), replica_groups=[2,2]<=[4], dimensions={0}
  %sl = f32[8]{0} slice(%ag), slice={[0:8]}
  %ar = f32[8]{0} all-reduce(%sl), replica_groups=[1,4]<=[4], to_apply=%add
  %i2 = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[8]) tuple(%i2, %ar)
}

ENTRY %main (a: f32[4,8], b: f32[8,16]) -> f32[4,16] {
  %a = f32[4,8]{1,0} parameter(0)
  %b = f32[8,16]{1,0} parameter(1)
  %w = (s32[], f32[8]) while((s32[], f32[8]) %init), condition=%cond.1, body=%body.1
  ROOT %d = f32[4,16]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_split_computations():
    comps, entry = split_computations(HLO)
    assert entry == "main"
    assert "body.1" in comps and "cond.1" in comps


def test_trip_count_from_condition():
    comps, _ = split_computations(HLO)
    assert _trip_count(comps["cond.1"]) == 12


def test_collectives_expanded_by_trips():
    st = analyze_hlo(HLO, world=4)
    # all-gather: out 16*4B=64B * (2-1)/2 = 32B, x12 trips = 384
    assert st["all-gather"]["count"] == 12
    assert abs(st["all-gather"]["wire_bytes"] - 12 * 32) < 1e-6
    # all-reduce: 2 * 32B * 3/4 = 48B, x12 = 576
    assert st["all-reduce"]["count"] == 12
    assert abs(st["all-reduce"]["wire_bytes"] - 12 * 48) < 1e-6


def test_dot_flops_counted():
    st = analyze_hlo(HLO, world=4)
    # dot: 2 * (4*16) * 8 = 1024 flops
    assert st["dot_flops"] == 1024


def test_default_rules_cover_model_axes():
    for k in ("batch", "ffn", "heads", "experts", "vocab", "fsdp"):
        assert k in DEFAULT_RULES
