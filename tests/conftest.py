"""Suite-wide fixtures.

`atomics_lint` is re-exported from the analysis pytest integration as a
plain import (pytest collects fixtures from conftest namespaces), instead
of the deprecated non-root ``pytest_plugins`` mechanism.
"""

from repro.analysis.pytest_plugin import atomics_lint  # noqa: F401
