"""End-to-end behaviour tests for the paper's system.

The training loop (launch/train.py) must reduce loss, checkpoint, survive an
injected failure, and resume bit-exactly (the deterministic-data contract)."""

import math

import pytest

from repro.launch.train import train


def test_quickstart_training_reduces_loss(tmp_path):
    out = train("gemma_2b", steps=30, seq_len=64, global_batch=4,
                ckpt_dir=str(tmp_path), checkpoint_every=10, lr=3e-3,
                log_every=5, seed=0)
    hist = out["history"]
    assert out["steps_done"] == 30
    first, last = hist[0]["loss"], hist[-1]["loss"]
    assert math.isfinite(last)
    assert last < first, (first, last)


def test_training_survives_injected_failure(tmp_path):
    crashes = {12: 1}

    def injector(step):
        if crashes.get(step):
            crashes[step] -= 1
            raise RuntimeError("simulated chip loss")

    out = train("qwen2_vl_2b", steps=20, seq_len=32, global_batch=4,
                ckpt_dir=str(tmp_path), checkpoint_every=5,
                failure_injector=injector, log_every=5)
    assert out["steps_done"] == 20
    assert out["failures"] == 1
    assert math.isfinite(out["final_loss"])


def test_resume_reproduces_uninterrupted_run(tmp_path):
    """Fault-tolerance determinism: crash+resume == straight-through run."""
    a = train("mamba2_780m", steps=16, seq_len=32, global_batch=2,
              lr=1e-3, log_every=1, seed=3)

    crashes = {9: 1}

    def injector(step):
        if crashes.get(step):
            crashes[step] -= 1
            raise RuntimeError("boom")

    b = train("mamba2_780m", steps=16, seq_len=32, global_batch=2,
              lr=1e-3, log_every=1, seed=3, ckpt_dir=str(tmp_path),
              checkpoint_every=4, failure_injector=injector)
    la = a["history"][-1]["loss"]
    lb = b["history"][-1]["loss"]
    assert abs(la - lb) / abs(la) < 1e-4, (la, lb)


def test_microbatched_equals_full_batch_loss():
    """Grad accumulation must not change the first-step loss."""
    a = train("gemma_2b", steps=2, seq_len=32, global_batch=4,
              microbatches=1, log_every=1, seed=11)
    b = train("gemma_2b", steps=2, seq_len=32, global_batch=4,
              microbatches=2, log_every=1, seed=11)
    assert abs(a["history"][0]["loss"] - b["history"][0]["loss"]) < 2e-2


def test_serving_end_to_end():
    from repro.launch.serve import BatchServer, Request
    import numpy as np
    server = BatchServer("gemma_2b", slots=2, s_max=32)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(
        0, server.cfg.vocab_size, 5).tolist(), max_new=3) for i in range(3)]
    stats = server.run(reqs)
    assert stats["completed"] == 3
    assert all(len(r.out) == 3 for r in reqs)
