"""repro.telemetry: stream mechanics, jit discipline, drift math, sinks."""

import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import atomics, telemetry
from repro.telemetry import drift


@pytest.fixture(autouse=True)
def _stream_off():
    """Every test starts and ends with the stream disabled."""
    telemetry.disable()
    yield
    telemetry.disable()


# ---------------------------------------------------------------------------
# Stream mechanics
# ---------------------------------------------------------------------------

def test_disabled_by_default_and_record_is_noop():
    assert not telemetry.enabled()
    telemetry.record("anything", x=1)          # must not raise, must not keep
    assert telemetry.sinks() == ()


def test_disabled_record_is_cheap():
    """The zero-overhead contract: a disabled record is one boolean check.
    Budget is deliberately loose (CI jitter) — 200k no-ops in under a
    second still rules out any per-call allocation/locking regression."""
    t0 = time.perf_counter()
    for _ in range(200_000):
        telemetry.record("noop", a=1, b=2.0)
    assert time.perf_counter() - t0 < 1.0


def test_ring_buffer_capture_and_restore():
    with telemetry.capture() as buf:
        assert telemetry.enabled()
        telemetry.record("ev", k=1)
        telemetry.record("ev", k=2)
    assert not telemetry.enabled()
    assert [e["k"] for e in buf.events] == [1, 2]
    assert all(e["event"] == "ev" and "t" in e for e in buf.events)


def test_ring_buffer_is_bounded():
    buf = telemetry.RingBuffer(capacity=4)
    with telemetry.capture(buf):
        for i in range(10):
            telemetry.record("ev", i=i)
    assert [e["i"] for e in buf.events] == [6, 7, 8, 9]


def test_capture_nests_and_restores_prior_sinks():
    outer = telemetry.RingBuffer()
    telemetry.enable(outer)
    with telemetry.capture() as inner:
        telemetry.record("both")
    telemetry.record("outer_only")
    assert [e["event"] for e in outer.events] == ["both", "outer_only"]
    assert [e["event"] for e in inner.events] == ["both"]


def test_jsonl_round_trip(tmp_path):
    path = str(tmp_path / "cap.jsonl")
    telemetry.enable(telemetry.JsonlWriter(path))
    telemetry.record("ev", i=np.int64(3), x=np.float32(0.5),
                     arr=np.arange(2), nested={"k": (1, 2)})
    telemetry.disable()                        # closes (and flushes) the file
    events = telemetry.read_jsonl(path)
    assert len(events) == 1
    ev = events[0]
    assert ev["event"] == "ev" and ev["i"] == 3
    assert ev["x"] == pytest.approx(0.5)
    assert ev["arr"] == [0, 1] and ev["nested"] == {"k": [1, 2]}


def test_broken_sink_never_breaks_the_instrumented_path():
    class Boom(telemetry.Sink):
        def emit(self, event):
            raise RuntimeError("sink died")
    good = telemetry.RingBuffer()
    telemetry.enable(Boom(), good)
    telemetry.record("ev")
    assert len(good.events) == 1               # later sinks still served


def test_counters_aggregate_numeric_fields():
    c = telemetry.Counters()
    with telemetry.capture(c):
        telemetry.record("ev", v=1.0, tag="a")
        telemetry.record("ev", v=3.0, tag="b")
        telemetry.record("other")
    s = c.summary()
    assert s["ev"]["count"] == 2 and s["other"]["count"] == 1
    v = s["ev"]["fields"]["v"]
    assert (v["n"], v["mean"], v["min"], v["max"]) == (2, 2.0, 1.0, 3.0)
    assert "tag" not in s["ev"]["fields"]      # strings are not aggregated


def test_span_measures_even_when_disabled():
    with telemetry.span("x") as sp:
        pass
    assert sp.wall_s is not None and sp.wall_s >= 0.0
    with telemetry.capture() as buf:
        with telemetry.span("x", step=3) as sp:
            pass
    (ev,) = buf.events
    assert ev["event"] == "x" and ev["step"] == 3 and ev["ok"] is True
    assert ev["wall_s"] == pytest.approx(sp.wall_s)


def test_span_records_failure_flag():
    with telemetry.capture() as buf:
        with pytest.raises(ValueError):
            with telemetry.span("x"):
                raise ValueError("boom")
    assert buf.events[0]["ok"] is False


def test_enable_from_env(tmp_path, monkeypatch):
    monkeypatch.delenv(telemetry.TELEMETRY_ENV, raising=False)
    assert telemetry.enable_from_env() is False
    path = str(tmp_path / "env.jsonl")
    monkeypatch.setenv(telemetry.TELEMETRY_ENV, path)
    assert telemetry.enable_from_env() is True
    telemetry.record("ev")
    telemetry.disable()
    assert telemetry.read_jsonl(path)[0]["event"] == "ev"


# ---------------------------------------------------------------------------
# Instrumented atomics: decision events, jit discipline
# ---------------------------------------------------------------------------

def _faa(n, m, seed=0):
    rng = np.random.default_rng(seed)
    return atomics.Faa(jnp.asarray(rng.integers(0, m, (n,)), jnp.int32),
                       jnp.ones((n,), jnp.int32))


def test_eager_execute_emits_one_decision_event_with_measured_time():
    tbl = atomics.AtomicTable(jnp.zeros((64,), jnp.int32))
    with telemetry.capture(sync=True) as buf:
        atomics.execute(tbl, _faa(32, 64))
    (ev,) = [e for e in buf.events if e["event"] == "atomics.execute"]
    assert ev["tier"] == "local" and ev["traced"] is False
    assert ev["op"] == "faa" and ev["n"] == 32 and ev["m"] == 64
    assert ev["backend"] in ("serialized", "sort", "onehot", "pallas")
    assert ev["predicted_s"] > 0.0 and ev["measured_s"] > 0.0


def test_predicted_matches_the_selectors_own_choice():
    from repro.core import rmw_engine
    tbl = atomics.AtomicTable(jnp.zeros((256,), jnp.int32))
    op = _faa(128, 256)
    with telemetry.capture() as buf:
        atomics.execute(tbl, op)
    (ev,) = [e for e in buf.events if e["event"] == "atomics.execute"]
    sel = rmw_engine.select_backend_with_cost("faa", 128, 256, None,
                                              dtype=tbl.dtype)
    assert ev["backend"] == sel.choice
    assert ev["predicted_s"] == pytest.approx(sel.predicted_s)


def test_jit_retrace_discipline_no_duplicate_events():
    tbl_data = jnp.zeros((32,), jnp.int32)
    op = _faa(16, 32)

    @jax.jit
    def step(data, idx, vals):
        res = atomics.execute(atomics.AtomicTable(data),
                              atomics.Faa(idx, vals))
        return res.table.data
    with telemetry.capture() as buf:
        data = tbl_data
        for _ in range(5):                     # 1 compile + 4 cached calls
            data = step(data, op.indices, op.values)
    evs = [e for e in buf.events if e["event"] == "atomics.execute"]
    assert len(evs) == 1                       # trace-time only, once
    assert evs[0]["traced"] is True
    assert "measured_s" not in evs[0]          # no wall time inside a trace
    # a NEW shape retraces: exactly one more event
    op2 = _faa(8, 32)
    with telemetry.capture() as buf2:
        step(data, op2.indices, op2.values)
        step(data, op2.indices, op2.values)
    evs2 = [e for e in buf2.events if e["event"] == "atomics.execute"]
    assert len(evs2) == 1 and evs2[0]["n"] == 8


def test_instrumentation_changes_no_results():
    tbl = atomics.AtomicTable(jnp.zeros((64,), jnp.int32))
    op = _faa(48, 64, seed=3)
    base = atomics.execute(tbl, op)
    with telemetry.capture(sync=True):
        instr = atomics.execute(tbl, op)
    np.testing.assert_array_equal(np.asarray(base.table.data),
                                  np.asarray(instr.table.data))
    np.testing.assert_array_equal(np.asarray(base.fetched),
                                  np.asarray(instr.fetched))


def test_retry_rounds_and_done_histogram():
    tbl = atomics.AtomicTable(jnp.zeros((8,), jnp.int32))
    n = 5

    def make_ops(slots, observed):
        if slots is None:
            return atomics.Cas(jnp.zeros((n,), jnp.int32),
                               jnp.ones((n,), jnp.int32),
                               expected=jnp.zeros((n,), jnp.int32))
        return observed + 1
    with telemetry.capture() as buf:
        res = atomics.retry.execute_until(tbl, make_ops, max_rounds=n)
    assert res.success.all()
    rounds = [e for e in buf.events if e["event"] == "atomics.retry.round"]
    assert len(rounds) == res.n_rounds == n    # full contention: n rounds
    assert [e["pending"] for e in rounds] == [5, 4, 3, 2, 1]
    assert all(e["resolved"] == 1 and e["measured_s"] > 0 for e in rounds)
    (done,) = [e for e in buf.events if e["event"] == "atomics.retry.done"]
    assert done["n"] == n and done["unresolved"] == 0
    # op i wins on round i+1: one op per attempt-count 1..n
    assert done["round_histogram"] == [0] + [1] * n
    assert done["attempts"] == n * (n + 1) // 2


def test_reshard_migrate_event(monkeypatch):
    mesh = jax.make_mesh((1,), ("dev",))
    data = jax.device_put(
        jnp.zeros((16,), jnp.int32),
        jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("dev")))
    tbl = atomics.AtomicTable(data, axis="dev")
    with telemetry.capture() as buf:
        atomics.reshard.migrate(tbl, mesh, path="device_put")
    (ev,) = [e for e in buf.events
             if e["event"] == "atomics.reshard.migrate"]
    assert ev["path"] == "device_put" and ev["tier"] == "migration"
    assert ev["n_slots"] == 16
    assert ev["measured_s"] > 0 and ev["predicted_s"] > 0


# ---------------------------------------------------------------------------
# Drift aggregation + spec fitting (pure math)
# ---------------------------------------------------------------------------

def _ev(tier, choice, op, n, pred, meas):
    key = "path" if tier == "migration" else \
        ("backend" if tier == "local" else "strategy")
    return {"event": ("atomics.reshard.migrate" if tier == "migration"
                      else "atomics.execute"),
            "tier": tier, key: choice, "op": op, "n": n,
            "predicted_s": pred, "measured_s": meas}


def test_drift_ratio_is_geometric_mean():
    # 2x slow and 2x fast must cancel exactly
    evs = [_ev("local", "sort", "faa", 64, 1e-4, 2e-4),
           _ev("local", "sort", "faa", 64, 1e-4, 5e-5)]
    stats = drift.aggregate(evs)
    (st,) = stats.values()
    assert st.n == 2
    assert st.ratio == pytest.approx(1.0)
    assert st.min_ratio == pytest.approx(0.5)
    assert st.max_ratio == pytest.approx(2.0)


def test_drift_grouping_and_skips():
    evs = [
        _ev("local", "sort", "faa", 64, 1e-4, 2e-4),
        _ev("local", "sort", "faa", 4096, 1e-4, 2e-4),   # other size bucket
        _ev("local", "serialized", "cas", 4, 1e-5, 1e-5),
        _ev("local", "sort", "faa", 64, None, 2e-4),     # unpriced: skipped
        {"event": "atomics.execute", "tier": "local", "backend": "sort",
         "op": "faa", "n": 64, "predicted_s": 1e-4, "traced": True},
        {"event": "train.step", "predicted_s": 1e-4, "measured_s": 1e-4},
    ]
    stats = drift.aggregate(evs)
    assert set(stats) == {("local", "sort", "faa", "2^6"),
                          ("local", "sort", "faa", "2^12"),
                          ("local", "serialized", "cas", "2^2")}


def test_size_bucket():
    assert drift.size_bucket(1) == "2^0"
    assert drift.size_bucket(8) == "2^3"
    assert drift.size_bucket(9) == "2^4"
    assert drift.size_bucket(None) == "?"


def test_fit_spec_update_direct_and_inverse():
    from repro.core.perf_model import cpu_default_spec
    spec = cpu_default_spec()
    evs = (
        # serialized 4x slow -> loop_step_s scales UP 4x
        [_ev("local", "serialized", "cas", 8, 1e-5, 4e-5)] * 4 +
        # device_put 2x slow -> host_roundtrip_Bps scales DOWN 2x
        [_ev("migration", "device_put", "-", 4096, 1e-3, 2e-3)] * 4
    )
    out = drift.fit_spec_update(drift.aggregate(evs), spec)
    f = out["fields"]
    assert f["loop_step_s"]["ratio"] == pytest.approx(4.0)
    assert f["loop_step_s"]["proposed"] == \
        pytest.approx(spec.loop_step_s * 4.0)
    assert f["host_roundtrip_Bps"]["proposed"] == \
        pytest.approx(spec.host_roundtrip_Bps / 2.0)
    assert out["spec"].loop_step_s == pytest.approx(spec.loop_step_s * 4.0)
    assert out["spec"].name == spec.name       # only constants move


def test_fit_spec_update_needs_min_samples():
    from repro.core.perf_model import cpu_default_spec
    evs = [_ev("local", "sort", "faa", 64, 1e-4, 2e-4)] * 2
    out = drift.fit_spec_update(drift.aggregate(evs), cpu_default_spec(),
                                min_samples=3)
    assert out["fields"] == {}


def test_fit_spec_update_per_field_floors_and_skipped():
    from repro.core.perf_model import cpu_default_spec
    spec = cpu_default_spec()
    evs = ([_ev("local", "serialized", "cas", 8, 1e-5, 2e-5)] * 5 +
           [_ev("local", "sort", "faa", 64, 1e-4, 3e-4)] * 2)
    stats = drift.aggregate(evs)
    # mapping floors: "*" default + a per-field override
    out = drift.fit_spec_update(stats, spec,
                                min_samples={"*": 2, "loop_step_s": 6})
    assert "sort_elem_pass_s" in out["fields"]          # 2 >= "*": 2
    assert out["skipped"]["loop_step_s"] == {"n": 5, "min_samples": 6}
    # an int floor still applies uniformly
    out2 = drift.fit_spec_update(stats, spec, min_samples=3)
    assert "loop_step_s" in out2["fields"]
    assert out2["skipped"]["sort_elem_pass_s"] == {"n": 2, "min_samples": 3}


def test_fit_spec_update_skips_unset_fields_with_reason():
    import dataclasses
    from repro.core.perf_model import cpu_default_spec
    spec = dataclasses.replace(cpu_default_spec(), loop_step_s=0.0)
    evs = [_ev("local", "serialized", "cas", 8, 1e-5, 2e-5)] * 4
    out = drift.fit_spec_update(drift.aggregate(evs), spec, min_samples=2)
    assert out["fields"] == {}
    assert out["skipped"]["loop_step_s"]["reason"] == "field unset on spec"


def test_report_build(tmp_path):
    from repro.telemetry.report import build_report, render_text
    evs = [_ev("local", "sort", "faa", 64, 1e-4, 2e-4)] * 3
    path = str(tmp_path / "cap.jsonl")
    with open(path, "w") as f:
        for e in evs:
            f.write(json.dumps(e) + "\n")
    report = build_report(telemetry.read_jsonl(path))
    assert report["n_events"] == 3
    assert report["events"]["atomics.execute"]["count"] == 3
    (row,) = report["drift"]
    assert row["ratio"] == pytest.approx(2.0)
    text = render_text(report)
    assert "atomics.execute" in text and "sort" in text


def test_report_surfaces_skipped_fields():
    from repro.telemetry.report import build_report, render_text
    evs = [_ev("local", "sort", "faa", 64, 1e-4, 2e-4)] * 2   # below floor
    report = build_report(evs)
    assert report["spec_update"] == {}
    assert report["spec_update_skipped"]["sort_elem_pass_s"]["n"] == 2
    text = render_text(report)
    assert "sort_elem_pass_s: skipped" in text


# ---------------------------------------------------------------------------
# add_sink / remove_sink and the ring crash-flush
# ---------------------------------------------------------------------------

def test_add_sink_widens_flags_and_remove_sink_resets():
    outer = telemetry.RingBuffer()
    telemetry.enable(outer, sync=True)
    tap = telemetry.RingBuffer()
    telemetry.add_sink(tap, sync=False)          # must NOT narrow sync
    assert telemetry.sync_enabled()
    telemetry.record("ev")
    assert len(outer.events) == 1 and len(tap.events) == 1
    assert telemetry.remove_sink(tap) is True
    assert telemetry.remove_sink(tap) is False   # already gone
    telemetry.record("ev")
    assert len(outer.events) == 2 and len(tap.events) == 1
    assert telemetry.remove_sink(outer) is True
    assert not telemetry.enabled()               # last sink out: stream off
    assert not telemetry.sync_enabled()


def test_add_sink_alone_enables_the_stream():
    tap = telemetry.RingBuffer()
    telemetry.add_sink(tap, sync=True)
    assert telemetry.enabled() and telemetry.sync_enabled()
    telemetry.remove_sink(tap)
    assert not telemetry.enabled()


def test_ring_events_and_flush_ring(tmp_path):
    assert telemetry.flush_ring() == 0           # no ring sink: no-op
    buf = telemetry.RingBuffer()
    telemetry.enable(buf)
    telemetry.record("a", i=1)
    telemetry.record("b", arr=np.arange(2))
    assert [e["event"] for e in telemetry.ring_events()] == ["a", "b"]
    path = str(tmp_path / "flush.jsonl")
    assert telemetry.flush_ring(path) == 2
    back = telemetry.read_jsonl(path)
    assert [e["event"] for e in back] == ["a", "b"]
    assert back[1]["arr"] == [0, 1]              # jsonable coercion applied
    # a JSONL-only stream has no ring to flush
    telemetry.disable()
    telemetry.enable(telemetry.JsonlWriter(str(tmp_path / "cap.jsonl")))
    telemetry.record("c")
    assert telemetry.ring_events() == [] and telemetry.flush_ring() == 0


def test_enable_from_env_ring_names_the_flush_path(tmp_path, monkeypatch):
    from repro.telemetry import core
    flush_to = str(tmp_path / "ring_tail.jsonl")
    monkeypatch.setattr(core, "_ring_flush_path", None)
    monkeypatch.setenv(telemetry.TELEMETRY_ENV, f"ring:{flush_to}")
    assert telemetry.enable_from_env() is True
    telemetry.record("crashy", step=3)
    assert telemetry.flush_ring() == 1           # default target = env path
    assert telemetry.read_jsonl(flush_to)[0]["event"] == "crashy"


def test_run_result_attaches_ring_tail():
    from repro.runtime.fault_tolerance import FaultConfig, run_with_recovery
    telemetry.enable(telemetry.RingBuffer())
    store = {}
    res = run_with_recovery(
        lambda s, x: x + 1, 0, 4,
        FaultConfig(checkpoint_every=2, backoff_base_s=0.0),
        lambda s, x: store.__setitem__(s, x),
        lambda: None)
    assert res.steps_done == 4
    assert any(e["event"] == "recovery.restore"
               for e in res.telemetry_ring)
    telemetry.disable()
    # without a ring sink the field is simply empty — no mode check needed
    res2 = run_with_recovery(
        lambda s, x: x + 1, 0, 2,
        FaultConfig(checkpoint_every=2, backoff_base_s=0.0),
        lambda s, x: None, lambda: None)
    assert res2.telemetry_ring == []


def test_fatal_fault_flushes_the_ring_to_disk(tmp_path, monkeypatch):
    from repro.runtime.fault_tolerance import (FatalFault, FaultConfig,
                                               run_with_recovery)
    from repro.telemetry import core
    flush_to = str(tmp_path / "postmortem.jsonl")
    monkeypatch.setattr(core, "_ring_flush_path", None)
    monkeypatch.setenv(telemetry.TELEMETRY_ENV, f"ring:{flush_to}")
    telemetry.enable_from_env()

    def dying_step(step, state):
        telemetry.record("train.step", step=step)
        if step == 2:
            raise FatalFault("chip gone for good")
        return state + 1

    with pytest.raises(FatalFault):
        run_with_recovery(
            dying_step, 0, 6,
            FaultConfig(checkpoint_every=2, backoff_base_s=0.0),
            lambda s, x: None, lambda: None)
    # the last-N events landed on disk before the fault propagated
    events = telemetry.read_jsonl(flush_to)
    assert any(e["event"] == "train.step" and e["step"] == 2
               for e in events)
    assert any(e["event"] == "recovery.fault" and e["fatal"]
               for e in events)


# ---------------------------------------------------------------------------
# Sharded tier: exactly one decision event per call site (8 fake devices)
# ---------------------------------------------------------------------------

_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import atomics, telemetry
from repro.sharding import shard_map_compat

mesh = jax.make_mesh((2, 4), ("pod", "dev"))
m_local, n_per = 8, 4
idx = jnp.arange(8 * n_per, dtype=jnp.int32).reshape(8, n_per) % (8 * m_local)
vals = jnp.ones((8, n_per), jnp.int32)

def body(t, i, v):
    tbl = atomics.AtomicTable(t, axis=("pod", "dev"))
    res = atomics.execute(tbl, atomics.Faa(i, v))
    return res.table.data, res.fetched

fn = jax.jit(shard_map_compat(
    body, mesh,
    (P(("pod", "dev")), P(("pod", "dev")), P(("pod", "dev"))),
    (P(("pod", "dev")), P(("pod", "dev")))))

tab = jax.device_put(jnp.zeros((8 * m_local,), jnp.int32),
                     NamedSharding(mesh, P(("pod", "dev"))))
buf = telemetry.RingBuffer()
telemetry.enable(buf)
out, _ = fn(tab, idx.reshape(-1), vals.reshape(-1))   # compile: traces once
for _ in range(4):                                    # cached: no events
    out, _ = fn(out, idx.reshape(-1), vals.reshape(-1))
evs = [e for e in buf.events if e["event"] == "atomics.execute"]
decision = {k: evs[0][k] for k in
            ("tier", "traced", "strategy", "n", "m", "n_shards")} if evs else {}
pred = evs[0].get("predicted_s") if evs else None
print("RESULT:" + json.dumps({
    "n_events": len(evs), "decision": decision,
    "predicted_positive": bool(pred and pred > 0),
    "total": int(np.asarray(out).sum())}))
"""


def test_sharded_execute_emits_one_decision_event_per_call_site():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, "-c", _SHARDED_SCRIPT], env=env,
        capture_output=True, text=True, timeout=600,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT:")][0]
    out = json.loads(line[len("RESULT:"):])
    # shard_map traces the body ONCE: one decision event for the whole
    # 8-device mesh on compile, zero for the 4 cached executions
    assert out["n_events"] == 1, out
    d = out["decision"]
    assert d["tier"] == "sharded" and d["traced"] is True
    assert d["n_shards"] == 8 and d["m"] == 64
    assert d["strategy"] in ("oneshot", "hierarchical", "naive", "dense")
    assert out["predicted_positive"] is True
    assert out["total"] == 5 * 32               # results unchanged
