"""The contention observatory (PR 10): `collect_stats=` across tiers.

The load-bearing contracts:

* stats are a pure observer — results bit-identical with the pass on or
  off, on the local engine tier and the 8-fake-device sharded exchange;
* the numbers are exact — distinct/max/histogram/top-k agree with a host
  ``np.bincount`` of the same batch, per-exchange-level in/out counts are
  monotone with level 0 = the issued batch;
* `execute_until` feeds the tuning estimator from the device counts when
  one is active (same site keys as the host ``np.unique`` path, which is
  skipped entirely), and surfaces the round-0 stats on `RetryResult`;
* the telemetry plumbing: one ``contention.stats`` event per collected
  batch at a sync boundary, aggregated into the report's contention
  section; ring flushes land under `telemetry_dir`, not the CWD.
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import atomics, telemetry
from repro.atomics import retry as retry_mod
from repro.atomics import stats as stats_mod
from repro.atomics.stats import HIST_BINS, TOPK, ContentionStats


def _np_stats(idx, m):
    occ = np.bincount(np.asarray(idx), minlength=m)
    hist = np.zeros(HIST_BINS, np.int64)
    for o in occ[occ > 0]:
        hist[min(int(np.floor(np.log2(o))), HIST_BINS - 1)] += 1
    return occ, hist


# ---------------------------------------------------------------------------
# the stats kernels themselves
# ---------------------------------------------------------------------------

def test_stats_from_occupancy_matches_numpy():
    m = 97
    rng = np.random.default_rng(0)
    idx = rng.integers(0, m, 513).astype(np.int32)
    occ, hist = _np_stats(idx, m)
    st = stats_mod.stats_from_occupancy(jnp.asarray(occ, jnp.int32),
                                        jnp.int32(idx.size))
    assert int(st.n_ops) == idx.size
    assert int(st.distinct_slots) == int((occ > 0).sum())
    assert int(st.max_occupancy) == int(occ.max())
    assert np.asarray(st.occupancy_hist).tolist() == hist.tolist()
    # top-k: counts are the k largest occupancies, slots actually hold them
    counts = np.asarray(st.topk_counts)
    slots = np.asarray(st.topk_slots)
    assert counts.tolist() == sorted(occ, reverse=True)[:TOPK]
    for s, c in zip(slots, counts):
        assert occ[s] == c


def test_topk_pads_with_minus_one_below_k_slots():
    occ = np.zeros(16, np.int32)
    occ[3], occ[11] = 5, 2
    slots, counts = stats_mod.topk_hot(jnp.asarray(occ))
    assert slots.tolist()[:2] == [3, 11]
    assert counts.tolist() == [5, 2] + [0] * (TOPK - 2)
    assert slots.tolist()[2:] == [-1] * (TOPK - 2)


def test_hist_buckets_are_log2():
    occ = np.array([1, 2, 3, 4, 7, 8, 0, 0], np.int32)
    hist = np.asarray(stats_mod.occupancy_hist(jnp.asarray(occ)))
    assert hist[0] == 1            # occupancy 1
    assert hist[1] == 2            # 2-3
    assert hist[2] == 2            # 4-7
    assert hist[3] == 1            # 8-15
    assert hist.sum() == 6         # unoccupied slots counted nowhere


def test_pallas_kernel_slot_occupancy_matches_bincount():
    from repro.kernels.rmw import ops as kops
    m = 300
    rng = np.random.default_rng(1)
    idx = rng.integers(-3, m + 5, 1000).astype(np.int32)  # some OOR
    occ = np.asarray(kops.slot_occupancy(jnp.asarray(idx), m))
    valid = idx[(idx >= 0) & (idx < m)]
    assert occ.tolist() == np.bincount(valid, minlength=m).tolist()


# ---------------------------------------------------------------------------
# execute(): bit identity + exactness, local tier
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.int32, jnp.float32])
def test_execute_collect_stats_bit_identical_local(dtype):
    m = 128
    rng = np.random.default_rng(2)
    idx = jnp.asarray(rng.integers(0, m, 700), jnp.int32)
    vals = jnp.asarray(rng.integers(-4, 5, 700), dtype)
    tbl = atomics.AtomicTable(jnp.zeros((m,), dtype))
    for op in (atomics.Faa(idx, vals),
               atomics.Cas(idx, vals,
                           expected=jnp.zeros((700,), dtype))):
        r_off = atomics.execute(tbl, op)
        r_on = atomics.execute(tbl, op, collect_stats=True)
        assert np.array_equal(np.asarray(r_off.table.data),
                              np.asarray(r_on.table.data))
        assert np.array_equal(np.asarray(r_off.fetched),
                              np.asarray(r_on.fetched))
        assert np.array_equal(np.asarray(r_off.success),
                              np.asarray(r_on.success))
        assert r_off.stats is None
        occ, _ = _np_stats(idx, m)
        st = r_on.stats
        assert isinstance(st, ContentionStats)
        assert int(np.asarray(st.distinct_slots)) == int((occ > 0).sum())
        assert int(np.asarray(st.max_occupancy)) == int(occ.max())
        assert int(np.asarray(st.n_ops)) == 700
        assert np.asarray(st.level_ops_in).size == 0   # local tier: L = 0


def test_execute_sequence_collects_one_stats_per_op():
    tbl = atomics.AtomicTable(jnp.zeros((16,), jnp.int32))
    ops = [atomics.Faa(jnp.zeros((4,), jnp.int32), jnp.ones((4,), jnp.int32)),
           atomics.Faa(jnp.arange(4, dtype=jnp.int32),
                       jnp.ones((4,), jnp.int32))]
    res = atomics.execute(tbl, ops, collect_stats=True)
    assert isinstance(res.stats, tuple) and len(res.stats) == 2
    assert int(np.asarray(res.stats[0].distinct_slots)) == 1
    assert int(np.asarray(res.stats[1].distinct_slots)) == 4
    assert atomics.execute(tbl, ops).stats is None


def test_sync_mode_emits_one_contention_event():
    tbl = atomics.AtomicTable(jnp.zeros((8,), jnp.int32))
    op = atomics.Faa(jnp.zeros((6,), jnp.int32), jnp.ones((6,), jnp.int32))
    with telemetry.capture(sync=True) as buf:
        atomics.execute(tbl, op, collect_stats=True)
        atomics.execute(tbl, op)                     # off: no event
    evs = [e for e in buf.events if e.get("event") == "contention.stats"]
    assert len(evs) == 1
    assert evs[0]["distinct_slots"] == 1 and evs[0]["max_occupancy"] == 6
    assert evs[0]["tier"] == "local" and evs[0]["op"] == "faa"


# ---------------------------------------------------------------------------
# execute_until: device-fed estimator, host-unique skip, RetryResult.stats
# ---------------------------------------------------------------------------

def _cas_loop(n=24, m=8, collect=None):
    idx = np.asarray(np.arange(n) % 4, np.int32)

    def make_ops(slots, observed):
        if slots is None:
            return atomics.Cas(jnp.asarray(idx), jnp.ones((n,), jnp.int32),
                               expected=jnp.zeros((n,), jnp.int32))
        return jnp.asarray(np.asarray(observed) + 1)

    return atomics.execute_until(
        atomics.AtomicTable(jnp.zeros((m,), jnp.int32)), make_ops,
        max_rounds=n, collect_stats=collect)


def test_retry_stats_none_by_default_without_controller():
    res = _cas_loop()
    assert res.success.all() and res.stats is None


def test_retry_collect_stats_explicit_true():
    res = _cas_loop(collect=True)
    assert res.success.all()
    assert int(np.asarray(res.stats.distinct_slots)) == 4
    assert int(np.asarray(res.stats.max_occupancy)) == 6
    # bit identity against the off path
    ref = _cas_loop(collect=False)
    assert ref.stats is None
    assert np.array_equal(np.asarray(res.table.data),
                          np.asarray(ref.table.data))
    assert np.array_equal(res.rounds, ref.rounds)


def test_controller_auto_feeds_estimator_from_device(monkeypatch):
    """Estimator active -> device stats on, host np.unique never runs."""
    from repro.tuning import SpecController, TuningConfig, site_key

    def boom(x):
        raise AssertionError("host np.unique path must be skipped when "
                             "device stats feed the estimator")

    monkeypatch.setattr(retry_mod, "_host_distinct", boom)
    with SpecController(TuningConfig()) as ctrl:
        res = _cas_loop()
        assert res.stats is not None
        assert ctrl.estimator.n_updates_device >= 1
        key = site_key("cas", "local", 8, 24)
        assert ctrl.estimator.raw(key) is not None
        # round-0 distinct = 4 contended slots; the CAS second observation
        # agrees, so the EWMA sits exactly at 4
        assert ctrl.estimator.raw(key) == pytest.approx(4.0)


def test_host_fallback_sites_match_device_sites():
    """Satellite key-stability: the host and device observation paths must
    produce identical site keys (and here, identical EWMA values)."""
    from repro.tuning import SpecController, TuningConfig
    with SpecController(TuningConfig()) as ctrl:
        _cas_loop(collect=False)                 # host np.unique path
        host_sites = ctrl.estimator.sites()
        assert ctrl.estimator.n_updates_host >= 1
    with SpecController(TuningConfig()) as ctrl:
        _cas_loop(collect=None)                  # auto -> device
        device_sites = ctrl.estimator.sites()
        assert ctrl.estimator.n_updates_device >= 1
    assert set(host_sites) == set(device_sites)
    assert host_sites == device_sites            # same EWMA values too


def test_host_unique_skipped_when_nothing_consumes_it(monkeypatch):
    """No estimator, no telemetry: round 0 must not pay the host pass."""
    calls = []
    monkeypatch.setattr(retry_mod, "_host_distinct",
                        lambda x: calls.append(1) or int(np.unique(x).size))
    res = _cas_loop()
    assert res.success.all() and calls == []
    with telemetry.capture():
        _cas_loop()                              # telemetry alone consumes it
    assert calls == [1]


def test_retry_emits_contention_event_once_under_sync(monkeypatch):
    from repro.tuning import SpecController, TuningConfig
    with telemetry.capture(sync=True) as buf:
        with SpecController(TuningConfig()):
            _cas_loop()
    evs = [e for e in buf.events if e.get("event") == "contention.stats"]
    assert len(evs) == 1                         # no double emit
    assert evs[0]["distinct_slots"] == 4


def test_estimator_state_roundtrip_with_device_fed_sites(tmp_path):
    """Satellite: snapshot()/restore() through SpecController state_path
    when the sites were fed from on-device counts."""
    from repro.tuning import SpecController, TuningConfig, site_key
    path = str(tmp_path / "tuning_state.json")
    cfg = TuningConfig()
    key = site_key("cas", "local", 8, 24)
    with SpecController(cfg, state_path=path) as ctrl:
        _cas_loop()                              # auto -> device feed
        assert ctrl.estimator.n_updates_device >= 1
        fed = ctrl.estimator.raw(key)
        assert fed is not None
    with SpecController(cfg, state_path=path) as ctrl2:
        assert ctrl2.estimator.raw(key) == fed
        # and the restored site keeps serving hints to the same site key
        assert ctrl2.estimator.hint(key) == 4


# ---------------------------------------------------------------------------
# telemetry plumbing: ring flush location + report section
# ---------------------------------------------------------------------------

def test_ring_flush_lands_under_telemetry_dir(tmp_path, monkeypatch):
    monkeypatch.setenv(telemetry.TELEMETRY_DIR_ENV,
                       str(tmp_path / "run_artifacts"))
    telemetry.enable(telemetry.RingBuffer())
    try:
        telemetry.record("crashy", step=1)
        assert telemetry.flush_ring() == 1
    finally:
        telemetry.disable()
    target = tmp_path / "run_artifacts" / "repro_telemetry_ring.jsonl"
    assert target.exists()                       # dir auto-created
    assert telemetry.read_jsonl(str(target))[0]["event"] == "crashy"
    assert not os.path.exists("repro_telemetry_ring.jsonl")


def test_telemetry_dir_default_is_artifacts(monkeypatch):
    monkeypatch.delenv(telemetry.TELEMETRY_DIR_ENV, raising=False)
    assert telemetry.telemetry_dir() == os.path.join("artifacts",
                                                     "telemetry")


def test_report_contention_section():
    from repro.telemetry.report import build_report, render_text
    evs = [
        {"event": "contention.stats", "tier": "local", "op": "faa",
         "n_ops": 64, "distinct_slots": 8, "max_occupancy": 16,
         "occupancy_hist": [0, 0, 0, 0, 8], "topk_slots": [3, 5],
         "topk_counts": [16, 12], "level_ops_in": [], "level_ops_out": []},
        {"event": "contention.stats", "tier": "local", "op": "faa",
         "n_ops": 64, "distinct_slots": 10, "max_occupancy": 8,
         "occupancy_hist": [0, 0, 0, 10], "topk_slots": [5, 9],
         "topk_counts": [8, 7], "level_ops_in": [], "level_ops_out": []},
        {"event": "contention.stats", "tier": "sharded", "op": "cas",
         "n_ops": 128, "distinct_slots": 2, "max_occupancy": 64,
         "occupancy_hist": [], "topk_slots": [], "topk_counts": [],
         "level_ops_in": [128, 64], "level_ops_out": [64, 2]},
    ]
    rep = build_report(evs, fit=False)
    rows = {(r["tier"], r["op"]): r for r in rep["contention"]}
    local = rows[("local", "faa")]
    assert local["batches"] == 2 and local["n_ops"] == 128
    assert local["mean_distinct"] == 9.0
    assert local["max_occupancy"] == 16
    assert local["occupancy_hist"] == [0, 0, 0, 10, 8]
    # hot slots merged across batches, max count kept per slot
    assert local["hot_slots"][0] == {"slot": 3, "count": 16}
    assert {h["slot"] for h in local["hot_slots"]} == {3, 5, 9}
    sharded = rows[("sharded", "cas")]
    assert sharded["level_efficiency"] == [0.5, round(2 / 64, 4)]
    text = render_text(rep)
    assert "contention (contention.stats events" in text
    assert "128->64" in text


# ---------------------------------------------------------------------------
# sharded tier (8 fake devices, subprocess)
# ---------------------------------------------------------------------------

_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import atomics

mesh = jax.make_mesh((2, 4), ("pod", "dev"))
m = 256
n = 512
rng = np.random.default_rng(11)
idx = jnp.asarray(rng.integers(0, m, (n,)), jnp.int32)
vals = jnp.asarray(rng.integers(-3, 4, (n,)), jnp.int32)

def table():
    return atomics.AtomicTable(
        jax.device_put(jnp.zeros((m,), jnp.int32),
                       NamedSharding(mesh, P(("pod", "dev")))),
        axis=("pod", "dev"))

def run(collect):
    def make_ops(slots, observed):
        if slots is None:
            return atomics.Faa(idx, vals)
        return None
    return atomics.execute_until(table(), make_ops, max_rounds=1,
                                 collect_stats=collect)

r_off = run(False)
r_on = run(True)
st = r_on.stats
occ = np.bincount(np.asarray(idx), minlength=m)
out = {
    "bit_identical": bool(
        np.array_equal(np.asarray(r_off.table.data),
                       np.asarray(r_on.table.data))
        and np.array_equal(r_off.fetched, r_on.fetched)),
    "off_stats_none": r_off.stats is None,
    "distinct_ok": int(np.asarray(st.distinct_slots)) == int((occ > 0).sum()),
    "max_ok": int(np.asarray(st.max_occupancy)) == int(occ.max()),
    "n_ops": int(np.asarray(st.n_ops)),
    "level_in": np.asarray(st.level_ops_in).tolist(),
    "level_out": np.asarray(st.level_ops_out).tolist(),
    "topk_ok": all(occ[s] == c
                   for s, c in zip(np.asarray(st.topk_slots),
                                   np.asarray(st.topk_counts)) if s >= 0),
}
print("RESULT:" + json.dumps(out))
"""


def test_sharded_8dev_stats_bit_identical_and_exact():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=os.pathsep.join(
                   [os.path.abspath("src")] +
                   os.environ.get("PYTHONPATH", "").split(os.pathsep)))
    proc = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT],
                          capture_output=True, text=True, env=env,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT:")][0]
    out = json.loads(line[len("RESULT:"):])
    assert out["bit_identical"] and out["off_stats_none"]
    assert out["distinct_ok"] and out["max_ok"] and out["topk_ok"]
    assert out["n_ops"] == 512
    # per-level efficiency: level 0 admits the whole batch; combining
    # never grows the op count on the way up
    assert out["level_in"], "sharded stats must report exchange levels"
    assert out["level_in"][0] == 512
    assert all(o <= i for i, o in zip(out["level_in"], out["level_out"]))
