"""Property tests: the combining RMW is serialized-equivalent (paper core)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image: fall back to the local shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.atomics import arrival_rank
from repro.core.rmw import rmw_combining, rmw_serialized, segmented_scan

SET = settings(max_examples=30, deadline=None)


def batches(max_table=8, max_ops=40, lo=-4, hi=4):
    return st.tuples(
        st.integers(1, max_table),
        st.lists(st.tuples(st.integers(0, max_table - 1),
                           st.integers(lo, hi)), min_size=1,
                 max_size=max_ops))


@SET
@given(batches(), st.sampled_from(["faa", "swp", "min", "max"]))
def test_combining_equals_serialized(batch, op):
    m, ops = batch
    idx = jnp.asarray([i % m for i, _ in ops], jnp.int32)
    vals = jnp.asarray([v for _, v in ops], jnp.int32)
    table = jnp.arange(m, dtype=jnp.int32) - m // 2
    a = rmw_serialized(table, idx, vals, op)
    b = rmw_combining(table, idx, vals, op)
    np.testing.assert_array_equal(a.table, b.table)
    np.testing.assert_array_equal(a.fetched, b.fetched)
    np.testing.assert_array_equal(a.success, b.success)


@SET
@given(batches(max_table=4, lo=-2, hi=2), st.integers(-2, 2))
def test_cas_uniform_equals_serialized(batch, expected):
    """Includes the desired==expected chain case (§3.2 success semantics)."""
    m, ops = batch
    idx = jnp.asarray([i % m for i, _ in ops], jnp.int32)
    vals = jnp.asarray([v for _, v in ops], jnp.int32)
    table = jnp.asarray([(i % 5) - 2 for i in range(m)], jnp.int32)
    exp_arr = jnp.full((len(ops),), expected, jnp.int32)
    a = rmw_serialized(table, idx, vals, "cas", exp_arr)
    b = rmw_combining(table, idx, vals, "cas", jnp.int32(expected))
    np.testing.assert_array_equal(a.table, b.table)
    np.testing.assert_array_equal(a.fetched, b.fetched)
    np.testing.assert_array_equal(a.success, b.success)


@SET
@given(st.lists(st.integers(0, 5), min_size=1, max_size=50))
def test_arrival_rank_is_faa_fetch(keys):
    """arrival_rank == fetch results of serialized FAA(counter[key], 1)."""
    k = jnp.asarray(keys, jnp.int32)
    counter = jnp.zeros((6,), jnp.int32)
    ones = jnp.ones((len(keys),), jnp.int32)
    ser = rmw_serialized(counter, k, ones, "faa")
    # both the argsort fallback and the sort-free path
    np.testing.assert_array_equal(arrival_rank(k), ser.fetched)
    np.testing.assert_array_equal(arrival_rank(k, 6), ser.fetched)


@SET
@given(st.lists(st.integers(-5, 5), min_size=1, max_size=40),
       st.lists(st.booleans(), min_size=1, max_size=40))
def test_segmented_scan_matches_loop(vals, flags):
    n = min(len(vals), len(flags))
    v = jnp.asarray(vals[:n], jnp.int32)
    f = np.asarray(flags[:n], bool)
    f[0] = True
    got = segmented_scan(v, jnp.asarray(f), jnp.add)
    want = np.zeros(n, np.int64)
    run = 0
    for i in range(n):
        run = vals[i] if f[i] else run + vals[i]
        want[i] = run
    np.testing.assert_array_equal(np.asarray(got), want)


def test_cas_requires_expected():
    t = jnp.zeros((2,), jnp.int32)
    i = jnp.zeros((1,), jnp.int32)
    with pytest.raises(ValueError):
        rmw_serialized(t, i, i, "cas")


def test_unknown_op_rejected():
    t = jnp.zeros((2,), jnp.int32)
    i = jnp.zeros((1,), jnp.int32)
    with pytest.raises(ValueError):
        rmw_combining(t, i, i, "xor")


def test_ilp_gap_measured():
    """Combining-mode throughput beats serialized on independent ops —
    the paper's Fig. 5 gap.

    Uses the RMW engine's auto-selected backend in table-only mode: the
    paper's bandwidth experiment measures update throughput of independent
    atomics (fetch results unconsumed), which is the engine's sort-free
    bincount fast path.

    Threshold is platform-dependent.  On vector hardware (TPU) the gap must
    be >= 3x.  On a scalar 1-core host BOTH sides lower to serial XLA loops
    at ~60-70 ns/op (measured ratio 0.6-1.2 across runs — there is no ILP to
    expose), so this only asserts combining is not substantially slower; the
    gap itself is covered by perf_model's test_ilp_gap_positive and tracked
    in benchmarks/results/rmw_backends.json."""
    import time

    from repro.core.rmw_engine import execute_backend

    rng = np.random.default_rng(0)
    n = 262144
    table = jnp.zeros((4096,), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 4096, n), jnp.int32)
    vals = jnp.asarray(rng.normal(size=n), jnp.float32)
    f_ser = jax.jit(lambda: rmw_serialized(table, idx[:4096], vals[:4096],
                                           "faa").table)
    f_comb = jax.jit(lambda: execute_backend(table, idx, vals, "faa",
                                             need_fetched=False).table)

    def best_of(fn, reps=5):
        out = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            out.append(time.perf_counter() - t0)
        return min(out)

    jax.block_until_ready(f_ser()); jax.block_until_ready(f_comb())
    t_ser = best_of(f_ser) / 4096
    t_comb = best_of(f_comb) / n
    threshold = 3.0 if jax.default_backend() == "tpu" else 0.3
    assert t_ser / t_comb > threshold, (t_ser, t_comb)
