"""Bounded-retry CAS loops: `atomics.execute_until`.

The contract under serialized-equivalence semantics: a fully-contended
batch (every op targeting one slot) resolves exactly one op per round, so
n ops converge in <= n rounds for the immediate and exponential-spacing
policies; `ShrinkBatch` trades rounds for fewer total attempts.  Local and
sharded tiers must produce identical round histories.
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import atomics
from repro.atomics import (Cas, ExponentialBackoff, Faa, ImmediateRetry,
                           RetryPolicy, ShrinkBatch, execute_until)


def _contended_make_ops(n, slot=0):
    """n CAS increments all fighting over one slot — the textbook CAS
    loop ``CAS(x, v, v + 1)``: every op expects the same pre-image, so
    each round serializes exactly one winner and the rest retry with the
    fetched value as their next ``expected``."""
    idx0 = jnp.zeros((n,), jnp.int32) + slot

    def make_ops(slots, observed):
        if slots is None:
            return Cas(idx0, jnp.ones((n,), jnp.int32),
                       expected=jnp.zeros((n,), jnp.int32))
        return Cas(jnp.asarray(slots), jnp.asarray(observed) + 1,
                   expected=jnp.asarray(observed))
    return make_ops


def test_fully_contended_resolves_in_n_rounds_immediate():
    for n in (1, 4, 16):
        t = atomics.AtomicTable(jnp.zeros((8,), jnp.int32))
        res = execute_until(t, _contended_make_ops(n), max_rounds=n,
                            policy="immediate")
        assert res.pending.size == 0, f"n={n}: ops left unresolved"
        assert res.n_rounds <= n
        assert res.success.all()
        # serialized equivalence: exactly one winner per round
        assert sorted(res.rounds.tolist()) == list(range(1, n + 1))
        # the chained increments commuted to a final value of n
        assert int(np.asarray(res.table.data)[0]) == n


def test_exponential_policy_also_bounded_by_n():
    n = 8
    t = atomics.AtomicTable(jnp.zeros((4,), jnp.int32))
    slept = []
    res = execute_until(t, _contended_make_ops(n), max_rounds=n,
                        policy=ExponentialBackoff(base_s=1e-5, factor=2.0,
                                                  max_s=1e-4),
                        sleep_fn=slept.append)
    assert res.pending.size == 0 and res.n_rounds <= n
    assert len(slept) == res.n_rounds - 1          # a delay between rounds
    assert slept == sorted(slept)                  # non-decreasing spacing
    assert max(slept) <= 1e-4 + 1e-12


def test_shrink_batch_issues_fewer_attempts():
    n = 16
    runs = {}
    for name, policy in (("immediate", "immediate"),
                         ("shrink", ShrinkBatch(factor=0.5, min_batch=1))):
        t = atomics.AtomicTable(jnp.zeros((4,), jnp.int32))
        res = execute_until(t, _contended_make_ops(n), max_rounds=4 * n,
                            policy=policy)
        assert res.pending.size == 0
        assert int(np.asarray(res.table.data)[0]) == n
        runs[name] = res
    # total attempts = sum over ops of rounds they were in flight; the
    # shrink policy's whole point (arxiv 1305.5800) is to spend fewer
    attempts = {k: int(r.rounds.sum()) for k, r in runs.items()}
    assert attempts["shrink"] < attempts["immediate"]


def test_uncontended_batch_one_round():
    t = atomics.AtomicTable(jnp.asarray(np.arange(8), jnp.int32))
    idx = jnp.asarray([0, 3, 5], jnp.int32)
    res = execute_until(
        t, lambda s, o: Cas(idx, jnp.asarray([10, 13, 15], jnp.int32),
                            expected=jnp.asarray([0, 3, 5], jnp.int32)),
        max_rounds=8)
    assert res.n_rounds == 1 and res.success.all()
    np.testing.assert_array_equal(np.asarray(res.table.data)[[0, 3, 5]],
                                  [10, 13, 15])


def test_max_rounds_exhaustion_reports_pending():
    n, budget = 16, 5
    t = atomics.AtomicTable(jnp.zeros((4,), jnp.int32))
    res = execute_until(t, _contended_make_ops(n), max_rounds=budget)
    assert res.n_rounds == budget
    assert int(res.success.sum()) == budget        # one winner per round
    assert res.pending.size == n - budget
    # losers report the budget as their round count, winners their round
    assert (res.rounds[res.pending] == budget).all()
    assert int(np.asarray(res.table.data)[0]) == budget


def test_make_ops_none_gives_up_early():
    n = 8
    base = _contended_make_ops(n)

    def capped(slots, observed):
        if slots is not None and len(slots) <= n - 3:
            return None                            # caller bails
        return base(slots, observed)

    t = atomics.AtomicTable(jnp.zeros((4,), jnp.int32))
    res = execute_until(t, capped, max_rounds=4 * n)
    assert res.pending.size == n - 3
    assert int(res.success.sum()) == 3


def test_values_only_retry_return():
    """make_ops may return a bare values array: the combinator re-issues
    CAS at the same slots with expected := the observed pre-images."""
    n = 6
    t = atomics.AtomicTable(jnp.zeros((4,), jnp.int32))

    def make_ops(slots, observed):
        if slots is None:
            return Cas(jnp.zeros((n,), jnp.int32),
                       jnp.ones((n,), jnp.int32),
                       expected=jnp.zeros((n,), jnp.int32))
        return jnp.asarray(observed) + 1           # values only
    res = execute_until(t, make_ops, max_rounds=n)
    assert res.pending.size == 0
    assert int(np.asarray(res.table.data)[0]) == n


def test_non_cas_op_resolves_in_one_round():
    t = atomics.AtomicTable(jnp.zeros((8,), jnp.int32))
    idx = jnp.asarray([1, 1, 2], jnp.int32)
    res = execute_until(t, lambda s, o: Faa(idx, jnp.ones((3,), jnp.int32)),
                        max_rounds=4)
    assert res.n_rounds == 1 and res.success.all()
    assert int(np.asarray(res.table.data)[1]) == 2


def test_validation_errors():
    t = atomics.AtomicTable(jnp.zeros((4,), jnp.int32))
    with pytest.raises(ValueError, match="max_rounds"):
        execute_until(t, _contended_make_ops(2), max_rounds=0)
    with pytest.raises(ValueError, match="unknown retry policy"):
        execute_until(t, _contended_make_ops(2), policy="warp-speed")
    with pytest.raises(TypeError, match="op batch"):
        execute_until(t, lambda s, o: "nope", max_rounds=2)
    with pytest.raises(ValueError, match="factor"):
        ShrinkBatch(factor=0.0)
    assert ShrinkBatch(min_batch=0).min_batch == 1   # clamped, not rejected


def test_policy_registry_and_base_class():
    assert set(atomics.POLICIES) >= {"immediate", "shrink", "exponential"}
    for p in atomics.POLICIES.values():
        assert isinstance(p(), RetryPolicy)
    assert isinstance(ImmediateRetry(), RetryPolicy)


def test_sharded_single_device_parity():
    """Same contended batch through the sharded tier on a 1-device mesh:
    identical round history and final table to the local tier."""
    n = 8
    local = execute_until(atomics.AtomicTable(jnp.zeros((8,), jnp.int32)),
                          _contended_make_ops(n), max_rounds=n)
    mesh = jax.make_mesh((1,), ("dev",))
    data = jax.device_put(
        jnp.zeros((8,), jnp.int32),
        jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("dev")))
    t = atomics.AtomicTable(data, axis="dev")
    res = execute_until(t, _contended_make_ops(n), max_rounds=n)
    assert res.n_rounds == local.n_rounds
    np.testing.assert_array_equal(res.rounds, local.rounds)
    np.testing.assert_array_equal(np.asarray(res.table.data),
                                  np.asarray(local.table.data))


_SHARDED_SCRIPT = r"""
import json, os
import jax, jax.numpy as jnp, numpy as np
from repro import atomics
from repro.atomics import Cas, execute_until

mesh = jax.make_mesh((2, 4), ("pod", "dev"))
P = jax.sharding.PartitionSpec
data = jax.device_put(jnp.zeros((32,), jnp.int32),
                      jax.sharding.NamedSharding(mesh, P(("pod", "dev"))))
t = atomics.AtomicTable(data, axis=("pod", "dev"))

n = 16
def make_ops(slots, observed):
    if slots is None:
        return Cas(jnp.zeros((n,), jnp.int32), jnp.ones((n,), jnp.int32),
                   expected=jnp.zeros((n,), jnp.int32))
    return Cas(jnp.asarray(slots), jnp.asarray(observed) + 1,
               expected=jnp.asarray(observed))

res = execute_until(t, make_ops, max_rounds=n)
out = {"n_rounds": int(res.n_rounds),
       "pending": int(res.pending.size),
       "rounds": sorted(np.asarray(res.rounds).tolist()),
       "final": int(np.asarray(res.table.data)[0])}
print("RESULT:" + json.dumps(out))
"""


def test_sharded_8dev_contended_bounded(tmp_path):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=os.pathsep.join(
                   [os.path.abspath("src")] +
                   os.environ.get("PYTHONPATH", "").split(os.pathsep)))
    proc = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT],
                          capture_output=True, text=True, env=env,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT:")][0]
    out = json.loads(line[len("RESULT:"):])
    assert out["pending"] == 0
    assert out["n_rounds"] <= 16                   # the <= n bound, sharded
    assert out["rounds"] == list(range(1, 17))     # one winner per round
    assert out["final"] == 16
