"""Fault tolerance: recovery state machine, determinism, stragglers."""

import pytest

from repro.runtime.fault_tolerance import (FaultConfig, StragglerMonitor,
                                           run_with_recovery)


class Store:
    """In-memory checkpoint store for the recovery driver."""

    def __init__(self):
        self.ckpts = {}

    def save(self, step, state):
        self.ckpts[step] = state

    def restore(self):
        if not self.ckpts:
            return None
        s = max(self.ckpts)
        return s, self.ckpts[s]


def test_recovers_from_injected_failures():
    store = Store()
    crashes = {7: 1, 23: 1}   # one-shot crashes at these steps

    def injector(step):
        if crashes.get(step):
            crashes[step] -= 1
            raise RuntimeError(f"chip lost at {step}")

    def step_fn(step, state):
        return state + 1

    cfg = FaultConfig(max_failures=5, checkpoint_every=5)
    res = run_with_recovery(step_fn, 0, 30, cfg, store.save, store.restore,
                            failure_injector=injector)
    assert res.steps_done == 30
    assert res.failures == 2
    assert res.restored_from  # resumed from checkpoints, not from scratch
    # final state must equal an uninterrupted run (determinism contract)
    assert store.ckpts[30] == 30


def test_too_many_failures_raises():
    store = Store()

    def injector(step):
        raise RuntimeError("persistent failure")

    cfg = FaultConfig(max_failures=2, checkpoint_every=5)
    with pytest.raises(RuntimeError):
        run_with_recovery(lambda s, x: x, 0, 10, cfg, store.save,
                          store.restore, failure_injector=injector)


def test_resume_from_existing_checkpoint():
    store = Store()
    store.save(20, 20)
    res = run_with_recovery(lambda s, x: x + 1, 0, 25,
                            FaultConfig(checkpoint_every=100),
                            store.save, store.restore)
    assert res.steps_done == 25
    assert res.restored_from == [20]
    assert store.ckpts[25] == 25


def test_straggler_monitor_flags_slow_host():
    cfg = FaultConfig(straggler_window=5, straggler_threshold=2.0)
    mon = StragglerMonitor(n_hosts=4, cfg=cfg)
    for _ in range(5):
        for h in range(4):
            mon.record(h, 1.0 if h != 2 else 5.0)
    assert mon.flag() == [2]


def test_straggler_monitor_quiet_when_uniform():
    mon = StragglerMonitor(n_hosts=3, cfg=FaultConfig())
    for _ in range(5):
        for h in range(3):
            mon.record(h, 1.0)
    assert mon.flag() == []
