"""Fault tolerance: recovery state machine, determinism, stragglers."""

import pytest

from repro.runtime.fault_tolerance import (FaultConfig, StragglerMonitor,
                                           run_with_recovery)


class Store:
    """In-memory checkpoint store for the recovery driver."""

    def __init__(self):
        self.ckpts = {}

    def save(self, step, state):
        self.ckpts[step] = state

    def restore(self):
        if not self.ckpts:
            return None
        s = max(self.ckpts)
        return s, self.ckpts[s]


def test_recovers_from_injected_failures():
    store = Store()
    crashes = {7: 1, 23: 1}   # one-shot crashes at these steps

    def injector(step):
        if crashes.get(step):
            crashes[step] -= 1
            raise RuntimeError(f"chip lost at {step}")

    def step_fn(step, state):
        return state + 1

    cfg = FaultConfig(max_failures=5, checkpoint_every=5)
    res = run_with_recovery(step_fn, 0, 30, cfg, store.save, store.restore,
                            failure_injector=injector)
    assert res.steps_done == 30
    assert res.failures == 2
    assert res.restored_from  # resumed from checkpoints, not from scratch
    # final state must equal an uninterrupted run (determinism contract)
    assert store.ckpts[30] == 30


def test_too_many_failures_raises():
    store = Store()

    def injector(step):
        raise RuntimeError("persistent failure")

    cfg = FaultConfig(max_failures=2, checkpoint_every=5)
    with pytest.raises(RuntimeError):
        run_with_recovery(lambda s, x: x, 0, 10, cfg, store.save,
                          store.restore, failure_injector=injector)


def test_resume_from_existing_checkpoint():
    store = Store()
    store.save(20, 20)
    res = run_with_recovery(lambda s, x: x + 1, 0, 25,
                            FaultConfig(checkpoint_every=100),
                            store.save, store.restore)
    assert res.steps_done == 25
    assert res.restored_from == [20]
    assert store.ckpts[25] == 25


def test_straggler_monitor_flags_slow_host():
    cfg = FaultConfig(straggler_window=5, straggler_threshold=2.0)
    mon = StragglerMonitor(n_hosts=4, cfg=cfg)
    for _ in range(5):
        for h in range(4):
            mon.record(h, 1.0 if h != 2 else 5.0)
    assert mon.flag() == [2]


def test_straggler_monitor_quiet_when_uniform():
    mon = StragglerMonitor(n_hosts=3, cfg=FaultConfig())
    for _ in range(5):
        for h in range(3):
            mon.record(h, 1.0)
    assert mon.flag() == []


# ---------------------------------------------------------------------------
# Recovery pacing: real backoff, deadline budget, fatal classification
# ---------------------------------------------------------------------------

import logging

from repro.runtime.fault_tolerance import FatalFault, backoff_delay


def _crashing_injector(steps):
    budget = dict(steps)

    def injector(step):
        if budget.get(step):
            budget[step] -= 1
            raise RuntimeError(f"chip lost at {step}")
    return injector


def test_backoff_delay_is_pure_capped_exponential():
    cfg = FaultConfig(backoff_base_s=0.01, backoff_factor=2.0,
                      backoff_max_s=0.05, backoff_jitter=0.0)
    delays = [backoff_delay(cfg, k) for k in (1, 2, 3, 4, 5)]
    assert delays == [0.01, 0.02, 0.04, 0.05, 0.05]   # doubles then caps
    jittered = FaultConfig(backoff_base_s=0.01, backoff_jitter=0.5)
    a = [backoff_delay(jittered, k) for k in range(1, 6)]
    assert a == [backoff_delay(jittered, k) for k in range(1, 6)]  # pure
    assert all(d >= 0.0 for d in a)


def test_recovery_sleeps_the_backoff_and_records_it():
    store = Store()
    slept = []
    cfg = FaultConfig(max_failures=5, checkpoint_every=5,
                      backoff_base_s=0.01, backoff_factor=2.0,
                      backoff_jitter=0.0)
    res = run_with_recovery(
        lambda s, x: x + 1, 0, 20, cfg, store.save, store.restore,
        failure_injector=_crashing_injector({4: 1, 9: 1}),
        sleep_fn=slept.append)
    assert res.steps_done == 20 and res.failures == 2
    assert slept == [0.01, 0.02]                   # grows per failure
    assert res.backoff_total_s == pytest.approx(sum(slept))
    # the structured trace replaces log-text parsing: one backoff event per
    # absorbed failure, carrying the exact delay slept
    backoffs = [e for e in res.events if e["event"] == "recovery.backoff"]
    assert [e["backoff_s"] for e in backoffs] == slept
    assert [e["attempt"] for e in backoffs] == [1, 2]
    faults = [e for e in res.events if e["event"] == "recovery.fault"]
    assert [e["site"] for e in faults] == ["step 4", "step 9"]
    assert all(e["error"] == "RuntimeError" and not e["fatal"]
               for e in faults)


def test_run_result_events_summarize_the_recovery_trace():
    store = Store()
    res = run_with_recovery(
        lambda s, x: x + 1, 0, 20,
        FaultConfig(max_failures=5, checkpoint_every=5, backoff_base_s=0.0),
        store.save, store.restore,
        failure_injector=_crashing_injector({4: 1, 9: 1}),
        sleep_fn=lambda d: None)
    counts = res.event_counts()
    # startup scratch restore + one restore per absorbed failure
    assert counts == {"recovery.restore": 3, "recovery.fault": 2,
                      "recovery.backoff": 2}
    restores = [e for e in res.events if e["event"] == "recovery.restore"]
    # startup and the first failure (no checkpoint yet) restart scratch;
    # the second failure resumes from the step-5 checkpoint
    assert [e["scratch"] for e in restores] == [True, True, False]
    assert restores[-1]["step"] == 5


def test_fatal_fault_event_carries_the_fatal_flag():
    store = Store()
    cfg = FaultConfig(max_failures=100, checkpoint_every=5)
    from repro import telemetry
    with telemetry.capture() as buf:
        with pytest.raises(FatalFault):
            run_with_recovery(
                lambda s, x: x + 1, 0, 20, cfg, store.save, store.restore,
                failure_injector=lambda s: (_ for _ in ()).throw(
                    FatalFault("operator abort")),
                sleep_fn=lambda d: None)
    faults = [e for e in buf.events if e["event"] == "recovery.fault"]
    assert len(faults) == 1 and faults[0]["fatal"] is True
    assert faults[0]["error"] == "FatalFault"


def test_deadline_budget_raises_timeout():
    store = Store()
    cfg = FaultConfig(max_failures=100, checkpoint_every=5,
                      backoff_base_s=0.0, deadline_s=0.0)
    with pytest.raises(TimeoutError, match="recovery deadline"):
        run_with_recovery(
            lambda s, x: x + 1, 0, 20, cfg, store.save, store.restore,
            failure_injector=_crashing_injector({4: 1}),
            sleep_fn=lambda d: None)


def test_fatal_fault_never_retried():
    store = Store()
    calls = []

    def injector(step):
        calls.append(step)
        raise FatalFault("operator abort")

    cfg = FaultConfig(max_failures=100, checkpoint_every=5)
    with pytest.raises(FatalFault):
        run_with_recovery(lambda s, x: x + 1, 0, 20, cfg, store.save,
                          store.restore, failure_injector=injector,
                          sleep_fn=lambda d: None)
    assert calls == [0]                            # exactly one attempt


def test_fatal_types_config_never_retried():
    store = Store()

    def injector(step):
        raise ValueError("misconfiguration")

    cfg = FaultConfig(max_failures=100, checkpoint_every=5,
                      fatal_types=(ValueError,))
    with pytest.raises(ValueError, match="misconfiguration"):
        run_with_recovery(lambda s, x: x + 1, 0, 20, cfg, store.save,
                          store.restore, failure_injector=injector,
                          sleep_fn=lambda d: None)


def test_flaky_restore_is_retried():
    """A failure during restore itself is retryable, not run-fatal."""
    store = Store()
    store.save(10, 10)
    flaky = {"left": 2}
    real_restore = store.restore

    def restore():
        if flaky["left"]:
            flaky["left"] -= 1
            raise OSError("ckpt server hiccup")
        return real_restore()

    cfg = FaultConfig(max_failures=5, checkpoint_every=100,
                      backoff_base_s=0.0)
    res = run_with_recovery(lambda s, x: x + 1, 0, 15, cfg, store.save,
                            restore, sleep_fn=lambda d: None)
    assert res.steps_done == 15 and res.failures == 2
    assert res.restored_from == [10]
    assert store.ckpts[15] == 15
