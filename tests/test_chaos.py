"""Deterministic fault injection (`runtime.chaos`) + the seeded chaos matrix.

The acceptance contract of the robustness layer (ISSUE 6): a seeded storm
of faults at every site of the recovery loop — step crashes, saves that
never land, restores that die, reshard failures, straggler stalls — must
complete through `run_with_recovery` with the final state (including a
live `AtomicTable`) **bit-equal** to a fault-free run, for every seed in
the matrix.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro import atomics
from repro.checkpoint import ckpt
from repro.runtime.chaos import (CHAOS_ENV, RECOVERY_SITES, SITES,
                                 ChaosError, FaultPlan,
                                 SiteSpec)
from repro.runtime.fault_tolerance import FaultConfig, run_with_recovery


# ---------------------------------------------------------------------------
# FaultPlan mechanics
# ---------------------------------------------------------------------------

def _fires(plan, site, visits):
    return [plan.fire(site) for _ in range(visits)]


def test_same_seed_same_schedule():
    sites = {"step": 0.3, "ckpt_save": 0.5}
    a = FaultPlan(7, sites)
    b = FaultPlan(7, sites)
    for site in ("step", "ckpt_save"):
        assert _fires(a, site, 200) == _fires(b, site, 200)
    assert _fires(FaultPlan(8, sites), "step", 200) != \
        _fires(FaultPlan(7, sites), "step", 200)


def test_sites_draw_independent_streams():
    """Visiting one site must never perturb another site's schedule."""
    only_step = _fires(FaultPlan(3, {"step": 0.4}), "step", 100)
    mixed = FaultPlan(3, {"step": 0.4, "ckpt_restore": 0.9})
    got = []
    for k in range(100):
        mixed.fire("ckpt_restore")     # interleaved traffic on another site
        if k % 3 == 0:
            mixed.fire("reshard")      # even an unconfigured site
        got.append(mixed.fire("step"))
    assert got == only_step


def test_count_cap_and_after():
    plan = FaultPlan(1, {"step": SiteSpec(prob=1.0, count=3, after=5)})
    fired = _fires(plan, "step", 20)
    assert sum(fired) == 3                      # capped
    assert not any(fired[:5])                   # warmup visits skipped
    assert fired[5:8] == [True, True, True]     # then prob=1 fires
    assert plan.stats()["step"] == {"visits": 20, "fired": 3}


def test_visit_raises_chaos_error_with_site_metadata():
    plan = FaultPlan(0, {"ckpt_save": 1.0})
    with pytest.raises(ChaosError, match="ckpt_save.*step 12"):
        plan.visit("ckpt_save", step=12)
    with pytest.raises(ValueError, match="unknown fault site"):
        plan.visit("not_a_site")
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultPlan(0, {"bogus": 1.0})


def test_straggler_delay_stalls_instead_of_raising():
    slept = []
    plan = FaultPlan(0, {"straggler_delay": SiteSpec(prob=1.0,
                                                     delay_s=0.25)},
                     sleep_fn=slept.append)
    plan.visit("straggler_delay", step=3)       # must NOT raise
    assert slept == [0.25]


def test_replay_reinjects_identical_faults():
    plan = FaultPlan(11, {"step": 0.5})
    first = _fires(plan, "step", 50)
    assert _fires(plan.replay(), "step", 50) == first


def test_from_spec_and_env(monkeypatch):
    plan = FaultPlan.from_spec(
        "seed=42, step=0.25, ckpt_save=0.5@2, straggler_delay=1.0, "
        "delay=0.125")
    assert plan.seed == 42
    assert plan.sites["step"] == SiteSpec(prob=0.25)
    assert plan.sites["ckpt_save"] == SiteSpec(prob=0.5, count=2)
    assert plan.sites["straggler_delay"].delay_s == 0.125
    with pytest.raises(ValueError, match="key=value"):
        FaultPlan.from_spec("step:0.5")

    monkeypatch.delenv(CHAOS_ENV, raising=False)
    assert FaultPlan.from_env().sites == {}     # null plan
    monkeypatch.setenv(CHAOS_ENV, "seed=9,step=1.0@1")
    env_plan = FaultPlan.from_env()
    assert env_plan.seed == 9 and env_plan.sites["step"].count == 1


def test_env_hook_reaches_run_with_recovery(monkeypatch):
    """chaos=None + REPRO_CHAOS set -> the run executes under faults."""
    monkeypatch.setenv(CHAOS_ENV, "seed=5,step=1.0@2")
    store = {}
    res = run_with_recovery(
        lambda s, x: x + 1, 0, 10,
        FaultConfig(max_failures=10, checkpoint_every=2,
                    backoff_base_s=0.0),
        lambda s, x: store.__setitem__(s, x),
        lambda: (max(store), store[max(store)]) if store else None)
    assert res.steps_done == 10 and res.failures == 2
    assert store[10] == 10                      # determinism survived


# ---------------------------------------------------------------------------
# The seeded chaos matrix: >= 5 seeds x faults at every recovery-loop site,
# final model state + live AtomicTable bit-equal to the fault-free run
# ---------------------------------------------------------------------------

N_STEPS = 20
M_SLOTS = 16


def _step_fn(step, state):
    """Deterministic per (step, state): an FAA batch against a live table
    plus a fetched-sum accumulator (so fetched values are load-bearing)."""
    table, acc = state
    idx = jnp.asarray((np.arange(8) * (step + 3)) % M_SLOTS, jnp.int32)
    vals = jnp.asarray(np.arange(8) + step, jnp.int32)
    res = atomics.execute(table, atomics.Faa(idx, vals))
    return res.table, acc + jnp.sum(res.fetched)


def _run(tmp_path, tag, chaos):
    ckpt_dir = str(tmp_path / tag)
    table0 = atomics.AtomicTable(jnp.zeros((M_SLOTS,), jnp.int32))
    init = (table0, jnp.int32(0))
    like = {"table": table0, "acc": jnp.int32(0)}

    def save_fn(step, state):
        ckpt.save(ckpt_dir, step, {"table": state[0], "acc": state[1]})

    def restore_fn():
        got = ckpt.restore_latest_valid(ckpt_dir, like)
        if got is None:
            return None
        step, tree, _ = got
        return step, (tree["table"], tree["acc"])

    from repro.runtime.elastic import reshard_tables
    cfg = FaultConfig(max_failures=60, checkpoint_every=5,
                      backoff_base_s=0.0)
    return run_with_recovery(_step_fn, init, N_STEPS, cfg, save_fn,
                             restore_fn, chaos=chaos,
                             reshard_fn=lambda s: reshard_tables(s, None))


def test_chaos_matrix_bit_equal_to_fault_free(tmp_path):
    baseline = _run(tmp_path, "baseline", FaultPlan.null())
    assert baseline.failures == 0
    base_final = ckpt.restore_latest_valid(
        str(tmp_path / "baseline"),
        {"table": atomics.AtomicTable(jnp.zeros((M_SLOTS,), jnp.int32)),
         "acc": jnp.int32(0)})
    assert base_final[0] == N_STEPS
    base_table = np.asarray(base_final[1]["table"].data)
    base_acc = int(base_final[1]["acc"])
    assert base_table.any()                      # the workload did work

    sites = {"step": SiteSpec(prob=0.25, count=2),
             "ckpt_save": SiteSpec(prob=0.25, count=2),
             "ckpt_restore": SiteSpec(prob=0.25, count=2),
             "reshard": SiteSpec(prob=0.25, count=2),
             "straggler_delay": SiteSpec(prob=0.2, count=2, delay_s=1e-4)}
    total_fired = 0
    any_restored = False
    for seed in range(1, 6):                     # the >= 5-seed matrix
        plan = FaultPlan(seed, sites)
        res = _run(tmp_path, f"seed{seed}", plan)
        assert res.steps_done == N_STEPS
        total_fired += plan.total_fired
        any_restored |= bool(res.restored_from)
        final = ckpt.restore_latest_valid(
            str(tmp_path / f"seed{seed}"),
            {"table": atomics.AtomicTable(jnp.zeros((M_SLOTS,), jnp.int32)),
             "acc": jnp.int32(0)})
        assert final[0] == N_STEPS
        np.testing.assert_array_equal(
            np.asarray(final[1]["table"].data), base_table,
            err_msg=f"seed {seed}: live table diverged from fault-free run")
        assert int(final[1]["acc"]) == base_acc, \
            f"seed {seed}: fetched-sum accumulator diverged"
    assert total_fired >= 5                      # the storm actually blew
    assert any_restored                          # and recovery restored


def test_chaos_all_sites_are_wired():
    """Every recovery-loop site is visited by run_with_recovery: prob=1@1
    at each site (one at a time) must produce exactly one absorbed failure
    (or one stall for straggler_delay).  ``spec_perturb`` is the tuning
    controller's site, covered by tests/test_tuning.py."""
    assert set(SITES) == set(RECOVERY_SITES) | {"spec_perturb"}
    for site in RECOVERY_SITES:
        plan = FaultPlan(0, {site: SiteSpec(prob=1.0, count=1,
                                            delay_s=1e-4)})
        # a pre-existing checkpoint so startup takes the restore+adopt
        # path (the reshard site is only visited when state is adopted)
        store = {2: 2}
        res = run_with_recovery(
            lambda s, x: x + 1, 0, 6,
            FaultConfig(max_failures=5, checkpoint_every=2,
                        backoff_base_s=0.0),
            lambda s, x: store.__setitem__(s, x),
            lambda: (max(store), store[max(store)]) if store else None,
            reshard_fn=lambda s: s, chaos=plan)
        assert res.steps_done == 6
        expect_failures = 0 if site == "straggler_delay" else 1
        assert res.failures == expect_failures, site
        assert plan.total_fired == 1, site
        assert store[6] == 6, site
