"""repro.analysis: the jaxpr-level atomics race detector & contract linter.

One known-bad function per rule (A001-A005) asserting the rule fires, a
matching known-good twin asserting it stays quiet, the PR-6 donation-bug
reconstruction caught statically, suppression mechanics, telemetry
emission, the CLI, and clean-pass sweeps over every registered entry
point.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import analysis, atomics, telemetry
from repro.analysis import lint
from repro.analysis.entries import ENTRY_POINTS
from repro.analysis.findings import (ERROR, RULES, WARNING,
                                     _line_suppresses)
from repro.atomics import contracts
from repro.runtime.fault_tolerance import declare_donation


def _sds(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _rules(findings):
    return [f.rule for f in findings]


@pytest.fixture(autouse=True)
def _stream_off():
    telemetry.disable()
    yield
    telemetry.disable()


# ---------------------------------------------------------------------------
# A001 — race detector
# ---------------------------------------------------------------------------

def test_a001_fires_on_raw_write_into_table():
    def bad(t, idx, v):
        tbl = atomics.AtomicTable(t)
        return tbl.data.at[idx].add(v)

    fs = analysis.check(bad, _sds((8,)), _sds((4,)), _sds((4,)))
    assert _rules(fs) == ["A001"]
    assert fs[0].severity == ERROR
    assert "atomics.execute" in fs[0].message


def test_a001_fires_on_table_passed_as_argument():
    tbl = atomics.AtomicTable(jnp.zeros((8,), jnp.int32))

    def bad(t, idx, v):
        return t.data.at[idx].set(v)

    fs = analysis.check(bad, tbl, _sds((4,)), _sds((4,)))
    assert _rules(fs) == ["A001"]


def test_a001_fires_on_aliasing_dynamic_scatter_set():
    def racy(buf, idx, v):
        return buf.at[idx].set(v)

    fs = analysis.check(racy, _sds((8,), jnp.float32), _sds((4,)),
                        _sds((4,), jnp.float32))
    assert _rules(fs) == ["A001"]


def test_a001_quiet_on_provably_unique_and_vouched_indices():
    def iota_set(buf, v):
        return buf.at[jnp.arange(4)].set(v)

    def vouched(buf, idx, v):
        return buf.at[idx].set(v, unique_indices=True)

    assert analysis.check(iota_set, _sds((8,), jnp.float32),
                          _sds((4,), jnp.float32)) == []
    assert analysis.check(vouched, _sds((8,), jnp.float32), _sds((4,)),
                          _sds((4,), jnp.float32)) == []


def test_a001_quiet_on_single_update_and_sanctioned_execute():
    def single(buf, i, v):
        return buf.at[i].set(v)

    def sanctioned(t, i, v):
        res = atomics.execute(atomics.AtomicTable(t), atomics.Faa(i, v))
        return res.table.data, res.fetched

    assert analysis.check(single, _sds((8,)), _sds(()), _sds(())) == []
    assert analysis.check(sanctioned, jnp.zeros((8,), jnp.int32),
                          _sds((4,)), _sds((4,))) == []


# ---------------------------------------------------------------------------
# A002 — primitive strength
# ---------------------------------------------------------------------------

def test_a002_fires_on_cas_expressible_as_faa():
    def cas_add(t, i, e):
        op = atomics.Cas(i, e + 1, expected=e)
        return atomics.execute(atomics.AtomicTable(t), op).table.data

    fs = analysis.check(cas_add, jnp.zeros((8,), jnp.int32),
                        _sds((4,)), _sds((4,)))
    assert _rules(fs) == ["A002"]
    assert fs[0].severity == WARNING
    assert "Faa" in fs[0].message
    # the message cites the consensus-number contract annotations
    assert "inf" in fs[0].message and "2" in fs[0].message


def test_a002_fires_on_cas_expressible_as_max():
    def cas_max(t, i, v, e):
        op = atomics.Cas(i, jnp.maximum(e, v), expected=e)
        return atomics.execute(atomics.AtomicTable(t), op).table.data

    fs = analysis.check(cas_max, jnp.zeros((8,), jnp.int32),
                        _sds((4,)), _sds((4,)), _sds((4,)))
    assert _rules(fs) == ["A002"]
    assert "Max" in fs[0].message


def test_a002_fires_on_degenerate_cas_writing_expected_back():
    def cas_noop(t, i, e):
        op = atomics.Cas(i, e, expected=e)
        return atomics.execute(atomics.AtomicTable(t), op).fetched

    fs = analysis.check(cas_noop, jnp.zeros((8,), jnp.int32),
                        _sds((4,)), _sds((4,)))
    assert _rules(fs) == ["A002"]


def test_a002_quiet_on_genuine_priority_cas():
    def cas_real(t, i, v, e):
        op = atomics.Cas(i, v, expected=e)
        return atomics.execute(atomics.AtomicTable(t), op).table.data

    fs = analysis.check(cas_real, jnp.zeros((8,), jnp.int32),
                        _sds((4,)), _sds((4,)), _sds((4,)))
    assert fs == []


# ---------------------------------------------------------------------------
# A003 — unbounded retry
# ---------------------------------------------------------------------------

def _cas_once(tab, i, v):
    res = atomics.execute(atomics.AtomicTable(tab),
                          atomics.Cas(i, v, expected=jnp.int32(0)))
    return res.table.data, jnp.all(res.success)


def test_a003_fires_on_unbounded_while_cas():
    def unbounded(t, i, v):
        def body(carry):
            tab, _ = carry
            return _cas_once(tab, i, v)

        out, _ = jax.lax.while_loop(lambda c: ~c[1], body,
                                    (t, jnp.bool_(False)))
        return out

    fs = analysis.check(unbounded, jnp.zeros((8,), jnp.int32),
                        _sds((4,)), _sds((4,)))
    assert _rules(fs) == ["A003"]
    assert "execute_until" in fs[0].message


def test_a003_quiet_on_round_bounded_while_cas():
    def bounded(t, i, v):
        def body(carry):
            tab, _, r = carry
            new, done = _cas_once(tab, i, v)
            return new, done, r + 1

        out, _, _ = jax.lax.while_loop(
            lambda c: ~c[1] & (c[2] < 16), body,
            (t, jnp.bool_(False), jnp.int32(0)))
        return out

    fs = analysis.check(bounded, jnp.zeros((8,), jnp.int32),
                        _sds((4,)), _sds((4,)))
    assert fs == []


def test_a003_quiet_on_cas_free_while():
    def loop(x):
        return jax.lax.while_loop(lambda c: jnp.any(c > 0),
                                  lambda c: c - 1, x)

    assert analysis.check(loop, _sds((4,))) == []


# ---------------------------------------------------------------------------
# A004 — donation safety
# ---------------------------------------------------------------------------

def test_a004_fires_on_donated_buffer_read_after_call():
    consume = jax.jit(lambda x: x * 2, donate_argnums=(0,))

    def bad(x):
        y = consume(x)
        return y + x                  # x is read AFTER being donated

    fs = analysis.check(bad, _sds((8,), jnp.float32))
    assert "A004" in _rules(fs)


def test_a004_quiet_when_donated_buffer_unused_afterwards():
    consume = jax.jit(lambda x: x * 2, donate_argnums=(0,))

    def ok(x):
        return consume(x) * 3

    assert analysis.check(ok, _sds((8,), jnp.float32)) == []


def test_a004_check_recovery_reconstructs_pr6_donation_bug():
    # the PR-6 bug class: a donating jitted step handed to recovery with a
    # CAPTURED state value — after step 0 the captured buffers are donated
    # away and every scratch restart replays aliased garbage
    step = declare_donation(
        jax.jit(lambda s, st: st * 2, donate_argnums=(1,)), (1,))
    fs = analysis.check_recovery(step, jnp.zeros((4,)))
    assert _rules(fs) == ["A004"]
    assert fs[0].severity == ERROR
    assert "factory" in fs[0].message


def test_a004_check_recovery_quiet_with_state_factory():
    step = declare_donation(
        jax.jit(lambda s, st: st * 2, donate_argnums=(1,)), (1,))
    assert analysis.check_recovery(step, lambda: jnp.zeros((4,))) == []


def test_a004_check_recovery_introspects_jit_without_declaration():
    # no declare_donation wrapper: donation is discovered from the jitted
    # function's own trace metadata when example args are provided
    step = jax.jit(lambda s, st: st * 2, donate_argnums=(1,))
    fs = analysis.check_recovery(step, jnp.zeros((4,)),
                                 example_args=(0, jnp.zeros((4,))))
    assert _rules(fs) == ["A004"]


def test_declare_donation_preserves_call_and_metadata():
    f = declare_donation(lambda s, st: st + s, 1)
    assert f.donate_argnums == (1,)
    assert f(2, 3) == 5


def test_run_with_recovery_warns_on_donating_step_with_captured_state():
    from repro.runtime.fault_tolerance import (FaultConfig,
                                               run_with_recovery)

    step = declare_donation(lambda s, st: st + 1, (1,))
    with telemetry.capture() as events:
        res = run_with_recovery(step, 0, 3, FaultConfig(), lambda s, x: None,
                                lambda: None)
    assert res.steps_done == 3
    hazards = [e for e in events.events
               if e["event"] == "recovery.donation_hazard"]
    assert len(hazards) == 1
    # the hazard is a static property of the call, not a recovery
    # occurrence: the run-local event trace must not change shape
    assert "recovery.donation_hazard" not in res.event_counts()


# ---------------------------------------------------------------------------
# A005 — shard contract
# ---------------------------------------------------------------------------

def test_a005_fires_on_sharded_execute_outside_shard_map():
    def outside(t, i, v):
        tbl = atomics.AtomicTable(t, axis="dev")
        return atomics.execute(tbl, atomics.Faa(i, v)).table.data

    fs = analysis.check(outside, jnp.zeros((8,), jnp.int32),
                        _sds((4,)), _sds((4,)))
    assert _rules(fs) == ["A005"]
    assert "shard_map" in fs[0].message


def _shard_mapped(body):
    from jax.sharding import PartitionSpec as P

    from repro.sharding import shard_map_compat

    mesh = jax.make_mesh((1,), ("dev",))
    spec = P("dev")
    return shard_map_compat(body, mesh, (spec, spec, spec), (spec,))


def test_a005_quiet_inside_shard_map():
    def fn(t, i, v):
        tbl = atomics.AtomicTable(t, axis="dev")
        return (atomics.execute(tbl, atomics.Faa(i[0], v[0])).table.data,)

    fs = analysis.check(_shard_mapped(fn), _sds((8,)), _sds((1, 4)),
                        _sds((1, 4)))
    assert fs == []


def test_a005_fires_on_reverse_ranks_without_forward_fetch():
    def fn(t, i, v):
        tbl = atomics.AtomicTable(t, axis="dev")
        r1 = atomics.execute(tbl, atomics.Swp(i[0], v[0]),
                             need_fetched=False)
        r2 = atomics.execute(r1.table, atomics.Swp(i[0], v[0]),
                             reverse_ranks=True, need_fetched=False)
        return (r2.table.data,)

    fs = analysis.check(_shard_mapped(fn), _sds((8,)), _sds((1, 4)),
                        _sds((1, 4)))
    assert _rules(fs) == ["A005"]
    assert "reverse_ranks" in fs[0].message


def test_a005_quiet_on_swp_plus_revert_with_forward_fetch():
    # the sanctioned SWP+revert scheme (core/bfs.py): forward pass fetches
    # pre-images, reversed pass writes them back
    def fn(t, i, v):
        tbl = atomics.AtomicTable(t, axis="dev")
        r1 = atomics.execute(tbl, atomics.Swp(i[0], v[0]),
                             need_fetched=True)
        r2 = atomics.execute(r1.table, atomics.Swp(i[0], r1.fetched),
                             reverse_ranks=True, need_fetched=False)
        return (r2.table.data,)

    fs = analysis.check(_shard_mapped(fn), _sds((8,)), _sds((1, 4)),
                        _sds((1, 4)))
    assert fs == []


# ---------------------------------------------------------------------------
# suppression mechanics
# ---------------------------------------------------------------------------

def test_line_suppression_parser():
    assert _line_suppresses("x = 1  # atomics-lint: disable=A001", "A001")
    assert _line_suppresses("# atomics-lint: disable=A001,A003", "A003")
    assert _line_suppresses("# atomics-lint: disable=all", "A005")
    assert not _line_suppresses("# atomics-lint: disable=A001", "A002")
    assert not _line_suppresses("# just a comment", "A001")


def test_suppressed_findings_stay_visible_but_do_not_gate(tmp_path):
    mod = tmp_path / "bad_mod.py"
    mod.write_text(
        "import jax.numpy as jnp\n"
        "def racy(buf, idx, v):\n"
        "    # atomics-lint: disable=A001\n"
        "    return buf.at[idx].set(v)\n")
    import importlib.util
    spec = importlib.util.spec_from_file_location("bad_mod", mod)
    m = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(m)
    fs = analysis.check(m.racy, _sds((8,), jnp.float32), _sds((4,)),
                        _sds((4,), jnp.float32))
    assert _rules(fs) == ["A001"]
    assert fs[0].suppressed
    # suppressed errors do not fail the sweep gate
    assert all(f.suppressed for f in fs if f.severity == ERROR)


def test_repo_suppressions_are_commented():
    # every in-repo suppression must carry a why (the comment block above
    # it) — spot-check the one deliberate suppression shipped today
    from pathlib import Path
    src = Path(__file__).resolve().parents[1] / "src/repro/models/moe.py"
    lines = src.read_text().splitlines()
    marks = [i for i, ln in enumerate(lines) if "atomics-lint:" in ln]
    assert marks, "expected the moe dispatch suppression to exist"
    for i in marks:
        context = "\n".join(lines[max(0, i - 4):i])
        assert "scratch row" in context or "distinct" in context


# ---------------------------------------------------------------------------
# telemetry + reporting
# ---------------------------------------------------------------------------

def test_findings_emit_telemetry_events():
    def bad(t, idx, v):
        tbl = atomics.AtomicTable(t)
        return tbl.data.at[idx].add(v)

    with telemetry.capture() as events:
        analysis.check(bad, _sds((8,)), _sds((4,)), _sds((4,)),
                       entry="unit.bad")
    evs = [e for e in events.events if e["event"] == "analysis.finding"]
    assert len(evs) == 1
    assert evs[0]["rule"] == "A001"
    assert evs[0]["severity"] == ERROR
    assert evs[0]["entry"] == "unit.bad"
    assert evs[0]["suppressed"] is False


def test_report_renders_analysis_section():
    from repro.telemetry.report import build_report, render_text

    events = [{"event": "analysis.finding", "rule": "A001",
               "severity": "error", "file": "x.py", "line": 3,
               "entry": "e", "suppressed": False, "message": "m"}]
    rep = build_report(events, fit=False)
    assert rep["analysis"][0]["rule"] == "A001"
    text = render_text(rep)
    assert "static analysis" in text
    assert "x.py:3" in text and "A001" in text


# ---------------------------------------------------------------------------
# CLI + sweep + fixture
# ---------------------------------------------------------------------------

def test_cli_list_and_single_entry(capsys):
    assert lint.main(["--list"]) == 0
    out = capsys.readouterr().out
    for name in ENTRY_POINTS:
        assert name in out
    assert lint.main(["--entries", "bfs.local"]) == 0
    out = capsys.readouterr().out
    assert "[bfs.local] clean" in out


def test_cli_json_output(capsys):
    assert lint.main(["--entries", "bfs.local", "--json"]) == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload["summary"]["errors"] == 0
    assert isinstance(payload["findings"], list)


def test_cli_unknown_entry_is_an_error(capsys):
    assert lint.main(["--entries", "no.such.entry"]) == 1
    assert "A000" in capsys.readouterr().out


def test_sweep_crashing_entry_becomes_a000_finding(monkeypatch):
    from repro.analysis import entries as entries_mod

    def boom():
        raise RuntimeError("entry exploded")

    monkeypatch.setitem(entries_mod.ENTRY_POINTS, "unit.boom", boom)
    res = lint.sweep(["unit.boom"])
    fs = res["unit.boom"]
    assert _rules(fs) == ["A000"]
    assert "entry exploded" in fs[0].message


@pytest.mark.parametrize("entry", sorted(ENTRY_POINTS))
def test_registered_entry_points_pass_clean(entry):
    findings = ENTRY_POINTS[entry]()
    bad = [f for f in findings if f.severity == ERROR and not f.suppressed]
    assert bad == [], "\n".join(f.format() for f in bad)


def test_atomics_lint_fixture_gates_and_returns(atomics_lint):
    def ok(buf, v):
        return buf.at[jnp.arange(4)].set(v)

    assert atomics_lint(ok, _sds((8,), jnp.float32),
                        _sds((4,), jnp.float32)) == []

    def bad(t, idx, v):
        tbl = atomics.AtomicTable(t)
        return tbl.data.at[idx].add(v)

    with pytest.raises(pytest.fail.Exception):
        atomics_lint(bad, _sds((8,)), _sds((4,)), _sds((4,)))


# ---------------------------------------------------------------------------
# analysis must not perturb production behavior
# ---------------------------------------------------------------------------

def test_no_marker_leaks_outside_observation():
    def fn(t, i, v):
        res = atomics.execute(atomics.AtomicTable(t), atomics.Faa(i, v))
        return res.table.data

    analysis.check(fn, jnp.zeros((8,), jnp.int32), _sds((4,)), _sds((4,)))
    assert not contracts.active()
    jaxpr = jax.make_jaxpr(fn)(jnp.zeros((8,), jnp.int32),
                               jnp.zeros((4,), jnp.int32),
                               jnp.zeros((4,), jnp.int32))
    assert contracts.MARKER not in str(jaxpr)


def test_checked_function_still_executes_correctly():
    def fn(t, i, v):
        res = atomics.execute(atomics.AtomicTable(t), atomics.Faa(i, v))
        return res.table.data

    t = jnp.zeros((8,), jnp.int32)
    i = jnp.array([1, 1, 2, 7], jnp.int32)
    v = jnp.array([1, 2, 3, 4], jnp.int32)
    before = np.asarray(fn(t, i, v))
    analysis.check(fn, t, i, v)
    after = np.asarray(fn(t, i, v))
    np.testing.assert_array_equal(before, after)
    np.testing.assert_array_equal(
        after, np.asarray([0, 3, 3, 0, 0, 0, 0, 4]))


def test_rule_table_is_complete():
    assert set(RULES) == {"A000", "A001", "A002", "A003", "A004", "A005"}
    for rule, (sev, desc) in RULES.items():
        assert sev in (ERROR, WARNING)
        assert desc
