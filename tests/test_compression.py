"""int8 error-feedback gradient compression: accuracy + unbiasedness."""

import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image: fall back to the local shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.optim.compression import (Compressed, compress, decompress,
                                     wire_bytes)


def test_roundtrip_bounded_error():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(1000,)) * 0.01, jnp.float32)
    comp, err = compress(g)
    dq = decompress(comp, g.shape)
    # per-block max-abs scaling bounds elementwise error by scale/2 ~ 1%/127
    assert float(jnp.max(jnp.abs(dq + err - g))) < 1e-6  # g = dq + err
    assert float(jnp.max(jnp.abs(dq - g))) <= float(
        jnp.max(jnp.abs(g))) / 127 + 1e-8


def test_error_feedback_recovers_signal():
    """A constant tiny gradient (below one quantization step) must not be
    lost forever: error feedback accumulates it until it crosses the step."""
    g = jnp.full((256,), 1e-4, jnp.float32)
    big = jnp.zeros((256,), jnp.float32).at[0].set(1.0)  # sets the scale
    err = None
    total = jnp.zeros((256,), jnp.float32)
    for _ in range(200):
        comp, err = compress(g + big, err)
        total = total + decompress(comp, g.shape) - big
    mean_recovered = float(total[1:].mean()) / 200
    # residual (unflushed) error is bounded by half a quantization step
    # (1/254 of the block scale) => up to ~±20% of the mean over 200 steps
    assert abs(mean_recovered - 1e-4) / 1e-4 < 0.25


def test_wire_savings_4x():
    g = jnp.ones((4096,), jnp.float32)
    comp, _ = compress(g)
    assert wire_bytes(comp) < g.size * 4 / 3.5


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 700), st.integers(0, 2**31 - 1))
def test_shapes_and_padding(n, seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    comp, err = compress(g)
    dq = decompress(comp, g.shape)
    assert dq.shape == g.shape
    np.testing.assert_allclose(np.asarray(dq + err), np.asarray(g),
                               rtol=0, atol=1e-6)


def test_zero_grad_stable():
    g = jnp.zeros((512,), jnp.float32)
    comp, err = compress(g)
    assert float(jnp.abs(decompress(comp, g.shape)).max()) == 0.0
    assert float(jnp.abs(err).max()) == 0.0
