"""Perf-model invariants (paper Eq. 1-11) + NRMSE machinery."""

import math

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image: fall back to the local shim
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.perf_model import (TPU_V5E, bandwidth, calibrate,
                                   cpu_default_spec, ilp_gap, latency,
                                   read_for_ownership, read_latency,
                                   relaxed_bandwidth, unaligned_latency)
from repro.core.placement import Ownership, PlacementState, Tier, shared
from repro.core.validation import ValidationRow, nrmse, validate

TIERS_ORDERED = (Tier.VREG, Tier.VMEM, Tier.HBM_LOCAL, Tier.ICI_NEIGHBOR,
                 Tier.DCN_REMOTE_POD)


def test_latency_monotone_in_tier():
    for op in ("cas", "faa", "swp"):
        ls = [latency(TPU_V5E, op, PlacementState(tier=t))
              for t in TIERS_ORDERED]
        assert all(a < b for a, b in zip(ls, ls[1:])), (op, ls)


def test_shared_costs_more_than_exclusive():
    """Paper Eq. (7)/(8): S/O-state acquisition adds the invalidation round."""
    for t in (Tier.HBM_LOCAL, Tier.ICI_NEIGHBOR):
        e = read_for_ownership(TPU_V5E, PlacementState(tier=t))
        s = read_for_ownership(TPU_V5E, shared(t, 4))
        assert s > e


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 64))
def test_shared_replicas_sublinear(n):
    """Invalidations run in parallel (max, not sum): near-flat in replicas."""
    s2 = read_for_ownership(TPU_V5E, shared(Tier.ICI_NEIGHBOR, 2))
    sn = read_for_ownership(TPU_V5E, shared(Tier.ICI_NEIGHBOR, n))
    assert sn <= s2 * (1 + 0.1 * math.log2(n))


def test_eq1_composition():
    """L = R_O + E + O exactly (Eq. 1)."""
    st_ = PlacementState(tier=Tier.HBM_LOCAL)
    spec = TPU_V5E.with_residuals({("faa", Tier.HBM_LOCAL): 1e-9})
    l = latency(spec, "faa", st_)
    assert l == pytest.approx(read_for_ownership(spec, st_)
                              + spec.execute_s["faa"] + 1e-9)


def test_atomics_comparable_headline():
    """The paper's headline: CAS ≈ FAA ≈ SWP (within 2x at every tier)."""
    for t in TIERS_ORDERED:
        ls = [latency(TPU_V5E, op, PlacementState(tier=t))
              for op in ("cas", "faa", "swp")]
        assert max(ls) / min(ls) < 2.0


def test_ilp_gap_positive():
    st_ = PlacementState(tier=Tier.HBM_LOCAL)
    assert ilp_gap(TPU_V5E, "faa", st_) > 5.0
    assert relaxed_bandwidth(TPU_V5E, st_) > bandwidth(TPU_V5E, "faa", st_)


def test_unaligned_at_least_double():
    st_ = PlacementState(tier=Tier.HBM_LOCAL)
    assert unaligned_latency(TPU_V5E, "cas", st_) \
        >= 2 * latency(TPU_V5E, "cas", st_)


def test_read_cheaper_than_rmw():
    for t in TIERS_ORDERED:
        st_ = PlacementState(tier=t)
        assert latency(TPU_V5E, "read", st_) <= latency(TPU_V5E, "faa", st_)


def test_calibration_fits_medians():
    spec0 = cpu_default_spec()
    reads = {Tier.VREG: [1e-9], Tier.VMEM: [3e-9], Tier.HBM_LOCAL: [50e-9]}
    rmws = {(op, t): [r[0] + 5e-9] for t, r in reads.items()
            for op in ("cas", "faa", "swp")}
    spec = calibrate(spec0, reads, rmws)
    for t, r in reads.items():
        assert spec.tier_latency_s[t] == pytest.approx(r[0])
    for op in ("cas", "faa", "swp"):
        # E absorbs the uniform 5ns gap minus the streaming term
        assert 0 <= spec.execute_s[op] <= 5e-9
        # with residuals, the model reproduces the measurements exactly
        for t in reads:
            got = latency(spec, op, PlacementState(tier=t))
            assert got == pytest.approx(rmws[(op, t)][0], rel=1e-6)


def test_nrmse_and_gate():
    assert nrmse([1.0, 2.0], [1.0, 2.0]) == 0.0
    with pytest.raises(ValueError):
        nrmse([1.0], [1.0, 2.0])
    rows = [ValidationRow("a", 1.0, 1.0), ValidationRow("b", 2.0, 1.0)]
    rep = validate(rows)
    assert not rep["passes"] and rep["flagged"] == ["b"]


def test_bandwidth_amortization():
    """Eq. (10): more operands per tile -> higher useful bandwidth."""
    st_ = PlacementState(tier=Tier.HBM_LOCAL)
    b8 = bandwidth(TPU_V5E, "faa", st_, operand_bytes=8)
    b512 = bandwidth(TPU_V5E, "faa", st_, operand_bytes=512)
    assert b8 > 0 and b512 > 0
    # fewer ops per tile (bigger operands) -> less per-op overhead
    assert b512 >= b8


def test_read_latency_increases_with_hops():
    near = read_latency(TPU_V5E, PlacementState(tier=Tier.ICI_FAR, hops=1))
    far = read_latency(TPU_V5E, PlacementState(tier=Tier.ICI_FAR, hops=7))
    assert far > near
