"""Elastic table migration: reshard live AtomicTables across mesh changes.

The acceptance contract of the migration subsystem (ISSUE 5):

* subprocess half (8 fake devices, same pattern as tests/test_rmw_sharded):
  grow (2->4), shrink (4->2), and replica-axis changes through
  `reshard.migrate` / `ReshardPlan.execute` yield tables bit-identical to
  the serialized oracle AND to a from-scratch replay on the new mesh;
  post-migration `atomics.execute` results (fetched/success, per-op-expected
  CAS state, OOR drops) match a never-resharded run; the grow-then-shrink
  round trip (2->4->2) is bit-exact end to end; same-fleet layout changes
  take the in-collective exchange path; checkpointed tables restore under a
  different mesh through `ckpt.restore`; `elastic.reshard_tables` migrates
  live state trees.
* in-process half: TableLayout derivations + serialization, the migration
  cost tier (`select_migration`, migration-vs-replay crossover), plan
  validation errors, `restore_table` fallbacks, local-table checkpoint
  round trips, and the `run_with_recovery` reshard hook.
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import atomics
from repro.atomics.layout import TableLayout, local_row, owner_shard

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, tempfile
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro import atomics
from repro.atomics import reshard
from repro.atomics.layout import TableLayout
from repro.checkpoint import ckpt
from repro.core.rmw import rmw_serialized
from repro.runtime.elastic import reshard_tables
from repro.sharding import shard_map_compat, use_mesh

rng = np.random.default_rng(11)
devs = jax.devices()
M = 64
out = {}

def mesh_of(k):
    return Mesh(np.array(devs[:k]), ("dev",))

def place(arr, mesh, axis="dev"):
    return jax.device_put(arr, NamedSharding(mesh, P(axis)))

def exec_batch(mesh, tbl, op_name, idx, vals, expected=None,
               replica_axes=(), axis="dev"):
    '''Run one (ndev, n) batch through the sharded tier; returns
    (new AtomicTable, fetched, success) with fetched/success flat in
    device-rank order.'''
    SPEC = P(tuple(mesh.axis_names))
    tab_spec = P(axis)
    args = [tbl.data, idx, vals]
    in_specs = [tab_spec, SPEC, SPEC]
    def fn(t, i, v, *e):
        handle = atomics.AtomicTable(t, axis=axis, replica_axes=replica_axes)
        if op_name == "cas":
            aop = atomics.Cas(i[0], v[0], expected=e[0][0])
        else:
            aop = atomics.OP_KINDS[op_name](i[0], v[0])
        res = atomics.execute(handle, aop)
        return res.table.data, res.fetched[None], res.success[None]
    if op_name == "cas":
        args.append(expected)
        in_specs.append(SPEC)
    tabs, fetched, success = shard_map_compat(
        fn, mesh, tuple(in_specs), (tab_spec, SPEC, SPEC))(*args)
    return (atomics.AtomicTable(tabs, axis=axis, replica_axes=replica_axes),
            np.asarray(fetched).reshape(-1), np.asarray(success).reshape(-1))

def oracle(table, idx, vals, op_name, expected=None):
    '''Serialized oracle with the subsystem's OOR-drop convention.'''
    flat_i = jnp.asarray(idx).reshape(-1)
    flat_v = jnp.asarray(vals).reshape(-1)
    valid = (flat_i >= 0) & (flat_i < M)
    pad = jnp.concatenate([jnp.asarray(table), jnp.zeros((1,), jnp.int32)])
    exp = None if expected is None else jnp.asarray(expected).reshape(-1)
    ref = rmw_serialized(pad, jnp.where(valid, flat_i, M), flat_v, op_name,
                         exp)
    return (np.asarray(ref.table)[:M],
            np.asarray(jnp.where(valid, ref.fetched, 0)),
            np.asarray(ref.success & valid))

def batch(ndev, n=24, dist="mixed"):
    idx = rng.integers(-2, M + 3, (ndev, n))      # includes OOR both sides
    vals = rng.integers(-3, 4, (ndev, n))
    return jnp.asarray(idx, jnp.int32), jnp.asarray(vals, jnp.int32)

# ---------------------------------------------------------------------------
# grow 2 -> 4 and shrink 4 -> 2, every op incl. per-op-expected CAS
# ---------------------------------------------------------------------------

def check_resize(tag, k_from, k_to, op_name):
    mesh_a, mesh_b = mesh_of(k_from), mesh_of(k_to)
    tab0 = jnp.asarray(rng.integers(-1, 2, M), jnp.int32)
    ia, va = batch(k_from)
    ib, vb = batch(k_to)
    ea = eb = None
    if op_name == "cas":
        ea = jnp.asarray(rng.integers(-1, 2, ia.shape), jnp.int32)
        eb = jnp.asarray(rng.integers(-1, 2, ib.shape), jnp.int32)

    tbl = atomics.AtomicTable(place(tab0, mesh_a), axis="dev")
    tbl, _, _ = exec_batch(mesh_a, tbl, op_name, ia, va, ea)
    mig = reshard.migrate(tbl, mesh_b)
    mig2, fb, sb = exec_batch(mesh_b, mig, op_name, ib, vb, eb)

    t1, _, _ = oracle(tab0, ia, va, op_name, ea)
    t2, f2, s2 = oracle(t1, ib, vb, op_name, eb)
    ok = np.array_equal(np.asarray(mig2.data), t2)
    ok &= np.array_equal(fb, f2) and np.array_equal(sb, s2)

    # from-scratch replay of both batches on the NEW mesh reaches the same
    # table — and the migrated route got there without replaying anything
    replay = atomics.AtomicTable(place(tab0, mesh_b), axis="dev")
    ia_r = ia.reshape(k_to, -1); va_r = va.reshape(k_to, -1)
    ea_r = None if ea is None else ea.reshape(k_to, -1)
    replay, _, _ = exec_batch(mesh_b, replay, op_name, ia_r, va_r, ea_r)
    ok &= np.array_equal(np.asarray(replay.data), np.asarray(mig.data))
    out[tag] = bool(ok)

for op_name in ("faa", "swp", "min", "cas"):
    check_resize(f"grow/{op_name}", 2, 4, op_name)
check_resize("shrink/faa", 4, 2, "faa")
check_resize("shrink/max", 4, 2, "max")
check_resize("shrink/cas", 4, 2, "cas")

# ---------------------------------------------------------------------------
# grow-then-shrink round trip (2 -> 4 -> 2): bit-identical to never-resharded
# ---------------------------------------------------------------------------

def check_roundtrip(op_name):
    mesh2, mesh4 = mesh_of(2), mesh_of(4)
    tab0 = jnp.asarray(rng.integers(-1, 2, M), jnp.int32)
    sa_i, sa_v = batch(2)
    sb_i, sb_v = batch(4)           # stream B: executed on the grown mesh
    sc_i, sc_v = batch(2)
    ea = eb = ec = None
    if op_name == "cas":
        ea = jnp.asarray(rng.integers(-1, 2, sa_i.shape), jnp.int32)
        eb = jnp.asarray(rng.integers(-1, 2, sb_i.shape), jnp.int32)
        ec = jnp.asarray(rng.integers(-1, 2, sc_i.shape), jnp.int32)

    # migrated timeline: 2 -> 4 -> 2
    tbl = atomics.AtomicTable(place(tab0, mesh2), axis="dev")
    tbl, _, _ = exec_batch(mesh2, tbl, op_name, sa_i, sa_v, ea)
    tbl = reshard.migrate(tbl, mesh4)
    tbl, _, _ = exec_batch(mesh4, tbl, op_name, sb_i, sb_v, eb)
    tbl = reshard.migrate(tbl, mesh2)
    tbl, fc, sc = exec_batch(mesh2, tbl, op_name, sc_i, sc_v, ec)

    # never-resharded timeline on mesh2: same three GLOBAL op streams (the
    # arrival-order contract maps any device split of a stream to the same
    # serialized order, so stream B re-splits 4 -> 2 losslessly)
    ref = atomics.AtomicTable(place(tab0, mesh2), axis="dev")
    ref, _, _ = exec_batch(mesh2, ref, op_name, sa_i, sa_v, ea)
    ref, _, _ = exec_batch(mesh2, ref, op_name, sb_i.reshape(2, -1),
                           sb_v.reshape(2, -1),
                           None if eb is None else eb.reshape(2, -1))
    ref, fr, sr = exec_batch(mesh2, ref, op_name, sc_i, sc_v, ec)

    ok = np.array_equal(np.asarray(tbl.data), np.asarray(ref.data))
    ok &= np.array_equal(fc, fr) and np.array_equal(sc, sr)
    t1, _, _ = oracle(tab0, sa_i, sa_v, op_name, ea)
    t2, _, _ = oracle(t1, sb_i, sb_v, op_name, eb)
    t3, f3, s3 = oracle(t2, sc_i, sc_v, op_name, ec)
    ok &= np.array_equal(np.asarray(tbl.data), t3)
    ok &= np.array_equal(fc, f3) and np.array_equal(sc, s3)
    out[f"roundtrip/{op_name}"] = bool(ok)

for op_name in ("faa", "swp", "min", "max", "cas"):
    check_roundtrip(op_name)

# ---------------------------------------------------------------------------
# same-fleet layout change rides the in-collective exchange path
# ---------------------------------------------------------------------------

mesh24 = jax.make_mesh((2, 4), ("pod", "dev"))
tab0 = jnp.asarray(rng.integers(-1, 2, M), jnp.int32)
tblC = atomics.AtomicTable(
    jax.device_put(tab0, NamedSharding(mesh24, P(("pod", "dev")))),
    axis=("pod", "dev"))
src_lay = tblC.layout()
dst_lay = TableLayout.from_mesh(mesh24, num_slots=M, dtype=jnp.int32,
                                axis=("dev",), replica_axes=("pod",))
plan = reshard.plan_reshard(src_lay, dst_lay, dst_mesh=mesh24,
                            src_mesh=mesh24)
out["exchange/path_selected"] = plan.path == "exchange"
out["exchange/model_orders_paths"] = (plan.predicted_s["exchange"]
                                      < plan.predicted_s["device_put"])
tblR = plan.execute(tblC)
out["exchange/bits"] = bool(np.array_equal(np.asarray(tblR.data),
                                           np.asarray(tab0)))
# the re-derived replica contract actually executes (pod-major arrival)
SPEC = P(("pod", "dev"))
idx = jnp.asarray(rng.integers(0, M, (8, 16)), jnp.int32)
vals = jnp.asarray(rng.integers(-3, 4, (8, 16)), jnp.int32)
def fn_rep(t, i, v):
    h = atomics.AtomicTable(t, axis="dev", replica_axes="pod")
    res = atomics.execute(h, atomics.Faa(i[0], v[0]))
    return res.table.data, res.fetched[None]
tabs, fetched = shard_map_compat(
    fn_rep, mesh24, (P("dev"), SPEC, SPEC), (P("dev"), SPEC))(
    tblR.data, idx, vals)
t_ref, f_ref, _ = oracle(tab0, idx, vals, "faa")
out["exchange/replica_execute"] = bool(
    np.array_equal(np.asarray(tabs).reshape(-1)[:M], t_ref)
    and np.array_equal(np.asarray(fetched).reshape(-1), f_ref))
# exchange and host-roundtrip agree bit for bit
tblR2 = reshard.plan_reshard(src_lay, dst_lay, dst_mesh=mesh24,
                             src_mesh=mesh24,
                             path="device_put").execute(tblC)
out["exchange/agrees_with_device_put"] = bool(
    np.array_equal(np.asarray(tblR.data), np.asarray(tblR2.data)))

# ---------------------------------------------------------------------------
# checkpointed tables restore under a different mesh (layout metadata)
# ---------------------------------------------------------------------------

mesh_a = jax.make_mesh((2, 4), ("pod", "model"))
mesh_b = jax.make_mesh((4, 2), ("pod", "model"))
from repro.sharding import DEFAULT_RULES
with use_mesh(mesh_a, dict(DEFAULT_RULES)):
    tbl = atomics.make_table(M, jnp.int32, fill=0)
tbl = tbl.with_data(place(jnp.asarray(rng.integers(-9, 9, M), jnp.int32),
                          mesh_a, "model"))
d = tempfile.mkdtemp()
ckpt.save(d, 3, {"w": jnp.arange(8.0), "counters": tbl})
man = json.load(open(os.path.join(d, "step-00000003", "manifest.json")))
(meta,) = man["atomic_tables"].values()   # exactly one table in the tree
out["ckpt/meta_layout"] = (meta["axis"] == ["model"]
                           and meta["mesh_axes"] == [["pod", 2],
                                                     ["model", 4]])
like = {"w": jnp.zeros((8,)),
        "counters": atomics.AtomicTable(jnp.zeros((M,), jnp.int32),
                                        axis="model")}
with use_mesh(mesh_b, dict(DEFAULT_RULES)):
    restored, _ = ckpt.restore(d, 3, like)
rt = restored["counters"]
out["ckpt/restored_bits"] = bool(np.array_equal(np.asarray(rt.data),
                                                np.asarray(tbl.data)))
out["ckpt/restored_axis"] = rt.axis == ("model",) or rt.axis == "model"
out["ckpt/restored_on_new_mesh"] = (
    rt.data.sharding.mesh.shape["pod"] == 4)

# ---------------------------------------------------------------------------
# elastic.reshard_tables migrates live state trees
# ---------------------------------------------------------------------------

mesh2, mesh4 = mesh_of(2), mesh_of(4)
live = {"step": jnp.int32(7),
        "tbl": atomics.AtomicTable(place(tab0, mesh2), axis="dev")}
moved = reshard_tables(live, mesh4)
out["elastic/tables_moved"] = bool(
    int(moved["step"]) == 7
    and moved["tbl"].data.sharding.mesh.shape["dev"] == 4
    and np.array_equal(np.asarray(moved["tbl"].data), np.asarray(tab0)))

# non-divisible new extents degrade to a LOCAL handle (make_table's
# divisibility convention) instead of crashing the recovery loop
mesh3 = Mesh(np.array(devs[:3]), ("dev",))
loc = reshard.migrate(
    atomics.AtomicTable(place(jnp.arange(M, dtype=jnp.int32), mesh2),
                        axis="dev"),
    mesh3)                                  # 64 slots over 3 shards
out["elastic/non_divisible_falls_back_local"] = bool(
    loc.axis is None
    and np.array_equal(np.asarray(loc.data), np.arange(M)))

print("RESULT:" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def reshard_result():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT:")][0]
    return json.loads(line[len("RESULT:"):])


def test_grow_shrink_matches_oracle_and_replay(reshard_result):
    bad = [k for k, v in reshard_result.items()
           if (k.startswith("grow/") or k.startswith("shrink/"))
           and v is not True]
    assert not bad, f"mismatches: {bad}"


def test_grow_then_shrink_roundtrip_bit_identical(reshard_result):
    bad = [k for k, v in reshard_result.items()
           if k.startswith("roundtrip/") and v is not True]
    assert not bad, f"mismatches: {bad}"


def test_same_fleet_change_uses_exchange_path(reshard_result):
    bad = [k for k, v in reshard_result.items()
           if k.startswith("exchange/") and v is not True]
    assert not bad, f"mismatches: {bad}"


def test_checkpoint_and_elastic_integration(reshard_result):
    bad = [k for k, v in reshard_result.items()
           if (k.startswith("ckpt/") or k.startswith("elastic/"))
           and v is not True]
    assert not bad, f"mismatches: {bad}"


# ---------------------------------------------------------------------------
# in-process: layout derivations + serialization
# ---------------------------------------------------------------------------

def _lay(axis=("pod", "dev"), rep=(), m=64):
    return TableLayout(num_slots=m, dtype="int32", axis=axis,
                       replica_axes=rep,
                       mesh_axes=(("pod", 2), ("dev", 4)))


def test_layout_owner_major_derivations():
    lay = _lay()
    assert lay.n_shards == 8 and lay.m_local == 8 and lay.n_replicas == 1
    assert lay.rows_of_shard(3) == (24, 32)
    assert [lay.shard_of_device(i) for i in range(8)] == list(range(8))
    # replica layout: shard over dev, replicate over pod
    rl = _lay(axis=("dev",), rep=("pod",))
    assert rl.n_shards == 4 and rl.n_replicas == 2 and rl.m_local == 16
    assert [rl.shard_of_device(i) for i in range(8)] == [0, 1, 2, 3] * 2
    assert [rl.replica_rank_of_device(i) for i in range(8)] == [0] * 4 + [1] * 4
    # arrival order: lexicographic over replica_axes + axis (pod major)
    assert [rl.arrival_rank_of_device(i) for i in range(8)] == list(range(8))
    np.testing.assert_array_equal(rl.arrival_order(), np.arange(8))


def test_layout_jnp_helpers_match_python():
    lay = _lay()
    g = jnp.asarray([0, 7, 8, 63, 64, 70], jnp.int32)  # incl. OOR-remapped
    own = owner_shard(g, lay.m_local, lay.n_shards)
    np.testing.assert_array_equal(np.asarray(own), [0, 0, 1, 7, 7, 7])
    rows = local_row(g, own, lay.m_local, lay.num_slots)
    np.testing.assert_array_equal(np.asarray(rows), [0, 7, 0, 7, 8, 8])


def test_layout_serialization_roundtrip_and_errors():
    lay = _lay(axis=("dev",), rep=("pod",))
    assert TableLayout.from_dict(lay.to_dict()) == lay
    with pytest.raises(ValueError, match="divide"):
        TableLayout(num_slots=13, dtype="int32", axis=("dev",),
                    mesh_axes=(("dev", 4),)).m_local
    with pytest.raises(ValueError, match="not on mesh"):
        TableLayout.from_mesh(jax.make_mesh((1,), ("x",)), num_slots=8,
                              dtype=jnp.int32, axis="nope")


def test_table_handle_layout_derivation():
    tbl = atomics.AtomicTable(jnp.zeros((16,), jnp.int32))
    lay = tbl.layout()
    assert not lay.is_sharded and lay.num_slots == 16
    sharded = atomics.AtomicTable(jnp.zeros((16,), jnp.int32), axis="dev")
    with pytest.raises(ValueError, match="mesh"):
        sharded.layout()   # no mesh derivable from a plain local array


# ---------------------------------------------------------------------------
# in-process: the migration cost tier
# ---------------------------------------------------------------------------

def test_select_migration_prefers_exchange_when_feasible():
    from repro.atomics.reshard import (cost_migrate_device_put,
                                       cost_migrate_exchange,
                                       select_migration)
    from repro.core import perf_model
    spec = perf_model.cpu_default_spec()
    src, dst = _lay(), _lay(axis=("dev",), rep=("pod",))
    assert select_migration(src, dst, exchange_feasible=True,
                           spec=spec) == "exchange"
    assert select_migration(src, dst, exchange_feasible=False,
                           spec=spec) == "device_put"
    assert cost_migrate_exchange(spec, src, dst) \
        < cost_migrate_device_put(spec, src, dst)


def test_migration_model_beats_replay_at_64k_slots():
    """The model-level mirror of the benchmark acceptance: moving a >=64k
    table once is cheaper than replaying even a modest op history."""
    from repro.atomics.reshard import cost_migrate_device_put, cost_replay
    from repro.core import perf_model
    spec = perf_model.cpu_default_spec()
    n_batches, n_per_dev, n_dev = 4, 4096, 4   # the benchmark's history
    for m in (1 << 16, 1 << 18):
        lay = TableLayout(num_slots=m, dtype="int32", axis=("dev",),
                          mesh_axes=(("dev", 4),))
        mig = cost_migrate_device_put(spec, lay, lay)
        rep = cost_replay(spec, lay,
                          n_ops_total=n_batches * n_per_dev * n_dev,
                          n_batches=n_batches)
        assert mig < rep * 0.5, (m, mig, rep)  # clear win, not a tie


def test_plan_reshard_validation():
    from repro.atomics.reshard import plan_reshard
    src, dst = _lay(), _lay(m=128)
    with pytest.raises(ValueError, match="slot-count"):
        plan_reshard(src, dst, dst_mesh=None)
    with pytest.raises(ValueError, match="unknown path"):
        plan_reshard(src, _lay(axis=("dev",)), dst_mesh=None, path="teleport")
    with pytest.raises(ValueError, match="same device set"):
        plan_reshard(src, _lay(axis=("dev",)), dst_mesh=None, live=False,
                     path="exchange")


def test_reverse_ranks_rejected_on_local_tier():
    t = jnp.zeros((8,), jnp.int32)
    i = jnp.asarray([1, 2], jnp.int32)
    with pytest.raises(ValueError, match="reverse the batch"):
        atomics.execute(t, atomics.Faa(i, i), reverse_ranks=True)


# ---------------------------------------------------------------------------
# in-process: restore_table + local checkpoint round trip + recovery hook
# ---------------------------------------------------------------------------

def test_restore_table_meshless_falls_back_local():
    from repro.atomics.reshard import restore_table
    host = np.arange(8, dtype=np.int32)
    like = atomics.AtomicTable(jnp.zeros((8,), jnp.int32), axis="model")
    tbl = restore_table(host, like=like)
    assert tbl.axis is None
    np.testing.assert_array_equal(np.asarray(tbl.data), host)
    # meta-only spelling (no like handle in the restore tree)
    tbl2 = restore_table(host, meta={"axis": ["model"]})
    assert tbl2.axis is None
    np.testing.assert_array_equal(np.asarray(tbl2.data), host)


def test_checkpoint_roundtrips_local_table(tmp_path):
    from repro.checkpoint import ckpt
    tbl = atomics.AtomicTable(jnp.arange(6, dtype=jnp.int32))
    ckpt.save(str(tmp_path), 1, {"t": tbl, "x": jnp.ones((3,))})
    like = {"t": atomics.AtomicTable(jnp.zeros((6,), jnp.int32)),
            "x": jnp.zeros((3,))}
    restored, _ = ckpt.restore(str(tmp_path), 1, like)
    assert isinstance(restored["t"], atomics.AtomicTable)
    np.testing.assert_array_equal(np.asarray(restored["t"].data),
                                  np.arange(6))


def test_checkpoint_table_restored_as_array_when_like_holds_array(tmp_path):
    """A leaf the writer stored as an AtomicTable but the caller's `like`
    holds as a plain array restores on the plain path — and sharding_fn is
    consulted for exactly the non-table leaves, keeping positional
    sharding iterators (elastic.reshard_restore) aligned."""
    from repro.checkpoint import ckpt
    tbl = atomics.AtomicTable(jnp.arange(6, dtype=jnp.int32))
    ckpt.save(str(tmp_path), 1, {"t": tbl, "x": jnp.ones((3,))})
    like = {"t": jnp.zeros((6,), jnp.int32), "x": jnp.zeros((3,))}
    consulted = []
    restored, _ = ckpt.restore(
        str(tmp_path), 1, like,
        sharding_fn=lambda key, ref: consulted.append(key))
    assert not isinstance(restored["t"], atomics.AtomicTable)
    np.testing.assert_array_equal(np.asarray(restored["t"]), np.arange(6))
    assert len(consulted) == 2      # every leaf, since none was a table


def test_run_with_recovery_invokes_reshard_hook():
    from repro.runtime.fault_tolerance import FaultConfig, run_with_recovery
    store = {2: 2}
    calls = []

    def reshard_fn(state):
        calls.append(state)
        return state

    crashes = {4: 1}

    def injector(step):
        if crashes.get(step):
            crashes[step] -= 1
            raise RuntimeError("chip lost")

    res = run_with_recovery(
        lambda s, x: x + 1, 0, 6,
        FaultConfig(max_failures=2, checkpoint_every=2),
        lambda step, s: store.__setitem__(step, s),
        lambda: (max(store), store[max(store)]) if store else None,
        failure_injector=injector, reshard_fn=reshard_fn)
    assert res.steps_done == 6 and res.failures == 1
    # hook ran on the initial resume AND on the post-failure restore
    assert len(calls) == 2


def test_run_with_recovery_reshards_scratch_restart_too():
    """No checkpoint to restore -> restart from init_state still crosses
    the mesh change, so the reshard hook must adopt it as well."""
    from repro.runtime.fault_tolerance import FaultConfig, run_with_recovery
    adopted = []
    crashes = {1: 1}

    def injector(step):
        if crashes.get(step):
            crashes[step] -= 1
            raise RuntimeError("chip lost")

    res = run_with_recovery(
        lambda s, x: x + 1, 0, 3,
        FaultConfig(max_failures=2, checkpoint_every=100),
        lambda step, s: None, lambda: None,
        failure_injector=injector,
        reshard_fn=lambda s: (adopted.append(s), s)[1])
    assert res.steps_done == 3
    assert adopted == [0]           # the scratch restart was adopted
