"""Data pipeline determinism + optimizer behaviour."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import (DataConfig, batch_kwargs_for, make_iterator,
                                 synthetic_batch)
from repro.optim.adamw import (AdamWConfig, apply_updates, global_norm,
                               init_state, schedule)


def test_batches_deterministic_in_step():
    cfg = DataConfig(seq_len=16, global_batch=4, vocab_size=100, seed=7)
    a = synthetic_batch(cfg, 5)
    b = synthetic_batch(cfg, 5)
    c = synthetic_batch(cfg, 6)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(c["tokens"]))


def test_iterator_resume_exact():
    """Restart-exactness: resuming at step k reproduces the stream."""
    cfg = DataConfig(seq_len=8, global_batch=2, vocab_size=50, seed=1)
    it = make_iterator(cfg, start_step=0)
    stream = [next(it)["tokens"] for _ in range(6)]
    it2 = make_iterator(cfg, start_step=3)
    for k in range(3, 6):
        np.testing.assert_array_equal(next(it2)["tokens"], stream[k])


def test_labels_are_shifted_tokens():
    cfg = DataConfig(seq_len=8, global_batch=2, vocab_size=50)
    b = synthetic_batch(cfg, 0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (np.asarray(b["labels"][:, -1]) == -100).all()


def test_batch_kwargs_match_model_contract():
    from repro.configs import get_reduced
    kw = batch_kwargs_for(get_reduced("whisper_small"))
    assert kw["with_frames"] > 0
    kw = batch_kwargs_for(get_reduced("qwen2_vl_2b"))
    assert kw["with_embeds"] and kw["with_positions3"]


# ---------------------------------------------------------------- optimizer

def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=1, total_steps=200,
                      weight_decay=0.0, grad_clip=0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = init_state(params, cfg)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = apply_updates(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, warmup_steps=0, total_steps=10)
    params = {"w": jnp.zeros(3)}
    state = init_state(params, cfg)
    huge = {"w": jnp.full((3,), 1e6)}
    _, _, metrics = apply_updates(params, huge, state, cfg)
    assert float(metrics["grad_norm"]) > 1e5  # raw norm reported


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(schedule(cfg, jnp.int32(0))) == 0.0
    assert float(schedule(cfg, jnp.int32(10))) == 1.0
    end = float(schedule(cfg, jnp.int32(100)))
    assert abs(end - 0.1) < 1e-5
    assert float(schedule(cfg, jnp.int32(55))) < 1.0


def test_moment_dtype_bf16():
    cfg = AdamWConfig(moment_dtype="bfloat16")
    state = init_state({"w": jnp.zeros((4,), jnp.bfloat16)}, cfg)
    assert state["m"]["w"].dtype == jnp.bfloat16
    assert state["master"]["w"].dtype == jnp.float32


def test_master_weights_carry_precision():
    """bf16 params + fp32 master: tiny updates must not be lost to bf16."""
    cfg = AdamWConfig(lr=1e-5, warmup_steps=0, total_steps=1000,
                      weight_decay=0.0, grad_clip=0)
    params = {"w": jnp.ones((1,), jnp.bfloat16) * 256}
    state = init_state(params, cfg)
    for _ in range(20):
        params, state, _ = apply_updates(params, {"w": jnp.ones(
            (1,), jnp.bfloat16)}, state, cfg)
    # master moved even though bf16 value may quantize
    assert float(state["master"]["w"][0]) < 256.0


def test_global_norm():
    assert float(global_norm({"a": jnp.asarray([3.0]),
                              "b": jnp.asarray([4.0])})) == 5.0
