"""HardwareSpec calibration persistence — fit, write, reload.

The engine's backend selection (core/rmw_engine.py) and the distributed
exchange selection (core/rmw_sharded.py) read constants from
`perf_model.HardwareSpec`.  The paper calibrates its Table 2/3 from the
latency suite; this entry extends that to the engine constants and
**persists** the result so every later process starts from measured numbers:

  1. tier latencies + execute costs + residuals — the paper's §5 procedure
     via `perf_model.calibrate` over the latency suite medians,
  2. `gather_elem_s`   — from the one-hot backend's table-only scatter pass
     (t / (n + m) over a small grid),
  3. `loop_step_s`     — from the slope of the blocked one-hot backend's
     fetched-mode time over the block count (two batch sizes),
  4. `sort_elem_pass_s`— from the argsort backend's fetched-mode time after
     subtracting the fitted scan + gather terms.

Writes ``benchmarks/results/calibrated_spec.json`` (or $REPRO_CALIBRATED_SPEC)
in the `perf_model.spec_to_dict` schema; `rmw_engine.default_spec()` loads it
when present, so `select_backend`/`select_exchange` decisions track this
container instead of the shipped priors.

Methodology (learned the hard way, see README "Measurement notes"): inputs
are passed as jit arguments so XLA cannot constant-fold the workload, the
full RmwResult is returned so nothing is DCE'd, and every cell is the median
of k reps against this container's ±50% timing noise.
"""

from __future__ import annotations

import json
import math
import os
from dataclasses import replace
from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, time_s
from benchmarks import latency as latency_bench
from benchmarks.model_validation import TIER_MAP
from repro import atomics
from repro.core import perf_model, rmw_engine
from repro.core.placement import Tier

RESULT_PATH = os.path.join(os.path.dirname(__file__), "results",
                           "calibrated_spec.json")


def _median_time(fn, *args, reps: int = 5) -> float:
    return time_s(lambda: fn(*args), reps=reps, warmup=2)


def _bench_engine(backend: str, n: int, m: int, need_fetched: bool,
                  rng) -> float:
    table = jnp.asarray(rng.normal(size=m), jnp.float32)
    idx = jnp.asarray(rng.integers(0, m, n), jnp.int32)
    vals = jnp.asarray(rng.normal(size=n), jnp.float32)

    @jax.jit
    def fn(t, i, v):
        res = atomics.execute(t, atomics.Faa(i, v), backend=backend,
                              need_fetched=need_fetched)
        if need_fetched:
            return res.table.data, res.fetched, res.success
        return res.table.data

    return _median_time(fn, table, idx, vals)


def fit_engine_constants(spec: perf_model.HardwareSpec,
                         rng) -> Dict[str, float]:
    """Fit gather/loop-step/sort-pass from the backend suites themselves."""
    # gather_elem_s: the table-only scatter pass is (n + m) gathers by model
    samples = []
    for n, m in ((16384, 4096), (65536, 4096), (65536, 65536)):
        t = _bench_engine("onehot", n, m, need_fetched=False, rng=rng)
        samples.append(t / (n + m))
    gather = float(np.median(samples))

    # loop_step_s: fetched-mode time grows ~linearly in the block count
    b = rmw_engine.DEFAULT_ONEHOT_BLOCK
    n1, n2, m = 4096, 32768, 4096
    t1 = _bench_engine("onehot", n1, m, need_fetched=True, rng=rng)
    t2 = _bench_engine("onehot", n2, m, need_fetched=True, rng=rng)
    blocks1, blocks2 = n1 // b, n2 // b
    mac = 2.0 * b * b / max(spec.peak_flops, 1.0)
    per_block = (t2 - t1) / max(1, blocks2 - blocks1)
    loop_step = max(1e-8, per_block - mac)  # carry bundled into the step

    # sort_elem_pass_s: subtract the fitted scan+gather terms from the
    # argsort backend and attribute the rest to log2(n) sort passes
    n, m = 16384, 4096
    t_sort = _bench_engine("sort", n, m, need_fetched=True, rng=rng)
    passes = max(1.0, math.log2(n))
    scan = passes / max(spec.combine_ops_per_s, 1.0)
    resid = t_sort - n * scan - 4 * n * gather
    sort_pass = max(1e-10, resid / (n * passes))
    return {"gather_elem_s": gather, "loop_step_s": loop_step,
            "sort_elem_pass_s": sort_pass}


def run(csv: Csv, fast: bool = False, out_path: str | None = None) -> Dict:
    # write where the loader will look: $REPRO_CALIBRATED_SPEC when set
    # (rmw_engine.calibrated_spec_path prefers it), else the committed path
    if out_path is None:
        out_path = os.environ.get("REPRO_CALIBRATED_SPEC", RESULT_PATH)
    rng = np.random.default_rng(23)
    # 1. the paper's Table 2/3 calibration from the latency suite
    measured = latency_bench.run(csv, n_ops=512 if fast else 2048)
    read_samples = {TIER_MAP[t]: [vals["read"] * 1e-9]
                    for t, vals in measured.items()}
    rmw_samples = {(op, TIER_MAP[t]): [vals[op] * 1e-9]
                   for t, vals in measured.items()
                   for op in ("cas", "faa", "swp")}
    spec = perf_model.calibrate(perf_model.cpu_default_spec(), read_samples,
                                rmw_samples)
    # 2-4. engine constants
    fitted = fit_engine_constants(spec, rng)
    spec = replace(spec, **fitted)

    # never persist constants that invert the PR-1 acceptance regime (the
    # selector must keep preferring the sort-free backend where the committed
    # shoot-out shows it winning) — that would mean the fit, not the machine,
    # is off; keep the priors for the offending constants instead.
    ok = all(rmw_engine.select_backend("faa", n, m, spec) == "onehot"
             for n in (4096, 16384, 65536) for m in (256, 4096, 65536))
    if not ok:
        base = perf_model.cpu_default_spec()
        spec = replace(spec, sort_elem_pass_s=base.sort_elem_pass_s,
                       gather_elem_s=base.gather_elem_s,
                       loop_step_s=base.loop_step_s)

    payload = {
        "jax_backend": jax.default_backend(),
        "selector_acceptance_preserved": bool(ok),
        "fitted_engine_constants": fitted,
        "spec": perf_model.spec_to_dict(spec),
    }
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)
    for k, v in fitted.items():
        csv.add(f"calibrate.{k}", v * 1e6, "fitted engine constant")
    csv.add("calibrate.spec", 0.0,
            f"acceptance_preserved={ok} json={out_path}")

    # reload sanity: the persisted file must round-trip through the loader
    prev = os.environ.get("REPRO_CALIBRATED_SPEC")
    rmw_engine._reset_spec_cache()
    os.environ["REPRO_CALIBRATED_SPEC"] = out_path
    try:
        loaded = rmw_engine.default_spec()
        assert loaded.gather_elem_s == spec.gather_elem_s
    finally:
        if prev is None:
            os.environ.pop("REPRO_CALIBRATED_SPEC", None)
        else:
            os.environ["REPRO_CALIBRATED_SPEC"] = prev
        rmw_engine._reset_spec_cache()
    return payload


if __name__ == "__main__":
    c = Csv()
    c.header()
    run(c)
