"""Sharded-RMW shoot-out: naive vs one-shot vs hierarchical combining.

The distributed analogue of benchmarks/rmw_backends.py: 8 fake host devices
(subprocess, XLA_FLAGS=--xla_force_host_platform_device_count=8, same
pattern as tests/test_distributed.py) arranged as a (2 pods x 4 devices)
mesh run the same RMW workload through every exchange strategy of
`core/rmw_sharded.py`:

  naive         per-op exchange, no pre-combining — the paper's measured
                serialized/ping-pong regime (§5.4): every contended op
                crosses the mesh individually.
  oneshot       local pre-combine + one all_to_all over the flat mesh.
  hierarchical  per-pod pre-combine (ICI), deputies re-combine, cross-pod
                exchange (DCN) — the paper's §6.2 combining tree.
  dense         pure-FAA table-only psum_scatter degenerate path.

The acceptance row (ISSUE 2): on **contended hot-shard batches** the
hierarchical tree must beat the naive per-op exchange — the contention
collapse of the paper's Fig. 8 and its proposed fix, measured end to end.
The gate is evaluated at the LARGEST per-device batch of the grid: below
~32k ops/device the exchange is dominated by this oversubscribed host's
ms-scale collective dispatch (±50% between runs), so smaller hot cells are
reported but not gated.  Emits benchmarks/results/rmw_sharded.json.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict

from benchmarks.common import Csv

RESULT_PATH = os.path.join(os.path.dirname(__file__), "results",
                           "rmw_sharded.json")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro import atomics

FAST = %(fast)r
mesh = jax.make_mesh((2, 4), ("pod", "dev"))
NDEV = 8

def shard_map(fn, in_specs, out_specs):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as sm
    return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=False)

def median_time(fn, args, reps=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    out = []
    for _ in range(reps):
        t0 = time.perf_counter_ns()
        jax.block_until_ready(fn(*args))
        out.append((time.perf_counter_ns() - t0) / 1e9)
    return float(np.median(out))

rng = np.random.default_rng(42)
SPEC = P(("pod", "dev"))
rows = []

def bench(op, strategy, n_per, m, dist, need_fetched):
    m_loc = m // NDEV
    if dist == "hot":     # 95%% of ops hammer 8 slots of ONE shard
        hot = rng.integers(0, 8, (NDEV, n_per))
        uni = rng.integers(0, m, (NDEV, n_per))
        idx = np.where(rng.random((NDEV, n_per)) < 0.95, hot, uni)
    else:
        idx = rng.integers(0, m, (NDEV, n_per))
    vals = rng.normal(size=(NDEV, n_per)).astype(np.float32)
    if op == "cas":
        vals = rng.integers(-1, 2, (NDEV, n_per)).astype(np.float32)
    table = jnp.zeros((m,), jnp.float32)
    idx_j = jnp.asarray(idx, jnp.int32)
    vals_j = jnp.asarray(vals)

    def fn(t, i, v):
        tbl = atomics.AtomicTable(t, axis=("pod", "dev"))
        if op == "cas":
            aop = atomics.Cas(i[0], v[0], expected=jnp.float32(0.0))
        else:
            aop = atomics.OP_KINDS[op](i[0], v[0])
        res = atomics.execute(tbl, aop, strategy=strategy,
                              need_fetched=need_fetched)
        if need_fetched:
            return res.table.data, res.fetched[None], res.success[None]
        return res.table.data

    out_specs = (SPEC, SPEC, SPEC) if need_fetched else SPEC
    jf = jax.jit(shard_map(fn, (SPEC, SPEC, SPEC), out_specs))
    # the largest batch carries the acceptance gate: buy it extra reps
    # against this host's noisy collective dispatch
    t = median_time(jf, (table, idx_j, vals_j),
                    reps=9 if n_per == max(GRID_N) else 5)
    n_total = NDEV * n_per
    rows.append({"suite": "fetched" if need_fetched else "table_only",
                 "op": op, "strategy": strategy, "n_per_device": n_per,
                 "m": m, "dist": dist, "us_per_call": t * 1e6,
                 "ns_per_op": t / n_total * 1e9})

GRID_N = (1024,) if FAST else (8192, 32768)
M = 4096
for n_per in GRID_N:
    for dist in ("hot", "uniform"):
        for strategy in ("naive", "oneshot", "hierarchical"):
            bench("faa", strategy, n_per, M, dist, True)
for dist in ("hot", "uniform"):
    for strategy in (("oneshot", "dense") if FAST else
                     ("naive", "oneshot", "hierarchical", "dense")):
        bench("faa", strategy, GRID_N[-1], M, dist, False)
if not FAST:
    for op in ("swp", "cas"):
        for strategy in ("naive", "oneshot", "hierarchical"):
            bench(op, strategy, GRID_N[-1], M, "hot", True)
print("RESULT:" + json.dumps(rows))
"""


def run(csv: Csv, fast: bool = False, out_path: str = RESULT_PATH
        ) -> Dict[str, object]:
    if fast and out_path == RESULT_PATH:
        # never clobber the committed full-grid table with a CI smoke run
        out_path = RESULT_PATH.replace(".json", "_fast.json")
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT % {"fast": fast}], env=env,
        capture_output=True, text=True, timeout=3600,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    if proc.returncode != 0:
        raise RuntimeError(f"rmw_sharded bench failed: {proc.stderr[-2000:]}")
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][0]
    rows = json.loads(line[len("RESULT:"):])

    for r in rows:
        csv.add(f"rmw_sharded.{r['suite']}.{r['op']}.{r['strategy']}"
                f".n{r['n_per_device']}.m{r['m']}.{r['dist']}",
                r["us_per_call"], f"{r['ns_per_op']:.1f} ns/op")

    # hierarchical-vs-naive on contended cells: the acceptance gate
    by_cell: Dict[tuple, Dict[str, float]] = {}
    for r in rows:
        by_cell.setdefault(
            (r["suite"], r["op"], r["n_per_device"], r["m"], r["dist"]),
            {})[r["strategy"]] = r["us_per_call"]
    speedups = {}
    acceptance = True
    n_gate = max(r["n_per_device"] for r in rows)
    for (suite, op, n, m, dist), cells in sorted(by_cell.items()):
        if "naive" in cells and "hierarchical" in cells:
            sp = cells["naive"] / cells["hierarchical"]
            speedups[f"{suite}/{op}/n{n}/m{m}/{dist}"] = round(sp, 3)
            if dist == "hot" and n == n_gate and sp <= 1.0:
                acceptance = False

    out = {
        "host": {"jax_backend": "cpu", "devices": 8, "mesh": "2x4 pod*dev"},
        "fast": fast,
        "rows": rows,
        "hierarchical_speedup_over_naive": speedups,
        "acceptance_hierarchical_beats_naive_on_hot": acceptance,
    }
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    csv.add("rmw_sharded.acceptance", 0.0,
            f"hierarchical_beats_naive_on_hot={acceptance} json={out_path}")
    return out
