"""Unaligned/tile-spanning RMW — paper §5.7 / Fig. 10a / Fig. 14.

The paper: an atomic spanning two cache lines locks the bus (CAS up to
~750ns, vs <=20% loss for plain reads).  TPU analogue: a combine whose table
tile is off the 128-lane grid touches two tiles per op.  We measure the
Pallas combining kernel with aligned (128-multiple) vs misaligned tile sizes
and report the model's 2x-acquisition prediction (perf_model.unaligned_latency).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, time_s
from repro.core.perf_model import TPU_V5E, latency, unaligned_latency
from repro.core.placement import PlacementState, Tier
from repro.kernels.rmw.ops import rmw_apply

N_OPS = 65_536
TABLE = 16_384


def run(csv: Csv) -> Dict[str, float]:
    rng = np.random.default_rng(5)
    table = jnp.zeros((TABLE,), jnp.float32)
    idx = jnp.asarray(rng.integers(0, TABLE, N_OPS), jnp.int32)
    vals = jnp.asarray(rng.normal(size=N_OPS), jnp.float32)
    out: Dict[str, float] = {}
    for name, tile in (("aligned_512", 512), ("misaligned_384", 384),
                       ("misaligned_96", 96)):
        t = time_s(jax.jit(lambda tile=tile: rmw_apply(
            table, idx, vals, "faa", table_tile=tile, block=1024))) / N_OPS
        out[name] = t
        csv.add(f"unaligned.faa.{name}", t * 1e6, f"tile={tile}")
    st = PlacementState(tier=Tier.HBM_LOCAL)
    m_al = latency(TPU_V5E, "cas", st)
    m_un = unaligned_latency(TPU_V5E, "cas", st)
    csv.add("unaligned.model.cas", m_un * 1e6 * 1e-0,
            f"aligned={m_al*1e9:.0f}ns spanning={m_un*1e9:.0f}ns "
            f"({m_un/m_al:.1f}x; paper saw up to ~750ns)")
    return out
