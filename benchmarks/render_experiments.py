"""Render EXPERIMENTS.md tables from dry-run JSON artifacts.

    PYTHONPATH=src python -m benchmarks.render_experiments [--tag baseline]
"""

from __future__ import annotations

import argparse
import json

from benchmarks.roofline import (HBM_BW, LINK_BW, PEAK_FLOPS, load_cells,
                                 roofline_row)


def fmt_bytes(b):
    return f"{b/1e9:.2f}"


def dryrun_table(cells, mesh):
    print(f"\n### Dry-run ({mesh} mesh)\n")
    print("| arch | shape | mb | compile s | peak GB/chip | fits 16G | "
          "wire GB | dot TFLOP/chip |")
    print("|---|---|---|---|---|---|---|---|")
    for r in cells:
        if r["mesh"] != mesh:
            continue
        peak = r.get("per_device_peak_bytes", 0)
        print(f"| {r['arch']} | {r['shape']} | {r.get('microbatches','-')} "
              f"| {r.get('compile_s','-')} | {peak/1e9:.1f} "
              f"| {'Y' if peak <= 16e9 else 'N'} "
              f"| {r.get('total_wire_bytes',0)/1e9:.2f} "
              f"| {r.get('dot_flops',0)/1e12:.2f} |")


def roofline_table(cells, mesh):
    print(f"\n### Roofline ({mesh} mesh; v5e: {PEAK_FLOPS/1e12:.0f} TF/s, "
          f"{HBM_BW/1e9:.0f} GB/s HBM, {LINK_BW/1e9:.0f} GB/s/link)\n")
    print("| arch | shape | t_comp ms | t_mem ms | t_coll ms | dominant | "
          "useful | roofline frac |")
    print("|---|---|---|---|---|---|---|---|")
    for r in cells:
        if r["mesh"] != mesh:
            continue
        row = roofline_row(r)
        if not row:
            continue
        print(f"| {row['arch']} | {row['shape']} "
              f"| {row['t_compute_s']*1e3:.1f} | {row['t_memory_s']*1e3:.1f} "
              f"| {row['t_collective_s']*1e3:.1f} | {row['dominant']} "
              f"| {row['useful_compute_ratio']:.2f} "
              f"| {row['roofline_fraction']:.3f} |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    cells = load_cells(args.dir, args.tag)
    dryrun_table(cells, args.mesh)
    roofline_table(cells, args.mesh)
    if args.mesh == "single":
        dryrun_table(cells, "multi")


if __name__ == "__main__":
    main()
