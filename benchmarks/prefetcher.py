"""DMA-pipelining benchmark — paper Fig. 9 (prefetchers & friends) analogue.

The paper toggles the Hardware Prefetcher / Adjacent Cache Line Prefetcher
and measures FAA bandwidth.  The TPU analogue of "prefetching the adjacent
line" is the Pallas grid streaming the next index/value block HBM->VMEM
while the current one combines: we sweep the kernel's block size (bigger
block = deeper effective pipeline, fewer grid stalls) and the table tile
(the cache-line-role buffer) and report the measured combining bandwidth —
plus the sequential-vs-random access pattern split (the paper's stream
detector prefetcher).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, time_s
from repro.kernels.rmw.ops import rmw_apply

N_OPS = 32_768
TABLE = 8_192   # small grid: the interpret-mode kernel executes per cell


def run(csv: Csv) -> Dict[str, float]:
    rng = np.random.default_rng(9)
    table = jnp.zeros((TABLE,), jnp.float32)
    vals = jnp.asarray(rng.normal(size=N_OPS), jnp.float32)
    idx_rand = jnp.asarray(rng.integers(0, TABLE, N_OPS), jnp.int32)
    idx_seq = jnp.asarray(np.arange(N_OPS) % TABLE, jnp.int32)
    out: Dict[str, float] = {}
    for pattern, idx in (("random", idx_rand), ("sequential", idx_seq)):
        for block in (512, 2048, 8192):
            t = time_s(jax.jit(lambda i=idx, b=block: rmw_apply(
                table, i, vals, "faa", table_tile=512, block=b)),
                reps=3, warmup=1) / N_OPS
            bw = 4 / t
            out[f"{pattern}.b{block}"] = bw
            csv.add(f"prefetch.faa.{pattern}.block{block}", t * 1e6,
                    f"{bw/1e6:.1f} MB/s (deeper block = deeper DMA pipeline)")
    return out
