"""Shared benchmark machinery (paper §2.1 methodology on this host).

Phases per benchmark: preparation (allocate + warm: the jit compile also
plays the TLB-warm role), synchronization (block_until_ready), measurement
(`telemetry.span` around the blocked call — the ONE clock the production
paths and the benchmark suites share), result collection (median of k).
When the telemetry stream is enabled each rep also lands in it as a
``bench.rep`` event, so a captured benchmark run feeds the same drift
report as production traffic.
"""

from __future__ import annotations

from typing import Callable, Dict, List

import jax
import numpy as np

from repro import telemetry

WARMUP = 2
REPS = 5


def time_s(fn: Callable[[], object], reps: int = REPS,
           warmup: int = WARMUP, name: str = "bench.rep") -> float:
    """Median wall seconds of fn() (each call fully blocked)."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    out: List[float] = []
    for rep in range(reps):
        with telemetry.span(name, rep=rep) as sp:
            jax.block_until_ready(fn())
        out.append(sp.wall_s)
    return float(np.median(out))


class Csv:
    """Collects `name,us_per_call,derived` rows (benchmarks/run.py format)."""

    def __init__(self):
        self.rows: List[Dict] = []

    def add(self, name: str, us_per_call: float, derived: str = "") -> None:
        self.rows.append({"name": name, "us_per_call": us_per_call,
                          "derived": derived})
        print(f"{name},{us_per_call:.4g},{derived}", flush=True)

    def header(self) -> None:
        print("name,us_per_call,derived", flush=True)
