"""Shared benchmark machinery (paper §2.1 methodology on this host).

Phases per benchmark: preparation (allocate + warm: the jit compile also
plays the TLB-warm role), synchronization (block_until_ready), measurement
(perf_counter_ns around the blocked call), result collection (median of k).
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax
import numpy as np

WARMUP = 2
REPS = 5


def time_s(fn: Callable[[], object], reps: int = REPS,
           warmup: int = WARMUP) -> float:
    """Median wall seconds of fn() (each call fully blocked)."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    out: List[float] = []
    for _ in range(reps):
        t0 = time.perf_counter_ns()
        jax.block_until_ready(fn())
        out.append((time.perf_counter_ns() - t0) / 1e9)
    return float(np.median(out))


class Csv:
    """Collects `name,us_per_call,derived` rows (benchmarks/run.py format)."""

    def __init__(self):
        self.rows: List[Dict] = []

    def add(self, name: str, us_per_call: float, derived: str = "") -> None:
        self.rows.append({"name": name, "us_per_call": us_per_call,
                          "derived": derived})
        print(f"{name},{us_per_call:.4g},{derived}", flush=True)

    def header(self) -> None:
        print("name,us_per_call,derived", flush=True)
