"""Contention benchmark — paper Fig. 8a-c (n writers -> one cache line).

The host analogue of thread count is *collision density*: a batch whose
indices all target one table slot (fully contended) versus spread uniformly
(uncontended).  Serialized execution collapses under contention exactly like
the paper's hardware; the combining mode (reduction tree) absorbs it — the
§6.2 fix, and the mechanism the MoE dispatch planner prices.
"""

from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, time_s
from repro.core import contention as cmodel
from repro.core.perf_model import TPU_V5E
from repro.core.rmw import rmw_combining, rmw_serialized

TABLE = 65_536
N_OPS = 262_144
WRITERS = (1, 2, 4, 8, 16, 61)


def run(csv: Csv) -> Dict[str, List]:
    rng = np.random.default_rng(2)
    table = jnp.zeros((TABLE,), jnp.float32)
    vals = jnp.asarray(rng.normal(size=N_OPS), jnp.float32)
    out = {"writers": list(WRITERS), "combining_Bps": [],
           "modeled_serialized_Bps": [], "modeled_combining_Bps": []}
    for w in WRITERS:
        # w writers hammering one slot each within a w-slot window — the
        # collision density of w contending threads
        idx = jnp.asarray(rng.integers(0, w, N_OPS), jnp.int32)
        t = time_s(jax.jit(lambda t=table, i=idx:
                           rmw_combining(t, i, vals, "faa").table)) / N_OPS
        bw = 4 / t
        out["combining_Bps"].append(bw)
        m_ser = cmodel.contended_bandwidth_serialized(TPU_V5E, "faa", w)
        m_comb = cmodel.contended_bandwidth_combining(TPU_V5E, "faa", w)
        out["modeled_serialized_Bps"].append(m_ser)
        out["modeled_combining_Bps"].append(m_comb)
        csv.add(f"contention.faa.w{w}", t * 1e6,
                f"measured={bw/1e6:.1f}MB/s modelTPU ser={m_ser/1e6:.1f} "
                f"comb={m_comb/1e6:.1f}MB/s")

    # serialized contended (small batch — it is slow by construction)
    idx1 = jnp.zeros((2048,), jnp.int32)
    t = time_s(jax.jit(lambda t=table: rmw_serialized(
        t, idx1, vals[:2048], "faa").table)) / 2048
    csv.add("contention.faa.serialized_hot", t * 1e6,
            f"{4/t/1e6:.2f} MB/s (paper regime)")
    return out
