"""RMW backend shoot-out: sort vs sort-free across batch/table sizes.

Measures every registered engine backend (core/rmw_engine.py) on the same
workload and emits a BENCH JSON (benchmarks/results/rmw_backends.json) so the
speedup is tracked across PRs and the cost-model constants in
`perf_model.HardwareSpec` can be (re)tuned against real numbers.

Two suites:

  fetched     full RmwResult contract (table + per-op fetched + success) —
              the MoE-dispatch / BFS-swp workload.  This is the acceptance
              table: the sort-free ``onehot`` backend must beat the argsort
              ``sort`` backend for FAA batches >= 4k against tables <= 64k.
  table_only  need_fetched=False — the grad-scatter / histogram / BFS-CAS
              workload, where ``onehot`` degenerates to one bincount-style
              scatter pass.  The sort backend has no table-only mode, but
              because this harness returns only ``.table`` here, XLA DCEs
              its unconsumed fetched machinery too — so these cells compare
              genuine table-only costs on both sides (near parity on a
              scalar host; the engine's fast path makes the skip explicit
              rather than DCE-dependent).

Plus the MoE hot-path microbench: argsort `arrival_rank` vs the engine's
sort-free one-hot FAA fetch.

Methodology: inputs are passed as jit arguments (never closed-over
constants — XLA constant-folds those and the numbers turn into memcpy
measurements), and the full result is returned so nothing is DCE'd.
"""

from __future__ import annotations

import json
import os
from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, time_s
from repro import atomics
from repro.core import rmw_engine

RESULT_PATH = os.path.join(os.path.dirname(__file__), "results",
                           "rmw_backends.json")

#: acceptance regime (ISSUE 1): FAA batches >= 4k against tables <= 64k slots
GRID_N = (4096, 16384, 65536)
GRID_M = (256, 4096, 65536)
GRID_N_FAST = (4096,)
GRID_M_FAST = (256, 4096)

#: serialized oracle is O(n) scan steps — keep it to the smallest batch
SERIALIZED_MAX_N = 4096


def _inputs(rng, n: int, m: int):
    table = jnp.asarray(rng.normal(size=m), jnp.float32)
    idx = jnp.asarray(rng.integers(0, m, n), jnp.int32)
    vals = jnp.asarray(rng.normal(size=n), jnp.float32)
    return table, idx, vals


def _bench_backend(backend: str, op: str, table, idx, vals,
                   need_fetched: bool) -> float:
    @partial(jax.jit, static_argnames=())
    def fn(t, i, v):
        res = atomics.execute(t, atomics.OP_KINDS[op](i, v), backend=backend,
                              need_fetched=need_fetched)
        if need_fetched:
            return res.table.data, res.fetched, res.success
        return res.table.data

    # this container's timings swing +/-50% between runs; 5 reps + median
    # (time_s) keeps single outliers out of the committed table
    return time_s(lambda: fn(table, idx, vals), reps=5, warmup=2)


def run(csv: Csv, fast: bool = False, out_path: str = RESULT_PATH
        ) -> Dict[str, object]:
    if fast and out_path == RESULT_PATH:
        # never clobber the committed full-grid table with a CI smoke run
        out_path = RESULT_PATH.replace(".json", "_fast.json")
    rng = np.random.default_rng(42)
    grid_n = GRID_N_FAST if fast else GRID_N
    grid_m = GRID_M_FAST if fast else GRID_M
    rows = []

    def record(suite, op, n, m, backend, t):
        rows.append({"suite": suite, "op": op, "n": n, "m": m,
                     "backend": backend, "us_per_call": t * 1e6,
                     "ns_per_op": t / n * 1e9})
        csv.add(f"rmw_backends.{suite}.{op}.{backend}.n{n}.m{m}",
                t * 1e6, f"{t / n * 1e9:.1f} ns/op")

    # -- fetched suite: the acceptance table ------------------------------
    for n in grid_n:
        for m in grid_m:
            table, idx, vals = _inputs(rng, n, m)
            for backend in ("sort", "onehot"):
                t = _bench_backend(backend, "faa", table, idx, vals, True)
                record("fetched", "faa", n, m, backend, t)
            if n <= SERIALIZED_MAX_N:
                t = _bench_backend("serialized", "faa", table, idx, vals,
                                   True)
                record("fetched", "faa", n, m, backend="serialized", t=t)

    # one non-FAA sample per suite keeps min/swp honest without 3x runtime
    n_s, m_s = grid_n[0], grid_m[-1]
    table, idx, vals = _inputs(rng, n_s, m_s)
    for op in ("min", "swp"):
        for backend in ("sort", "onehot"):
            t = _bench_backend(backend, op, table, idx, vals, True)
            record("fetched", op, n_s, m_s, backend, t)

    # -- table_only suite -------------------------------------------------
    for n in grid_n:
        for m in grid_m:
            table, idx, vals = _inputs(rng, n, m)
            for backend in ("sort", "onehot"):
                t = _bench_backend(backend, "faa", table, idx, vals, False)
                record("table_only", "faa", n, m, backend, t)

    # -- MoE hot path: arrival_rank argsort vs sort-free ------------------
    # (one canonical function now: num_keys=None is the argsort fallback,
    # num_keys=<static> the sort-free one-hot path)
    n_tok, n_exp = (8192, 64)
    keys = jnp.asarray(rng.integers(0, n_exp, n_tok), jnp.int32)
    rank_argsort = jax.jit(atomics.arrival_rank)
    t_sortrank = time_s(lambda: rank_argsort(keys), reps=3, warmup=2)
    rank_sf = jax.jit(partial(atomics.arrival_rank, num_keys=n_exp))
    t_sfrank = time_s(lambda: rank_sf(keys), reps=3, warmup=2)
    csv.add("rmw_backends.arrival_rank.argsort", t_sortrank * 1e6,
            f"{t_sortrank / n_tok * 1e9:.1f} ns/key")
    csv.add("rmw_backends.arrival_rank.sortfree", t_sfrank * 1e6,
            f"{t_sfrank / n_tok * 1e9:.1f} ns/key "
            f"speedup={t_sortrank / t_sfrank:.2f}x")

    # -- summarize: onehot-vs-sort speedups + acceptance gate -------------
    speedups: Dict[str, float] = {}
    by_cell: Dict[tuple, Dict[str, float]] = {}
    for r in rows:
        by_cell.setdefault((r["suite"], r["op"], r["n"], r["m"]), {})[
            r["backend"]] = r["us_per_call"]
    acceptance = True
    for (suite, op, n, m), cells in sorted(by_cell.items()):
        if "sort" in cells and "onehot" in cells:
            sp = cells["sort"] / cells["onehot"]
            speedups[f"{suite}/{op}/n{n}/m{m}"] = round(sp, 3)
            if suite == "fetched" and op == "faa" and n >= 4096 \
                    and m <= 65536 and sp <= 1.0:
                acceptance = False

    out = {
        "host": {"jax_backend": jax.default_backend(),
                 "spec": rmw_engine.default_spec().name},
        "onehot_block": rmw_engine.DEFAULT_ONEHOT_BLOCK,
        "fast": fast,
        "rows": rows,
        "onehot_speedup_over_sort": speedups,
        "arrival_rank": {
            "n_tokens": n_tok, "n_experts": n_exp,
            "argsort_us": t_sortrank * 1e6,
            "sortfree_us": t_sfrank * 1e6,
            "speedup": round(t_sortrank / t_sfrank, 3),
        },
        "acceptance_onehot_beats_sort_faa_n>=4k_m<=64k": acceptance,
    }
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    csv.add("rmw_backends.acceptance", 0.0,
            f"onehot_beats_sort={acceptance} json={out_path}")
    return out
