"""Roofline analysis from the dry-run artifacts (§Roofline deliverable).

Per (arch x shape x mesh) cell:
  compute term    = dot_flops / peak_FLOP/s          (per chip; HLO-expanded)
  memory term     = hbm_traffic / HBM_bw             (2x result-bytes proxy)
  collective term = wire_bytes / link_bw
Dominant term = the bottleneck; plus MODEL_FLOPS / HLO_FLOPS (useful-compute
ratio) and the roofline fraction = model-flops-time / dominant-term-time.

Hardware constants (v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def load_cells(dryrun_dir: str = "experiments/dryrun",
               tag: str = "baseline") -> List[Dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir,
                                              f"*.{tag}.json"))):
        r = json.load(open(path))
        if r.get("status") == "ok":
            cells.append(r)
    return cells


def roofline_row(rec: Dict) -> Optional[Dict]:
    if "dot_flops" not in rec:
        return None
    chips = rec["chips"]
    flops = rec["dot_flops"]                      # per chip, loop-expanded
    # HBM traffic proxy: bytes touched by matmuls (lhs+rhs+out, expanded) —
    # fused elementwise rides along with these; `result_bytes` (recorded)
    # is the nothing-fused upper bound
    hbm = rec.get("dot_bytes", 0) or 2.0 * rec.get("result_bytes", 0)
    wire = rec.get("total_wire_bytes", 0.0)
    t_compute = flops / PEAK_FLOPS
    t_memory = hbm / HBM_BW
    t_coll = wire / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    model_flops = rec.get("model_flops_global", 0.0) / chips
    useful = model_flops / flops if flops else 0.0
    t_model = model_flops / PEAK_FLOPS
    frac = t_model / max(terms[dominant], 1e-30)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops_per_chip": model_flops,
        "hlo_flops_per_chip": flops,
        "useful_compute_ratio": useful,
        "roofline_fraction": frac,
        "peak_gb": rec.get("per_device_peak_bytes", 0) / 1e9,
        "fits_16g": rec.get("per_device_peak_bytes", 1 << 62) <= 16e9,
    }


def table(dryrun_dir: str = "experiments/dryrun", tag: str = "baseline",
          mesh: str = "single") -> List[Dict]:
    rows = []
    for rec in load_cells(dryrun_dir, tag):
        if rec["mesh"] != mesh:
            continue
        row = roofline_row(rec)
        if row:
            rows.append(row)
    return rows


def run(csv, dryrun_dir: str = "experiments/dryrun") -> List[Dict]:
    rows = table(dryrun_dir)
    for r in rows:
        dom_t = r[f"t_{r['dominant']}_s"]
        csv.add(f"roofline.{r['arch']}.{r['shape']}", dom_t * 1e6,
                f"dom={r['dominant']} frac={r['roofline_fraction']:.3f} "
                f"useful={r['useful_compute_ratio']:.2f} "
                f"peak={r['peak_gb']:.1f}GB")
    return rows
