"""Telemetry drift benchmark: the observability layer measuring itself.

Two deliverables, emitted to benchmarks/results/telemetry_drift.json
(--fast writes the *_fast.json variant):

  drift ratios       real instrumented traffic through all three selector
                     tiers — local engine (eager `atomics.execute` under
                     ``sync=True``: serialized / sort / onehot backends),
                     sharded exchange (one-round `execute_until` FAA on the
                     8-fake-device mesh, subprocess), and migration (both
                     reshard paths on the same mesh) — folded by
                     `telemetry.drift.aggregate` into per-(tier, choice,
                     op, size-bucket) measured/predicted ratios and the
                     `fit_spec_update` HardwareSpec proposal.
  overhead gate      eager-execute wall time with the stream enabled
                     (RingBuffer sink, no sync) vs disabled, < 5% at the
                     representative batch (n=4096, the drift capture's
                     largest) AND at jit steady-state (cached executions
                     run no instrumentation at all).  An eager size sweep
                     is reported alongside: below ~1k ops the jax CPU
                     dispatch floor (~70us) dominates and the instrument's
                     fixed ~2-5us Python cost reads as an inflated
                     percentage no production batch pays.

The drift ratios on this container are expected to be large for the local
tier (the engine constants price TPU-tier work; eager CPU dispatch costs
Python) — the point of the table is that the *loop is closed*: the numbers
are per-tier, reproducible, and `fit_spec_update` turns them into spec
corrections.  The overhead gate, by contrast, is a hard acceptance bound.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv
from repro import atomics, telemetry
from repro.telemetry import drift as drift_lib

RESULT_PATH = os.path.join(os.path.dirname(__file__), "results",
                           "telemetry_drift.json")

#: ISSUE 7 acceptance: enabled-stream overhead on eager execute
OVERHEAD_GATE = 0.05

_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import atomics, telemetry

FAST = %(fast)r
OUT = %(out)r
mesh = jax.make_mesh((2, 4), ("pod", "dev"))
m = 4096

def table():
    return atomics.AtomicTable(
        jax.device_put(jnp.zeros((m,), jnp.int32),
                       NamedSharding(mesh, P(("pod", "dev")))),
        axis=("pod", "dev"))

def faa_ops(n):
    rng = np.random.default_rng(n)
    def make_ops(slots, observed):
        if slots is None:
            return atomics.Faa(
                jnp.asarray(rng.integers(0, m, (n,)), jnp.int32),
                jnp.ones((n,), jnp.int32))
        return None
    return make_ops

sizes = (64, 512) if FAST else (64, 512, 4096)
for n in sizes:                      # warm the per-shape round compiles
    atomics.execute_until(table(), faa_ops(n), max_rounds=1)

telemetry.enable(telemetry.JsonlWriter(OUT), sync=True)
reps = 3 if FAST else 5
for n in sizes:
    for _ in range(reps):
        # FAA resolves in one round: each call = one sharded exchange with
        # a (predicted_s, measured_s) pair from the retry combinator
        atomics.execute_until(table(), faa_ops(n), max_rounds=1)

telemetry.disable()
# migration tier: both paths, several reps each
built = table()
for _ in range(2):                   # warm both migration compiles
    atomics.reshard.migrate(built, mesh, axis=("dev",),
                            replica_axes=("pod",), path="exchange")
    atomics.reshard.migrate(built, mesh, axis=("dev",),
                            replica_axes=("pod",), path="device_put")
telemetry.enable(telemetry.JsonlWriter(OUT + ".mig"), sync=True)
for _ in range(reps):
    atomics.reshard.migrate(built, mesh, axis=("dev",),
                            replica_axes=("pod",), path="exchange")
    atomics.reshard.migrate(built, mesh, axis=("dev",),
                            replica_axes=("pod",), path="device_put")
telemetry.disable()
print("RESULT:" + json.dumps({"ok": True}))
"""


def _local_capture(path: str, fast: bool) -> None:
    """Eager instrumented traffic across the local engine's backends."""
    m = 1024
    # n=4 exercises the serialized backend (it wins tiny batches)
    sizes = (4, 64, 512) if fast else (4, 64, 512, 4096)
    rng = np.random.default_rng(0)

    def batches(n):
        dup = jnp.asarray(rng.integers(0, 8, (n,)), jnp.int32)
        spread = jnp.asarray(rng.integers(0, m, (n,)), jnp.int32)
        ones = jnp.ones((n,), jnp.int32)
        return [
            atomics.Faa(spread, ones),               # large-m: onehot/sort
            atomics.Faa(dup, ones),                  # 8 hot slots: sort
            atomics.Cas(dup, ones, expected=jnp.zeros((), jnp.int32)),
        ]

    tbl = atomics.AtomicTable(jnp.zeros((m,), jnp.int32))
    for n in sizes:                  # warm primitive compiles un-instrumented
        for op in batches(n):
            atomics.execute(tbl, op)
    telemetry.enable(telemetry.JsonlWriter(path), sync=True)
    try:
        reps = 3 if fast else 5
        for n in sizes:
            for _ in range(reps):
                for op in batches(n):
                    atomics.execute(tbl, op)
    finally:
        telemetry.disable()


def _timed_pair(call, *, batch: int, n_batches: int) -> Tuple[float, float]:
    """(enabled_s, disabled_s) per call: min of per-batch means.  Each
    batch amortizes timer overhead, the min rejects scheduler noise (the
    standard microbenchmark floor), and enabled/disabled batches
    interleave so load drift hits both equally.  Raw ``perf_counter`` on
    purpose — measuring the instrumentation with `telemetry.span` would
    put the instrument inside its own measurement."""
    for _ in range(batch):               # warm
        call()
    ring = telemetry.RingBuffer(capacity=16)
    t_on: list = []
    t_off: list = []
    try:
        for _ in range(n_batches):
            telemetry.enable(ring)
            t0 = time.perf_counter()
            for _ in range(batch):
                call()
            t_on.append((time.perf_counter() - t0) / batch)
            telemetry.disable()
            t0 = time.perf_counter()
            for _ in range(batch):
                call()
            t_off.append((time.perf_counter() - t0) / batch)
    finally:
        telemetry.disable()
    return min(t_on), min(t_off)


#: overhead gate batch: the drift capture's largest size — eager calls
#: below ~1k ops sit at the jax CPU *dispatch floor* (~70us regardless of
#: n), where the instrument's fixed ~2-5us Python cost is an inflated
#: fraction of a cost that no production batch pays
_GATE_N = 4096


def _overhead(fast: bool) -> Dict[str, object]:
    """Eager-execute wall with the stream enabled (ring, no sync) vs off.

    Gates on two points; everything else in the sweep is informational:

    * eager at ``n=_GATE_N`` — the representative instrumented-dispatch
      workload (the drift capture's largest batch);
    * jit steady-state — the production path: cached executions of a
      jitted step run **no** instrumentation at all (events are
      trace-time-only), so the overhead there must be noise-level.
    """
    m = 1024
    rng = np.random.default_rng(1)
    tbl = atomics.AtomicTable(jnp.zeros((m,), jnp.int32))
    batch = 20
    n_batches = 8 if fast else 25
    sizes = (4, 512, _GATE_N) if fast else (4, 64, 512, _GATE_N)

    sweep = {}
    for n in sizes:
        op = atomics.Faa(jnp.asarray(rng.integers(0, m, (n,)), jnp.int32),
                         jnp.ones((n,), jnp.int32))

        def call(op=op):
            return jax.block_until_ready(
                atomics.execute(tbl, op).table.data)

        on, off = _timed_pair(call, batch=batch, n_batches=n_batches)
        sweep[n] = {"disabled_us": off * 1e6, "enabled_us": on * 1e6,
                    "overhead": on / off - 1.0}

    n = _GATE_N
    op = atomics.Faa(jnp.asarray(rng.integers(0, m, (n,)), jnp.int32),
                     jnp.ones((n,), jnp.int32))
    step = jax.jit(lambda data, i, v: atomics.execute(
        atomics.AtomicTable(data), atomics.Faa(i, v)).table.data)

    def jit_call():
        return jax.block_until_ready(step(tbl.data, op.indices, op.values))

    jit_on, jit_off = _timed_pair(jit_call, batch=batch,
                                  n_batches=n_batches)

    gate = sweep[_GATE_N]
    return {"gate_n": _GATE_N,
            "disabled_us": gate["disabled_us"],
            "enabled_us": gate["enabled_us"],
            "overhead": gate["overhead"],
            "jit_disabled_us": jit_off * 1e6,
            "jit_enabled_us": jit_on * 1e6,
            "jit_overhead": jit_on / jit_off - 1.0,
            "eager_sweep": {str(k): v for k, v in sweep.items()}}


def run(csv: Csv, fast: bool = False, out_path: str = RESULT_PATH
        ) -> Dict[str, object]:
    if fast and out_path == RESULT_PATH:
        # never clobber the committed full run with a CI smoke run
        out_path = RESULT_PATH.replace(".json", "_fast.json")
    tmp = tempfile.mkdtemp(prefix="telemetry_drift_")
    local_cap = os.path.join(tmp, "local.jsonl")
    sharded_cap = os.path.join(tmp, "sharded.jsonl")

    _local_capture(local_cap, fast)

    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, "-c",
         _SHARDED_SCRIPT % {"fast": fast, "out": sharded_cap}],
        env=env, capture_output=True, text=True, timeout=1800,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    if proc.returncode != 0:
        raise RuntimeError(
            f"sharded capture subprocess failed:\n{proc.stderr[-2000:]}")

    events = telemetry.read_jsonl(local_cap)
    events += telemetry.read_jsonl(sharded_cap)
    events += telemetry.read_jsonl(sharded_cap + ".mig")
    stats = drift_lib.aggregate(events)
    rows = drift_lib.summarize(stats)
    fitted = drift_lib.fit_spec_update(stats)
    overhead = _overhead(fast)

    tiers = {r["tier"] for r in rows}
    for r in rows:
        csv.add(f"telemetry.drift.{r['tier']}.{r['choice']}."
                f"{r['op']}.{r['size_bucket']}",
                r["mean_measured_s"] * 1e6,
                f"pred={r['mean_predicted_s'] * 1e6:.3g}us "
                f"ratio={r['ratio']:.3g} n={r['n']}")
    csv.add("telemetry.overhead", overhead["enabled_us"],
            f"n={overhead['gate_n']} "
            f"disabled={overhead['disabled_us']:.0f}us "
            f"overhead={overhead['overhead'] * 100:.1f}pct "
            f"gate<{OVERHEAD_GATE * 100:.0f}pct")
    csv.add("telemetry.overhead.jit", overhead["jit_enabled_us"],
            f"disabled={overhead['jit_disabled_us']:.0f}us "
            f"overhead={overhead['jit_overhead'] * 100:.1f}pct "
            f"(cached executions: trace-time events only)")

    acceptance = (overhead["overhead"] < OVERHEAD_GATE
                  and overhead["jit_overhead"] < OVERHEAD_GATE
                  and {"local", "sharded", "migration"} <= tiers)
    out = {
        "fast": fast,
        "n_events": len(events),
        "drift": rows,
        "spec_update": fitted["fields"],
        "overhead": {**overhead, "gate": OVERHEAD_GATE},
        "tiers_covered": sorted(tiers),
        "acceptance_overhead_lt_gate_and_all_tiers": bool(acceptance),
    }
    assert acceptance, (
        f"telemetry drift acceptance failed: overhead="
        f"{overhead['overhead']:.3f} jit={overhead['jit_overhead']:.3f} "
        f"(gate {OVERHEAD_GATE}), tiers={sorted(tiers)}")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    csv.add("telemetry_drift/artifact", 0.0, os.path.relpath(out_path))
    return out
