"""Latency benchmark — paper Fig. 2/3/4/6 (and appendix Figs. 11-13).

Measures the serialized per-op latency of CAS/FAA/SWP/read against tables of
increasing size, which moves the working set down the cache hierarchy — the
host analogue of the paper's cache-proximity axis (the TPU tiers are modeled;
see model_validation.py for the calibrated-model crossover).

Methodology notes (paper §2.1/§3 adapted to a 1-core container):
  * serialized mode = dependency-chained ops (pointer-chase; no ILP),
  * difference method: per-op latency = (T(2n) - T(n)) / n, cancelling the
    per-call constant costs (jit dispatch, non-donated table copy),
  * reads use a full-buffer permutation walk (every cache line touched).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, time_s
from repro.core.rmw import rmw_serialized

#: table sizes stepping through the cache hierarchy (bytes = n * 4)
TABLE_SIZES = {
    "L1": 2_048,          # 8 KB
    "L2": 65_536,         # 256 KB
    "LLC": 1_048_576,     # 4 MB
    "DRAM": 16_777_216,   # 64 MB
}
N_OPS = 2_048


def _chase_permutation(size: int, rng) -> jnp.ndarray:
    """Single-cycle permutation => a dependency chain visiting every entry."""
    order = rng.permutation(size)
    nxt = np.empty(size, np.int32)
    nxt[order[:-1]] = order[1:]
    nxt[order[-1]] = order[0]
    return jnp.asarray(nxt)


def run(csv: Csv, n_ops: int = N_OPS) -> Dict[str, Dict[str, float]]:
    rng = np.random.default_rng(0)
    results: Dict[str, Dict[str, float]] = {}
    for tier, size in TABLE_SIZES.items():
        table = jnp.zeros((size,), jnp.int32)
        chase = _chase_permutation(size, rng)
        # ops scaled with the table so (a) the touched set spans the tier and
        # (b) the one-time table copy amortizes below the per-op signal
        n = int(min(max(n_ops, size // 16), 4 * 1024 * 1024))
        idx = jnp.asarray(rng.integers(0, size, n), jnp.int32)
        vals = jnp.asarray(rng.integers(1, 100, n), jnp.int32)
        exp = jnp.zeros((n,), jnp.int32)

        steps = int(min(size, 4 * 1024 * 1024))

        @jax.jit
        def read_walk(chase=chase, steps=steps):
            def body(_, c):
                return chase[c]
            return jax.lax.fori_loop(0, steps, body, jnp.int32(0))

        t_read = time_s(read_walk, reps=3, warmup=1) / steps

        def make_rmw_chase(op, chase=chase, steps=steps):
            # the RMW *is* the chase: the next address depends on the fetched
            # value, so ops serialize with full memory latency (paper §3.2).
            # The modify/store goes to a small sink kept in the dependency
            # chain — on a 1-core host an E/M-state line needs no
            # invalidation, so R_O = R exactly as the paper's Eq. (2); the
            # sink store carries the write-pipeline cost E(A).
            @jax.jit
            def f():
                def body(_, st):
                    sink, c = st
                    old = chase[c]
                    if op == "faa":
                        upd = old + 1
                    elif op == "swp":
                        upd = old
                    else:  # cas: compare, conditionally keep
                        upd = jnp.where(old == c, old, old ^ 0)
                    sink = sink.at[old % 8].add(upd)
                    return sink, old
                sink, c = jax.lax.fori_loop(
                    0, steps, body, (jnp.zeros((8,), jnp.int32),
                                     jnp.int32(0)))
                return c + sink[0]
            return f

        per_tier = {"read": t_read * 1e9}
        for op in ("faa", "swp", "cas"):
            t = time_s(make_rmw_chase(op), reps=3, warmup=1) / steps
            per_tier[op] = t * 1e9
            csv.add(f"latency.{op}.{tier}", t * 1e6,
                    f"table={size*4}B rmw-chase ns/op={t*1e9:.1f}")
        csv.add(f"latency.read.{tier}", t_read * 1e6,
                f"chase ns/op={t_read*1e9:.1f}")
        results[tier] = per_tier
        del idx, vals, exp, n, table
    return results
