"""Benchmark harness entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.Csv).

  latency           Fig 2/3/4/6 + Figs 11-13   (per-op latency by tier)
  bandwidth         Fig 5 / Fig 15             (ILP gap: serialized vs comb.)
  contention        Fig 8a-c                   (n writers -> one slot)
  operand_size      Fig 7                      (wide-operand CAS)
  operands_fetched  Fig 8d / §5.5              (two-operand CAS)
  unaligned         Fig 10a / Fig 14           (tile-spanning combine)
  bfs               Fig 10b / §6.1             (CAS vs SWP vs FAA TEPS)
  model_validation  Tables 2-3 + §5 NRMSE gate (calibration + validation)
  roofline          §Roofline deliverable      (from dry-run artifacts)
  rmw_backends      RMW-engine shoot-out       (sort vs sort-free backends;
                                                emits results/rmw_backends.json)
  rmw_sharded       Distributed-RMW shoot-out  (naive vs one-shot vs
                                                hierarchical combining on an
                                                8-fake-device mesh; emits
                                                results/rmw_sharded.json)
  reshard           Elastic-migration shoot-out (reshard vs full replay,
                                                exchange vs host roundtrip;
                                                emits results/reshard.json)
  calibrate         HardwareSpec persistence   (fits engine constants, writes
                                                results/calibrated_spec.json)
  fault_recovery    Recovery + bounded retry   (chaos-driven recovery latency,
                                                execute_until <= n-round gate
                                                on local and sharded tiers;
                                                emits results/
                                                fault_recovery.json)
  telemetry_drift   Cost-model drift           (instrumented traffic across
                                                all three selector tiers +
                                                <5% enabled-stream overhead
                                                gate; emits results/
                                                telemetry_drift.json)
  analysis          Static lint sweep          (repro.analysis over all
                                                registered entry points —
                                                zero device cost; fails on
                                                unsuppressed errors)
  tuning            Guarded self-tuning        (SpecController convergence,
                                                rollback latency, quarantine
                                                pair, <5% live-controller
                                                overhead gate, tuned-vs-
                                                untuned bit-identity; emits
                                                results/tuning.json)
"""

from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--fast", action="store_true",
                    help="smaller problem sizes (CI)")
    args = ap.parse_args()

    from benchmarks import (analysis_sweep, bandwidth, bfs, calibrate,
                            contention, contention_observe, fault_recovery,
                            latency, model_validation, operand_size,
                            operands_fetched, prefetcher, reshard,
                            rmw_backends, rmw_sharded, roofline,
                            telemetry_drift, tuning, unaligned)
    from benchmarks.common import Csv
    from repro import telemetry

    # REPRO_TELEMETRY=<path.jsonl|ring> captures the whole run — every
    # bench.rep span plus the instrumented production-path events — for
    # `python -m repro.telemetry.report`
    telemetry.enable_from_env()

    suite = {
        "latency": lambda c: latency.run(c, n_ops=512 if args.fast else 2048),
        "bandwidth": bandwidth.run,
        "contention": contention.run,
        "operand_size": operand_size.run,
        "operands_fetched": operands_fetched.run,
        "unaligned": unaligned.run,
        "prefetcher": prefetcher.run,
        "bfs": lambda c: bfs.run(c, scale=10 if args.fast else 12),
        "rmw_backends": lambda c: rmw_backends.run(c, fast=args.fast),
        "rmw_sharded": lambda c: rmw_sharded.run(c, fast=args.fast),
        "reshard": lambda c: reshard.run(c, fast=args.fast),
        "calibrate": lambda c: calibrate.run(c, fast=args.fast),
        "fault_recovery": lambda c: fault_recovery.run(c, fast=args.fast),
        "telemetry_drift": lambda c: telemetry_drift.run(c, fast=args.fast),
        "contention_observe":
            lambda c: contention_observe.run(c, fast=args.fast),
        "analysis": lambda c: analysis_sweep.run(c, fast=args.fast),
        "tuning": lambda c: tuning.run(c, fast=args.fast),
        "model_validation": model_validation.run,
        "roofline": roofline.run,
    }
    only = set(args.only.split(",")) if args.only else None

    csv = Csv()
    csv.header()
    failures = []
    measured_latency = None
    for name, fn in suite.items():
        if only and name not in only:
            continue
        try:
            if name == "latency":
                measured_latency = fn(csv)
            elif name == "model_validation" and measured_latency is not None:
                fn(csv, measured_latency)
            else:
                fn(csv)
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            print(f"{name},FAILED,{e!r}", flush=True)
    telemetry.disable()              # flush a REPRO_TELEMETRY capture
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
