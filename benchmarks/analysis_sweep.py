"""Static-analysis sweep as a zero-cost benchmark "suite".

Runs the `repro.analysis` lint over every registered entry point (the
same sweep as ``python -m repro.analysis.lint``) and emits one CSV row
per entry with the wall time of the trace+rules pass.  Nothing executes
on devices — the point of registering it here is that ``make smoke``
exercises the linter end-to-end on every CI run, so the sweep (and every
entry point it traces) can never silently rot.

Raises on unsuppressed error-severity findings: a red sweep fails the
harness like any other broken benchmark.
"""

from __future__ import annotations

import time

from benchmarks.common import Csv


def run(csv: Csv, fast: bool = False) -> None:
    del fast                           # the sweep is already the fast path
    from repro.analysis.findings import ERROR
    from repro.analysis.lint import sweep

    errors = []
    for entry in _entry_names():
        t0 = time.perf_counter()
        findings = [f for fs in sweep([entry]).values() for f in fs]
        wall_us = (time.perf_counter() - t0) * 1e6
        n_sup = sum(1 for f in findings if f.suppressed)
        csv.add(f"analysis/{entry}", wall_us,
                f"findings={len(findings)};suppressed={n_sup}")
        errors += [f for f in findings
                   if f.severity == ERROR and not f.suppressed]
    if errors:
        raise AssertionError(
            "analysis sweep found unsuppressed errors:\n"
            + "\n".join(f.format() for f in errors))


def _entry_names():
    from repro.analysis.entries import ENTRY_POINTS
    return list(ENTRY_POINTS)
