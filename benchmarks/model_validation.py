"""Model calibration + NRMSE validation — paper Table 2 / Table 3 / §5 gate.

Exactly the paper's procedure on this host:
 1. tier latencies R from the read benchmark medians       (Table 2, R rows)
 2. execute costs E(A) = median(L_measured - R_O)          (Table 2, E rows)
 3. residuals O per (op, tier)                             (Table 3)
 4. NRMSE between model predictions and measurements; the paper discusses
    every cell above 10% — `flagged` lists ours.
"""

from __future__ import annotations

from typing import Dict

from benchmarks.common import Csv
from benchmarks import latency as latency_bench
from repro.core.perf_model import calibrate, cpu_default_spec, latency
from repro.core.placement import PlacementState, Tier
from repro.core.validation import NRMSE_GATE, ValidationRow, validate

#: host working-set tiers -> model tiers (CPU hierarchy in the paper's roles)
TIER_MAP = {"L1": Tier.VREG, "L2": Tier.VMEM, "LLC": Tier.HBM_LOCAL,
            "DRAM": Tier.HOST}


def run(csv: Csv, measured: Dict[str, Dict[str, float]] | None = None
        ) -> Dict:
    if measured is None:
        measured = latency_bench.run(csv)

    read_samples = {TIER_MAP[t]: [vals["read"] * 1e-9]
                    for t, vals in measured.items()}
    rmw_samples = {(op, TIER_MAP[t]): [vals[op] * 1e-9]
                   for t, vals in measured.items()
                   for op in ("cas", "faa", "swp")}
    spec = calibrate(cpu_default_spec(), read_samples, rmw_samples)

    # validation uses the three-term model WITHOUT the per-cell residual O
    # (otherwise NRMSE would be zero by construction — the paper fits
    # Table 2 and *reports* Table 3 as the unexplained part)
    import dataclasses
    spec_no_o = dataclasses.replace(spec, residual_s={})
    rows = []
    for t, vals in measured.items():
        st = PlacementState(tier=TIER_MAP[t])
        for op in ("cas", "faa", "swp"):
            pred = latency(spec_no_o, op, st)
            rows.append(ValidationRow(label=f"{op}@{t}", predicted_s=pred,
                                      observed_s=vals[op] * 1e-9))
    report = validate(rows)
    csv.add("model_validation.nrmse", report["nrmse"] * 100,
            f"gate={NRMSE_GATE*100:.0f}% passes={report['passes']} "
            f"flagged={report['flagged']}")
    # Table 2 analog
    for tier in (Tier.VREG, Tier.VMEM, Tier.HBM_LOCAL, Tier.HOST):
        csv.add(f"model_validation.R.{tier.value}",
                spec.tier_latency_s[tier] * 1e6, "calibrated tier latency")
    for op in ("cas", "faa", "swp"):
        csv.add(f"model_validation.E.{op}", spec.execute_s[op] * 1e6,
                "calibrated execute cost")
    # Table 3 analog (residuals)
    for (op, tier), o in sorted(spec.residual_s.items(),
                                key=lambda kv: (kv[0][0], kv[0][1].value)):
        csv.add(f"model_validation.O.{op}.{tier.value}", o * 1e6, "residual")
    report["spec"] = spec
    return report
