"""Recovery-latency and bounded-retry benchmark (ISSUE 6 acceptance rows).

Three experiments, emitted to benchmarks/results/fault_recovery.json
(--fast writes the *_fast.json variant):

  recovery/p<rate>     wall-clock + recovery overhead of a checkpointed
                       run under a seeded chaos plan firing step faults at
                       the given probability, against a tmpdir store; each
                       cell re-validates the final state bit-equal to the
                       fault-free run (the determinism contract — recovery
                       must cost time, never correctness).
  retry/<policy>/n<n>  `atomics.execute_until` convergence on a fully-
                       contended CAS batch (n ops -> one slot, the textbook
                       CAS-increment loop): rounds, total attempts, wall
                       time.  Gate: the immediate and exponential policies
                       resolve in <= n rounds (serialized equivalence says
                       one winner per round); shrink trades extra rounds
                       for fewer attempts and is gated on attempts only.
  retry/sharded/n16    the same contended batch through the sharded tier —
                       an 8-fake-device (2,4) mesh in a subprocess (fast
                       mode: a 1-device mesh in-process) — gated on the
                       same <= n bound, closing the "local AND sharded"
                       acceptance clause.

The recovery grid uses `FaultConfig(backoff_base_s=0)` so the measured
overhead is restore+replay work, not configured sleeps (backoff pacing is
benchmarked by its pure function, not by actually sleeping)."""

from __future__ import annotations

import json
import os
import shutil
import subprocess
import sys
import tempfile
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv
from repro import telemetry

RESULT_PATH = os.path.join(os.path.dirname(__file__), "results",
                           "fault_recovery.json")

_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro import atomics
from repro.atomics import Cas, execute_until

mesh = jax.make_mesh((2, 4), ("pod", "dev"))
P = jax.sharding.PartitionSpec
n = 16

def make_table():
    data = jax.device_put(
        jnp.zeros((32,), jnp.int32),
        jax.sharding.NamedSharding(mesh, P(("pod", "dev"))))
    return atomics.AtomicTable(data, axis=("pod", "dev"))

def make_ops(slots, observed):
    if slots is None:
        return Cas(jnp.zeros((n,), jnp.int32), jnp.ones((n,), jnp.int32),
                   expected=jnp.zeros((n,), jnp.int32))
    return Cas(jnp.asarray(slots), jnp.asarray(observed) + 1,
               expected=jnp.asarray(observed))

from repro import telemetry
res = execute_until(make_table(), make_ops, max_rounds=n)  # warm compile
with telemetry.span("bench.retry.sharded", n=n) as sp:
    res = execute_until(make_table(), make_ops, max_rounds=n)
dt = sp.wall_s
out = {"n": n, "n_rounds": int(res.n_rounds),
       "pending": int(res.pending.size),
       "attempts": int(res.rounds.sum()),
       "final": int(np.asarray(res.table.data)[0]), "seconds": dt}
print("RESULT:" + json.dumps(out))
"""


def _contended_make_ops(n):
    def make_ops(slots, observed):
        from repro.atomics import Cas
        if slots is None:
            return Cas(jnp.zeros((n,), jnp.int32), jnp.ones((n,), jnp.int32),
                       expected=jnp.zeros((n,), jnp.int32))
        return Cas(jnp.asarray(slots), jnp.asarray(observed) + 1,
                   expected=jnp.asarray(observed))
    return make_ops


def _recovery_grid(csv: Csv, fast: bool) -> list:
    from repro import atomics
    from repro.checkpoint import ckpt
    from repro.runtime.chaos import FaultPlan, SiteSpec
    from repro.runtime.fault_tolerance import FaultConfig, run_with_recovery

    n_steps = 20 if fast else 40
    m = 32

    def step_fn(step, state):
        table, acc = state
        idx = jnp.asarray((np.arange(8) * (step + 3)) % m, jnp.int32)
        res = atomics.execute(table, atomics.Faa(
            idx, jnp.asarray(np.arange(8) + step, jnp.int32)))
        return res.table, acc + jnp.sum(res.fetched)

    def run_once(root, prob):
        ckpt_dir = os.path.join(root, f"p{prob}")
        like = {"table": atomics.AtomicTable(jnp.zeros((m,), jnp.int32)),
                "acc": jnp.int32(0)}

        def restore_fn():
            got = ckpt.restore_latest_valid(ckpt_dir, like)
            if got is None:
                return None
            s, tree, _ = got
            return s, (tree["table"], tree["acc"])

        plan = (FaultPlan.null() if prob == 0.0 else
                FaultPlan(7, {"step": SiteSpec(prob=prob, count=6)}))
        cfg = FaultConfig(max_failures=20, checkpoint_every=5,
                          backoff_base_s=0.0)
        with telemetry.span("bench.recovery", prob=prob) as sp:
            res = run_with_recovery(
                step_fn,
                (atomics.AtomicTable(jnp.zeros((m,), jnp.int32)),
                 jnp.int32(0)),
                n_steps, cfg,
                lambda s, st: ckpt.save(ckpt_dir, s,
                                        {"table": st[0], "acc": st[1]}),
                restore_fn, chaos=plan, sleep_fn=lambda d: None)
        dt = sp.wall_s
        final = restore_fn()
        return {"prob": prob, "seconds": dt, "failures": res.failures,
                "restored_from": res.restored_from,
                "final_step": final[0],
                "table": np.asarray(final[1][0].data).tolist(),
                "acc": int(final[1][1])}

    rows = []
    root = tempfile.mkdtemp(prefix="fault_recovery_")
    try:
        run_once(os.path.join(root, "warm"), 0.0)   # absorb jit compiles
        base = run_once(root, 0.0)
        for prob in (0.0, 0.05, 0.2):
            cell = base if prob == 0.0 else run_once(root, prob)
            bit_equal = (cell["table"] == base["table"]
                         and cell["acc"] == base["acc"]
                         and cell["final_step"] == n_steps)
            assert bit_equal, (
                f"recovery at fault rate {prob} diverged from fault-free")
            row = {"name": f"recovery/p{prob}",
                   "seconds": cell["seconds"],
                   "failures": cell["failures"],
                   "overhead_x": cell["seconds"] / base["seconds"],
                   "bit_equal": True}
            rows.append(row)
            csv.add(row["name"], cell["seconds"] / n_steps * 1e6,
                    f"failures={cell['failures']} "
                    f"overhead={row['overhead_x']:.2f}x bit_equal=True")
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return rows


def _retry_grid(csv: Csv, fast: bool) -> list:
    from repro import atomics
    from repro.atomics import execute_until

    sizes = (8, 32) if fast else (8, 32, 128)
    policies = ("immediate", "shrink", "exponential")
    rows = []
    for n in sizes:
        for pol in policies:
            budget = n if pol != "shrink" else 8 * n
            t = atomics.AtomicTable(jnp.zeros((8,), jnp.int32))
            with telemetry.span("bench.retry", policy=pol, n=n) as sp:
                res = execute_until(t, _contended_make_ops(n),
                                    max_rounds=budget, policy=pol,
                                    sleep_fn=lambda d: None)
            dt = sp.wall_s
            assert res.pending.size == 0, f"{pol}/n{n}: unresolved ops"
            assert int(np.asarray(res.table.data)[0]) == n
            if pol != "shrink":      # the <= n acceptance bound
                assert res.n_rounds <= n, \
                    f"{pol}/n{n}: {res.n_rounds} rounds > n"
            row = {"name": f"retry/{pol}/n{n}", "n": n, "policy": pol,
                   "rounds": int(res.n_rounds),
                   "attempts": int(res.rounds.sum()), "seconds": dt,
                   "le_n_rounds": bool(res.n_rounds <= n)}
            rows.append(row)
            csv.add(row["name"], dt / max(1, res.n_rounds) * 1e6,
                    f"rounds={res.n_rounds} attempts={row['attempts']} "
                    f"le_n={row['le_n_rounds']}")
    # the shrink policy must actually buy fewer attempts at the top size
    top = max(sizes)
    att = {r["policy"]: r["attempts"] for r in rows if r["n"] == top}
    assert att["shrink"] < att["immediate"], \
        "shrink-batch spent no fewer attempts than immediate retry"
    return rows


def _sharded_row(csv: Csv, fast: bool) -> Dict:
    if fast:
        from repro import atomics
        from repro.atomics import execute_until
        n = 16
        mesh = jax.make_mesh((1,), ("dev",))
        data = jax.device_put(
            jnp.zeros((32,), jnp.int32),
            jax.sharding.NamedSharding(mesh,
                                       jax.sharding.PartitionSpec("dev")))
        with telemetry.span("bench.retry.sharded", n=n) as sp:
            res = execute_until(atomics.AtomicTable(data, axis="dev"),
                                _contended_make_ops(n), max_rounds=n)
        out = {"n": n, "n_rounds": int(res.n_rounds),
               "pending": int(res.pending.size),
               "attempts": int(res.rounds.sum()),
               "final": int(np.asarray(res.table.data)[0]),
               "seconds": sp.wall_s,
               "mesh": "1-device (fast)"}
    else:
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(os.path.dirname(__file__), "..", "src")] +
            env.get("PYTHONPATH", "").split(os.pathsep))
        env["JAX_PLATFORMS"] = "cpu"
        proc = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT],
                              capture_output=True, text=True, env=env,
                              timeout=900)
        if proc.returncode != 0:
            raise RuntimeError(f"sharded retry subprocess failed:\n"
                               f"{proc.stderr[-2000:]}")
        line = [l for l in proc.stdout.splitlines()
                if l.startswith("RESULT:")][0]
        out = json.loads(line[len("RESULT:"):])
        out["mesh"] = "(2,4) 8-fake-device"
    assert out["pending"] == 0 and out["n_rounds"] <= out["n"], \
        f"sharded tier violated the <= n bound: {out}"
    assert out["final"] == out["n"]
    row = {"name": f"retry/sharded/n{out['n']}", **out}
    csv.add(row["name"], out["seconds"] / max(1, out["n_rounds"]) * 1e6,
            f"rounds={out['n_rounds']} mesh={out['mesh']} le_n=True")
    return row


def run(csv: Csv, fast: bool = False) -> None:
    results = {"fast": fast,
               "recovery": _recovery_grid(csv, fast),
               "retry": _retry_grid(csv, fast),
               "sharded": _sharded_row(csv, fast)}
    path = (RESULT_PATH.replace(".json", "_fast.json") if fast
            else RESULT_PATH)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(results, f, indent=2)
    csv.add("fault_recovery/artifact", 0.0, os.path.relpath(path))
