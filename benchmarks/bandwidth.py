"""Bandwidth benchmark — paper Fig. 5 / Fig. 15 (atomics-vs-writes ILP gap).

Two execution modes over the same independent-op stream:
  serialized — one RMW at a time (paper's measured hardware: atomics drain
               write buffers, no ILP even without data dependencies)
  combining  — vectorized segmented combine (the paper's proposed relaxed
               atomics, §6.2.3, which the TPU/JAX formulation provides)

The measured ratio is this work's reproduction of the paper's 5-30x
writes-vs-atomics gap, plus the demonstration that the proposed fix closes
it.  Also runs the plain-write (scatter) reference.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, time_s
from repro.core.rmw import rmw_combining, rmw_serialized
from repro.kernels.rmw.ops import rmw_apply

N_OPS_SER = 4_096
N_OPS_COMB = 1_048_576
TABLE = 262_144


def run(csv: Csv) -> Dict[str, float]:
    rng = np.random.default_rng(1)
    table = jnp.zeros((TABLE,), jnp.float32)
    out: Dict[str, float] = {}

    idx_s = jnp.asarray(rng.integers(0, TABLE, N_OPS_SER), jnp.int32)
    val_s = jnp.asarray(rng.normal(size=N_OPS_SER), jnp.float32)
    idx_c = jnp.asarray(rng.integers(0, TABLE, N_OPS_COMB), jnp.int32)
    val_c = jnp.asarray(rng.normal(size=N_OPS_COMB), jnp.float32)

    for op in ("faa", "swp"):
        t_ser = time_s(jax.jit(lambda t=table, op=op:
                               rmw_serialized(t, idx_s, val_s, op).table)) \
            / N_OPS_SER
        t_comb = time_s(jax.jit(lambda t=table, op=op:
                                rmw_combining(t, idx_c, val_c, op).table)) \
            / N_OPS_COMB
        bw_ser = 4 / t_ser
        bw_comb = 4 / t_comb
        out[f"{op}_serialized_Bps"] = bw_ser
        out[f"{op}_combining_Bps"] = bw_comb
        out[f"{op}_ilp_gap"] = bw_comb / bw_ser
        csv.add(f"bandwidth.{op}.serialized", t_ser * 1e6,
                f"{bw_ser/1e6:.2f} MB/s")
        csv.add(f"bandwidth.{op}.combining", t_comb * 1e6,
                f"{bw_comb/1e6:.2f} MB/s gap={bw_comb/bw_ser:.1f}x")

    # plain writes (scatter, no read-modify) — the paper's baseline
    t_wr = time_s(jax.jit(lambda t=table: t.at[idx_c].set(val_c))) / N_OPS_COMB
    out["write_Bps"] = 4 / t_wr
    csv.add("bandwidth.write", t_wr * 1e6, f"{4/t_wr/1e6:.2f} MB/s")

    # the MXU-combining kernel path (one-hot matmul formulation)
    t_k = time_s(jax.jit(lambda t=table: rmw_apply(
        t, idx_c[:65536], val_c[:65536], "faa", table_tile=8192,
        block=8192)), reps=3, warmup=1) / 65536
    out["kernel_faa_Bps"] = 4 / t_k
    csv.add("bandwidth.faa.kernel", t_k * 1e6,
            f"{4/t_k/1e6:.2f} MB/s (pallas interpret)")
    return out
