"""Operand-size benchmark — paper Fig. 7 (64- vs 128-bit CAS).

Sweeps the RMW operand width; the paper found AMD slower on wide operands
(~5-20ns) while Intel was flat.  x64 dtypes are unavailable in this jax
build's default config, so wide operands are emulated the way the paper's
cmpxchg16b works: one op touching two adjacent lanes (2x int32 / 2x float32).
Model prediction for the TPU target is flat per-lane (VREG lanes are width-
agnostic until the tile splits — see unaligned.py).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, time_s
from repro.core.perf_model import TPU_V5E, bandwidth
from repro.core.placement import PlacementState, Tier
from repro.core.rmw import rmw_serialized

N_OPS = 2_048
TABLE = 65_536


def _measure(dtype, width: int) -> float:
    rng = np.random.default_rng(3)
    table = jnp.zeros((TABLE,), dtype)
    idx0 = jnp.asarray(rng.integers(0, TABLE // width, N_OPS), jnp.int32) \
        * width
    vals = jnp.asarray(rng.integers(1, 100, N_OPS)).astype(dtype)
    exp = jnp.zeros((N_OPS,), dtype)

    def run_once(t=table):
        r = rmw_serialized(t, idx0, vals, "cas", exp)
        for w in range(1, width):       # adjacent lanes of the wide operand
            r = rmw_serialized(r.table, idx0 + w, vals, "cas", exp)
        return r.table

    return time_s(jax.jit(run_once)) / N_OPS


def run(csv: Csv) -> Dict[str, float]:
    out: Dict[str, float] = {}
    for name, dtype, width, nbytes in (
            ("int32", jnp.int32, 1, 4),
            ("float32", jnp.float32, 1, 4),
            ("int64_pair", jnp.int32, 2, 8),
            ("int128_quad", jnp.int32, 4, 16)):
        t = _measure(dtype, width)
        out[name] = t
        model_bw = bandwidth(TPU_V5E, "cas",
                             PlacementState(tier=Tier.HBM_LOCAL),
                             operand_bytes=nbytes)
        csv.add(f"operand_size.cas.{name}", t * 1e6,
                f"{nbytes}B/op modelTPU bw={model_bw/1e9:.2f}GB/s")
    return out
