"""Contention observatory benchmark: `collect_stats=` measured end to end.

Four deliverables, emitted to benchmarks/results/contention_observe.json
(--fast writes the *_fast.json variant):

  bit identity       results with ``collect_stats=True`` vs ``False`` are
                     digest-equal on the local engine tier (FAA + per-op
                     CAS) and on the 8-fake-device sharded exchange tier
                     (subprocess) — the observatory is a pure observer.
  overhead gates     (a) the stats-off path vs the flag simply absent —
                     both dispatch identical programs, so the measured
                     delta is the interleaved-timing noise floor, gated
                     < 3%; (b) the representative contended workload — a
                     64-writers-per-slot CAS loop over n=4096 ops
                     (`execute_until`, the round-0-only device pass
                     amortized over the convergence rounds), gated < 5%.
                     The *per eager call* cost of the stats pass at
                     n=4096 is reported un-gated alongside: on CPU XLA an
                     exact occupancy pass costs one scatter (~0.6ms,
                     serialized per element) against an eager dispatch of
                     ~1.7ms, an overhead no retry loop or jitted step
                     pays (the pass fuses into the caller's program).
  estimator feed     under a running `SpecController`, `execute_until`
                     defaults to feeding the contention estimator from
                     the device-side ``distinct_slots`` — site keys must
                     match the host-``np.unique`` path exactly, with the
                     device counters populated (`n_updates_device`).
  model vs measured  the paper's Fig. 8 axis on this container: a
                     writers-per-slot sweep (1 -> 512) with measured
                     eager throughput and the measured occupancy
                     spectrum next to `core.contention`'s serialized vs
                     combining bandwidth predictions for the same writer
                     count.  The combine-tier backends keep measured
                     throughput ~flat where the serialized model
                     predicts collapse — the observatory showing the
                     combining fix working.
"""

from __future__ import annotations

import hashlib
import json
import os
import subprocess
import sys
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv
from repro import atomics

RESULT_PATH = os.path.join(os.path.dirname(__file__), "results",
                           "contention_observe.json")

#: ISSUE 10 acceptance: stats-on overhead on the contended retry workload
OVERHEAD_GATE = 0.05
#: stats-off must be indistinguishable from the flag not existing
NOISE_GATE = 0.03

_GATE_N = 4096
_GATE_M = 1024
#: writers per slot in the gate workload: 64 contenders on each of 64
#: slots -> 64 convergence rounds, the contended regime of Fig. 8
_GATE_DUP = 64

_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import hashlib
import json
import time
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import atomics

FAST = %(fast)r
mesh = jax.make_mesh((2, 4), ("pod", "dev"))
m = 4096
n = 1024 if FAST else 4096

def table():
    return atomics.AtomicTable(
        jax.device_put(jnp.zeros((m,), jnp.int32),
                       NamedSharding(mesh, P(("pod", "dev")))),
        axis=("pod", "dev"))

rng = np.random.default_rng(7)
idx = rng.integers(0, m // 2, size=n).astype(np.int32)   # half the table hot

def make_ops(slots, observed):
    if slots is None:
        return atomics.Faa(jnp.asarray(idx), jnp.ones((n,), jnp.int32))
    return None

def run(collect):
    return atomics.execute_until(table(), make_ops, max_rounds=1,
                                 collect_stats=collect)

def digest(res):
    h = hashlib.sha256()
    for a in (res.table.data, res.fetched, res.success, res.rounds):
        h.update(np.ascontiguousarray(np.asarray(a)).tobytes())
    return h.hexdigest()

r_off = run(False)
r_on = run(True)
st = r_on.stats
levels_in = np.asarray(st.level_ops_in).tolist()
levels_out = np.asarray(st.level_ops_out).tolist()
reps = 3 if FAST else 5
t_on, t_off = [], []
for _ in range(reps):                       # interleaved, warm from above
    t0 = time.perf_counter(); run(True);  t_on.append(time.perf_counter() - t0)
    t0 = time.perf_counter(); run(False); t_off.append(time.perf_counter() - t0)
print("RESULT:" + json.dumps({
    "bit_identical": digest(r_off) == digest(r_on),
    "stats_off_is_none": r_off.stats is None,
    "distinct_device": int(np.asarray(st.distinct_slots)),
    "distinct_host": int(np.unique(idx).size),
    "max_occupancy": int(np.asarray(st.max_occupancy)),
    "n_ops": int(np.asarray(st.n_ops)),
    "level_ops_in": levels_in,
    "level_ops_out": levels_out,
    "levels_monotone": all(o <= i for i, o in zip(levels_in, levels_out)),
    "on_s": min(t_on), "off_s": min(t_off),
}))
"""


def _digest(res) -> str:
    h = hashlib.sha256()
    for a in (res.table.data, res.fetched, res.success):
        h.update(np.ascontiguousarray(np.asarray(a)).tobytes())
    return h.hexdigest()


def _bit_identity_local() -> Dict[str, object]:
    m = 256
    rng = np.random.default_rng(3)
    idx = jnp.asarray(rng.integers(0, m, 2048), jnp.int32)
    vals = jnp.asarray(rng.integers(-5, 6, 2048), jnp.int32)
    exp = jnp.asarray(rng.integers(-1, 2, 2048), jnp.int32)
    tbl = atomics.AtomicTable(jnp.asarray(rng.integers(-1, 2, m), jnp.int32))
    out: Dict[str, object] = {}
    for name, op in (("faa", atomics.Faa(idx, vals)),
                     ("cas_perop", atomics.Cas(idx, vals, expected=exp))):
        r_off = atomics.execute(tbl, op)
        r_on = atomics.execute(tbl, op, collect_stats=True)
        out[f"{name}_bit_identical"] = _digest(r_off) == _digest(r_on)
        st = r_on.stats
        occ = np.bincount(np.asarray(idx), minlength=m)
        out[f"{name}_distinct_exact"] = (
            int(np.asarray(st.distinct_slots)) == int((occ > 0).sum()))
        out[f"{name}_max_occ_exact"] = (
            int(np.asarray(st.max_occupancy)) == int(occ.max()))
    out["stats_off_is_none"] = atomics.execute(tbl, op).stats is None
    return out


def _min_wall(call, *, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        call()
        best = min(best, time.perf_counter() - t0)
    return best


def _retry_workload(collect) -> None:
    """The gate workload: _GATE_DUP writers per slot, full convergence."""
    idx = np.tile(np.arange(_GATE_N // _GATE_DUP, dtype=np.int32),
                  _GATE_DUP)

    def make_ops(slots, observed):
        if slots is None:
            return atomics.Cas(jnp.asarray(idx),
                               jnp.ones((_GATE_N,), jnp.int32),
                               expected=jnp.zeros((_GATE_N,), jnp.int32))
        return jnp.asarray(np.asarray(observed) + 1)

    res = atomics.execute_until(
        atomics.AtomicTable(jnp.zeros((_GATE_M,), jnp.int32)), make_ops,
        max_rounds=_GATE_DUP + 1, collect_stats=collect)
    assert res.success.all()


def _overhead(fast: bool) -> Dict[str, object]:
    reps = 3 if fast else 5
    # noise floor: collect_stats=False vs the kwarg absent — identical
    # dispatch, so the pair calibrates what "unmeasurable" means here
    m, n = _GATE_M, _GATE_N
    rng = np.random.default_rng(5)
    tbl = atomics.AtomicTable(jnp.zeros((m,), jnp.int32))
    op = atomics.Faa(jnp.asarray(rng.integers(0, m, n), jnp.int32),
                     jnp.ones((n,), jnp.int32))

    def eager(**kw):
        return jax.block_until_ready(
            atomics.execute(tbl, op, **kw).table.data)

    eager()
    eager(collect_stats=True)                       # warm both programs
    batch = 10

    def pair(call_a, call_b, attempts=3):
        """min-of-batch-means, interleaved; retried a few times so one
        scheduler hiccup cannot fail a gate (the tuning lane's pattern)."""
        best = None
        for _ in range(attempts):
            t_a, t_b = [], []
            for _ in range(reps):
                t0 = time.perf_counter()
                for _ in range(batch):
                    call_a()
                t_a.append((time.perf_counter() - t0) / batch)
                t0 = time.perf_counter()
                for _ in range(batch):
                    call_b()
                t_b.append((time.perf_counter() - t0) / batch)
            cand = (min(t_a), min(t_b))
            if best is None or cand[0] / cand[1] < best[0] / best[1]:
                best = cand
        return best

    off_s, plain_s = pair(lambda: eager(collect_stats=False), eager)
    noise = off_s / plain_s - 1.0

    on_s, base_s = pair(lambda: eager(collect_stats=True), eager)
    eager_overhead = on_s / base_s - 1.0            # informational, un-gated

    _retry_workload(True)                           # warm all round shapes
    _retry_workload(False)
    retry_on = _min_wall(lambda: _retry_workload(True), reps=reps)
    retry_off = _min_wall(lambda: _retry_workload(False), reps=reps)
    retry_overhead = retry_on / retry_off - 1.0
    if retry_overhead >= OVERHEAD_GATE or noise >= NOISE_GATE:
        # one more full attempt before declaring a regression: these are
        # sub-ms deltas on a shared container
        retry_on = min(retry_on,
                       _min_wall(lambda: _retry_workload(True), reps=reps))
        retry_off = min(retry_off,
                        _min_wall(lambda: _retry_workload(False), reps=reps))
        retry_overhead = retry_on / retry_off - 1.0
        off_s, plain_s = pair(lambda: eager(collect_stats=False), eager)
        noise = off_s / plain_s - 1.0
    return {
        "noise_floor": noise,
        "noise_gate": NOISE_GATE,
        "eager_base_us": base_s * 1e6,
        "eager_stats_us": on_s * 1e6,
        "eager_per_call_overhead_ungated": eager_overhead,
        "retry_n": _GATE_N, "retry_m": _GATE_M,
        "retry_writers_per_slot": _GATE_DUP,
        "retry_off_ms": retry_off * 1e3,
        "retry_on_ms": retry_on * 1e3,
        "retry_overhead": retry_overhead,
        "gate": OVERHEAD_GATE,
    }


def _estimator_feed() -> Dict[str, object]:
    from repro.tuning import SpecController, TuningConfig, site_key

    def loop(collect):
        idx = np.tile(np.arange(32, dtype=np.int32), 8)

        def make_ops(slots, observed):
            if slots is None:
                return atomics.Cas(jnp.asarray(idx),
                                   jnp.ones((256,), jnp.int32),
                                   expected=jnp.zeros((256,), jnp.int32))
            return jnp.asarray(np.asarray(observed) + 1)

        return atomics.execute_until(
            atomics.AtomicTable(jnp.zeros((64,), jnp.int32)), make_ops,
            max_rounds=16, collect_stats=collect)

    key = site_key("cas", "local", 64, 256)
    with SpecController(TuningConfig()) as ctrl:
        loop(False)                                 # host np.unique path
        host_sites = len(ctrl.estimator)
        host_raw = ctrl.estimator.raw(key)
        host_updates = ctrl.estimator.n_updates_host
    with SpecController(TuningConfig()) as ctrl:
        res = loop(None)                            # auto -> device stats
        device_sites = len(ctrl.estimator)
        device_raw = ctrl.estimator.raw(key)
        device_updates = ctrl.estimator.n_updates_device
    return {
        "host_sites": host_sites, "device_sites": device_sites,
        "host_raw": host_raw, "device_raw": device_raw,
        "host_updates": host_updates, "n_updates_device": device_updates,
        "stats_returned": res.stats is not None,
        "distinct_agree": host_raw == device_raw,
    }


def _model_vs_measured(fast: bool) -> Dict[str, object]:
    from repro.core import contention as cmodel
    from repro.core import rmw_engine
    spec = rmw_engine.default_spec()
    n = _GATE_N
    m = _GATE_M
    reps = 3 if fast else 5
    rows = []
    # 4 is the floor that still fits n // dup distinct slots in the table
    for dup in (4, 16, 64, 512):
        idx_np = np.tile(np.arange(n // dup, dtype=np.int32), dup) % m
        idx = jnp.asarray(idx_np)
        op = atomics.Faa(idx, jnp.ones((n,), jnp.int32))
        tbl = atomics.AtomicTable(jnp.zeros((m,), jnp.int32))

        def call(op=op, tbl=tbl):
            return jax.block_until_ready(
                atomics.execute(tbl, op).table.data)

        call()
        wall = _min_wall(call, reps=reps)
        st = atomics.execute(tbl, op, collect_stats=True).stats
        hist = np.asarray(st.occupancy_hist).tolist()
        rows.append({
            "writers_per_slot": dup,
            "measured_bytes_per_s": n * 4 / wall,
            "measured_wall_us": wall * 1e6,
            "measured_max_occupancy": int(np.asarray(st.max_occupancy)),
            "measured_distinct_slots": int(np.asarray(st.distinct_slots)),
            "occupancy_hist": hist,
            "predicted_serialized_bytes_per_s":
                cmodel.contended_bandwidth_serialized(spec, "faa", dup,
                                                      operand_bytes=4),
            "predicted_combining_bytes_per_s":
                cmodel.contended_bandwidth_combining(spec, "faa", dup,
                                                     operand_bytes=4,
                                                     batch_per_writer=dup),
        })
    flat = rows[0]["measured_bytes_per_s"] / rows[-1]["measured_bytes_per_s"]
    return {"rows": rows,
            # the combine-tier claim: throughput at 512 writers/slot stays
            # within ~4x of uncontended (the serialized model predicts a
            # collapse orders of magnitude deeper)
            "measured_collapse_factor": flat}


def run(csv: Csv, fast: bool = False, out_path: str = RESULT_PATH
        ) -> Dict[str, object]:
    if fast and out_path == RESULT_PATH:
        # never clobber the committed full run with a CI smoke run
        out_path = RESULT_PATH.replace(".json", "_fast.json")

    local_ident = _bit_identity_local()

    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, "-c", _SHARDED_SCRIPT % {"fast": fast}],
        env=env, capture_output=True, text=True, timeout=1800,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    if proc.returncode != 0:
        raise RuntimeError(
            f"sharded observe subprocess failed:\n{proc.stderr[-2000:]}")
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT:")][-1]
    sharded = json.loads(line[len("RESULT:"):])

    overhead = _overhead(fast)
    est = _estimator_feed()
    model = _model_vs_measured(fast)

    csv.add("contention_observe.noise_floor",
            overhead["noise_floor"] * 100,
            f"off-vs-absent pct, gate<{NOISE_GATE * 100:.0f}pct")
    csv.add("contention_observe.retry_overhead",
            overhead["retry_overhead"] * 100,
            f"n={_GATE_N} dup={_GATE_DUP} on={overhead['retry_on_ms']:.1f}ms "
            f"off={overhead['retry_off_ms']:.1f}ms "
            f"gate<{OVERHEAD_GATE * 100:.0f}pct")
    csv.add("contention_observe.eager_per_call",
            overhead["eager_per_call_overhead_ungated"] * 100,
            "pct, informational (fused scatter on eager CPU dispatch)")
    csv.add("contention_observe.sharded_overhead",
            (sharded["on_s"] / sharded["off_s"] - 1.0) * 100,
            f"pct, informational (8 fake devices, n_ops={sharded['n_ops']})")
    for r in model["rows"]:
        csv.add(f"contention_observe.bw.dup{r['writers_per_slot']}",
                r["measured_bytes_per_s"] / 1e6,
                f"MB/s max_occ={r['measured_max_occupancy']} "
                f"pred_ser={r['predicted_serialized_bytes_per_s'] / 1e6:.3g} "
                f"pred_comb={r['predicted_combining_bytes_per_s'] / 1e6:.3g}")

    identity_ok = (all(v for k, v in local_ident.items())
                   and sharded["bit_identical"]
                   and sharded["stats_off_is_none"]
                   and sharded["distinct_device"] == sharded["distinct_host"]
                   and sharded["levels_monotone"])
    est_ok = (est["device_sites"] >= est["host_sites"]
              and est["n_updates_device"] >= 1 and est["distinct_agree"])
    gates_ok = (overhead["retry_overhead"] < OVERHEAD_GATE
                and overhead["noise_floor"] < NOISE_GATE)
    acceptance = identity_ok and est_ok and gates_ok
    out = {
        "fast": fast,
        "bit_identity_local": local_ident,
        "sharded": sharded,
        "overhead": overhead,
        "estimator_feed": est,
        "model_vs_measured": model,
        "acceptance_bit_identical_overhead_and_device_feed":
            bool(acceptance),
    }
    assert acceptance, (
        f"contention observe acceptance failed: identity={identity_ok} "
        f"est={est_ok} retry_overhead={overhead['retry_overhead']:.3f} "
        f"noise={overhead['noise_floor']:.3f}")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    csv.add("contention_observe/artifact", 0.0, os.path.relpath(out_path))
    return out
