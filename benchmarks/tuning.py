"""Self-tuning benchmark: the guarded spec controller measuring itself.

Four deliverables, emitted to benchmarks/results/tuning.json (--fast
writes the *_fast.json variant); the first three are hard acceptance
gates, the fourth is the tentpole invariant re-proved on real traffic:

  convergence      a controller driven by closed-loop drift windows
                   against a "true" hardware spec (two constants
                   mis-calibrated 4x slow / 4x fast, first window skewed
                   by the ``spec_perturb`` chaos site) must walk every
                   tuned constant to within 25% (log-space) of truth in
                   <= 12 update windows — clamp, deadband, and the
                   perturbation included.
  rollback         after a confirmed honest apply, one regressed window
                   must reinstall the previous spec in exactly one update
                   (rollback latency = 1 window) and restore it bit-equal.
                   A NaN-poisoned window (chaos) must quarantine instead
                   of installing, and the same window without chaos must
                   apply — the firing/non-firing pair.
  overhead         a *live* controller (sink attached, sync on, step()
                   every call, real spec swaps) on eager FAA at n=4096
                   must cost < 5% wall vs the stream fully off —
                   interleaved min-of-batch-means, the telemetry_drift
                   timing convention.
  bit-identity     tuned vs untuned runs of a deterministic FAA+fetched-
                   sum workload produce bit-equal tables and accumulators
                   — in-process on the local tier, and (full runs only)
                   in subprocess on the 8-fake-device sharded tier with
                   the contention estimator live (estimator-backed
                   ``distinct_slots`` on a contended CAS loop included).

The selection-probe section is informational: it reports how often the
tuned spec and the truth spec pick the same local backend across a size
sweep (agreement can legitimately dip when a constant lands within the
convergence tolerance but on the other side of a crossover point).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import subprocess
import sys
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv
from repro import atomics, telemetry
from repro.core import rmw_engine
from repro.runtime.chaos import FaultPlan, SiteSpec
from repro.tuning import SpecController, TuningConfig

RESULT_PATH = os.path.join(os.path.dirname(__file__), "results",
                           "tuning.json")

#: ISSUE 9 acceptance: live-controller overhead on eager execute
OVERHEAD_GATE = 0.05
#: ... and convergence: |log(tuned / truth)| per field after the run
CONVERGENCE_LOG_TOL = 0.25
MAX_WINDOWS = 12

#: the deliberate mis-calibration the controller must correct: one
#: constant 4x slow (needs two clamped applies), one 4x fast
TRUTH_FACTORS = {"loop_step_s": 4.0, "gather_elem_s": 0.25}
_FIELD_GROUP = {"loop_step_s": "serialized", "gather_elem_s": "onehot"}
P0 = 1e-5


def _perturb_seed(pick) -> int:
    """First seed whose deterministic spec_perturb draw satisfies
    ``pick`` — the same discovery the chaos tests use."""
    for seed in range(256):
        plan = FaultPlan(seed, {"spec_perturb": SiteSpec(prob=1.0)})
        plan.fire("spec_perturb")
        if pick(plan.param("spec_perturb")):
            return seed
    raise RuntimeError("no seed in 0..255 draws the wanted parameter")


def _drive_window(ctrl: SpecController, factors: Dict[str, float]):
    """One closed-loop drift window: predictions priced off the ACTIVE
    spec, measurements off the truth (``base * factor``) — the same
    feedback the controller sees from live instrumented traffic."""
    per = max(1, ctrl.cfg.min_events // len(factors))
    for field, factor in factors.items():
        k = getattr(ctrl.active, field) / getattr(ctrl.base, field)
        for _ in range(per):
            telemetry.record("atomics.execute", tier="local",
                             backend=_FIELD_GROUP[field], op="faa", n=256,
                             predicted_s=P0 * k, measured_s=P0 * factor)
    return ctrl.step()


def _log_errs(ctrl: SpecController) -> Dict[str, float]:
    return {f: abs(math.log(getattr(ctrl.active, f)
                            / (getattr(ctrl.base, f) * factor)))
            for f, factor in TRUTH_FACTORS.items()}


def _convergence(csv: Csv) -> Dict[str, object]:
    skew = _perturb_seed(
        lambda u: u < 0.5 and abs(4.0 * u - 1.0) * math.log(8.0) > 0.3)
    plan = FaultPlan(skew, {"spec_perturb": SiteSpec(prob=1.0, count=1)})
    cfg = TuningConfig(cooldown_updates=0)
    outcomes: List[str] = []
    converged_at = None
    with SpecController(cfg, chaos=plan) as ctrl:
        for w in range(1, MAX_WINDOWS + 1):
            outcomes.append(_drive_window(ctrl, TRUTH_FACTORS))
            if max(_log_errs(ctrl).values()) < CONVERGENCE_LOG_TOL:
                converged_at = w
                break
        errs = _log_errs(ctrl)
        fields = {f: {"calibrated": getattr(ctrl.base, f),
                      "truth": getattr(ctrl.base, f) * factor,
                      "tuned": getattr(ctrl.active, f),
                      "log_err": errs[f]}
                  for f, factor in TRUTH_FACTORS.items()}
        probe = _selection_probe(ctrl)
        stats = ctrl.stats()
    for f, info in fields.items():
        csv.add(f"tuning.converge.{f}", info["tuned"] * 1e6,
                f"truth={info['truth'] * 1e6:.3g}us "
                f"log_err={info['log_err']:.3f} "
                f"tol<{CONVERGENCE_LOG_TOL}")
    csv.add("tuning.converge.windows",
            float(converged_at or MAX_WINDOWS + 1),
            f"max={MAX_WINDOWS} outcomes={'/'.join(outcomes)} "
            f"perturbs={stats['perturbs']}")
    return {"skew_seed": skew, "windows_to_converge": converged_at,
            "outcomes": outcomes, "fields": fields,
            "selection_probe": probe, "controller": stats,
            "ok": converged_at is not None}


def _selection_probe(ctrl: SpecController) -> Dict[str, object]:
    """Informational: does the tuned spec pick the same local backend as
    the truth spec would?  Probed across a batch-size sweep at m=1024."""
    truth = dataclasses.replace(
        ctrl.base, **{f: getattr(ctrl.base, f) * factor
                      for f, factor in TRUTH_FACTORS.items()})
    agree, rows = 0, {}
    sizes = (4, 32, 256, 2048)
    for n in sizes:
        a = rmw_engine.select_backend_with_cost(
            "faa", n, 1024, ctrl.active, uniform_expected=True).choice
        b = rmw_engine.select_backend_with_cost(
            "faa", n, 1024, truth, uniform_expected=True).choice
        rows[str(n)] = {"tuned": a, "truth": b}
        agree += a == b
    return {"agreement": agree / len(sizes), "choices": rows}


def _rollback_and_quarantine(csv: Csv) -> Dict[str, object]:
    cfg = TuningConfig(cooldown_updates=0)
    # rollback latency: honest apply, then one regressed window
    with SpecController(cfg) as ctrl:
        assert _drive_window(ctrl, {"loop_step_s": 2.0}) == "apply"
        pre_apply = ctrl.base
        applied = ctrl.active
        windows = 0
        outcome = None
        while windows < 3 and outcome != "rollback":
            outcome = _drive_window(ctrl, {"loop_step_s": 64.0})
            windows += 1
        rollback = {"windows": windows, "outcome": outcome,
                    "restored_bit_equal": ctrl.active == pre_apply,
                    "had_applied": applied != pre_apply,
                    "ok": outcome == "rollback" and windows == 1
                    and ctrl.active == pre_apply}
    # quarantine firing/non-firing pair: the SAME drift window, with and
    # without the NaN-poison chaos draw
    nan_seed = _perturb_seed(lambda u: 0.5 <= u < 0.75)
    plan = FaultPlan(nan_seed, {"spec_perturb": SiteSpec(prob=1.0,
                                                         count=1)})
    with SpecController(cfg, chaos=plan) as ctrl:
        fired = _drive_window(ctrl, {"loop_step_s": 3.0})
        poisoned_installed = ctrl.active != ctrl.base
        n_quarantined = ctrl.n_quarantined
    with SpecController(cfg) as ctrl:
        unfired = _drive_window(ctrl, {"loop_step_s": 3.0})
        honest_applied = ctrl.active != ctrl.base
    quarantine = {"nan_seed": nan_seed, "fired_outcome": fired,
                  "unfired_outcome": unfired,
                  "n_quarantined": n_quarantined,
                  "ok": fired == "quarantine" and not poisoned_installed
                  and n_quarantined >= 1 and unfired == "apply"
                  and honest_applied}
    csv.add("tuning.rollback.windows", float(rollback["windows"]),
            f"outcome={rollback['outcome']} "
            f"bit_equal={rollback['restored_bit_equal']}")
    csv.add("tuning.quarantine", float(quarantine["n_quarantined"]),
            f"fired={fired} unfired={unfired}")
    return {"rollback": rollback, "quarantine": quarantine}


def _overhead(fast: bool) -> Dict[str, object]:
    """Eager FAA wall with a LIVE controller (sink + sync + step() every
    call + whatever swaps its updates decide) vs the stream fully off.
    Interleaved min-of-batch-means; raw perf_counter on purpose."""
    m = 1024
    n = 4096
    rng = np.random.default_rng(2)
    tbl = atomics.AtomicTable(jnp.zeros((m,), jnp.int32))
    op = atomics.Faa(jnp.asarray(rng.integers(0, m, (n,)), jnp.int32),
                     jnp.ones((n,), jnp.int32))

    # backend pinned: spec updates may legitimately flip the dispatch
    # choice mid-run, and the gate measures the CONTROLLER's machinery
    # (sync stream + sink + step + update cycles), not a kernel swap
    pinned = rmw_engine.select_backend_with_cost(
        "faa", n, m, rmw_engine.calibrated_spec(),
        uniform_expected=True).choice

    def call():
        return jax.block_until_ready(
            atomics.execute(tbl, op, backend=pinned).table.data)

    batch = 20
    n_batches = 15 if fast else 25
    ctrl = SpecController()
    for _ in range(batch):
        call()                               # warm compiles, no stream
    ctrl.start()
    try:
        for _ in range(4 * batch):           # quiesce: let early windows
            call()                           # apply and settle to holds
            ctrl.step()
    finally:
        ctrl.stop()

    def measure() -> Tuple[float, float]:
        t_on: List[float] = []
        t_off: List[float] = []
        for _ in range(n_batches):
            ctrl.start()
            try:
                t0 = time.perf_counter()
                for _ in range(batch):
                    call()
                    ctrl.step()
                t_on.append((time.perf_counter() - t0) / batch)
            finally:
                ctrl.stop()
            t0 = time.perf_counter()
            for _ in range(batch):
                call()
            t_off.append((time.perf_counter() - t0) / batch)
        return min(t_on), min(t_off)

    # On a shared box a whole measurement can land inside a throttling
    # window (per-batch walls here swing tens of percent), so the gate
    # retries the measurement and keeps the best attempt: the controller's
    # systematic cost is a FLOOR on every attempt's ratio — noise only
    # fakes failures, never passes — so min-across-attempts is the honest
    # estimate of what the machinery actually costs.
    attempts = []
    for _ in range(3):
        on, off = measure()
        attempts.append((on / off - 1.0, on, off))
        if attempts[-1][0] < OVERHEAD_GATE:
            break
    overhead, on, off = min(attempts)
    return {"n": n, "backend": pinned,
            "disabled_us": off * 1e6, "enabled_us": on * 1e6,
            "overhead": overhead, "gate": OVERHEAD_GATE,
            "attempts": [round(a[0], 4) for a in attempts],
            "controller": ctrl.stats(),
            "ok": overhead < OVERHEAD_GATE}


# --- bit-identity -----------------------------------------------------------

_N_STEPS = 16
_M = 64


def _workload(controller) -> Tuple[np.ndarray, int]:
    """Deterministic FAA + fetched-sum accumulator steps (fetched values
    load-bearing), optionally under a live controller."""
    table = atomics.AtomicTable(jnp.zeros((_M,), jnp.int32))
    acc = 0
    for step in range(_N_STEPS):
        idx = jnp.asarray((np.arange(16) * (step + 3)) % _M, jnp.int32)
        vals = jnp.asarray(np.arange(16) + step, jnp.int32)
        res = atomics.execute(table, atomics.Faa(idx, vals))
        table = res.table
        acc += int(np.asarray(res.fetched).sum())
        if controller is not None:
            controller.step()
    return np.asarray(table.data), acc


def _bit_identity_local() -> Dict[str, object]:
    base_table, base_acc = _workload(None)
    plan = FaultPlan(7, {"spec_perturb": SiteSpec(prob=0.5)})
    cfg = TuningConfig(min_events=8, min_samples=1, cooldown_updates=0)
    with SpecController(cfg, chaos=plan) as ctrl:
        tuned_table, tuned_acc = _workload(ctrl)
        stats = ctrl.stats()
    ok = bool((tuned_table == base_table).all()) and tuned_acc == base_acc
    return {"ok": ok, "acc": base_acc, "controller_updates":
            stats["updates"], "controller_applied": stats["applied"]}


_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import hashlib
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import atomics
from repro.tuning import SpecController, TuningConfig

TUNED = %(tuned)r
mesh = jax.make_mesh((2, 4), ("pod", "dev"))
m = 512

def table():
    return atomics.AtomicTable(
        jax.device_put(jnp.zeros((m,), jnp.int32),
                       NamedSharding(mesh, P(("pod", "dev")))),
        axis=("pod", "dev"))

def faa_ops(step, n=256):
    rng = np.random.default_rng(step)
    def make_ops(slots, observed):
        if slots is None:
            return atomics.Faa(
                jnp.asarray(rng.integers(0, m, (n,)), jnp.int32),
                jnp.ones((n,), jnp.int32))
        return None
    return make_ops

def cas_ops(slots, observed):
    # 64 ops over 8 hot slots: the contended loop the estimator observes
    if slots is None:
        return atomics.Cas(jnp.asarray(np.arange(64) %% 8, jnp.int32),
                           jnp.ones((64,), jnp.int32),
                           expected=jnp.int32(0))
    return observed + 1          # lock-free fetch-increment

ctrl = None
if TUNED:
    ctrl = SpecController(TuningConfig(min_events=8, min_samples=1,
                                       cooldown_updates=0)).start()
try:
    tab = table()
    digest = hashlib.sha256()
    fetched_total = 0
    for step in range(5):
        res = atomics.execute_until(tab, faa_ops(step), max_rounds=1)
        tab = res.table
        fetched_total += int(res.fetched.sum())
        if ctrl is not None:
            ctrl.step()
    # the CAS loop twice: under tuning, the SECOND call consumes the
    # estimator's distinct_slots hint learned from the first
    for _ in range(2):
        res = atomics.execute_until(tab, cas_ops, max_rounds=16)
        tab = res.table
        fetched_total += int(res.fetched.sum())
        digest.update(np.asarray(res.rounds).tobytes())
        if ctrl is not None:
            ctrl.step()
    digest.update(np.asarray(jax.device_get(tab.data)).tobytes())
    est_sites = len(ctrl.estimator) if ctrl is not None else 0
finally:
    if ctrl is not None:
        ctrl.stop()
print("RESULT:" + json.dumps({
    "digest": digest.hexdigest(), "fetched_total": fetched_total,
    "estimator_sites": est_sites,
    "updates": ctrl.n_updates if ctrl else 0}))
"""


def _bit_identity_sharded() -> Dict[str, object]:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("REPRO_TUNING", None)
    results = {}
    for tuned in (False, True):
        proc = subprocess.run(
            [sys.executable, "-c", _SHARDED_SCRIPT % {"tuned": tuned}],
            env=env, capture_output=True, text=True, timeout=1800,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
        if proc.returncode != 0:
            raise RuntimeError(f"sharded bit-identity subprocess "
                               f"(tuned={tuned}) failed:\n"
                               f"{proc.stderr[-2000:]}")
        line = [l for l in proc.stdout.splitlines()
                if l.startswith("RESULT:")][0]
        results["tuned" if tuned else "untuned"] = \
            json.loads(line[len("RESULT:"):])
    ok = (results["tuned"]["digest"] == results["untuned"]["digest"]
          and results["tuned"]["fetched_total"]
          == results["untuned"]["fetched_total"]
          and results["tuned"]["estimator_sites"] >= 1)
    return {"ok": ok, **results}


def run(csv: Csv, fast: bool = False, out_path: str = RESULT_PATH
        ) -> Dict[str, object]:
    if fast and out_path == RESULT_PATH:
        # never clobber the committed full run with a CI smoke run
        out_path = RESULT_PATH.replace(".json", "_fast.json")

    convergence = _convergence(csv)
    guards = _rollback_and_quarantine(csv)
    overhead = _overhead(fast)
    bit_local = _bit_identity_local()
    bit_sharded = None if fast else _bit_identity_sharded()

    csv.add("tuning.overhead", overhead["enabled_us"],
            f"n={overhead['n']} disabled={overhead['disabled_us']:.0f}us "
            f"overhead={overhead['overhead'] * 100:.1f}pct "
            f"gate<{OVERHEAD_GATE * 100:.0f}pct")
    csv.add("tuning.bit_identity", 0.0 if bit_local["ok"] else 1.0,
            f"local_ok={bit_local['ok']}"
            + (f" sharded_ok={bit_sharded['ok']}" if bit_sharded else
               " sharded=skipped(fast)"))

    acceptance = (convergence["ok"] and guards["rollback"]["ok"]
                  and guards["quarantine"]["ok"] and overhead["ok"]
                  and bit_local["ok"]
                  and (bit_sharded is None or bit_sharded["ok"]))
    out = {
        "fast": fast,
        "convergence": convergence,
        "rollback": guards["rollback"],
        "quarantine": guards["quarantine"],
        "overhead": overhead,
        "bit_identity": {"local": bit_local, "sharded": bit_sharded},
        "acceptance_converged_guarded_cheap_and_bit_identical":
            bool(acceptance),
    }
    assert acceptance, (
        f"tuning acceptance failed: convergence={convergence['ok']} "
        f"rollback={guards['rollback']['ok']} "
        f"quarantine={guards['quarantine']['ok']} "
        f"overhead={overhead['overhead']:.3f} (gate {OVERHEAD_GATE}) "
        f"bit_local={bit_local['ok']} "
        f"bit_sharded={bit_sharded and bit_sharded['ok']}")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    csv.add("tuning/artifact", 0.0, os.path.relpath(out_path))
    return out
