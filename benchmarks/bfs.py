"""BFS benchmark — paper Fig. 10b (CAS vs SWP vs FAA on Kronecker graphs).

Reports traversed edges per second per combiner.  The paper's conclusion —
primitives cost the same, semantics decide — shows up as nearly identical
TEPS for CAS/SWP with FAA paying for its revert scheme.
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks.common import Csv, time_s
from repro.core.bfs import bfs, kronecker_graph, validate_parents

SCALE = 12
EDGEFACTOR = 8


def run(csv: Csv, scale: int = SCALE) -> Dict[str, float]:
    src, dst = kronecker_graph(scale=scale, edgefactor=EDGEFACTOR, seed=0)
    n = 1 << scale
    s2 = np.concatenate([src, dst])
    d2 = np.concatenate([dst, src])
    root = int(s2[0])
    out: Dict[str, float] = {}
    for op in ("cas", "swp", "faa"):
        r = bfs(s2, d2, n, root=root, op=op)      # warm + correctness
        assert validate_parents(s2, d2, np.asarray(r.parent), root), op
        t = time_s(lambda op=op: bfs(s2, d2, n, root=root, op=op).parent,
                   reps=3, warmup=1)
        teps = r.edges_traversed / t
        out[op] = teps
        csv.add(f"bfs.{op}.scale{scale}", t * 1e6,
                f"TEPS={teps:.3g} levels={r.levels} "
                f"edges={r.edges_traversed}")
    return out
