"""Two-operands-fetched CAS — paper §5.5 / Fig. 8d.

The paper's CAS variant fetches both the expected value and the desired
value from the memory subsystem (instead of registers); the pipelined second
fetch cost only ~2-4ns locally.  Here the second fetch is a gather of the
per-op expected values from a second table, chained into the serialized CAS.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, time_s
from repro.core.perf_model import TPU_V5E, latency
from repro.core.placement import PlacementState, Tier
from repro.core.rmw import rmw_serialized

N_OPS = 2_048
TABLE = 65_536


def run(csv: Csv) -> Dict[str, float]:
    rng = np.random.default_rng(4)
    table = jnp.zeros((TABLE,), jnp.int32)
    aux = jnp.asarray(rng.integers(0, 3, TABLE), jnp.int32)   # operand table
    idx = jnp.asarray(rng.integers(0, TABLE, N_OPS), jnp.int32)
    vals = jnp.asarray(rng.integers(1, 100, N_OPS), jnp.int32)
    exp_reg = jnp.zeros((N_OPS,), jnp.int32)

    t1 = time_s(jax.jit(lambda t=table: rmw_serialized(
        t, idx, vals, "cas", exp_reg).table)) / N_OPS
    # cas2: expected fetched from memory per op (second memory operand)
    t2 = time_s(jax.jit(lambda t=table: rmw_serialized(
        t, idx, vals, "cas", aux[idx]).table)) / N_OPS

    st = PlacementState(tier=Tier.HBM_LOCAL)
    m1 = latency(TPU_V5E, "cas", st)
    m2 = latency(TPU_V5E, "cas2", st)
    csv.add("operands_fetched.cas1", t1 * 1e6,
            f"modelTPU={m1*1e9:.0f}ns")
    csv.add("operands_fetched.cas2", t2 * 1e6,
            f"delta={(t2-t1)*1e9:.1f}ns modelTPU={m2*1e9:.0f}ns "
            f"(paper: +2-4ns local)")
    return {"cas1_s": t1, "cas2_s": t2}
