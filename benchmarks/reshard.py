"""Elastic-migration shoot-out: reshard vs full replay, predicted vs measured.

The migration subsystem's claim (repro.atomics.reshard): because ownership
is a pure function of (slot, extent), moving a table to a new mesh costs one
slot exchange — independent of how many RMWs built the table — while the
only alternative, replaying the op history through the sharded tier on the
new mesh, scales with that history.  This benchmark measures both on the
8-fake-device harness (subprocess, XLA_FLAGS before jax init, same pattern
as benchmarks/rmw_sharded.py):

  migrate/device_put   host-roundtrip path, fleet change (2 -> 4 devices)
  migrate/exchange     in-collective all_to_all path, same-fleet layout
                       change ((pod,dev)-sharded -> dev-sharded/pod-replica)
  replay               re-execute the recorded history (4 batches of FAA)
                       through `atomics.execute` on the new mesh

and validates each migrated table bit-for-bit against the replay before
timing.  Predicted costs come from the migration tier of the HardwareSpec
cost model (`cost_migrate_*`, `cost_replay`) so the table doubles as a
predicted-vs-measured check.

The acceptance row (ISSUE 5): migration must beat full replay on every
table of >= 64k slots.  Below that, this host's per-placement dispatch can
rival the handful of collective launches a short replay needs (container
timings are +/-50% noisy); those cells are reported, not gated.

Fake-device caveat (same as rmw_sharded's hierarchical-vs-oneshot): on one
host a "host roundtrip" is a memcpy while a shard_map all_to_all pays
XLA's ms-scale collective dispatch, so the measured exchange path loses to
device_put here even though the cost model — priced for real PCIe vs ICI —
prefers it.  The exchange cell is therefore reported (and verified
bit-identical), never gated on speed.  Emits benchmarks/results/
reshard.json (--fast writes the *_fast.json variant).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict

from benchmarks.common import Csv

RESULT_PATH = os.path.join(os.path.dirname(__file__), "results",
                           "reshard.json")

#: acceptance gate: migration must beat replay from this table size up
GATE_SLOTS = 1 << 16

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from benchmarks.common import time_s
from repro import atomics
from repro.atomics import reshard
from repro.atomics.layout import TableLayout
from repro.core import rmw_engine
from repro.sharding import shard_map_compat

FAST = %(fast)r
devs = jax.devices()
rng = np.random.default_rng(42)
spec = rmw_engine.default_spec()
rows = []

N_BATCHES = 4
N_PER_DEV = 1024 if FAST else 4096
GRID_M = (4096,) if FAST else (4096, 65536, 262144)

def median_time(fn):
    # the shared benchmark clock (telemetry.span under the hood); warmup=1
    # keeps this suite's historical rep budget
    return time_s(fn, warmup=1, name="bench.reshard.rep")

_STEPS = {}

def step_fn(mesh, axis="dev"):
    '''One jitted sharded-FAA step per (mesh, axis) — cached so replay
    timings measure execution, not recompilation (the post-restart step is
    compiled exactly once in a real elastic run too).'''
    key = (id(mesh), axis)
    if key not in _STEPS:
        SPEC = P(tuple(mesh.axis_names))
        def fn(t, i, v):
            h = atomics.AtomicTable(t, axis=axis)
            res = atomics.execute(h, atomics.Faa(i[0], v[0]),
                                  need_fetched=True)
            return res.table.data, res.fetched[None]
        _STEPS[key] = jax.jit(shard_map_compat(
            fn, mesh, (P(axis), SPEC, SPEC), (P(axis), SPEC)))
    return _STEPS[key]

def exec_history(mesh, tbl, history, axis="dev"):
    mapped = step_fn(mesh, axis)
    data = tbl.data
    for i, v in history:
        data, _ = mapped(data, i, v)
    return atomics.AtomicTable(data, axis=axis)

def history_for(mesh, m):
    ndev = int(mesh.devices.size)
    return [(jnp.asarray(rng.integers(0, m, (ndev, N_PER_DEV)), jnp.int32),
             jnp.asarray(rng.integers(-3, 4, (ndev, N_PER_DEV)), jnp.int32))
            for _ in range(N_BATCHES)]

def resplit(history, ndev):
    return [(i.reshape(ndev, -1), v.reshape(ndev, -1)) for i, v in history]

# --- cell 1: fleet change 2 -> 4 (device_put path) ------------------------
mesh2 = Mesh(np.array(devs[:2]), ("dev",))
mesh4 = Mesh(np.array(devs[:4]), ("dev",))
for m in GRID_M:
    hist = history_for(mesh2, m)
    tab0 = jnp.zeros((m,), jnp.int32)
    tbl2 = atomics.AtomicTable(
        jax.device_put(tab0, NamedSharding(mesh2, P("dev"))), axis="dev")
    built = exec_history(mesh2, tbl2, hist)

    src = built.layout()
    dst = TableLayout.from_mesh(mesh4, num_slots=m, dtype=jnp.int32,
                                axis="dev")
    plan = reshard.plan_reshard(src, dst, dst_mesh=mesh4, src_mesh=mesh2)
    migrated = plan.execute(built)

    def replay():
        t = atomics.AtomicTable(
            jax.device_put(tab0, NamedSharding(mesh4, P("dev"))), axis="dev")
        return exec_history(mesh4, t, resplit(hist, 4)).data

    replayed = replay()
    assert np.array_equal(np.asarray(migrated.data), np.asarray(replayed)), \
        f"migrated table != replay at m={m}"

    t_mig = median_time(lambda: plan.execute(built).data)
    t_rep = median_time(replay)
    n_ops = N_BATCHES * N_PER_DEV * 2
    rows.append({
        "cell": "grow_2to4", "path": plan.path, "m": m,
        "history_ops": n_ops,
        "migrate_us": t_mig * 1e6, "replay_us": t_rep * 1e6,
        "speedup_vs_replay": t_rep / t_mig,
        "predicted_migrate_us": plan.predicted_s[plan.path] * 1e6,
        "predicted_replay_us": reshard.cost_replay(
            spec, dst, n_ops, n_batches=N_BATCHES) * 1e6,
    })

# --- cell 2: same-fleet layout change (in-collective exchange path) -------
mesh24 = jax.make_mesh((2, 4), ("pod", "dev"))
for m in GRID_M:
    hist = history_for(mesh24, m)
    tab0 = jnp.zeros((m,), jnp.int32)
    built = exec_history(
        mesh24,
        atomics.AtomicTable(
            jax.device_put(tab0, NamedSharding(mesh24, P(("pod", "dev")))),
            axis=("pod", "dev")),
        hist, axis=("pod", "dev"))
    src = built.layout()
    dst = TableLayout.from_mesh(mesh24, num_slots=m, dtype=jnp.int32,
                                axis=("dev",), replica_axes=("pod",))
    plan = reshard.plan_reshard(src, dst, dst_mesh=mesh24, src_mesh=mesh24)
    plan_host = reshard.plan_reshard(src, dst, dst_mesh=mesh24,
                                     src_mesh=mesh24, path="device_put")
    a = plan.execute(built); b = plan_host.execute(built)
    assert np.array_equal(np.asarray(a.data), np.asarray(b.data))
    t_exc = median_time(lambda: plan.execute(built).data)
    t_put = median_time(lambda: plan_host.execute(built).data)
    rows.append({
        "cell": "refleet_8dev", "path": plan.path, "m": m,
        "history_ops": N_BATCHES * N_PER_DEV * 8,
        "migrate_us": t_exc * 1e6, "device_put_us": t_put * 1e6,
        "speedup_vs_device_put": t_put / t_exc,
        "predicted_migrate_us": plan.predicted_s["exchange"] * 1e6,
        "predicted_device_put_us": plan.predicted_s["device_put"] * 1e6,
    })

print("RESULT:" + json.dumps(rows))
"""


def run(csv: Csv, fast: bool = False, out_path: str = RESULT_PATH
        ) -> Dict[str, object]:
    if fast and out_path == RESULT_PATH:
        # never clobber the committed full-grid table with a CI smoke run
        out_path = RESULT_PATH.replace(".json", "_fast.json")
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT % {"fast": fast}], env=env,
        capture_output=True, text=True, timeout=3600,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    if proc.returncode != 0:
        raise RuntimeError(f"reshard bench failed: {proc.stderr[-2000:]}")
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][0]
    rows = json.loads(line[len("RESULT:"):])

    for r in rows:
        alt = ("replay", r["replay_us"]) if "replay_us" in r \
            else ("device_put", r["device_put_us"])
        csv.add(f"reshard.{r['cell']}.m{r['m']}.{r['path']}",
                r["migrate_us"],
                f"{alt[0]}={alt[1]:.0f}us "
                f"pred={r['predicted_migrate_us']:.0f}us")

    # acceptance: migration beats full replay on every >= 64k-slot table
    gated = [r for r in rows
             if r["cell"] == "grow_2to4" and r["m"] >= GATE_SLOTS]
    acceptance = bool(gated) and all(r["speedup_vs_replay"] > 1.0
                                     for r in gated)
    out = {
        "host": {"jax_backend": "cpu", "devices": 8,
                 "meshes": "2dev -> 4dev grow; 2x4 pod*dev refleet"},
        "fast": fast,
        "rows": rows,
        "acceptance_migration_beats_replay_ge_64k_slots": acceptance,
        "gate_slots": GATE_SLOTS,
    }
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    csv.add("reshard.acceptance", 0.0,
            f"migration_beats_replay_ge_64k={acceptance} json={out_path}")
    return out
