"""Cost-model exploration: the paper's L(A,S) model driving system choices.

    PYTHONPATH=src python examples/cost_model_explore.py

Walks through: (1) the three-term latency model across placement states,
(2) the ILP gap, (3) contention regimes, (4) the planner pricing gradient
sync / FSDP dtype / MoE capacity for a deepseek-v3-scale training step.
"""

from repro.core import (TPU_V5E, bandwidth, ilp_gap, latency,
                        relaxed_bandwidth)
from repro.core.contention import (contended_bandwidth_combining,
                                   contended_bandwidth_serialized)
from repro.core.placement import PlacementState, Tier, remote_pod, shared
from repro.core.planner import (default_axes, plan_fsdp_gather_dtype,
                                plan_grad_sync, plan_moe_dispatch)


def main() -> None:
    print("== L(A,S) across placement states (TPU v5e model), ns")
    states = {
        "VMEM local (E)": PlacementState(tier=Tier.VMEM),
        "HBM local (E)": PlacementState(tier=Tier.HBM_LOCAL),
        "ICI neighbor (E)": PlacementState(tier=Tier.ICI_NEIGHBOR),
        "ICI neighbor (S,8 replicas)": shared(Tier.ICI_NEIGHBOR, 8),
        "remote pod (DCN)": remote_pod(),
    }
    print(f"{'state':32s}" + "".join(f"{op:>10s}" for op in
                                     ("read", "faa", "swp", "cas")))
    for name, st in states.items():
        row = "".join(f"{latency(TPU_V5E, op, st)*1e9:10.0f}"
                      for op in ("read", "faa", "swp", "cas"))
        print(f"{name:32s}{row}")
    print("\n-> the paper's headline holds in the model: CAS≈FAA≈SWP; "
          "placement dominates.")

    st = PlacementState(tier=Tier.HBM_LOCAL)
    print(f"\n== ILP gap at HBM: serialized {bandwidth(TPU_V5E,'faa',st)/1e9:.2f} "
          f"GB/s vs relaxed {relaxed_bandwidth(TPU_V5E,st)/1e9:.0f} GB/s "
          f"({ilp_gap(TPU_V5E,'faa',st):.0f}x)")

    print("\n== contention (writers -> one shard), GB/s")
    print(f"{'writers':>8s}{'serialized':>12s}{'combining':>12s}")
    for w in (1, 4, 16, 64, 256):
        print(f"{w:8d}"
              f"{contended_bandwidth_serialized(TPU_V5E,'faa',w)/1e9:12.3f}"
              f"{contended_bandwidth_combining(TPU_V5E,'faa',w)/1e9:12.3f}")

    print("\n== planner: deepseek-v3 train step on (pod=2, data=16, model=16)")
    axes = default_axes({"pod": 2, "data": 16, "model": 16})
    grad_bytes = int(37.6e9 * 4 / 16)      # active-params grads, fp32, /TP
    d = plan_grad_sync(grad_bytes, axes["data"], axes["pod"])
    print(f"grad sync -> {d.choice}")
    for k, v in d.priced.items():
        print(f"  {k:12s} {v*1e3:8.2f} ms/step")
    d = plan_fsdp_gather_dtype(int(671e9 * 4 / 61 / 16), axes["data"])
    print(f"FSDP gather dtype -> {d.choice} ({d.priced})")
    d = plan_moe_dispatch(tokens_per_step=256 * 4096, n_experts=256, top_k=8,
                          ep_degree=16, step_budget_s=0.5)
    print(f"MoE dispatch -> {d.choice}")
    print(f"  note: {d.note}")


if __name__ == "__main__":
    main()
