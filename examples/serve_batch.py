"""Batched serving example: continuous batching over mixed-length prompts.

    PYTHONPATH=src python examples/serve_batch.py [--arch qwen2_vl_2b]
"""

import argparse
import json

import numpy as np

from repro.launch.serve import BatchServer, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_12b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=6)
    args = ap.parse_args()

    server = BatchServer(args.arch, slots=args.slots, s_max=64)
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, server.cfg.vocab_size,
                                        int(rng.integers(3, 20))).tolist(),
                    max_new=args.max_new)
            for i in range(args.requests)]
    stats = server.run(reqs)
    print(json.dumps(stats, indent=2))
    for r in reqs[:3]:
        print(f"req {r.rid}: prompt[{len(r.prompt)}] -> {r.out}")


if __name__ == "__main__":
    main()
