"""The paper's §6.1 application: BFS over Kronecker graphs with CAS/SWP/FAA.

    PYTHONPATH=src python examples/bfs_traversal.py [--scale 14]

Reproduces Fig. 10b's comparison: the three combiners traverse the same
graph; their TEPS are close (the paper's 'primitives cost the same' result)
and the semantics determine protocol complexity — CAS is the natural fit,
SWP needs the revert trick, FAA needs a full revert scheme.
"""

import argparse
import time

import numpy as np

from repro.core.bfs import bfs, kronecker_graph, validate_parents


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=13)
    ap.add_argument("--edgefactor", type=int, default=8)
    args = ap.parse_args()

    n = 1 << args.scale
    src, dst = kronecker_graph(args.scale, args.edgefactor, seed=0)
    s = np.concatenate([src, dst])
    d = np.concatenate([dst, src])
    root = int(s[0])
    print(f"Kronecker graph: scale={args.scale} n={n} edges={len(s)}")

    for op in ("cas", "swp", "faa"):
        r = bfs(s, d, n, root=root, op=op)          # warm/compile
        ok = validate_parents(s, d, np.asarray(r.parent), root)
        t0 = time.perf_counter()
        r = bfs(s, d, n, root=root, op=op)
        dt = time.perf_counter() - t0
        teps = r.edges_traversed / dt
        reached = int((np.asarray(r.parent) >= 0).sum())
        print(f"{op:4s}: levels={r.levels:2d} reached={reached:7d} "
              f"valid={ok}  TEPS={teps:.3g}")
    print("\npaper's conclusion: pick the combiner by SEMANTICS — "
          "the costs match (see benchmarks/bfs.py for the measured table)")


if __name__ == "__main__":
    main()
