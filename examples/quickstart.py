"""Quickstart: train a tiny LM for 50 steps on CPU, then generate.

    PYTHONPATH=src python examples/quickstart.py [--arch gemma_2b]

Uses the public API only: configs registry -> build_model -> train loop ->
serving.  Every assigned architecture id works via --arch.
"""

import argparse
import logging

import numpy as np

from repro.launch.serve import BatchServer, Request
from repro.launch.train import train


def main() -> None:
    logging.basicConfig(level=logging.INFO, format="%(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma_2b")
    ap.add_argument("--steps", type=int, default=50)
    args = ap.parse_args()

    print(f"== training {args.arch} (reduced config) for {args.steps} steps")
    out = train(args.arch, steps=args.steps, seq_len=64, global_batch=4,
                lr=3e-3, log_every=10)
    print(f"loss: {out['history'][0]['loss']:.3f} -> "
          f"{out['final_loss']:.3f} over {out['steps_done']} steps")

    print("== serving 3 batched requests")
    server = BatchServer(args.arch, slots=2, s_max=32)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(
        0, server.cfg.vocab_size, 6).tolist(), max_new=4) for i in range(3)]
    print(server.run(reqs))


if __name__ == "__main__":
    main()
