"""Mesh-wide sharded atomics demo: the paper's §6.2 combining tree, live.

    PYTHONPATH=src python examples/sharded_atomics.py [--n-per-device 8192]

Spins up 8 fake host devices as a (2 pods x 4 devices) mesh, hammers one
hot table shard with FAA batches from every device (the paper's §5.4
contention workload), and runs the same batch through every exchange
strategy of `core/rmw_sharded.py` — verifying they agree bit-for-bit with
the single-device serialized oracle under the documented arrival order, and
timing naive per-op exchange vs one-shot vs hierarchical combining.  Ends
with a sharded-frontier BFS whose parents match the single-device run.
"""

import argparse
import os
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402
import numpy as np                                            # noqa: E402
from jax.sharding import PartitionSpec as P                   # noqa: E402

from repro.core.bfs import bfs, bfs_sharded, kronecker_graph  # noqa: E402
from repro.core.rmw import rmw_serialized                     # noqa: E402
from repro.core.rmw_sharded import rmw_sharded, select_exchange  # noqa: E402
from repro.core.rmw_sharded import MeshAxis                   # noqa: E402
from repro.core.placement import Tier                         # noqa: E402
from repro.sharding import DEFAULT_RULES, named_sharding, use_mesh  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-per-device", type=int, default=8192)
    ap.add_argument("--table", type=int, default=4096)
    args = ap.parse_args()

    ndev = jax.device_count()
    mesh = jax.make_mesh((2, ndev // 2), ("pod", "model"))
    n, m = args.n_per_device, args.table
    rng = np.random.default_rng(0)
    # 95% of every device's ops hit 8 slots of shard 0 — the hot line
    hot = rng.integers(0, 8, (ndev, n))
    uni = rng.integers(0, m, (ndev, n))
    idx = np.where(rng.random((ndev, n)) < 0.95, hot, uni).astype(np.int32)
    vals = rng.integers(-5, 6, (ndev, n)).astype(np.int32)

    spec = P(("pod", "model"))

    def run(strategy):
        def fn(t, i, v):
            res = rmw_sharded(t, i[0], v[0], "faa", axis=("pod", "model"),
                              strategy=strategy)
            return res.table, res.fetched[None]
        sm = (jax.shard_map(fn, mesh=mesh, in_specs=(spec, spec, spec),
                            out_specs=(spec, spec), check_vma=False)
              if hasattr(jax, "shard_map") else None)
        if sm is None:
            from jax.experimental.shard_map import shard_map
            sm = shard_map(fn, mesh=mesh, in_specs=(spec, spec, spec),
                           out_specs=(spec, spec), check_rep=False)
        return jax.jit(sm)

    with use_mesh(mesh, dict(DEFAULT_RULES)):
        # the RMW table is a first-class sharded object: the "rmw_table"
        # logical axis maps it onto the EP/model axis
        table = jax.device_put(jnp.zeros((m,), jnp.int32),
                               named_sharding(("rmw_table",), (m,)))
    idx_j, vals_j = jnp.asarray(idx), jnp.asarray(vals)

    ref = rmw_serialized(jnp.zeros((m,), jnp.int32), idx_j.reshape(-1),
                         vals_j.reshape(-1), "faa")
    pick = select_exchange(
        "faa", n, m, (MeshAxis("pod", 2, Tier.DCN_REMOTE_POD),
                      MeshAxis("model", ndev // 2, Tier.ICI_NEIGHBOR)))
    print(f"{ndev} devices (2 pods x {ndev // 2}), {n} ops/device, "
          f"table {m} ({m // ndev}/shard), hot shard 0 — "
          f"cost model picks: {pick}\n")
    for strategy in ("naive", "oneshot", "hierarchical"):
        fn = run(strategy)
        tab, fetched = jax.block_until_ready(fn(table, idx_j, vals_j))
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(fn(table, idx_j, vals_j))
        dt = (time.perf_counter() - t0) / 3
        exact = (np.array_equal(np.asarray(tab), np.asarray(ref.table)) and
                 np.array_equal(np.asarray(fetched).reshape(-1),
                                np.asarray(ref.fetched)))
        print(f"{strategy:13s}: {dt * 1e3:8.2f} ms/batch   "
              f"bit-identical-to-oracle={exact}")

    print("\nsharded-frontier BFS (parent table = the contended line):")
    src, dst = kronecker_graph(scale=10, edgefactor=8, seed=1)
    s = np.concatenate([src, dst])
    d = np.concatenate([dst, src])
    root = int(s[0])
    r_local = bfs(s, d, 1 << 10, root=root, op="cas")
    r_shard = bfs_sharded(s, d, 1 << 10, root=root, axis="dev")
    same = np.array_equal(np.asarray(r_local.parent),
                          np.asarray(r_shard.parent))
    print(f"levels={r_shard.levels} edges={r_shard.edges_traversed} "
          f"parents match single-device: {same}")


if __name__ == "__main__":
    main()
