"""Mesh-wide sharded atomics demo: the unified `repro.atomics` API, live.

    PYTHONPATH=src python examples/sharded_atomics.py [--n-per-device 8192]

Spins up 8 fake host devices as a (2 pods x 4 devices) mesh, hammers one
hot table shard with FAA batches from every device (the paper's §5.4
contention workload), and runs the same typed op batch through every
exchange strategy — verifying they agree bit-for-bit with the single-device
serialized oracle under the documented arrival order, and timing naive
per-op exchange vs one-shot vs hierarchical combining.  Then demonstrates
the two capabilities unique to the unified front-end: **per-op-expected
CAS across shards** (the owner-side oracle pass) and the **dynamic
contention hint** for `select_exchange`.  Ends with a sharded-frontier BFS
whose parents match the single-device run.
"""

import argparse
import os
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402
import numpy as np                                            # noqa: E402
from jax.sharding import PartitionSpec as P                   # noqa: E402

from repro import atomics                                     # noqa: E402
from repro.core.bfs import bfs, bfs_sharded, kronecker_graph  # noqa: E402
from repro.core.rmw import rmw_serialized                     # noqa: E402
from repro.core.rmw_sharded import MeshAxis, select_exchange  # noqa: E402
from repro.core.placement import Tier                         # noqa: E402
from repro.sharding import (DEFAULT_RULES, shard_map_compat,  # noqa: E402
                            use_mesh)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-per-device", type=int, default=8192)
    ap.add_argument("--table", type=int, default=4096)
    args = ap.parse_args()

    ndev = jax.device_count()
    mesh = jax.make_mesh((2, ndev // 2), ("pod", "model"))
    n, m = args.n_per_device, args.table
    rng = np.random.default_rng(0)
    # 95% of every device's ops hit 8 slots of shard 0 — the hot line
    hot = rng.integers(0, 8, (ndev, n))
    uni = rng.integers(0, m, (ndev, n))
    idx = np.where(rng.random((ndev, n)) < 0.95, hot, uni).astype(np.int32)
    vals = rng.integers(-5, 6, (ndev, n)).astype(np.int32)

    spec = P(("pod", "model"))
    axis = ("pod", "model")

    def run(strategy):
        def fn(t, i, v):
            tbl = atomics.AtomicTable(t, axis=axis)
            res = atomics.execute(tbl, atomics.Faa(i[0], v[0]),
                                  strategy=strategy)
            return res.table.data, res.fetched[None]
        return jax.jit(shard_map_compat(fn, mesh, (spec, spec, spec),
                                        (spec, spec)))

    with use_mesh(mesh, dict(DEFAULT_RULES)):
        # the RMW table is a first-class typed object: make_table places it
        # via the "rmw_table" logical-axis rule and records the mesh axes
        table = atomics.make_table(m, jnp.int32)
        print(f"make_table under the mesh -> {table}")
    idx_j, vals_j = jnp.asarray(idx), jnp.asarray(vals)
    table0 = jnp.zeros((m,), jnp.int32)

    ref = rmw_serialized(table0, idx_j.reshape(-1),
                         vals_j.reshape(-1), "faa")
    axes = (MeshAxis("pod", 2, Tier.DCN_REMOTE_POD),
            MeshAxis("model", ndev // 2, Tier.ICI_NEIGHBOR))
    pick = select_exchange("faa", n, m, axes)
    print(f"{ndev} devices (2 pods x {ndev // 2}), {n} ops/device, "
          f"table {m} ({m // ndev}/shard), hot shard 0 — "
          f"cost model picks: {pick}\n")
    for strategy in ("naive", "oneshot", "hierarchical"):
        fn = run(strategy)
        tab, fetched = jax.block_until_ready(fn(table0, idx_j, vals_j))
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(fn(table0, idx_j, vals_j))
        dt = (time.perf_counter() - t0) / 3
        exact = (np.array_equal(np.asarray(tab), np.asarray(ref.table)) and
                 np.array_equal(np.asarray(fetched).reshape(-1),
                                np.asarray(ref.fetched)))
        print(f"{strategy:13s}: {dt * 1e3:8.2f} ms/batch   "
              f"bit-identical-to-oracle={exact}")

    # --- per-op-expected CAS across shards (the owner-side oracle pass) ---
    n_cas = min(n, 2048)
    cidx = jnp.asarray(rng.integers(0, m, (ndev, n_cas)), jnp.int32)
    cvals = jnp.asarray(rng.integers(-1, 2, (ndev, n_cas)), jnp.int32)
    cexp = jnp.asarray(rng.integers(-1, 2, (ndev, n_cas)), jnp.int32)

    def cas_fn(t, i, v, e):
        tbl = atomics.AtomicTable(t, axis=axis)
        res = atomics.execute(tbl, atomics.Cas(i[0], v[0], expected=e[0]))
        return res.table.data, res.fetched[None], res.success[None]

    tab, fetched, success = jax.jit(shard_map_compat(
        cas_fn, mesh, (spec, spec, spec, spec), (spec, spec, spec)))(
        table0, cidx, cvals, cexp)
    cref = rmw_serialized(table0, cidx.reshape(-1), cvals.reshape(-1),
                          "cas", cexp.reshape(-1))
    exact = (np.array_equal(np.asarray(tab), np.asarray(cref.table)) and
             np.array_equal(np.asarray(fetched).reshape(-1),
                            np.asarray(cref.fetched)) and
             np.array_equal(np.asarray(success).reshape(-1),
                            np.asarray(cref.success)))
    print(f"\nper-op-expected CAS across shards ({n_cas}/device): "
          f"bit-identical-to-oracle={exact}")

    # --- the dynamic contention hint sharpens the exchange crossover ------
    # Demonstrated on the cost model at multi-pod scale (slow shared DCN
    # uplink, real collective-launch costs): this single-host container's
    # fake "DCN" is a memcpy, so the one-shot-vs-hierarchical crossover
    # only exists in the model — exactly where select_exchange reads it.
    import dataclasses
    from repro.core import perf_model
    base = perf_model.cpu_default_spec()
    geo = dataclasses.replace(
        base,
        tier_bandwidth_Bps={**base.tier_bandwidth_Bps,
                            Tier.DCN_REMOTE_POD: 1e8},
        collective_launch_s=1e-4)
    stat = select_exchange("faa", 65536, 1 << 19, axes, spec=geo)
    hint = select_exchange("faa", 65536, 1 << 19, axes, spec=geo,
                           distinct_slots=16)
    print(f"contention hint (slow-DCN spec, 64k ops/device, 512k table): "
          f"static caps pick {stat!r}; distinct_slots=16 (skewed batch) "
          f"picks {hint!r}")

    print("\nsharded-frontier BFS (parent table = the contended line):")
    src, dst = kronecker_graph(scale=10, edgefactor=8, seed=1)
    s = np.concatenate([src, dst])
    d = np.concatenate([dst, src])
    root = int(s[0])
    r_local = bfs(s, d, 1 << 10, root=root, op="cas")
    r_shard = bfs_sharded(s, d, 1 << 10, root=root, axis="dev")
    same = np.array_equal(np.asarray(r_local.parent),
                          np.asarray(r_shard.parent))
    print(f"levels={r_shard.levels} edges={r_shard.edges_traversed} "
          f"parents match single-device: {same}")


if __name__ == "__main__":
    main()
