"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

    PYTHONPATH=src python examples/train_100m.py [--steps 300]

A ~110M dense transformer (GPT-2-small-ish dims from the gemma family
config), the full substrate in play: deterministic data pipeline, sharded
AdamW with fp32 master weights, async checkpointing with keep-last-k,
fault-tolerant step loop, cosine schedule.  On this CPU container a few
hundred steps take a while at full size — --small shrinks width for a fast
demonstration with identical plumbing.
"""

import argparse
import logging
import time

import jax

from repro.configs import get_config
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.launch.steps import make_train_step
from repro.checkpoint.ckpt import AsyncCheckpointer, latest_step, restore
from repro.models.model import build_model
from repro.optim.adamw import AdamWConfig, init_state

log = logging.getLogger("train100m")


def config_100m(small: bool):
    base = get_config("gemma_2b")
    if small:
        return base.replace(n_layers=4, d_model=256, n_heads=4, n_kv_heads=1,
                            d_ff=1024, vocab_size=8192, max_seq_len=512)
    # ~110M backbone (excl. embeddings): 12L x 768 x 3072
    return base.replace(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                        d_ff=3072, vocab_size=32_768, max_seq_len=1024)


def main() -> None:
    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = config_100m(args.small)
    model = build_model(cfg, attn_impl="chunked", remat_policy="full",
                        loss_chunk=1024)
    n_params = cfg.param_count()
    log.info("config: %dL d=%d ff=%d vocab=%d  ~%.0fM params",
             cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab_size,
             n_params / 1e6)

    opt_cfg = AdamWConfig(lr=6e-4, warmup_steps=20, total_steps=args.steps)
    data_cfg = DataConfig(seq_len=args.seq_len,
                          global_batch=args.global_batch,
                          vocab_size=cfg.vocab_size, seed=0)

    params = model.init(jax.random.PRNGKey(0))
    opt = init_state(params, opt_cfg)
    step_fn = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0, 1))
    saver = AsyncCheckpointer(args.ckpt_dir, keep=2)

    start = 0
    last = latest_step(args.ckpt_dir)
    if last is not None:
        tree, _ = restore(args.ckpt_dir, last,
                          {"params": params, "opt": opt})
        params, opt = tree["params"], tree["opt"]
        start = last
        log.info("resumed from step %d", start)

    t0 = time.time()
    for step in range(start, args.steps):
        batch = synthetic_batch(data_cfg, step)
        params, opt, metrics = step_fn(params, opt, batch)
        if step % 20 == 0 or step == args.steps - 1:
            log.info("step %4d loss=%.4f lr=%.2e  %.2fs/step", step,
                     float(metrics["loss"]), float(metrics["lr"]),
                     (time.time() - t0) / max(step - start + 1, 1))
        if step and step % 100 == 0:
            saver.save_async(step, {"params": params, "opt": opt})
    saver.save_async(args.steps, {"params": params, "opt": opt})
    saver.wait()
    log.info("done; final loss %.4f", float(metrics["loss"]))


if __name__ == "__main__":
    main()
