PYTHON ?= python
# src for the repro package, repo root for the benchmarks package
export PYTHONPATH := src:.:$(PYTHONPATH)

.PHONY: test test-tier1 test-deprecations test-chaos test-telemetry \
        test-tuning smoke bench-rmw bench-rmw-sharded bench-atomics \
        bench-reshard calibrate bench-telemetry bench-tuning \
        bench-contention-observe lint-atomics lint-ruff clean

# Tier-1 gate + benchmark smoke (what CI runs).
test: test-tier1 smoke

test-tier1:
	$(PYTHON) -m pytest -x -q

# Deprecation lane (CI): the RMW surface + examples under
# -W error::DeprecationWarning.  The PR-3 shims themselves are deleted
# (tests/test_atomics.py pins their absence); this lane remains the
# tripwire that keeps the surface shim-free — any future warn-and-forward
# alias, ours or a dependency's, fails here first.  pytest.ini already
# errors on repro-originated deprecations in every run.
test-deprecations:
	$(PYTHON) -m pytest -q -W error::DeprecationWarning \
	  tests/test_atomics.py tests/test_rmw.py tests/test_rmw_engine.py \
	  tests/test_bfs.py tests/test_moe.py
	$(PYTHON) -W error::DeprecationWarning examples/sharded_atomics.py \
	  --n-per-device 512 --table 1024

# Chaos lane: deterministic fault injection + bounded-retry CAS loops —
# the seeded chaos matrix (fault-free bit-equality through recovery),
# checkpoint-corruption fallback, and the execute_until <= n-round gates.
# The final line proves the REPRO_CHAOS env hook injects faults into an
# unmodified caller (and that the run still completes).
test-chaos:
	$(PYTHON) -m pytest -q tests/test_chaos.py tests/test_retry.py \
	  tests/test_checkpoint.py tests/test_fault_tolerance.py
	REPRO_CHAOS="seed=7,step=1.0@2" $(PYTHON) -c "\
	from repro.runtime.fault_tolerance import FaultConfig, run_with_recovery;\
	store = {};\
	res = run_with_recovery(lambda s, x: x + 1, 0, 12, \
	    FaultConfig(max_failures=5, checkpoint_every=3, backoff_base_s=0.0), \
	    lambda s, x: store.__setitem__(s, x), \
	    lambda: (max(store), store[max(store)]) if store else None);\
	assert res.failures == 2 and res.steps_done == 12, res;\
	print('REPRO_CHAOS hook ok:', res)"

# Telemetry lane: stream mechanics + sinks, the jit discipline (events at
# trace/dispatch boundaries only — no duplicates across cached executions,
# one decision event per sharded call site on 8 fake devices), drift
# aggregation math, and the recovery-trace events.
test-telemetry:
	$(PYTHON) -m pytest -q tests/test_telemetry.py \
	  tests/test_fault_tolerance.py

# Self-tuning lane: the guarded SpecController — live-spec indirection,
# clamp/hysteresis/deadband guardrails, rollback on induced regression,
# quarantine of poisoned proposals (spec_perturb chaos site), contention-
# estimator feeds, tuned-vs-untuned bit-identity (chaos matrix + train
# metrics), and validated state persistence.
test-tuning:
	$(PYTHON) -m pytest -q tests/test_tuning.py tests/test_chaos.py

# Static atomics contract lint (repro.analysis): traces every registered
# entry point to a jaxpr (no execution) and applies rules A001-A005 —
# races into AtomicTable buffers, CAS-strength downgrades, unbounded
# retry loops, donation hazards, shard-contract violations.  Exit 1 on
# any unsuppressed error-severity finding; its own CI lane.
lint-atomics:
	$(PYTHON) -m repro.analysis.lint

# Style lint (ruff, from requirements-dev.txt).  Guarded: the baked
# container image does not ship ruff — skip with a notice rather than
# fail environments that only have the jax toolchain.
lint-ruff:
	@$(PYTHON) -m ruff --version >/dev/null 2>&1 \
	  && $(PYTHON) -m ruff check src/repro/analysis \
	  || echo "ruff not installed (pip install -r requirements-dev.txt); skipping"

# Where `make smoke` drops its instrumented capture (JSONL, overwritten)
# and the rendered report (CI uploads both as workflow artifacts).
SMOKE_TRACE ?= /tmp/repro_smoke_trace.jsonl
SMOKE_REPORT ?= /tmp/repro_smoke_report.txt

# Fast benchmark smoke: latency + bandwidth + the sharded-RMW exchange +
# the elastic-migration paths + the fault-recovery/bounded-retry gates +
# the telemetry drift/overhead gates (exercises the serialized oracle, the
# combining path, the Pallas kernel, the 8-fake-device distributed
# protocol, both reshard paths, and the chaos-driven recovery loop end to
# end).  The second pass re-runs the latency suite with the telemetry
# stream capturing to $(SMOKE_TRACE) and renders the drift report from
# the captured events — the full observability loop in one make target.
smoke:
	$(PYTHON) benchmarks/run.py --fast \
	  --only latency,bandwidth,rmw_sharded,reshard,fault_recovery,telemetry_drift,contention_observe,analysis,tuning
	REPRO_TELEMETRY=$(SMOKE_TRACE) $(PYTHON) benchmarks/run.py --fast \
	  --only latency
	$(PYTHON) -m repro.telemetry.report $(SMOKE_TRACE) | tee $(SMOKE_REPORT)

# Full RMW backend shoot-out; rewrites benchmarks/results/rmw_backends.json.
bench-rmw:
	$(PYTHON) benchmarks/run.py --only rmw_backends

# Distributed shoot-out (8 fake devices); rewrites results/rmw_sharded.json.
bench-rmw-sharded:
	$(PYTHON) benchmarks/run.py --only rmw_sharded

# Atomics front-end smoke: both execution tiers (engine backends + sharded
# exchange strategies) exercised through repro.atomics.execute; writes the
# *_fast.json variants, never the committed full-grid tables.
bench-atomics:
	$(PYTHON) benchmarks/run.py --fast --only rmw_backends,rmw_sharded

# Elastic-migration shoot-out (8 fake devices): reshard vs full replay,
# in-collective exchange vs host roundtrip; rewrites results/reshard.json.
bench-reshard:
	$(PYTHON) benchmarks/run.py --only reshard

# Telemetry drift + overhead gates, full grid; rewrites
# benchmarks/results/telemetry_drift.json.
bench-telemetry:
	$(PYTHON) benchmarks/run.py --only telemetry_drift

# Self-tuning gates (convergence under perturbation, rollback latency,
# quarantine pair, <5% live-controller overhead, tuned-vs-untuned
# bit-identity incl. the 8-fake-device sharded tier); rewrites
# benchmarks/results/tuning.json.
bench-tuning:
	$(PYTHON) benchmarks/run.py --only tuning

# Contention observatory gates (collect_stats= bit-identity local +
# 8-fake-device sharded, stats-off noise floor, <5% stats-on overhead on
# the contended retry workload, device-fed estimator sites, predicted-vs-
# measured Fig. 8 sweep); rewrites benchmarks/results/contention_observe.json.
bench-contention-observe:
	$(PYTHON) benchmarks/run.py --only contention_observe

# Fit + persist the container HardwareSpec (results/calibrated_spec.json).
calibrate:
	$(PYTHON) benchmarks/run.py --only calibrate

# Full fault-recovery + bounded-retry grid; rewrites
# benchmarks/results/fault_recovery.json.
bench-fault-recovery:
	$(PYTHON) benchmarks/run.py --only fault_recovery

dev-deps:
	pip install -r requirements-dev.txt

# Run artifacts: telemetry ring flushes (artifacts/telemetry/, or a stray
# CWD repro_telemetry_ring.jsonl from pre-observatory checkouts), smoke
# captures, and the uncommitted *_fast.json benchmark variants.
clean:
	rm -rf artifacts
	rm -f repro_telemetry_ring.jsonl $(SMOKE_TRACE) $(SMOKE_REPORT)
	rm -f benchmarks/results/*_fast.json
