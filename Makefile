PYTHON ?= python
# src for the repro package, repo root for the benchmarks package
export PYTHONPATH := src:.:$(PYTHONPATH)

.PHONY: test test-tier1 smoke bench-rmw bench-rmw-sharded calibrate

# Tier-1 gate + benchmark smoke (what CI runs).
test: test-tier1 smoke

test-tier1:
	$(PYTHON) -m pytest -x -q

# Fast benchmark smoke: latency + bandwidth + the sharded-RMW exchange
# (exercises the serialized oracle, the combining path, the Pallas kernel,
# and the 8-fake-device distributed protocol end to end).
smoke:
	$(PYTHON) benchmarks/run.py --fast --only latency,bandwidth,rmw_sharded

# Full RMW backend shoot-out; rewrites benchmarks/results/rmw_backends.json.
bench-rmw:
	$(PYTHON) benchmarks/run.py --only rmw_backends

# Distributed shoot-out (8 fake devices); rewrites results/rmw_sharded.json.
bench-rmw-sharded:
	$(PYTHON) benchmarks/run.py --only rmw_sharded

# Fit + persist the container HardwareSpec (results/calibrated_spec.json).
calibrate:
	$(PYTHON) benchmarks/run.py --only calibrate

dev-deps:
	pip install -r requirements-dev.txt
