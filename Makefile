PYTHON ?= python
# src for the repro package, repo root for the benchmarks package
export PYTHONPATH := src:.:$(PYTHONPATH)

.PHONY: test test-tier1 test-deprecations smoke bench-rmw \
        bench-rmw-sharded bench-atomics calibrate

# Tier-1 gate + benchmark smoke (what CI runs).
test: test-tier1 smoke

test-tier1:
	$(PYTHON) -m pytest -x -q

# Deprecation lane (CI): the RMW surface + examples under
# -W error::DeprecationWarning — no internal caller may reach the legacy
# shims (rmw_run / rmw_execute / rmw_sharded / old arrival_rank names).
# pytest.ini already errors on repro-originated deprecations in every run;
# this lane widens that to ALL DeprecationWarnings over the atomics-facing
# tests and drives an example end to end under the same flag.
test-deprecations:
	$(PYTHON) -m pytest -q -W error::DeprecationWarning \
	  tests/test_atomics.py tests/test_rmw.py tests/test_rmw_engine.py \
	  tests/test_bfs.py tests/test_moe.py
	$(PYTHON) -W error::DeprecationWarning examples/sharded_atomics.py \
	  --n-per-device 512 --table 1024

# Fast benchmark smoke: latency + bandwidth + the sharded-RMW exchange
# (exercises the serialized oracle, the combining path, the Pallas kernel,
# and the 8-fake-device distributed protocol end to end).
smoke:
	$(PYTHON) benchmarks/run.py --fast --only latency,bandwidth,rmw_sharded

# Full RMW backend shoot-out; rewrites benchmarks/results/rmw_backends.json.
bench-rmw:
	$(PYTHON) benchmarks/run.py --only rmw_backends

# Distributed shoot-out (8 fake devices); rewrites results/rmw_sharded.json.
bench-rmw-sharded:
	$(PYTHON) benchmarks/run.py --only rmw_sharded

# Atomics front-end smoke: both execution tiers (engine backends + sharded
# exchange strategies) exercised through repro.atomics.execute; writes the
# *_fast.json variants, never the committed full-grid tables.
bench-atomics:
	$(PYTHON) benchmarks/run.py --fast --only rmw_backends,rmw_sharded

# Fit + persist the container HardwareSpec (results/calibrated_spec.json).
calibrate:
	$(PYTHON) benchmarks/run.py --only calibrate

dev-deps:
	pip install -r requirements-dev.txt
