PYTHON ?= python
# src for the repro package, repo root for the benchmarks package
export PYTHONPATH := src:.:$(PYTHONPATH)

.PHONY: test test-tier1 smoke bench-rmw

# Tier-1 gate + benchmark smoke (what CI runs).
test: test-tier1 smoke

test-tier1:
	$(PYTHON) -m pytest -x -q

# Fast benchmark smoke: latency + bandwidth only (exercises the serialized
# oracle, the combining path, and the Pallas kernel end to end).
smoke:
	$(PYTHON) benchmarks/run.py --fast --only latency,bandwidth

# Full RMW backend shoot-out; rewrites benchmarks/results/rmw_backends.json.
bench-rmw:
	$(PYTHON) benchmarks/run.py --only rmw_backends

dev-deps:
	pip install -r requirements-dev.txt
