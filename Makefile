PYTHON ?= python
# src for the repro package, repo root for the benchmarks package
export PYTHONPATH := src:.:$(PYTHONPATH)

.PHONY: test test-tier1 test-deprecations smoke bench-rmw \
        bench-rmw-sharded bench-atomics bench-reshard calibrate

# Tier-1 gate + benchmark smoke (what CI runs).
test: test-tier1 smoke

test-tier1:
	$(PYTHON) -m pytest -x -q

# Deprecation lane (CI): the RMW surface + examples under
# -W error::DeprecationWarning.  The PR-3 shims themselves are deleted
# (tests/test_atomics.py pins their absence); this lane remains the
# tripwire that keeps the surface shim-free — any future warn-and-forward
# alias, ours or a dependency's, fails here first.  pytest.ini already
# errors on repro-originated deprecations in every run.
test-deprecations:
	$(PYTHON) -m pytest -q -W error::DeprecationWarning \
	  tests/test_atomics.py tests/test_rmw.py tests/test_rmw_engine.py \
	  tests/test_bfs.py tests/test_moe.py
	$(PYTHON) -W error::DeprecationWarning examples/sharded_atomics.py \
	  --n-per-device 512 --table 1024

# Fast benchmark smoke: latency + bandwidth + the sharded-RMW exchange +
# the elastic-migration paths (exercises the serialized oracle, the
# combining path, the Pallas kernel, the 8-fake-device distributed
# protocol, and both reshard paths end to end).
smoke:
	$(PYTHON) benchmarks/run.py --fast \
	  --only latency,bandwidth,rmw_sharded,reshard

# Full RMW backend shoot-out; rewrites benchmarks/results/rmw_backends.json.
bench-rmw:
	$(PYTHON) benchmarks/run.py --only rmw_backends

# Distributed shoot-out (8 fake devices); rewrites results/rmw_sharded.json.
bench-rmw-sharded:
	$(PYTHON) benchmarks/run.py --only rmw_sharded

# Atomics front-end smoke: both execution tiers (engine backends + sharded
# exchange strategies) exercised through repro.atomics.execute; writes the
# *_fast.json variants, never the committed full-grid tables.
bench-atomics:
	$(PYTHON) benchmarks/run.py --fast --only rmw_backends,rmw_sharded

# Elastic-migration shoot-out (8 fake devices): reshard vs full replay,
# in-collective exchange vs host roundtrip; rewrites results/reshard.json.
bench-reshard:
	$(PYTHON) benchmarks/run.py --only reshard

# Fit + persist the container HardwareSpec (results/calibrated_spec.json).
calibrate:
	$(PYTHON) benchmarks/run.py --only calibrate

dev-deps:
	pip install -r requirements-dev.txt
