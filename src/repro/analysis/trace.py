"""Tracing layer: function -> (jaxpr, recorded contract call sites).

`jax.make_jaxpr` runs the function abstractly — **no execution, no
devices** — while the `repro.atomics.contracts` observer records every
atomics API interaction the trace performs: each `AtomicTable`
construction, each `execute` call site (op kind, tier arguments), each
`execute_until` entry.  Array identity crosses into the jaxpr via the
contracts *marker primitive*: table data and op operands pass through an
identity equation tagged with a role (and the call-site id), because
trace-internal `Var` objects are renumbered by jax's literal-inlining
clone pass and cannot be matched by identity afterwards.  The rule engine
(`repro.analysis.rules`) walks the jaxpr and joins marker equations back
to the recorded call sites.

A trace that aborts is still a result: a sharded-table execute outside
``shard_map`` raises the executor's guidance ValueError mid-trace — the
observer already recorded the call site, and the shard-contract rule
(A005) turns (recorded site, aborted trace) into a finding instead of a
crash.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.atomics import contracts
from repro.atomics.ops import AtomicOp
from repro.atomics.table import AtomicTable


@dataclasses.dataclass
class CallSite:
    """One recorded atomics API call inside the traced function."""

    site: str                          # "execute" | "execute_until"
    kind: Optional[str] = None         # op kind for execute sites
    file: Optional[str] = None
    line: Optional[int] = None
    site_id: Optional[int] = None      # joins to marker eqn params["site"]
    table_sharded: bool = False
    axis_names: Tuple[str, ...] = ()
    axes_bound: Optional[bool] = None
    need_fetched: bool = True
    reverse_ranks: bool = False
    n: Optional[int] = None
    uniform_expected: bool = True
    #: jaxpr Vars for indices/values/expected — filled by the rule engine
    #: from this site's marker equations (empty when the operands were
    #: concrete host values)
    vars: Dict[str, Any] = dataclasses.field(default_factory=dict)
    #: concrete values for non-traced arguments (host constants)
    concrete: Dict[str, Any] = dataclasses.field(default_factory=dict)
    max_rounds: Optional[int] = None   # execute_until sites


@dataclasses.dataclass
class TraceResult:
    """Everything `rules.run` consumes."""

    closed: Optional[Any]              # ClosedJaxpr, None if trace aborted
    error: Optional[BaseException]
    callsites: List[CallSite]
    table_invars: List[Any]            # jaxpr invars that arrived as tables
    observer_errors: List[str]


def _axis_names(table: AtomicTable) -> Tuple[str, ...]:
    names: Tuple[str, ...] = ()
    for group in (table.axis, table.replica_axes):
        if group:
            names += (group,) if isinstance(group, str) else tuple(group)
    return names


def _capture_concrete(name: str, x, cs: CallSite) -> None:
    if x is None or isinstance(x, jax.core.Tracer):
        return
    try:
        cs.concrete[name] = np.asarray(x)
    except Exception:  # noqa: BLE001 — non-materializable is fine
        pass


def trace(fn, *args, **kwargs) -> TraceResult:
    """Trace ``fn(*args, **kwargs)`` to a jaxpr under contract observation.

    Arguments may be live arrays or `jax.ShapeDtypeStruct`s (mixing is
    fine); `AtomicTable` arguments are recognized and their jaxpr invars
    recorded as table lineage.  Nothing executes on devices.
    """
    callsites: List[CallSite] = []

    def observer(site: str, fields: Dict[str, Any]) -> None:
        if site == "table":
            return                      # lineage travels via the marker
        file, line = contracts.caller_site()
        cs = CallSite(site=site, file=file, line=line,
                      site_id=fields.get("site_id"))
        table = fields.get("table")
        if isinstance(table, AtomicTable):
            cs.table_sharded = table.is_sharded
            cs.axis_names = _axis_names(table)
        if site == "execute":
            op = fields.get("op")
            if isinstance(op, AtomicOp):
                cs.kind = op.kind
                try:
                    cs.n = int(op.indices.shape[0])
                except Exception:  # noqa: BLE001 — polymorphic shapes
                    pass
                cs.uniform_expected = bool(op.uniform_expected) \
                    if op.kind == "cas" else True
                _capture_concrete("indices", op.indices, cs)
                _capture_concrete("values", op.values, cs)
                _capture_concrete("expected", op.expected, cs)
            cs.need_fetched = bool(fields.get("need_fetched", True))
            cs.reverse_ranks = bool(fields.get("reverse_ranks", False))
            cs.axes_bound = fields.get("axes_bound")
        elif site == "execute_until":
            cs.max_rounds = fields.get("max_rounds")
        callsites.append(cs)

    closed = None
    error: Optional[BaseException] = None

    # trace through a per-call shim, never `fn` itself: jax's trace cache
    # is keyed on (function identity, avals) but NOT on the contracts
    # observer, so tracing `fn` directly would (a) replay a stale
    # marker-free jaxpr if the caller traced `fn` before linting and
    # (b) leave a marker-bearing jaxpr in the cache for the caller's own
    # later traces.  The shim is a fresh key each time and dies with it.
    def _shim(*a, **kw):
        return fn(*a, **kw)

    with contracts.observe(observer) as errs:
        try:
            closed = jax.make_jaxpr(_shim)(*args, **kwargs)
        except Exception as e:  # noqa: BLE001 — aborted traces are results
            error = e
        observer_errors = list(errs)

    table_invars: List[Any] = []
    if closed is not None:
        # each flat leaf of (args, kwargs) binds one jaxpr invar, in
        # flattening order; an AtomicTable is a one-leaf pytree (data)
        flat, _ = jax.tree_util.tree_flatten(
            (args, kwargs), is_leaf=lambda x: isinstance(x, AtomicTable))
        invars = closed.jaxpr.invars
        for pos, node in enumerate(flat):
            if isinstance(node, AtomicTable) and pos < len(invars):
                table_invars.append(invars[pos])
    return TraceResult(closed=closed, error=error, callsites=callsites,
                       table_invars=table_invars,
                       observer_errors=observer_errors)
