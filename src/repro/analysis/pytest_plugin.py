"""Pytest integration: the ``atomics_lint`` fixture.

Re-export it from a ``conftest.py`` to make it available to a suite::

    from repro.analysis.pytest_plugin import atomics_lint  # noqa: F401

Then in tests::

    def test_my_kernel_clean(atomics_lint):
        atomics_lint(my_fn, example_args)          # raises on errors

    def test_entry_points_clean(atomics_lint):
        atomics_lint.sweep()                       # all registered entries

The fixture object is callable (``check`` + assert) and carries
``.sweep(names=None)`` for entry-point sweeps; both raise
``pytest.fail`` with the formatted findings when any unsuppressed
error-severity finding is present, and return the findings list
otherwise so tests can assert on warnings too.
"""

from __future__ import annotations

from typing import List, Optional, Sequence

import pytest

from repro import analysis
from repro.analysis.findings import ERROR, Finding


class AtomicsLint:
    """Assertion helper wrapping `analysis.check` / `lint.sweep`."""

    @staticmethod
    def _gate(findings: List[Finding]) -> List[Finding]:
        errors = [f for f in findings
                  if f.severity == ERROR and not f.suppressed]
        if errors:
            pytest.fail("atomics lint errors:\n" + "\n".join(
                f.format() for f in errors), pytrace=False)
        return findings

    def __call__(self, fn, *args, **kwargs) -> List[Finding]:
        return self._gate(analysis.check(fn, *args, **kwargs))

    def check_recovery(self, step_fn, init_state, **kw) -> List[Finding]:
        return self._gate(analysis.check_recovery(step_fn, init_state,
                                                  **kw))

    def sweep(self, names: Optional[Sequence[str]] = None
              ) -> List[Finding]:
        from repro.analysis.lint import sweep
        return self._gate([f for fs in sweep(names).values() for f in fs])


@pytest.fixture
def atomics_lint() -> AtomicsLint:
    return AtomicsLint()
