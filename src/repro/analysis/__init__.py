"""repro.analysis — jaxpr-level atomics race detector & contract linter.

Static analysis over traced jaxprs: `check(fn, *args)` traces ``fn`` with
`jax.make_jaxpr` (no execution, no devices) under the
`repro.atomics.contracts` observer and applies the rule engine:

====  ========  =====================================================
id    severity  what it catches
====  ========  =====================================================
A001  error     raw scatter / ``.at[].set``/``.add`` into an
                AtomicTable buffer, or aliasing-capable scatter races
A002  warning   CAS batches expressible as Faa/Min/Max/Swp
                (consensus number 2 instead of ∞)
A003  warning   unbounded while+CAS retry loops (use
                ``atomics.execute_until``)
A004  error     donated buffers read after the donating call; donating
                step functions handed to recovery without a factory
A005  error     sharded-table execute outside shard_map / unbound mesh
                axes / incoherent mixed ``reverse_ranks``
====  ========  =====================================================

Suppress a deliberate pattern with ``# atomics-lint: disable=A001`` on
(or directly above) the flagged line — suppressed findings stay visible
in output, marked, so silenced true positives remain auditable.

CLI: ``python -m repro.analysis.lint`` sweeps the registered entry points
(`repro.analysis.entries`).  Pytest: the ``atomics_lint`` fixture
(`repro.analysis.pytest_plugin`) asserts clean passes in test suites.
"""

from __future__ import annotations

import inspect
from typing import Callable, Iterable, List, Optional

from repro import telemetry
from repro.analysis.findings import (ERROR, RULES, WARNING, Finding,
                                     apply_suppressions, make_finding)
from repro.analysis import rules as _rules
from repro.analysis import trace as _trace

__all__ = ["check", "check_recovery", "Finding", "RULES", "ERROR",
           "WARNING"]

_SEV_ORDER = {ERROR: 0, WARNING: 1}


def _finalize(findings: List[Finding], entry: Optional[str],
              ignore: Iterable[str]) -> List[Finding]:
    ignore = set(ignore)
    findings = [f for f in findings if f.rule not in ignore]
    apply_suppressions(findings)
    for f in findings:
        f.entry = entry
        telemetry.record("analysis.finding", rule=f.rule,
                         severity=f.severity, file=f.file, line=f.line,
                         entry=entry, suppressed=f.suppressed,
                         message=f.message)
    findings.sort(key=lambda f: (_SEV_ORDER.get(f.severity, 2), f.rule,
                                 f.where))
    return findings


def check(fn: Callable, *args, entry: Optional[str] = None,
          ignore: Iterable[str] = (), **kwargs) -> List[Finding]:
    """Statically check ``fn(*args, **kwargs)`` against all rules.

    Arguments may be concrete arrays, `jax.ShapeDtypeStruct` stand-ins, or
    `AtomicTable`s (mixing is fine); nothing executes.  Returns findings
    sorted errors-first; ``ignore`` drops whole rule ids; per-line
    suppression comments mark (not drop) findings.
    """
    tr = _trace.trace(fn, *args, **kwargs)
    return _finalize(_rules.run(tr), entry, ignore)


def _donate_argnums(step_fn, example_args) -> tuple:
    """Best-effort donation metadata for a step function: an explicit
    ``declare_donation`` wrapper, or jit's own trace-time report."""
    d = getattr(step_fn, "donate_argnums", None)
    if d:
        return tuple(d)
    if example_args is not None:
        try:
            return tuple(step_fn.trace(*example_args).donate_argnums or ())
        except Exception:  # noqa: BLE001 — not a jitted fn / trace failed
            pass
    return ()


def check_recovery(step_fn: Callable, init_state,
                   *, example_args=None, entry: Optional[str] = None,
                   ignore: Iterable[str] = ()) -> List[Finding]:
    """The API-level half of rule A004: a donating step function handed to
    `runtime.fault_tolerance.run_with_recovery` together with a *captured
    state value* (instead of a zero-arg factory) re-feeds donated — hence
    possibly aliased — buffers on every restart.  This is exactly the PR-6
    recovery bug, caught statically.
    """
    findings: List[Finding] = []
    donated = _donate_argnums(step_fn, example_args)
    if donated and not callable(init_state):
        fn = inspect.unwrap(getattr(step_fn, "fn", step_fn))
        file = line = None
        try:
            file = inspect.getsourcefile(fn)
            _, line = inspect.getsourcelines(fn)
        except (TypeError, OSError):
            pass
        findings.append(make_finding(
            "A004",
            f"step function donates argnums {tuple(donated)} but "
            f"run_with_recovery received a captured state value — after the "
            f"first step the captured buffers are donated away, and every "
            f"recovery restart replays aliased garbage; pass a zero-arg "
            f"state factory (init_state=lambda: ...) so restarts rebuild "
            f"fresh buffers", file=file, line=line,
            provenance="check_recovery"))
    return _finalize(findings, entry, ignore)
