"""Typed findings: what every analysis rule returns.

A :class:`Finding` is one diagnosed contract violation with enough
provenance to act on: the rule id (A001..A005 — see :data:`RULES`), a
severity (``error`` fails `make lint-atomics`; ``warning`` does not), the
source location the offending jaxpr equation (or API call site) traces to,
and a human message that says what to do instead.

Suppression is source-comment based, pylint-style: a finding is marked
``suppressed`` when the flagged line — or the line directly above it —
carries ``# atomics-lint: disable=<rule-id>[,<rule-id>...]`` (or
``disable=all``).  Suppressions are *visible* in lint output (counted, not
hidden) so a silenced true positive stays auditable.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

ERROR = "error"
WARNING = "warning"

#: rule id -> (default severity, one-line description).  The README's
#: "Static analysis" table renders from the same text.
RULES: Dict[str, Tuple[str, str]] = {
    "A000": (ERROR,
             "analysis could not complete — the trace aborted for an "
             "undiagnosed reason or an entry point crashed; never a clean "
             "pass"),
    "A001": (ERROR,
             "raw scatter write into an AtomicTable-typed buffer (or "
             "duplicate-capable scatter on a multiply-written buffer) — "
             "bypasses atomics.execute; XLA duplicate-index ordering is "
             "undefined"),
    "A002": (WARNING,
             "CAS batch expressible as a lower-consensus-number primitive "
             "(Faa/Min/Max/Swp) — arxiv 1802.03844"),
    "A003": (WARNING,
             "while_loop wraps a CAS with data-dependent trip count and no "
             "round bound — use atomics.execute_until(max_rounds=...)"),
    "A004": (ERROR,
             "donated buffer read after the donating call — the PR-6 "
             "recovery-restart bug class (pass a zero-arg state factory)"),
    "A005": (ERROR,
             "sharded-table execute outside shard_map / unbound mesh axes, "
             "or mixed reverse_ranks directions across a combine tree"),
}

#: the magic comment token (``# atomics-lint: disable=A001``)
SUPPRESS_TOKEN = "atomics-lint:"


@dataclasses.dataclass
class Finding:
    """One diagnosed violation.

    ``file``/``line`` point at user source (jaxpr equation provenance via
    ``source_info``, or the recorded API call site); ``provenance`` names
    the jaxpr primitive / call path for debugging; ``entry`` the registered
    entry point a CLI sweep found it under.
    """

    rule: str
    severity: str
    message: str
    file: Optional[str] = None
    line: Optional[int] = None
    entry: Optional[str] = None
    provenance: Optional[str] = None
    suppressed: bool = False

    @property
    def where(self) -> str:
        if self.file is None:
            return "<unknown>"
        return f"{self.file}:{self.line}" if self.line else self.file

    def format(self) -> str:
        sup = " [suppressed]" if self.suppressed else ""
        prov = f"  ({self.provenance})" if self.provenance else ""
        return (f"{self.where}: {self.severity.upper()} {self.rule}{sup}: "
                f"{self.message}{prov}")


def make_finding(rule: str, message: str, *, file=None, line=None,
                 provenance=None, severity: Optional[str] = None) -> Finding:
    """Construct a Finding with the rule's default severity."""
    default_sev, _ = RULES[rule]
    return Finding(rule=rule, severity=severity or default_sev,
                   message=message, file=file, line=line,
                   provenance=provenance)


@functools.lru_cache(maxsize=256)
def _source_lines(path: str) -> Tuple[str, ...]:
    try:
        with open(path, encoding="utf-8", errors="replace") as f:
            return tuple(f.readlines())
    except OSError:
        return ()


def _line_suppresses(text: str, rule: str) -> bool:
    pos = text.find(SUPPRESS_TOKEN)
    if pos < 0:
        return False
    rest = text[pos + len(SUPPRESS_TOKEN):]
    if "disable=" not in rest:
        return False
    spec = rest.split("disable=", 1)[1].split()[0]
    ids = {s.strip() for s in spec.split(",")}
    return "all" in ids or rule in ids


def apply_suppressions(findings) -> None:
    """Mark findings whose flagged line (or the line above) carries a
    matching ``# atomics-lint: disable=`` comment.  In place."""
    for f in findings:
        if f.file is None or not f.line:
            continue
        lines = _source_lines(f.file)
        for ln in (f.line, f.line - 1):
            if 1 <= ln <= len(lines) and _line_suppresses(lines[ln - 1],
                                                          f.rule):
                f.suppressed = True
                break
