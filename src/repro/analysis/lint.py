"""CLI sweep: ``python -m repro.analysis.lint``.

Traces every registered entry point (`repro.analysis.entries`) and prints
the findings.  Exit status is 1 iff any **unsuppressed error-severity**
finding exists — warnings and suppressed findings are printed (and
counted) but do not fail the build, so `make lint-atomics` can gate CI on
the race/donation/shard-contract rules while the strength/retry hints
stay advisory.

    python -m repro.analysis.lint                # sweep everything
    python -m repro.analysis.lint --entries moe.local,bfs.local
    python -m repro.analysis.lint --json         # machine-readable
    python -m repro.analysis.lint --list         # show registered entries
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import traceback
from typing import Dict, List, Optional, Sequence

from repro.analysis.entries import ENTRY_POINTS
from repro.analysis.findings import ERROR, Finding, make_finding


def sweep(entries: Optional[Sequence[str]] = None
          ) -> Dict[str, List[Finding]]:
    """Run the named entries (default: all); a crashing entry yields a
    single A000 error finding instead of aborting the sweep."""
    names = list(entries) if entries else list(ENTRY_POINTS)
    out: Dict[str, List[Finding]] = {}
    for name in names:
        fn = ENTRY_POINTS.get(name)
        if fn is None:
            out[name] = [make_finding(
                "A000", f"unknown entry point {name!r} (registered: "
                        f"{', '.join(ENTRY_POINTS)})",
                provenance="lint.sweep")]
            continue
        try:
            out[name] = fn()
        except Exception as e:  # noqa: BLE001 — a crash is a finding
            tb = traceback.extract_tb(e.__traceback__)
            last = tb[-1] if tb else None
            f = make_finding(
                "A000", f"entry point crashed: {type(e).__name__}: {e}",
                file=last.filename if last else None,
                line=last.lineno if last else None,
                provenance="lint.sweep")
            f.entry = name
            out[name] = [f]
    return out


def _summary(results: Dict[str, List[Finding]]) -> Dict[str, int]:
    flat = [f for fs in results.values() for f in fs]
    return {
        "entries": len(results),
        "findings": len(flat),
        "errors": sum(1 for f in flat
                      if f.severity == ERROR and not f.suppressed),
        "warnings": sum(1 for f in flat
                        if f.severity != ERROR and not f.suppressed),
        "suppressed": sum(1 for f in flat if f.suppressed),
    }


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="static atomics contract linter (jaxpr-level)")
    ap.add_argument("--entries", default=None,
                    help="comma-separated entry names (default: all)")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as JSON on stdout")
    ap.add_argument("--list", action="store_true",
                    help="list registered entry points and exit")
    args = ap.parse_args(argv)

    if args.list:
        for name in ENTRY_POINTS:
            print(name)
        return 0

    names = ([s.strip() for s in args.entries.split(",") if s.strip()]
             if args.entries else None)
    results = sweep(names)
    summary = _summary(results)

    if args.json:
        payload = {
            "summary": summary,
            "findings": [dataclasses.asdict(f)
                         for fs in results.values() for f in fs],
        }
        print(json.dumps(payload, indent=2, default=str))
    else:
        for name, findings in results.items():
            mark = "clean" if not findings else \
                f"{len(findings)} finding(s)"
            print(f"[{name}] {mark}")
            for f in findings:
                print(f"  {f.format()}")
        print(f"swept {summary['entries']} entries: "
              f"{summary['errors']} error(s), "
              f"{summary['warnings']} warning(s), "
              f"{summary['suppressed']} suppressed")
    return 1 if summary["errors"] else 0


if __name__ == "__main__":
    sys.exit(main())
