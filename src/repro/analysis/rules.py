"""The rule engine: walk a jaxpr (recursively) and apply checkers A001-A005.

Rules
=====

A001 (error)   race detector — a scatter-family primitive writing an
               AtomicTable-lineage buffer without coming from a sanctioned
               RMW module (`contracts.SANCTIONED_PATHS`), or a
               duplicate-capable set-style scatter / a multiply-scattered
               plain buffer with potentially-aliasing indices.  XLA leaves
               duplicate-index scatter-set ordering undefined; table writes
               additionally bypass the serialized-equivalence contract.
A002 (warn)    primitive strength — a `Cas` whose update value is
               ``expected + d`` / ``max(expected, x)`` / ``min`` /
               ``expected`` itself is expressible as Faa/Max/Min/a read:
               consensus number 2 beats ∞ when 2 is all you need
               (arxiv 1802.03844; `AtomicOp.CONSENSUS_NUMBER`).
A003 (warn)    unbounded retry — a `while_loop` whose body issues a CAS and
               whose continuation predicate depends on *no* counter-like
               carry: the trip count is purely data-dependent (the CAS-storm
               shape of arxiv 1305.5800).  `atomics.execute_until` is the
               bounded, policy-driven spelling.
A004 (error)   donation safety — a jitted call that donates an input buffer
               which a *later* equation (or the function result) still
               reads: the donated buffer may already be aliased to the
               output.  The API-level half (donating step functions handed
               to recovery without a state factory) lives in
               `analysis.check_recovery`.
A005 (error)   shard contract — an `execute` on a mesh-sharded table whose
               declared axes are not bound (outside ``shard_map``), or
               mixed ``reverse_ranks`` directions over one combine tree
               whose forward pass never fetched (no pre-image feedback, so
               the reversed stream cannot be a revert).

Everything here is pure jaxpr walking + the `trace.TraceResult` side
channel; no execution.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Set, Tuple

import numpy as np

from jax.extend.core import Literal, Var

from repro.atomics import contracts
from repro.atomics.ops import OP_KINDS
from repro.analysis.findings import Finding, make_finding
from repro.analysis.trace import CallSite, TraceResult

try:
    from jax._src import source_info_util as _siu
except Exception:  # noqa: BLE001 — provenance degrades, rules still run
    _siu = None

#: scatter-family primitive names (set-style "scatter" is the
#: undefined-ordering one; add/mul/min/max are duplicate-commutative)
SCATTER_PRIMS = ("scatter", "scatter-add", "scatter-mul", "scatter-min",
                 "scatter-max")

#: shape-preserving wrappers resolved through when chasing a value to the
#: equation that actually computes it (the contracts marker is an identity)
_TRANSPARENT = ("convert_element_type", "broadcast_in_dim", "reshape",
                "squeeze", "expand_dims", "copy", "stop_gradient",
                "transpose", contracts.MARKER)


def _frames(eqn) -> List[Any]:
    if _siu is None:
        return []
    si = getattr(eqn, "source_info", None)
    if si is None:
        return []
    try:
        return list(_siu.user_frames(si))
    except Exception:  # noqa: BLE001
        return []


def _sanctioned(eqn) -> bool:
    """True when any user frame of the equation lives in a sanctioned RMW
    module — the scatter is the engine's own, not a bypass."""
    for fr in _frames(eqn):
        fname = getattr(fr, "file_name", "").replace("\\", "/")
        if any(p in fname for p in contracts.SANCTIONED_PATHS):
            return True
    return False


def _loc(eqn) -> Tuple[Optional[str], Optional[int]]:
    frames = _frames(eqn)
    if not frames:
        return None, None
    fr = frames[0]                       # innermost user frame
    return getattr(fr, "file_name", None), getattr(fr, "start_line", None)


class _Ctx:
    """Mutable state threaded through the recursive walk."""

    def __init__(self, tr: TraceResult):
        self.tr = tr
        self.findings: List[Finding] = []
        self.table_vars: Set[Var] = set(tr.table_invars)
        self.defs: Dict[Var, Any] = {}            # var -> defining eqn
        self.const_vals: Dict[Var, Any] = {}      # constvar -> concrete
        self._roots: Dict[Var, Var] = {}          # buffer lineage union
        self.root_writes: Dict[Var, List[Any]] = {}
        self.site_map = {cs.site_id: cs for cs in tr.callsites
                         if cs.site_id is not None}
        self._cas_cache: Dict[int, bool] = {}

    def root(self, v):
        seen = []
        while v in self._roots and self._roots[v] is not v:
            seen.append(v)
            v = self._roots[v]
        for s in seen:
            self._roots[s] = v
        return v

    def link(self, child: Var, parent) -> None:
        if isinstance(parent, Var):
            self._roots[child] = self.root(parent)

    def emit(self, rule: str, message: str, eqn=None, file=None, line=None,
             provenance=None) -> None:
        if eqn is not None and file is None:
            file, line = _loc(eqn)
            provenance = provenance or eqn.primitive.name
        self.findings.append(make_finding(rule, message, file=file,
                                          line=line, provenance=provenance))


def _as_open(j):
    return j.jaxpr if hasattr(j, "jaxpr") else j


def _consts_of(j) -> List[Any]:
    return list(getattr(j, "consts", ()) or ())


def _sub_jaxprs(eqn):
    """Yield (jaxpr-like, [(outer, inner_invar)...], [(inner_outvar,
    outer_outvar)...]) for every sub-jaxpr of ``eqn`` with its variable
    correspondence (best effort — unknown primitives fall back to a 1:1
    mapping when arities line up, else no mapping)."""
    name = eqn.primitive.name
    p = eqn.params
    out = []
    if name == "while":
        cj, bj = p["cond_jaxpr"], p["body_jaxpr"]
        cn, bn = p["cond_nconsts"], p["body_nconsts"]
        carry = list(eqn.invars[cn + bn:])
        c_open, b_open = _as_open(cj), _as_open(bj)
        out.append((cj, list(zip(list(eqn.invars[:cn]) + carry,
                                 c_open.invars)), []))
        out.append((bj, list(zip(list(eqn.invars[cn:cn + bn]) + carry,
                                 b_open.invars)),
                    list(zip(b_open.outvars, eqn.outvars))))
    elif name == "scan":
        j = p["jaxpr"]
        jo = _as_open(j)
        k = p.get("num_consts", 0) + p.get("num_carry", 0)
        out.append((j, list(zip(eqn.invars[:k], jo.invars[:k])),
                    list(zip(jo.outvars[:p.get("num_carry", 0)],
                             eqn.outvars[:p.get("num_carry", 0)]))))
    elif name == "cond":
        for br in p.get("branches", ()):
            bo = _as_open(br)
            out.append((br, list(zip(eqn.invars[1:], bo.invars)),
                        list(zip(bo.outvars, eqn.outvars))))
    else:
        for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
            j = p.get(key)
            if j is None:
                continue
            jo = _as_open(j)
            inmap = list(zip(eqn.invars, jo.invars)) \
                if len(jo.invars) == len(eqn.invars) else []
            outmap = list(zip(jo.outvars, eqn.outvars)) \
                if len(jo.outvars) == len(eqn.outvars) else []
            out.append((j, inmap, outmap))
            break
    return out


# ---------------------------------------------------------------------------
# value chasing (A001 index provenance, A002 pattern match)
# ---------------------------------------------------------------------------

def _resolve(ctx: _Ctx, v, limit: int = 32):
    """Follow shape-preserving wrapper equations up the def chain."""
    for _ in range(limit):
        if not isinstance(v, Var):
            return v
        eqn = ctx.defs.get(v)
        if eqn is None or eqn.primitive.name not in _TRANSPARENT:
            return v
        src = next((iv for iv in eqn.invars if isinstance(iv, Var)),
                   eqn.invars[0] if eqn.invars else None)
        if src is None:
            return v
        v = src
    return v


def _is_const_operand(ctx: _Ctx, x) -> bool:
    return isinstance(x, Literal) or (isinstance(x, Var)
                                      and x in ctx.const_vals)


def _unique_base(ctx: _Ctx, v, depth: int = 0):
    """Resolve ``v`` through *injective* transformations to its base:
    shape-preserving wrappers, ``x ± const``, and ``select_n`` whose data
    branches share one base (jnp's negative-index normalization
    ``where(x < 0, x + n, x)``).  Injective steps preserve distinctness,
    so uniqueness of the base implies uniqueness of ``v``."""
    if depth > 16 or not isinstance(v, Var):
        return v
    eqn = ctx.defs.get(v)
    if eqn is None:
        return v
    name = eqn.primitive.name
    if name in _TRANSPARENT:
        src = next((iv for iv in eqn.invars if isinstance(iv, Var)), None)
        return v if src is None else _unique_base(ctx, src, depth + 1)
    if name in ("add", "sub"):
        data = [iv for iv in eqn.invars
                if not _is_const_operand(ctx, iv)]
        if len(data) == 1 and isinstance(data[0], Var):
            return _unique_base(ctx, data[0], depth + 1)
        return v
    if name == "select_n":
        bases = [_unique_base(ctx, b, depth + 1) for b in eqn.invars[1:]]
        if bases and all(b is bases[0] for b in bases[1:]):
            return bases[0]
        return v
    return v


def _indices_provably_unique(ctx: _Ctx, idx) -> bool:
    """True when the scatter's index operand is statically known
    collision-free: concrete non-negative unique indices, or an injective
    chain over an iota (e.g. ``.at[jnp.arange(n)]``)."""
    v = _unique_base(ctx, idx)
    arr = None
    if isinstance(v, Literal):
        arr = np.asarray(v.val)
    elif isinstance(v, Var) and v in ctx.const_vals:
        arr = np.asarray(ctx.const_vals[v])
    if arr is not None:
        flat = arr.reshape(-1)
        # negatives wrap through the normalization select, so a raw
        # uniqueness check only holds for the non-negative case
        return bool((flat >= 0).all()
                    and len(np.unique(flat)) == flat.size)
    if isinstance(v, Var):
        eqn = ctx.defs.get(v)
        if eqn is not None and eqn.primitive.name == "iota":
            return True
        # concatenation of iota-derived pieces etc. stays "dynamic"
    return False


def _n_updates(idx) -> int:
    aval = getattr(idx, "aval", None)
    shape = getattr(aval, "shape", ())
    return int(shape[0]) if shape else 1


# ---------------------------------------------------------------------------
# the walk
# ---------------------------------------------------------------------------

def _walk(ctx: _Ctx, jaxpr_like) -> None:
    jaxpr = _as_open(jaxpr_like)
    for cv, val in zip(jaxpr.constvars, _consts_of(jaxpr_like)):
        ctx.const_vals[cv] = val

    # per-jaxpr liveness for A004: last equation index using each var
    last_use: Dict[Var, int] = {}
    n_eqns = len(jaxpr.eqns)
    for i, eqn in enumerate(jaxpr.eqns):
        for v in eqn.invars:
            if isinstance(v, Var):
                last_use[v] = i
    for v in jaxpr.outvars:
        if isinstance(v, Var):
            last_use[v] = n_eqns           # "used by the result"

    for i, eqn in enumerate(jaxpr.eqns):
        for ov in eqn.outvars:
            ctx.defs[ov] = eqn
        name = eqn.primitive.name

        if name == contracts.MARKER:
            src = eqn.invars[0]
            ctx.link(eqn.outvars[0], src)
            role = eqn.params.get("role")
            if role == "table":
                if isinstance(src, Var):
                    ctx.table_vars.add(src)
                ctx.table_vars.add(eqn.outvars[0])
            elif role and role.startswith("op_"):
                cs = ctx.site_map.get(eqn.params.get("site"))
                if cs is not None and isinstance(src, Var):
                    cs.vars[role[3:]] = src

        if name in SCATTER_PRIMS or name == "dynamic_update_slice":
            operand = eqn.invars[0]
            ctx.link(eqn.outvars[0], operand)
            is_table = isinstance(operand, Var) and (
                operand in ctx.table_vars
                or ctx.root(operand) in ctx.table_vars)
            if is_table:
                ctx.table_vars.add(eqn.outvars[0])
            if name in SCATTER_PRIMS:
                _rule_a001(ctx, eqn, is_table)

        if name == "while":
            _rule_a003(ctx, eqn)

        don = eqn.params.get("donated_invars") if name == "pjit" else None
        if don and any(don):
            _rule_a004(ctx, eqn, don, last_use, i, n_eqns)

        for sub, inmap, outmap in _sub_jaxprs(eqn):
            for outer, inner in inmap:
                if isinstance(outer, Var) and isinstance(inner, Var):
                    if outer in ctx.table_vars \
                            or ctx.root(outer) in ctx.table_vars:
                        ctx.table_vars.add(inner)
                    ctx.link(inner, outer)
            _walk(ctx, sub)
            for inner, outer in outmap:
                if isinstance(inner, Var) and isinstance(outer, Var):
                    if inner in ctx.table_vars \
                            or ctx.root(inner) in ctx.table_vars:
                        ctx.table_vars.add(outer)
                    ctx.link(outer, inner)


# ---------------------------------------------------------------------------
# A001 — race detector
# ---------------------------------------------------------------------------

def _rule_a001(ctx: _Ctx, eqn, is_table: bool) -> None:
    if _sanctioned(eqn):
        return
    name = eqn.primitive.name
    operand, indices = eqn.invars[0], eqn.invars[1]
    if is_table:
        ctx.emit("A001",
                 "raw scatter write into AtomicTable data bypasses "
                 "atomics.execute — duplicate-index ordering is undefined "
                 "and the serialized-equivalence contract is lost; route "
                 "the update through repro.atomics.execute", eqn=eqn)
        return
    n = _n_updates(indices)
    if n <= 1:
        return                          # a single update cannot self-alias
    if eqn.params.get("unique_indices", False):
        return                          # caller vouched for distinctness
    if _indices_provably_unique(ctx, indices):
        return
    root = ctx.root(operand) if isinstance(operand, Var) else None
    writes = ctx.root_writes.setdefault(root, []) if root is not None else []
    writes.append(eqn)
    if name == "scatter":
        ctx.emit("A001",
                 f"set-style scatter with potentially-aliasing dynamic "
                 f"indices ({n} updates): XLA duplicate-index ordering is "
                 f"undefined — pass unique_indices=True if collisions are "
                 f"impossible, or use atomics.execute (Swp) for "
                 f"last-writer-wins semantics", eqn=eqn,
                 provenance="scatter")
    elif len(writes) > 1:
        ctx.emit("A001",
                 f"buffer receives multiple {name} writes with "
                 f"potentially-aliasing indices in one jaxpr — hand-rolled "
                 f"read-modify-write; use repro.atomics.execute for "
                 f"serialized-equivalent semantics", eqn=eqn,
                 provenance=name)


# ---------------------------------------------------------------------------
# A003 — unbounded-retry detector
# ---------------------------------------------------------------------------

def _contains_cas(ctx: _Ctx, jaxpr_like) -> bool:
    """True when the jaxpr (recursively) holds a CAS op-marker equation —
    i.e. some `atomics.execute(Cas(...))` was traced inside it."""
    jaxpr = _as_open(jaxpr_like)
    cached = ctx._cas_cache.get(id(jaxpr))
    if cached is not None:
        return cached
    found = False
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == contracts.MARKER \
                and eqn.params.get("kind") == "cas":
            found = True
            break
        if any(_contains_cas(ctx, sub) for sub, _, _ in _sub_jaxprs(eqn)):
            found = True
            break
    ctx._cas_cache[id(jaxpr)] = found
    return found


def _cond_influencing_positions(cond_open, nconsts: int) -> List[int]:
    """Carry positions whose value reaches the loop predicate (backward
    slice from the cond jaxpr's outputs)."""
    needed: Set[Var] = {v for v in cond_open.outvars if isinstance(v, Var)}
    for eqn in reversed(cond_open.eqns):
        if any(ov in needed for ov in eqn.outvars):
            needed.update(v for v in eqn.invars if isinstance(v, Var))
    carry = cond_open.invars[nconsts:]
    return [i for i, v in enumerate(carry) if v in needed]


def _is_counter_carry(ctx: _Ctx, body_open, nconsts: int, pos: int) -> bool:
    """True when carry ``pos`` is a monotone counter: its body output is
    ``add/sub(carry_in, constant)`` (through wrapper hops)."""
    if nconsts + pos >= len(body_open.invars) \
            or pos >= len(body_open.outvars):
        return False
    inv = body_open.invars[nconsts + pos]
    outv = body_open.outvars[pos]
    if not isinstance(outv, Var):
        return False
    defs = {ov: e for e in body_open.eqns for ov in e.outvars}
    v = outv
    for _ in range(8):                  # resolve convert/broadcast hops
        e = defs.get(v)
        if e is None:
            return False
        if e.primitive.name in _TRANSPARENT:
            v = next((iv for iv in e.invars if isinstance(iv, Var)), None)
            if v is None:
                return False
            continue
        break
    if e is None or e.primitive.name not in ("add", "sub"):
        return False
    ops = []
    for iv in e.invars:
        if isinstance(iv, Var):
            w = iv
            for _ in range(8):
                d = defs.get(w)
                if d is not None and d.primitive.name in _TRANSPARENT:
                    nxt = next((x for x in d.invars if isinstance(x, Var)),
                               None)
                    if nxt is None:
                        break
                    w = nxt
                else:
                    break
            ops.append(w)
        else:
            ops.append(iv)
    has_self = any(o is inv for o in ops)
    has_const = any(isinstance(o, Literal) or
                    (isinstance(o, Var) and o not in defs and o is not inv)
                    for o in ops)
    return has_self and has_const


def _rule_a003(ctx: _Ctx, eqn) -> None:
    if _sanctioned(eqn):
        return
    p = eqn.params
    body, cond = p["body_jaxpr"], p["cond_jaxpr"]
    if not _contains_cas(ctx, body):
        return                          # loop body issues no CAS
    cond_open, body_open = _as_open(cond), _as_open(body)
    cn, bn = p["cond_nconsts"], p["body_nconsts"]
    for pos in _cond_influencing_positions(cond_open, cn):
        if _is_counter_carry(ctx, body_open, bn, pos):
            return                      # a round counter bounds the loop
    ctx.emit("A003",
             "while_loop retries a CAS with a data-dependent predicate and "
             "no counter-like round bound — under contention this is the "
             "unbounded CAS storm of arxiv 1305.5800; use "
             "atomics.execute_until(make_ops, max_rounds=..., policy=...) "
             "or add a bounded round counter to the carry", eqn=eqn)


# ---------------------------------------------------------------------------
# A004 — donation safety (jaxpr half)
# ---------------------------------------------------------------------------

def _rule_a004(ctx: _Ctx, eqn, donated, last_use, idx: int,
               n_eqns: int) -> None:
    if _sanctioned(eqn):
        return
    for i, d in enumerate(donated):
        if not d or i >= len(eqn.invars):
            continue
        v = eqn.invars[i]
        if not isinstance(v, Var):
            continue
        lu = last_use.get(v, -1)
        if lu > idx:
            how = "the function result" if lu == n_eqns \
                else "a later equation"
            ctx.emit("A004",
                     f"buffer donated to a jitted call (donate_argnums) is "
                     f"still read by {how} — after donation the buffer may "
                     f"alias the callee's output; keep a copy or drop the "
                     f"donation", eqn=eqn, provenance="pjit donated_invars")


# ---------------------------------------------------------------------------
# A002 / A005 — call-site rules (run even when the trace aborted)
# ---------------------------------------------------------------------------

def _rule_a002(ctx: _Ctx, cs: CallSite) -> None:
    cas_cn = OP_KINDS["cas"].CONSENSUS_NUMBER
    faa_cn = OP_KINDS["faa"].CONSENSUS_NUMBER

    def _say(alt: str, why: str) -> None:
        ctx.findings.append(make_finding(
            "A002",
            f"Cas batch (consensus number {cas_cn}) {why} — express it as "
            f"atomics.{alt} (consensus number {faa_cn}): same cost on every "
            f"tier (the paper's headline result), combinable instead of "
            f"serialized, and no retry loop needed (arxiv 1802.03844)",
            file=cs.file, line=cs.line, provenance="atomics.Cas"))

    c_vals = cs.concrete.get("values")
    c_exp = cs.concrete.get("expected")
    v_vals = cs.vars.get("values")
    v_exp = cs.vars.get("expected")

    if c_vals is not None and c_exp is not None:
        try:
            if np.array_equal(np.broadcast_to(c_exp, c_vals.shape), c_vals):
                _say("execute(..., need_fetched=True) read or Swp",
                     "writes back exactly its expected value (a no-op when "
                     "it succeeds)")
                return
            diff = c_vals - np.broadcast_to(c_exp, c_vals.shape)
            if len(np.unique(diff)) == 1:
                _say("Faa", f"always adds a constant {diff.reshape(-1)[0]} "
                            f"to its expected value")
                return
        except Exception:  # noqa: BLE001 — dtype mismatch etc.
            return
    if v_vals is None:
        return
    rv = _resolve(ctx, v_vals)
    re_ = _resolve(ctx, v_exp) if v_exp is not None else None
    if re_ is not None and rv is re_:
        _say("execute(..., need_fetched=True) read or Swp",
             "writes back exactly its expected value (a no-op when it "
             "succeeds)")
        return
    eqn = ctx.defs.get(rv) if isinstance(rv, Var) else None
    if eqn is None:
        return
    name = eqn.primitive.name
    if name not in ("add", "sub", "max", "min"):
        return
    operands = [_resolve(ctx, iv) for iv in eqn.invars]
    matches_exp = any(o is re_ for o in operands if re_ is not None)
    if not matches_exp:
        return
    if name in ("add", "sub"):
        _say("Faa", "computes value = expected ± delta (the classic "
                    "fetch-and-add retry shape)")
    elif name == "max":
        _say("Max", "computes value = max(expected, x)")
    else:
        _say("Min", "computes value = min(expected, x)")


def _rule_a005(ctx: _Ctx, callsites: List[CallSite]) -> None:
    for cs in callsites:
        if cs.site == "execute" and cs.table_sharded \
                and cs.axes_bound is False:
            ctx.findings.append(make_finding(
                "A005",
                f"execute on a table sharded over mesh axes "
                f"{cs.axis_names!r} with those axes unbound — the call is "
                f"outside shard_map (or the shard_map does not carry the "
                f"table's declared axis/replica_axes); wrap it in "
                f"repro.sharding.shard_map_compat over exactly those axes",
                file=cs.file, line=cs.line, provenance="atomics.execute"))
    # mixed reverse_ranks across one combine tree: group sharded execute
    # sites by the axes they bind
    by_axes: Dict[Tuple[str, ...], List[CallSite]] = {}
    for cs in callsites:
        if cs.site == "execute" and cs.table_sharded and cs.axes_bound:
            by_axes.setdefault(cs.axis_names, []).append(cs)
    for axes, group in by_axes.items():
        fwd = [c for c in group if not c.reverse_ranks]
        rev = [c for c in group if c.reverse_ranks]
        if rev and fwd and not any(c.need_fetched for c in fwd):
            c = rev[0]
            ctx.findings.append(make_finding(
                "A005",
                f"mixed reverse_ranks directions over axes {axes!r} but no "
                f"forward pass fetches pre-images (need_fetched=False "
                f"everywhere): a reversed second pass is only coherent as "
                f"a revert of fetched values (the SWP+revert scheme) — "
                f"fetch on the forward pass or drop reverse_ranks",
                file=c.file, line=c.line, provenance="atomics.execute"))


# ---------------------------------------------------------------------------
# entry
# ---------------------------------------------------------------------------

def run(tr: TraceResult) -> List[Finding]:
    """Apply every rule to a trace; returns findings (unsorted, raw)."""
    ctx = _Ctx(tr)
    if tr.closed is not None:
        _walk(ctx, tr.closed)
    _rule_a005(ctx, tr.callsites)
    for cs in tr.callsites:
        if cs.site == "execute" and cs.kind == "cas":
            _rule_a002(ctx, cs)
    if tr.error is not None:
        # an aborted trace with no diagnosed cause is itself a finding —
        # the analyzer must not silently report "clean" on it
        diagnosed = any(f.rule == "A005" for f in ctx.findings)
        if not diagnosed:
            ctx.findings.append(make_finding(
                "A000", f"trace aborted: {type(tr.error).__name__}: "
                        f"{tr.error}", provenance="jax.make_jaxpr"))
    for msg in tr.observer_errors:
        ctx.findings.append(make_finding(
            "A000", f"contract observer error (analysis bug, not a code "
                    f"finding): {msg.splitlines()[-1]}",
            provenance="contracts.observe"))
    return ctx.findings
