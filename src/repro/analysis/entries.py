"""Registered entry points for the lint sweep.

Each entry is a zero-arg callable returning a findings list; the CLI
(`python -m repro.analysis.lint`) and the ``atomics_lint`` pytest fixture
sweep all of them.  Entries build their functions-under-analysis from
*reduced* configs with `jax.ShapeDtypeStruct` stand-ins wherever shapes
suffice — the sweep traces jaxprs but never runs a model, so it stays
fast enough for CI's lint lane.

Register new atomics-touching code paths here: an entry that exists is an
entry the linter guards.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro import analysis
from repro.analysis.findings import Finding


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


# ---------------------------------------------------------------------------
# MoE dispatch (models/moe.py) — the densest atomics consumer in the repo
# ---------------------------------------------------------------------------

def check_moe_local() -> List[Finding]:
    from repro.configs import get_reduced
    from repro.models.moe import moe_ffn, moe_init

    out: List[Finding] = []
    base = get_reduced("dbrx_132b")
    params = jax.eval_shape(
        lambda: moe_init(jax.random.PRNGKey(0), base, jnp.float32))
    x = _sds((2, 8, base.d_model))
    for policy in ("cas_keep_top_gate", "swp_drop_newest"):
        cfg = dataclasses.replace(
            base, moe=dataclasses.replace(base.moe, overflow_policy=policy))
        out += analysis.check(lambda p, xx: moe_ffn(p, xx, cfg), params, x,
                              entry=f"moe.local[{policy}]")
    return out


# ---------------------------------------------------------------------------
# BFS (core/bfs.py) — bounded while+CAS loops that must NOT trip A003
# ---------------------------------------------------------------------------

def check_bfs_local() -> List[Finding]:
    from repro.core.bfs import _bfs_run

    out: List[Finding] = []
    n = 8
    src = np.array([0, 0, 1, 2, 4, 5], np.int32)
    dst = np.array([1, 2, 3, 3, 5, 6], np.int32)
    root = np.int32(0)
    for op in ("cas", "swp", "faa"):
        out += analysis.check(
            partial(_bfs_run, n=n, op=op, max_levels=8), src, dst, root,
            entry=f"bfs.local[{op}]")
    return out


# ---------------------------------------------------------------------------
# training (launch/train.py path) — donation hygiene end to end
# ---------------------------------------------------------------------------

def _reduced_model():
    from repro.configs import get_reduced
    from repro.models.model import build_model

    cfg = get_reduced("gemma_2b")
    return cfg, build_model(cfg, attn_impl="ref")


def check_train_step() -> List[Finding]:
    from repro.data.pipeline import DataConfig, synthetic_batch
    from repro.launch.steps import abstract_train_state, make_train_step
    from repro.optim.adamw import AdamWConfig

    cfg, model = _reduced_model()
    opt_cfg = AdamWConfig()
    params, opt = abstract_train_state(model, opt_cfg)
    batch = synthetic_batch(
        DataConfig(seq_len=8, global_batch=2, vocab_size=cfg.vocab_size), 0)
    step = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0, 1))
    return analysis.check(step, params, opt, batch, entry="train.step")


def check_train_recovery() -> List[Finding]:
    from repro.launch.steps import make_train_step
    from repro.optim.adamw import AdamWConfig
    from repro.runtime.fault_tolerance import declare_donation

    _, model = _reduced_model()
    step = declare_donation(
        jax.jit(make_train_step(model, AdamWConfig()),
                donate_argnums=(0, 1)), (0, 1))
    # the trainer passes a zero-arg factory (launch/train.py fresh_state);
    # this entry pins that contract so a regression to a captured value —
    # the PR-6 bug — fails lint before it fails a chaos run
    return analysis.check_recovery(step, lambda: None,
                                   entry="train.recovery")


# ---------------------------------------------------------------------------
# serving (launch/serve.py path) — KV-cache update hygiene
# ---------------------------------------------------------------------------

def check_serve_prefill() -> List[Finding]:
    _, model = _reduced_model()
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    batch = {"tokens": _sds((1, 8), jnp.int32)}
    return analysis.check(lambda p, b: model.prefill(p, b, 16), params,
                          batch, entry="serve.prefill")


def check_serve_decode() -> List[Finding]:
    _, model = _reduced_model()
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    batch = {"tokens": _sds((1, 8), jnp.int32)}
    cache, _ = jax.eval_shape(lambda p, b: model.prefill(p, b, 16), params,
                              batch)
    tok = {"tokens": _sds((1, 1), jnp.int32)}
    return analysis.check(lambda p, c, b: model.decode_step(p, c, b),
                          params, cache, tok, entry="serve.decode")


# ---------------------------------------------------------------------------
# self-tuning (repro.tuning) — controller-wrapped steps stay lint-clean
# ---------------------------------------------------------------------------

def check_tuning_train_step() -> List[Finding]:
    """`SpecController.wrap_step` around the donating train step: the
    wrapper must preserve the donation contract (rule A004) and add no
    atomics hazards of its own — an unstarted controller's step() is a
    no-op, so the sweep needs no live stream."""
    from repro.data.pipeline import DataConfig, synthetic_batch
    from repro.launch.steps import abstract_train_state, make_train_step
    from repro.optim.adamw import AdamWConfig
    from repro.runtime.fault_tolerance import declare_donation
    from repro.tuning import SpecController

    cfg, model = _reduced_model()
    opt_cfg = AdamWConfig()
    params, opt = abstract_train_state(model, opt_cfg)
    batch = synthetic_batch(
        DataConfig(seq_len=8, global_batch=2, vocab_size=cfg.vocab_size), 0)
    ctrl = SpecController()
    step = ctrl.wrap_step(declare_donation(
        jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0, 1)),
        (0, 1)))
    out = analysis.check(step, params, opt, batch,
                         entry="tuning.train_step")
    out += analysis.check_recovery(step, lambda: None,
                                   entry="tuning.train_step")
    return out


def check_tuning_serve_decode() -> List[Finding]:
    from repro.tuning import SpecController

    _, model = _reduced_model()
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    batch = {"tokens": _sds((1, 8), jnp.int32)}
    cache, _ = jax.eval_shape(lambda p, b: model.prefill(p, b, 16), params,
                              batch)
    tok = {"tokens": _sds((1, 1), jnp.int32)}
    ctrl = SpecController()
    step = ctrl.wrap_step(lambda p, c, b: model.decode_step(p, c, b))
    return analysis.check(step, params, cache, tok,
                          entry="tuning.serve_decode")


# ---------------------------------------------------------------------------
# sharded execute (examples/sharded_atomics.py pattern) — A005 coverage
# ---------------------------------------------------------------------------

def check_examples_sharded() -> List[Finding]:
    from jax.sharding import PartitionSpec as P

    from repro import atomics
    from repro.sharding import shard_map_compat

    mesh = jax.make_mesh((1,), ("dev",))
    spec = P("dev")

    def fn(t, i, v):
        tbl = atomics.AtomicTable(t, axis="dev")
        res = atomics.execute(tbl, atomics.Faa(i[0], v[0]))
        return res.table.data, res.fetched[None]

    wrapped = shard_map_compat(fn, mesh, (spec, spec, spec), (spec, spec))
    return analysis.check(wrapped, _sds((8,), jnp.int32),
                          _sds((1, 4), jnp.int32), _sds((1, 4), jnp.int32),
                          entry="examples.sharded_atomics")


#: name -> zero-arg callable returning findings; ``lint.sweep`` iterates
#: this in order
ENTRY_POINTS: Dict[str, Callable[[], List[Finding]]] = {
    "moe.local": check_moe_local,
    "bfs.local": check_bfs_local,
    "train.step": check_train_step,
    "train.recovery": check_train_recovery,
    "serve.prefill": check_serve_prefill,
    "serve.decode": check_serve_decode,
    "tuning.train_step": check_tuning_train_step,
    "tuning.serve_decode": check_tuning_serve_decode,
    "examples.sharded_atomics": check_examples_sharded,
}
