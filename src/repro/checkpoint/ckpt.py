"""Sharded checkpointing: atomic, async, keep-last-k, reshard-on-load.

Format: one directory per step containing
  manifest.json — pytree structure, shapes, dtypes, logical shardings,
                  plus per-leaf `AtomicTable` layout metadata
  arrays.npz    — flattened leaves (host-gathered)
Writes go to `<dir>/tmp-<step>` then rename — a torn write can never be
mistaken for a valid checkpoint (restart safety).  `restore(..., mesh=...)`
re-device_puts every leaf under the *target* mesh's shardings, so elastic
resizes (different data-axis extent) restore transparently.

`repro.atomics.AtomicTable` handles are first-class: they checkpoint as
their data plus the serialized `TableLayout` (`manifest["atomic_tables"]`),
and restore through `repro.atomics.reshard.restore_table`, which re-derives
the owner-major layout under the *active* mesh — the writer's extents are
provenance, never trusted for placement, so a table written on mesh A
restores bit-identical on mesh B (the elastic-resize contract).
"""

from __future__ import annotations

import hashlib
import json
import logging
import os
import shutil
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.atomics.layout import norm_axes
from repro.atomics.table import AtomicTable

PyTree = Any

log = logging.getLogger("repro.checkpoint")


class CheckpointCorruptError(RuntimeError):
    """A checkpoint directory failed integrity validation: missing/unreadable
    manifest or arrays, truncated npz, or a per-array sha256 mismatch.
    `restore_latest_valid` treats it as "walk back one step"."""


def _sha256(a: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(a).tobytes()).hexdigest()


def _is_table(x) -> bool:
    return isinstance(x, AtomicTable)


def _table_meta(t: AtomicTable) -> Dict:
    """Serialized layout of a live table — full extents when the array's
    sharding names a mesh, axis names alone otherwise."""
    try:
        return t.layout().to_dict()
    except ValueError:                # sharded handle, mesh not derivable
        return {"num_slots": int(t.data.shape[0]),
                "dtype": str(t.data.dtype),
                "axis": list(norm_axes(t.axis)),
                "replica_axes": list(norm_axes(t.replica_axes)),
                "mesh_axes": []}


def _flatten(tree: PyTree) -> Tuple[List[np.ndarray], Any, List[str],
                                    List[str], Dict[str, Dict]]:
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=_is_table)
    keys = [f"leaf_{i}" for i in range(len(leaves))]
    out, dtypes, tables = [], [], {}
    for key, x in zip(keys, leaves):
        if _is_table(x):
            tables[key] = _table_meta(x)
            x = x.data
        a = np.asarray(x)
        dtypes.append(str(a.dtype))   # logical dtype (pre-view)
        if a.dtype == jnp.bfloat16:
            a = a.view(np.uint16)     # npz cannot store bf16; view-roundtrip
        out.append(a)
    return out, treedef, keys, dtypes, tables


def save(ckpt_dir: str, step: int, tree: PyTree,
         extra: Optional[Dict] = None) -> str:
    """Synchronous atomic save; returns the final path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp-{step}")
    final = os.path.join(ckpt_dir, f"step-{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef, keys, dtypes, tables = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{k: v for k, v in zip(keys, leaves)})
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "keys": keys,
        "shapes": [list(v.shape) for v in leaves],
        "dtypes": dtypes,
        "atomic_tables": tables,
        # per-array integrity (over the stored bytes, post bf16-view):
        # restore validates these, restore_latest_valid walks back on
        # mismatch instead of resuming from silently corrupt state
        "checksums": {k: _sha256(v) for k, v in zip(keys, leaves)},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class AsyncCheckpointer:
    """Background-thread saver with keep-last-k garbage collection."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save_async(self, step: int, tree: PyTree,
                   extra: Optional[Dict] = None) -> None:
        self.wait()
        # materialize on host *before* handing to the thread so training can
        # immediately mutate the live buffers
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save(self.ckpt_dir, step, host_tree, extra)
                self.gc()
            except BaseException as e:  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def gc(self) -> None:
        """Keep-last-k, with one hard guarantee: the newest step that still
        passes validation is never deleted, even when it has fallen out of
        the keep window because every newer step is corrupt — otherwise a
        burst of torn writes could gc away the only restorable state.
        (Validation walks newest-first and stops at the first valid step,
        so the common all-healthy case hashes exactly one checkpoint.)"""
        if self.keep <= 0:
            return
        steps = list_steps(self.ckpt_dir)
        keep_set = set(steps[-self.keep:])
        for s in reversed(steps):
            if validate_step(self.ckpt_dir, s):
                keep_set.add(s)      # the last validated step survives gc
                break
        for s in steps:
            if s not in keep_set:
                shutil.rmtree(os.path.join(self.ckpt_dir, f"step-{s:08d}"),
                              ignore_errors=True)


def list_steps(ckpt_dir: str) -> List[int]:
    """Steps with a plausible checkpoint directory.  Tolerant by design:
    a ``step-garbage`` name or a ``step-N`` directory whose manifest is
    gone (torn delete, external mangling) is *skipped*, never raised — one
    bad directory must not brick `latest_step`/`restore_latest_valid`."""
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if not name.startswith("step-"):
            continue
        try:
            step = int(name.split("-", 1)[1])
        except ValueError:
            log.warning("ignoring non-step entry %r in %s", name, ckpt_dir)
            continue
        if not os.path.isfile(os.path.join(ckpt_dir, name, "manifest.json")):
            log.warning("ignoring manifest-less checkpoint dir %r in %s",
                        name, ckpt_dir)
            continue
        out.append(step)
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def _step_path(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step-{step:08d}")


def _load_validated(path: str, *, validate: bool = True
                    ) -> Tuple[Dict, Dict[str, np.ndarray]]:
    """Read manifest + arrays, raising :class:`CheckpointCorruptError` on
    any integrity failure: unreadable/undecodable manifest, missing or
    truncated npz, a manifest key absent from the archive, or (when the
    manifest carries ``checksums`` — pre-hardening checkpoints do not) a
    per-array sha256 mismatch.  ``validate=False`` skips only the hash
    comparison; structural damage always raises."""
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointCorruptError(f"{path}: unreadable manifest ({e})")
    try:
        with np.load(os.path.join(path, "arrays.npz")) as npz:
            data = {k: npz[k] for k in npz.files}
    except Exception as e:  # noqa: BLE001 — BadZipFile/OSError/ValueError:
        # a truncated or torn archive surfaces differently per numpy/zlib
        # version; all of them mean the same thing here
        raise CheckpointCorruptError(f"{path}: unreadable arrays.npz ({e})")
    missing = [k for k in manifest.get("keys", []) if k not in data]
    if missing:
        raise CheckpointCorruptError(
            f"{path}: arrays.npz is missing leaves {missing[:4]}")
    checksums = manifest.get("checksums")
    if validate and checksums:
        for key, want in checksums.items():
            if key in data and _sha256(data[key]) != want:
                raise CheckpointCorruptError(
                    f"{path}: sha256 mismatch on {key!r} — array bytes do "
                    f"not match the manifest (bit rot or torn write)")
    return manifest, data


def validate_step(ckpt_dir: str, step: int) -> bool:
    """True iff step's checkpoint passes full integrity validation."""
    try:
        _load_validated(_step_path(ckpt_dir, step))
        return True
    except CheckpointCorruptError:
        return False


def restore(ckpt_dir: str, step: int, like: PyTree,
            sharding_fn: Optional[Callable[[str, Any], Any]] = None,
            *, validate: bool = True) -> Tuple[PyTree, Dict]:
    """Restore into the structure of `like`.  `sharding_fn(key, abstract)` may
    return a Sharding per leaf — this is the elastic reshard-on-load hook:
    leaves are device_put under the *current* mesh regardless of how many
    hosts/chips wrote the checkpoint.  `AtomicTable` leaves in `like` bypass
    `sharding_fn` (it is never called for them): they restore through
    `reshard.restore_table`, which re-derives the owner-major layout from
    the handle's contract under the active mesh.

    Integrity: the manifest's per-array sha256 checksums are verified
    before any leaf is materialized (``validate=False`` skips the hash
    walk); any structural or checksum failure raises
    :class:`CheckpointCorruptError` — callers that must survive a corrupt
    newest step use :func:`restore_latest_valid` instead."""
    path = _step_path(ckpt_dir, step)
    manifest, data = _load_validated(path, validate=validate)
    leaves_like, treedef = jax.tree_util.tree_flatten(like, is_leaf=_is_table)
    assert len(leaves_like) == len(manifest["keys"]), \
        "checkpoint structure mismatch"
    table_meta = manifest.get("atomic_tables", {})
    new_leaves = []
    for i, (key, ref) in enumerate(zip(manifest["keys"], leaves_like)):
        arr = data[key]
        if manifest["dtypes"][i] == "bfloat16" and arr.dtype == np.uint16:
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        if _is_table(ref):
            # table handles bypass sharding_fn entirely (placement comes
            # from the handle's own contract).  A leaf the WRITER stored as
            # a table but the caller's `like` holds as a plain array stays
            # on the plain path below — the caller asked for an array, and
            # skipping sharding_fn only for `like`-tables keeps the
            # positional iterator callers like elastic.reshard_restore
            # build aligned.
            from repro.atomics.reshard import restore_table
            new_leaves.append(restore_table(arr, like=ref,
                                            meta=table_meta.get(key)))
            continue
        if sharding_fn is not None:
            sh = sharding_fn(key, ref)
            if sh is not None:
                new_leaves.append(jax.device_put(jnp.asarray(arr), sh))
                continue
        new_leaves.append(jnp.asarray(arr).astype(ref.dtype)
                          if hasattr(ref, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, new_leaves), manifest["extra"]


def restore_latest_valid(ckpt_dir: str, like: PyTree,
                         sharding_fn: Optional[Callable[[str, Any], Any]]
                         = None) -> Optional[Tuple[int, PyTree, Dict]]:
    """Restore the newest checkpoint that passes validation, walking
    *backward* past corrupt/truncated/mangled steps instead of crashing on
    the newest — the recovery loop's restore primitive (a fault during or
    after `save` must cost one checkpoint interval, never the run).

    Returns ``(step, tree, extra)`` or None when no step restores cleanly.
    Every skipped step is logged with its failure; a skipped step is NOT
    deleted (post-mortem evidence, and `AsyncCheckpointer.gc` already
    refuses to drop the newest valid step).
    """
    for step in reversed(list_steps(ckpt_dir)):
        try:
            tree, extra = restore(ckpt_dir, step, like,
                                  sharding_fn=sharding_fn)
            return step, tree, extra
        except Exception as e:  # noqa: BLE001 — a corrupt manifest can
            # surface as CheckpointCorruptError, AssertionError (structure
            # mismatch), KeyError, or an np/json decode error; all mean
            # "this step is unusable, try the previous one"
            log.warning("checkpoint step %d failed validation/restore "
                        "(%s: %s); falling back to the previous step",
                        step, type(e).__name__, e)
    return None
