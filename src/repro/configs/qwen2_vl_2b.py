"""qwen2-vl-2b [vlm]: M-RoPE, dynamic resolution (frontend stubbed).

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936 [arXiv:2409.12191]
The vision tower is a stub: input_specs() provides precomputed patch/text
embeddings (B, S, d) plus 3-axis M-RoPE position ids.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2_vl_2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, d_ff=8960,
    vocab_size=151_936, mlp_act="swiglu", norm="rmsnorm", pos_emb="mrope",
    mrope_sections=(16, 24, 24), qkv_bias=True, tie_embeddings=True,
    embeds_input=True, rope_theta=1_000_000.0, max_seq_len=32_769,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab_size=256, mrope_sections=(4, 2, 2),
                          max_seq_len=64)
