"""command-r-plus-104b [dense]: parallel residual, no-bias, tied embeddings.

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000
[hf:CohereForAI/c4ai-command-r-plus]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command_r_plus_104b", family="dense",
    n_layers=64, d_model=12_288, n_heads=96, n_kv_heads=8, d_ff=33_792,
    vocab_size=256_000, mlp_act="swiglu", norm="layernorm",
    parallel_residual=True, tie_embeddings=True, rope_theta=75_000_000.0,
    max_seq_len=32_769,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab_size=256, max_seq_len=64)
