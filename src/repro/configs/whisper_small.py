"""whisper-small [audio]: enc-dec, conv frontend stubbed to frame embeddings.

12L d_model=768 12H (GQA kv=12) d_ff=3072 vocab=51865  [arXiv:2212.04356]
"""
from repro.models.config import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper_small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab_size=51_865, mlp_act="gelu", norm="layernorm", pos_emb="learned",
    max_seq_len=32_769, encoder=EncoderConfig(n_layers=12, n_frames=1500),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256, max_seq_len=64,
        encoder=EncoderConfig(n_layers=2, n_frames=24))
