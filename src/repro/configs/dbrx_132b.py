"""dbrx-132b [moe]: 16 experts top-4, fine-grained.

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352
[hf:databricks/dbrx-base]
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx_132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=10752,
    vocab_size=100_352, mlp_act="swiglu", norm="layernorm",
    rope_theta=500_000.0, max_seq_len=32_769,
    moe=MoEConfig(n_experts=16, top_k=4, d_ff_expert=10_752),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=128,
        vocab_size=256, max_seq_len=64,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128))
