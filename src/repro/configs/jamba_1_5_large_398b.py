"""jamba-1.5-large-398b [hybrid]: Mamba+attn 1:7 interleave, MoE 16e top-2.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536  [arXiv:2403.19887]
Attention on layers where idx % 8 == 4; MoE every other layer.  The mamba
layers use our SSD (Mamba-2) blocks — a documented simplification
(DESIGN.md: Jamba ships Mamba-1; SSD is the TPU-native formulation).
"""
from repro.models.config import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba_1_5_large_398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=24_576,
    vocab_size=65_536, mlp_act="swiglu", norm="rmsnorm", pos_emb="none",
    max_seq_len=524_289,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=256),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24_576,
                  every_k_layers=2),
    attn_layer_period=8, attn_layer_offset=4,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, max_seq_len=128,
        ssm=SSMConfig(d_state=16, head_dim=16, expand=2, chunk=16),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128, every_k_layers=2),
        attn_layer_period=4, attn_layer_offset=2)
