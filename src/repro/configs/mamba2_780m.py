"""mamba2-780m [ssm]: SSD (state-space duality), attention-free.

48L d_model=1536 d_ff=0 vocab=50280 ssm_state=128 [arXiv:2405.21060]
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2_780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=50_280, head_dim=1, norm="rmsnorm", pos_emb="none",
    tie_embeddings=True, max_seq_len=524_289,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=256),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=64, vocab_size=256, max_seq_len=128,
        ssm=SSMConfig(d_state=16, head_dim=16, expand=2, chunk=16))
