"""gemma-2b [dense]: GeGLU, head_dim=256, MQA, tied + scaled embeddings.

18L d_model=2048 8H (kv=1) d_ff=16384 vocab=256000 [arXiv:2403.08295]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma_2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, d_ff=16_384,
    vocab_size=256_000, head_dim=256, mlp_act="geglu", norm="rmsnorm",
    tie_embeddings=True, scale_embeddings=True, max_seq_len=32_769,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
                          head_dim=32, d_ff=128, vocab_size=256,
                          max_seq_len=64)
