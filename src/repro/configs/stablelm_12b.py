"""stablelm-12b [dense]: partial rotary, layernorm.

40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352
[hf:stabilityai/stablelm-2-12b]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm_12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_ff=13_824,
    vocab_size=100_352, mlp_act="swiglu", norm="layernorm",
    rope_fraction=0.25, max_seq_len=32_769,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab_size=256, max_seq_len=64)
