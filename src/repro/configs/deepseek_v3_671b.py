"""deepseek-v3-671b [moe]: MLA, 1 shared + 256 routed top-8, MTP.

61L d_model=7168 128H d_ff=2048(expert) vocab=129280  [arXiv:2412.19437]
First 3 layers dense (d_ff 18432); MTP is implemented as an optional extra
prediction head (depth 1) — enabled in training via mtp_weight.
"""
from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek_v3_671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, d_ff=18_432,
    vocab_size=129_280, mlp_act="swiglu", norm="rmsnorm",
    rope_theta=10_000.0, max_seq_len=32_769,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_rope_head_dim=64,
                  qk_nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048,
                  n_shared_experts=1, first_dense_layers=3),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256, max_seq_len=64,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_rope_head_dim=8,
                      qk_nope_head_dim=16, v_head_dim=16),
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32,
                      n_shared_experts=1, first_dense_layers=1))
