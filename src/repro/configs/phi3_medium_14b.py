"""phi3-medium-14b [dense]: RoPE SwiGLU GQA.

40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352 [arXiv:2404.14219]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3_medium_14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10, d_ff=17_920,
    vocab_size=100_352, mlp_act="swiglu", norm="rmsnorm",
    max_seq_len=32_769,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
                          d_ff=128, vocab_size=256, max_seq_len=64)
