"""Architecture registry: --arch <id> lookup + shape cells + reduced configs.

Every assigned architecture exposes:
  CONFIG          — the exact full-size ModelConfig from the assignment
  reduced()       — a same-family small config for CPU smoke tests
Shapes (assignment): train_4k / prefill_32k / decode_32k / long_500k; the
skip matrix for long_500k lives here (see DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional, Tuple

from repro.models.config import ModelConfig

ARCH_IDS = (
    "whisper_small",
    "dbrx_132b",
    "deepseek_v3_671b",
    "jamba_1_5_large_398b",
    "stablelm_12b",
    "phi3_medium_14b",
    "gemma_2b",
    "command_r_plus_104b",
    "qwen2_vl_2b",
    "mamba2_780m",
)

#: canonical dash-form aliases (--arch whisper-small etc.)
ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)

#: long_500k runs only for sub-quadratic-decode archs (SSM/hybrid);
#: pure full-attention archs skip it (noted in DESIGN.md §5).
LONG_CONTEXT_ARCHS = ("mamba2_780m", "jamba_1_5_large_398b")


def get_config(arch: str) -> ModelConfig:
    arch = ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def get_reduced(arch: str) -> ModelConfig:
    arch = ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.reduced()


def cells_for(arch: str) -> List[ShapeCell]:
    arch = ALIASES.get(arch, arch)
    out = []
    for s in SHAPES:
        if s.name == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
            continue
        out.append(s)
    return out


def all_cells() -> List[Tuple[str, ShapeCell]]:
    return [(a, s) for a in ARCH_IDS for s in cells_for(a)]
