"""Sharded AdamW with fp32 master weights and configurable moment dtype.

ZeRO-3 falls out of sharding: optimizer-state leaves inherit the parameter
sharding (fsdp x model), so each chip updates only its shard.  For the
largest assigned models (deepseek-v3-671b, jamba-1.5-large) the moments are
kept in bf16 (`moment_dtype`) to fit the v5e HBM budget — the memory plan is
recorded in EXPERIMENTS.md §Dry-run.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"        # "bfloat16" for the >300B models
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: Array) -> Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.minimum(warm, 1.0) * jnp.where(step < cfg.warmup_steps,
                                                       1.0, cos)


def init_state(params, cfg: AdamWConfig) -> Dict[str, Any]:
    mdt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32

    def zeros_like_m(p):
        return jnp.zeros(p.shape, mdt)

    # copy=True: astype on an already-f32 leaf would alias the param buffer,
    # breaking donation (same buffer donated twice in the train step)
    master = jax.tree.map(lambda p: jnp.array(p, jnp.float32, copy=True),
                          params)
    return {"step": jnp.zeros((), jnp.int32),
            "master": master,
            "m": jax.tree.map(zeros_like_m, params),
            "v": jax.tree.map(zeros_like_m, params)}


def global_norm(tree) -> Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params, grads, state, cfg: AdamWConfig
                  ) -> Tuple[Any, Dict[str, Any], Dict[str, Array]]:
    """One AdamW step.  Returns (new bf16/compute params, new state, metrics)."""
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip > 0 else jnp.float32(1.0)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mas, m, v):
        gf = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * gf
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * gf * gf
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        new_master = mas - lr * (delta + decay * mas)
        new_p = new_master.astype(p.dtype)
        if new_p.dtype == new_master.dtype:
            # keep param/master outputs in distinct buffers (donation safety)
            new_p = jax.lax.optimization_barrier(new_p)
        return (new_p, new_master, m32.astype(m.dtype), v32.astype(v.dtype))

    out = jax.tree.map(upd, params, grads, state["master"], state["m"],
                       state["v"])
    # unzip the 4-tuples
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_master = jax.tree.map(lambda t: t[1], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[3], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"step": step, "master": new_master, "m": new_m, "v": new_v}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
