"""int8 error-feedback gradient compression (the DCN/pod-axis trick).

The planner (core/planner.plan_grad_sync) prices a `zero_int8` schedule: on
the slow cross-pod axis, gradients are quantized to int8 with per-block
scales before the reduce, and the quantization error is fed back into the
next step's gradient (error feedback keeps the scheme unbiased over time —
Seide et al. 1-bit SGD / Karimireddy et al. EF-SGD).

Usage (train loop, applied leaf-wise to the grad pytree before the cross-pod
reduction):

    comp, state = compress(grad, state)      # int8 payload + scales
    reduced = psum(comp) ...                  # 4x fewer DCN bytes (vs f32)
    grad_hat = decompress(reduced, ...)

This module provides the quantizer + error-feedback state; wiring it into
the shard_map cross-pod reduction is the planner-directed deployment (see
EXPERIMENTS.md §Perf next-steps).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

BLOCK = 256


class Compressed(NamedTuple):
    q: Array          # int8 payload, shape = padded flat grads
    scales: Array     # f32 per-block scales


def _pad_flat(x: Array) -> Tuple[Array, int]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, pad


def compress(grad: Array, error: Optional[Array] = None
             ) -> Tuple[Compressed, Array]:
    """Quantize grad+error to int8 with per-block max-abs scales.

    Returns (compressed, new_error) where new_error = (grad+error) - dequant
    is carried to the next step (error feedback)."""
    g = grad.astype(jnp.float32)
    if error is not None:
        g = g + error.astype(jnp.float32)
    flat, _ = _pad_flat(g)
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    safe = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / safe), -127, 127).astype(jnp.int8)
    dq = (q.astype(jnp.float32) * safe).reshape(flat.shape)[
        :g.size].reshape(g.shape)
    new_error = g - dq
    return Compressed(q=q.reshape(-1), scales=safe[:, 0]), \
        new_error.astype(grad.dtype)


def decompress(comp: Compressed, shape: Tuple[int, ...],
               dtype=jnp.float32) -> Array:
    blocks = comp.q.reshape(-1, BLOCK).astype(jnp.float32) \
        * comp.scales[:, None]
    n = 1
    for d in shape:
        n *= d
    return blocks.reshape(-1)[:n].reshape(shape).astype(dtype)


def wire_bytes(comp: Compressed) -> int:
    """Bytes on the wire for one compressed tensor (int8 + f32 scales)."""
    return comp.q.size + comp.scales.size * 4


def compress_tree(grads, errors):
    """Leaf-wise compression over a grad pytree; errors pytree may be None."""
    if errors is None:
        errors = jax.tree.map(lambda g: jnp.zeros_like(g), grads)
    pairs = jax.tree.map(compress, grads, errors)
    comp = jax.tree.map(lambda p: p[0], pairs,
                        is_leaf=lambda p: isinstance(p, tuple)
                        and isinstance(p[0], Compressed))
    errs = jax.tree.map(lambda p: p[1], pairs,
                        is_leaf=lambda p: isinstance(p, tuple)
                        and isinstance(p[0], Compressed))
    return comp, errs
