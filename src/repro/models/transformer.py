"""Model assembly: blocks -> scanned stages -> unified LM API.

Layer stacks are grouped into *stages* of identical block structure and
executed with jax.lax.scan over stacked parameters (small HLO, fast compiles,
remat-friendly).  Heterogeneous-but-periodic schedules (jamba's 1:7
attn:mamba interleave with MoE every other layer) scan over super-blocks.

Block = token mixer (GQA/MLA attention | Mamba-2 SSD) + channel mixer
(dense MLP | MoE | none) with pre-norm residuals; optional parallel residual
(command-r) and cross-attention (whisper decoder).
"""

from __future__ import annotations

import functools
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models import runtime_flags
from repro.models.config import ModelConfig
from repro.models.layers import (chunked_softmax_xent, embed_init, mlp_apply,
                                 mlp_init, norm, norm_init)
from repro.sharding import hint

Array = jax.Array

Sig = Tuple[str, bool]  # (kind: "attn"|"ssm", is_moe)


@jax.custom_vjp
def grad_barrier(x: Array) -> Array:
    """`lax.optimization_barrier` with an identity gradient.

    The raw primitive has no differentiation rule; this wrapper keeps the
    anti-CSE/anti-hoisting effect in both passes (the cotangent is barriered
    too, so the backward scan's saved-residual layout matches the forward)
    while differentiating as the identity.
    """
    return jax.lax.optimization_barrier(x)


def _grad_barrier_fwd(x):
    return grad_barrier(x), None


def _grad_barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


grad_barrier.defvjp(_grad_barrier_fwd, _grad_barrier_bwd)


# ---------------------------------------------------------------------------
# stage planning
# ---------------------------------------------------------------------------

def plan_stages(cfg: ModelConfig) -> List[Tuple[List[Sig], int]]:
    """[(sub-layer signatures, repeats)] — scan runs `repeats` iterations,
    each applying the listed sub-layers in order."""
    sigs: List[Sig] = [(cfg.layer_kind(i), cfg.layer_is_moe(i))
                       for i in range(cfg.n_layers)]
    runs: List[Tuple[Sig, int]] = []
    for s in sigs:
        if runs and runs[-1][0] == s:
            runs[-1] = (s, runs[-1][1] + 1)
        else:
            runs.append((s, 1))
    if len(runs) <= 4:
        return [([s], c) for s, c in runs]
    # periodic super-block (jamba): smallest q with sig[i] == sig[i % q]
    for q in range(2, cfg.n_layers + 1):
        if cfg.n_layers % q == 0 and all(
                sigs[i] == sigs[i % q] for i in range(cfg.n_layers)):
            return [(sigs[:q], cfg.n_layers // q)]
    return [([s], c) for s, c in runs]


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------

def block_init(key, cfg: ModelConfig, sig: Sig, dtype,
               cross: bool = False) -> dict:
    kind, is_moe = sig
    ks = jax.random.split(key, 4)
    p: dict = {"ln1": norm_init(cfg.d_model, cfg.norm, dtype)}
    if kind == "attn":
        p["attn"] = attn_mod.attn_init(ks[0], cfg, dtype)
    else:
        p["ssm"] = mamba_mod.mamba_init(ks[0], cfg, dtype)
    if cross:
        p["ln_cross"] = norm_init(cfg.d_model, cfg.norm, dtype)
        p["cross"] = attn_mod.cross_attn_init(ks[1], cfg, dtype)
    if is_moe:
        p["ln2"] = norm_init(cfg.d_model, cfg.norm, dtype)
        p["moe"] = moe_mod.moe_init(ks[2], cfg, dtype)
    elif cfg.d_ff > 0:
        p["ln2"] = norm_init(cfg.d_model, cfg.norm, dtype)
        p["mlp"] = mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp_act, dtype,
                            bias=cfg.mlp_bias)
    return p


def block_forward(bp: dict, x: Array, cfg: ModelConfig, sig: Sig, *,
                  cache: Optional[dict], enc_out: Optional[Array],
                  positions3: Optional[Array], causal: bool, impl: str
                  ) -> Tuple[Array, Optional[dict], Array]:
    kind, is_moe = sig
    aux = jnp.zeros((), jnp.float32)
    h = norm(x, bp["ln1"], cfg.norm, cfg.norm_eps)

    if kind == "attn":
        mix, new_cache = attn_mod.attn_forward(
            bp["attn"], h, cfg, causal=causal, cache=cache,
            positions3=positions3, impl=impl)
    else:
        mix, new_cache = mamba_mod.mamba_forward(bp["ssm"], h, cfg,
                                                 cache=cache)

    def channel(inp: Array) -> Array:
        nonlocal aux
        if is_moe:
            out, a = moe_mod.moe_ffn(bp["moe"], inp, cfg)
            aux = aux + a
            return out
        if "mlp" in bp:
            return mlp_apply(inp, bp["mlp"], cfg.mlp_act)
        return jnp.zeros_like(inp)

    if cfg.parallel_residual:
        x = x + mix + channel(h)
    else:
        x = x + mix
        if "cross" in bp:
            hc = norm(x, bp["ln_cross"], cfg.norm, cfg.norm_eps)
            x = x + attn_mod.cross_attn_forward(bp["cross"], hc, enc_out, cfg,
                                                impl=impl)
        if "ln2" in bp:
            h2 = norm(x, bp["ln2"], cfg.norm, cfg.norm_eps)
            x = x + channel(h2)
    x = hint(x, "batch", "act_seq", "embed")
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# stages (scan over stacked params / caches)
# ---------------------------------------------------------------------------

def stage_init(key, cfg: ModelConfig, sub_sigs: List[Sig], repeats: int,
               dtype, cross: bool = False) -> List[Any]:
    """Returns list (per sub-layer) of param trees stacked over repeats."""
    out = []
    for j, sig in enumerate(sub_sigs):
        keys = jax.random.split(jax.random.fold_in(key, j), repeats)
        ps = [block_init(k, cfg, sig, dtype, cross=cross) for k in keys]
        out.append(jax.tree.map(lambda *xs: jnp.stack(xs), *ps))
    return out


def stage_cache(cfg: ModelConfig, sub_sigs: List[Sig], repeats: int,
                batch: int, s_max: int, dtype) -> List[Any]:
    caches = []
    for sig in sub_sigs:
        kind, _ = sig
        one = (attn_mod.make_kv_cache(cfg, batch, s_max, dtype)
               if kind == "attn"
               else mamba_mod.make_ssm_cache(cfg, batch, dtype))
        caches.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x, (repeats,) + x.shape), one))
    return caches


def stage_forward(stage_params: List[Any], x: Array, cfg: ModelConfig,
                  sub_sigs: List[Sig], *, caches: Optional[List[Any]],
                  enc_out: Optional[Array], positions3: Optional[Array],
                  causal: bool, impl: str, remat_policy: str
                  ) -> Tuple[Array, Optional[List[Any]], Array]:
    have_cache = caches is not None

    def body(carry, xs):
        xc, aux = carry
        # keep the saved residual in model dtype: the barrier stops XLA from
        # hoisting the norm's f32 upcast into the carry stacking buffer
        # (doubles saved-activation memory otherwise); grad_barrier so the
        # train step can differentiate through the scan
        xc = grad_barrier(xc)
        if have_cache:
            params_j, caches_j = xs
        else:
            params_j, caches_j = xs, [None] * len(sub_sigs)
        new_caches = []
        for j, sig in enumerate(sub_sigs):
            xc, nc, a = block_forward(
                params_j[j], xc, cfg, sig, cache=caches_j[j],
                enc_out=enc_out, positions3=positions3, causal=causal,
                impl=impl)
            new_caches.append(nc)
            aux = aux + a
        return (xc, aux), (tuple(new_caches) if have_cache else None)

    body = _remat(body, remat_policy)
    xs = (tuple(stage_params), tuple(caches)) if have_cache \
        else tuple(stage_params)
    reps = jax.tree.leaves(stage_params)[0].shape[0]
    (x, aux), new_caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), xs,
        unroll=runtime_flags.scan_unroll_arg(reps))
    return x, (list(new_caches) if have_cache else None), aux


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    policies = {
        "full": None,
        "dots": jax.checkpoint_policies.checkpoint_dots,
        "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
    }
    return jax.checkpoint(fn, policy=policies.get(policy), prevent_cse=False)
