"""Process-wide model-lowering flags (contextvar-scoped).

unroll_scans: the dry-run sets this so every lax.scan lowers unrolled —
XLA's cost_analysis and the HLO collective parser then count each layer /
chunk / microbatch exactly once per execution instead of once per program.
Runtime (train/serve) keeps rolled scans for compile-time and code size.
"""

from __future__ import annotations

import contextlib
import contextvars

_UNROLL = contextvars.ContextVar("repro_unroll_scans", default=False)


def unroll_scans() -> bool:
    return _UNROLL.get()


@contextlib.contextmanager
def set_unroll_scans(value: bool = True):
    tok = _UNROLL.set(value)
    try:
        yield
    finally:
        _UNROLL.reset(tok)


def scan_unroll_arg(length: int) -> int:
    """Value for lax.scan's unroll= argument under the current flag."""
    return max(1, length) if _UNROLL.get() else 1
