"""Unified LM API: init / loss / prefill / decode_step for every assigned arch.

Batch dict contract (launch/dryrun.input_specs produces matching
ShapeDtypeStructs):
  tokens     (B, S) int32          — unless cfg.embeds_input
  embeds     (B, S, d)             — vlm/audio backbone stubs
  labels     (B, S) int32          — train only; -100 = masked
  frames     (B, n_frames, d)      — whisper encoder stub input
  positions3 (3, B, S) int32       — qwen2-vl M-RoPE (optional)
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import (chunked_softmax_xent, embed_init, norm,
                                 norm_init)
from repro.models.transformer import (plan_stages, stage_cache, stage_forward,
                                      stage_init)
from repro.sharding import hint

Array = jax.Array


class LM:
    """Pure-function model bound to a config (params are explicit pytrees)."""

    def __init__(self, cfg: ModelConfig, *, attn_impl: str = "chunked",
                 remat_policy: str = "full", loss_chunk: int = 4096):
        self.cfg = cfg
        self.attn_impl = attn_impl
        self.remat_policy = remat_policy
        self.loss_chunk = loss_chunk
        self.stages = plan_stages(cfg)
        self._dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    # ------------------------------------------------------------------ init
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        dt = self._dtype
        ks = jax.random.split(key, 8)
        params: Dict[str, Any] = {
            "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dt),
            "final_norm": norm_init(cfg.d_model, cfg.norm, dt),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = embed_init(ks[1], cfg.vocab_size,
                                           cfg.d_model, dt)
        if cfg.pos_emb == "learned":
            params["pos_embed"] = embed_init(ks[2], cfg.max_seq_len,
                                             cfg.d_model, dt)
        params["stages"] = [
            stage_init(jax.random.fold_in(ks[3], i), cfg, sigs, reps, dt)
            for i, (sigs, reps) in enumerate(self.stages)]
        if cfg.encoder is not None:
            enc_cfg = cfg  # same dims; encoder blocks are non-causal attn
            params["enc_stages"] = [stage_init(
                ks[4], enc_cfg, [("attn", False)], cfg.encoder.n_layers, dt)]
            params["enc_norm"] = norm_init(cfg.d_model, cfg.norm, dt)
            params["enc_pos"] = embed_init(ks[5], cfg.encoder.n_frames,
                                           cfg.d_model, dt)
            # decoder cross-attn params live in the decoder stages
            params["stages"] = [
                stage_init(jax.random.fold_in(ks[6], i), cfg, sigs, reps, dt,
                           cross=True)
                for i, (sigs, reps) in enumerate(self.stages)]
        return params

    # ----------------------------------------------------------------- embed
    def _embed_in(self, params, batch, cache_len) -> Array:
        cfg = self.cfg
        if cfg.embeds_input and "embeds" in batch:
            x = batch["embeds"].astype(self._dtype)
        else:
            x = jnp.take(params["embed"], batch["tokens"], axis=0)
        if cfg.scale_embeddings:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        if cfg.pos_emb == "learned":
            s = x.shape[1]
            pos = jax.lax.dynamic_slice_in_dim(
                params["pos_embed"], cache_len, s, axis=0) \
                if isinstance(cache_len, int) else jax.lax.dynamic_slice(
                    params["pos_embed"], (cache_len, 0),
                    (s, cfg.d_model))
            x = x + pos
        return hint(x, "batch", "act_seq", "embed")

    def _encode(self, params, frames: Array) -> Array:
        cfg = self.cfg
        x = frames.astype(self._dtype) + params["enc_pos"][None, :frames.shape[1]]
        x, _, _ = stage_forward(
            params["enc_stages"][0], x, cfg, [("attn", False)], caches=None,
            enc_out=None, positions3=None, causal=False, impl=self.attn_impl,
            remat_policy=self.remat_policy)
        return norm(x, params["enc_norm"], cfg.norm, cfg.norm_eps)

    # --------------------------------------------------------------- forward
    def _backbone(self, params, x: Array, *, caches, enc_out, positions3,
                  ) -> Tuple[Array, Optional[List], Array]:
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        new_caches = [] if caches is not None else None
        for i, (sigs, reps) in enumerate(self.stages):
            c = caches[i] if caches is not None else None
            x, nc, a = stage_forward(
                params["stages"][i], x, cfg, sigs, caches=c, enc_out=enc_out,
                positions3=positions3, causal=True, impl=self.attn_impl,
                remat_policy=self.remat_policy)
            aux = aux + a
            if new_caches is not None:
                new_caches.append(nc)
        x = norm(x, params["final_norm"], cfg.norm, cfg.norm_eps)
        return x, new_caches, aux

    def _head(self, params) -> Array:
        w = params["embed"] if self.cfg.tie_embeddings else params["lm_head"]
        return w.T  # (d, vocab)

    # ------------------------------------------------------------------ loss
    def loss(self, params, batch: Dict[str, Array]) -> Array:
        enc_out = (self._encode(params, batch["frames"])
                   if self.cfg.encoder is not None else None)
        x = self._embed_in(params, batch, 0)
        h, _, aux = self._backbone(params, x, caches=None, enc_out=enc_out,
                                   positions3=batch.get("positions3"))
        ce = chunked_softmax_xent(h, self._head(params), batch["labels"],
                                  chunk=self.loss_chunk,
                                  logit_softcap=self.cfg.logit_softcap)
        return ce + aux

    # --------------------------------------------------------------- serving
    def init_cache(self, batch_size: int, s_max: int) -> Dict[str, Any]:
        dt = self._dtype
        return {"stages": [
            stage_cache(self.cfg, sigs, reps, batch_size, s_max, dt)
            for (sigs, reps) in self.stages],
            "enc_out": None}

    def prefill(self, params, batch: Dict[str, Array], s_max: int
                ) -> Tuple[Dict[str, Any], Array]:
        """Run the full prompt, fill caches, return (cache, last logits)."""
        bsz = (batch["embeds"] if self.cfg.embeds_input else
               batch["tokens"]).shape[0]
        cache = self.init_cache(bsz, s_max)
        enc_out = (self._encode(params, batch["frames"])
                   if self.cfg.encoder is not None else None)
        cache["enc_out"] = enc_out
        x = self._embed_in(params, batch, 0)
        h, new_stage_caches, _ = self._backbone(
            params, x, caches=cache["stages"], enc_out=enc_out,
            positions3=batch.get("positions3"))
        cache["stages"] = new_stage_caches
        logits = (h[:, -1].astype(jnp.float32) @ self._head(params)
                  .astype(jnp.float32))
        return cache, logits

    def decode_step(self, params, cache: Dict[str, Any],
                    batch: Dict[str, Array]) -> Tuple[Dict[str, Any], Array]:
        """One token: batch['tokens'] (B,1) (or embeds (B,1,d))."""
        # cache length lives inside the per-layer caches; embed position uses
        # the first stage/sub-layer attn cache if present, else ssm len.
        cache_len = _peek_len(cache["stages"])
        x = self._embed_in(params, batch, cache_len)
        h, new_stage_caches, _ = self._backbone(
            params, x, caches=cache["stages"], enc_out=cache.get("enc_out"),
            positions3=batch.get("positions3"))
        cache["stages"] = new_stage_caches
        logits = (h[:, -1].astype(jnp.float32) @ self._head(params)
                  .astype(jnp.float32))
        if self.cfg.logit_softcap > 0:
            c = self.cfg.logit_softcap
            logits = c * jnp.tanh(logits / c)
        return cache, logits


def _peek_len(stage_caches) -> Array:
    leaf = stage_caches[0][0]
    # scan-stacked cache: take sub-layer 0, repeat 0
    return leaf["len"][0] if leaf["len"].ndim else leaf["len"]


def build_model(cfg: ModelConfig, **kw) -> LM:
    return LM(cfg, **kw)
