"""Unified model configuration covering the 10 assigned architectures."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    every_k_layers: int = 1          # MoE on layers where idx % k == k-1
    first_dense_layers: int = 0      # deepseek: first N layers stay dense
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01  # load-balance loss
    overflow_policy: str = "cas_keep_top_gate"  # or "swp_drop_newest"


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek multi-head latent attention dims."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block dims."""
    d_state: int = 128
    head_dim: int = 64               # P
    expand: int = 2
    conv_kernel: int = 4
    chunk: int = 128
    n_groups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder half of an enc-dec model (whisper).  The modality frontend is
    a stub: input_specs() provides precomputed frame embeddings."""
    n_layers: int
    n_frames: int = 1500             # whisper 30s @ 50Hz after conv stub


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # blocks / activations
    mlp_act: str = "swiglu"          # swiglu | geglu | gelu | silu_glu(alias)
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    norm_eps: float = 1e-5
    parallel_residual: bool = False  # command-r style
    qkv_bias: bool = False           # qwen2
    mlp_bias: bool = False
    tie_embeddings: bool = False
    scale_embeddings: bool = False   # gemma: * sqrt(d_model)
    logit_softcap: float = 0.0
    # positions
    pos_emb: str = "rope"            # rope | mrope | learned | none
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0       # stablelm partial rotary
    mrope_sections: Tuple[int, ...] = (16, 24, 24)  # qwen2-vl halves
    max_seq_len: int = 131_072
    # structured sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None
    # hybrid schedule (jamba): attention on layers where idx % period == offset
    attn_layer_period: int = 0       # 0 -> every layer is attention (or ssm-only)
    attn_layer_offset: int = 4
    # modality stub: model consumes precomputed embeddings instead of ids
    embeds_input: bool = False
    dtype: str = "bfloat16"

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))

    # ---- layer schedule -------------------------------------------------
    def layer_kind(self, idx: int) -> str:
        """'attn' or 'ssm' for layer idx."""
        if self.family == "ssm":
            return "ssm"
        if self.family == "hybrid" and self.attn_layer_period:
            return ("attn" if idx % self.attn_layer_period == self.attn_layer_offset
                    else "ssm")
        return "attn"

    def layer_is_moe(self, idx: int) -> bool:
        if self.moe is None:
            return False
        if idx < self.moe.first_dense_layers:
            return False
        k = self.moe.every_k_layers
        return idx % k == (k - 1) if k > 1 else True

    def stages(self) -> Tuple[Tuple[str, int], ...]:
        """Group consecutive layers into scan-able stages of identical
        structure.  Returns ((signature, count), ...) preserving order, where
        signature = f"{kind}:{'moe' if moe else 'dense'}".  Periodic schedules
        (jamba) produce a repeating super-block handled by transformer.py."""
        sigs = [f"{self.layer_kind(i)}:{'moe' if self.layer_is_moe(i) else 'dense'}"
                for i in range(self.n_layers)]
        out = []
        for s in sigs:
            if out and out[-1][0] == s:
                out[-1][1] += 1
            else:
                out.append([s, 1])
        return tuple((a, b) for a, b in out)

    def replace(self, **kw) -> "ModelConfig":
        if "head_dim" not in kw and ("d_model" in kw or "n_heads" in kw):
            kw["head_dim"] = 0  # recompute from the new dims (__post_init__)
        return dataclasses.replace(self, **kw)

    # ---- parameter count (for roofline MODEL_FLOPS) ---------------------
    def param_count(self) -> int:
        d, v = self.d_model, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb
        for i in range(self.n_layers):
            kind = self.layer_kind(i)
            if kind == "ssm":
                assert self.ssm is not None
                di = self.ssm.d_inner(d)
                g = self.ssm.n_groups
                n = self.ssm.d_state
                h = self.ssm.n_heads(d)
                inproj = d * (2 * di + 2 * g * n + h)
                conv = (di + 2 * g * n) * self.ssm.conv_kernel
                total += inproj + conv + h + di * d + di  # +outproj +norm-ish
            else:
                if self.mla is not None:
                    m = self.mla
                    h = self.n_heads
                    total += d * m.q_lora_rank \
                        + m.q_lora_rank * h * (m.qk_nope_head_dim + m.qk_rope_head_dim) \
                        + d * (m.kv_lora_rank + m.qk_rope_head_dim) \
                        + m.kv_lora_rank * h * (m.qk_nope_head_dim + m.v_head_dim) \
                        + h * m.v_head_dim * d
                else:
                    hd = self.head_dim
                    total += d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
                        + (self.n_heads * hd) * d
            # mlp
            mult = 3 if self.mlp_act in ("swiglu", "geglu") else 2
            if self.layer_is_moe(i):
                assert self.moe is not None
                total += self.moe.n_experts * mult * d * self.moe.d_ff_expert
                total += self.moe.n_shared_experts * mult * d * self.moe.d_ff_expert
                total += d * self.moe.n_experts  # router
            elif kind != "ssm":
                total += mult * d * self.d_ff
            total += 2 * d  # norms
        if self.encoder is not None:
            mult = 3 if self.mlp_act in ("swiglu", "geglu") else 2
            per = 4 * d * d + mult * d * self.d_ff + 2 * d
            # decoder cross-attn adds ~4 d^2 per decoder layer
            total += self.encoder.n_layers * per + self.n_layers * 4 * d * d
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        m = self.moe
        mult = 3 if self.mlp_act in ("swiglu", "geglu") else 2
        n_moe_layers = sum(self.layer_is_moe(i) for i in range(self.n_layers))
        all_experts = n_moe_layers * m.n_experts * mult * self.d_model * m.d_ff_expert
        active = n_moe_layers * m.top_k * mult * self.d_model * m.d_ff_expert
        return int(full - all_experts + active)
