"""Shared layers: norms, activations, dense projections, position encodings."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32,
               scale: Optional[float] = None) -> Array:
    scale = (d_in ** -0.5) if scale is None else scale
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
            ).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x: Array, weight: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    return out.astype(x.dtype)


def layernorm(x: Array, weight: Array, bias: Optional[Array],
              eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def norm(x: Array, params: dict, kind: str, eps: float) -> Array:
    if kind == "rmsnorm":
        return rmsnorm(x, params["w"], eps)
    return layernorm(x, params["w"], params.get("b"), eps)


def norm_init(d: int, kind: str, dtype=jnp.float32) -> dict:
    p = {"w": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["b"] = jnp.zeros((d,), dtype)
    return p


# ---------------------------------------------------------------------------
# MLP activations
# ---------------------------------------------------------------------------

def mlp_apply(x: Array, params: dict, act: str) -> Array:
    """Gated (swiglu/geglu: w1=gate, w3=up, w2=down) or plain (gelu: w1, w2)."""
    if act in ("swiglu", "geglu"):
        g = x @ params["w1"]
        u = x @ params["w3"]
        h = (jax.nn.silu(g) if act == "swiglu" else
             jax.nn.gelu(g, approximate=True)) * u
        return h @ params["w2"]
    h = x @ params["w1"]
    if "b1" in params:
        h = h + params["b1"]
    h = jax.nn.gelu(h, approximate=True)
    out = h @ params["w2"]
    if "b2" in params:
        out = out + params["b2"]
    return out


def mlp_init(key, d: int, d_ff: int, act: str, dtype=jnp.float32,
             bias: bool = False) -> dict:
    ks = jax.random.split(key, 3)
    if act in ("swiglu", "geglu"):
        return {"w1": dense_init(ks[0], d, d_ff, dtype),
                "w3": dense_init(ks[1], d, d_ff, dtype),
                "w2": dense_init(ks[2], d_ff, d, dtype)}
    p = {"w1": dense_init(ks[0], d, d_ff, dtype),
         "w2": dense_init(ks[1], d_ff, d, dtype)}
    if bias:
        p["b1"] = jnp.zeros((d_ff,), dtype)
        p["b2"] = jnp.zeros((d,), dtype)
    return p


# ---------------------------------------------------------------------------
# rotary position embeddings (RoPE / partial RoPE / M-RoPE)
# ---------------------------------------------------------------------------

def _rope_freqs(dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, jnp.float32) / dim))


def rope_apply(x: Array, positions: Array, theta: float,
               fraction: float = 1.0) -> Array:
    """x: (B, S, H, D); positions: (B, S) int32.  Rotates the first
    `fraction * D` dims (stablelm partial rotary)."""
    d = x.shape[-1]
    rot = int(d * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    freqs = _rope_freqs(rot, theta)                       # (rot/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,rot/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., ::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


def mrope_apply(x: Array, positions3: Array, theta: float,
                sections: Tuple[int, ...]) -> Array:
    """Qwen2-VL multimodal RoPE.  positions3: (3, B, S) — temporal/h/w ids.
    `sections` splits the half-dim freq bands among the three axes."""
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    freqs = _rope_freqs(d, theta)                         # (half,)
    # per-band position selection
    band_axis = jnp.concatenate([
        jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)])
    # positions per element of the half-dim: (B, S, half)
    pos = jnp.take(positions3.astype(jnp.float32),
                   band_axis, axis=0)                     # (half, B, S)
    pos = jnp.moveaxis(pos, 0, -1)                        # (B, S, half)
    ang = pos * freqs
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., ::2], x[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    return jnp.stack([o1, o2], axis=-1).reshape(x.shape).astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> Array:
    pos = jnp.arange(seq, jnp.float32)[:, None]
    freqs = _rope_freqs(d, 10_000.0)[None, :]
    ang = pos * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# chunked cross-entropy (vocab-sharded-friendly, bounded logit memory)
# ---------------------------------------------------------------------------

def chunked_softmax_xent(h: Array, emb_out: Array, labels: Array,
                         chunk: int = 4096,
                         logit_softcap: float = 0.0) -> Array:
    """Mean next-token CE over (B,S,d) hidden states without materializing
    the full (tokens, vocab) logits: scans *sequence* chunks so the batch
    axis stays sharded, and remats the body so the fp32 logits of one chunk
    are the only transient (never saved for backward).  label = -100 entries
    are masked."""
    b, s, d = h.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-100)
    nch = (s + pad) // chunk
    hc = h.reshape(b, nch, chunk, d).swapaxes(0, 1)       # (nch, B, chunk, d)
    lc = labels.reshape(b, nch, chunk).swapaxes(0, 1)

    def body(carry, inp):
        tot, cnt = carry
        hx, lx = inp                                      # (B, chunk, d)
        logits = (hx @ emb_out).astype(jnp.float32)       # (B, chunk, vocab)
        if logit_softcap > 0:
            logits = logit_softcap * jnp.tanh(logits / logit_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.clip(lx, 0)[..., None], axis=-1)[..., 0]
        mask = (lx >= 0).astype(jnp.float32)
        tot = tot + jnp.sum((lse - tgt) * mask)
        cnt = cnt + jnp.sum(mask)
        return (tot, cnt), None

    from repro.models import runtime_flags
    (tot, cnt), _ = jax.lax.scan(
        jax.checkpoint(body, prevent_cse=False),
        (jnp.float32(0), jnp.float32(0)), (hc, lc),
        unroll=runtime_flags.scan_unroll_arg(nch))
    return tot / jnp.maximum(cnt, 1.0)
