"""Mamba-2 (SSD) block: in_proj -> depthwise causal conv -> SSD -> gated out.

Used standalone (mamba2-780m) and interleaved with attention (jamba).
Decode carries (conv window, ssm state) — O(1) per token in context length,
which is why the SSM archs run the long_500k cell.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ssd.ops import ssd, ssd_decode_step
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, rmsnorm

Array = jax.Array


def mamba_init(key, cfg: ModelConfig, dtype) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    g, n, h = s.n_groups, s.d_state, s.n_heads(d)
    conv_ch = di + 2 * g * n
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * g * n + h, dtype),
        "conv_w": (jax.random.normal(ks[1], (s.conv_kernel, conv_ch),
                                     jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((h,), jnp.float32),     # A = -exp(A_log) = -1
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm_w": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[2], di, d, dtype),
    }


def make_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    g, n, h = s.n_groups, s.d_state, s.n_heads(d)
    conv_ch = di + 2 * g * n
    return {"conv": jnp.zeros((batch, s.conv_kernel - 1, conv_ch), dtype),
            "ssm": jnp.zeros((batch, h, n, s.head_dim), jnp.float32),
            "len": jnp.zeros((), jnp.int32)}


def _split_proj(z_all: Array, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    g, n, h = s.n_groups, s.d_state, s.n_heads(d)
    zs = jnp.split(z_all, [di, 2 * di, 2 * di + g * n, 2 * di + 2 * g * n],
                   axis=-1)
    z, xc, bc, cc, dt = zs
    return z, xc, bc, cc, dt, (di, g, n, h)


def _causal_conv(seq: Array, w: Array, b: Array,
                 state: Optional[Array]) -> Tuple[Array, Array]:
    """Depthwise causal conv over (B, S, C); returns (out, new window)."""
    kk = w.shape[0]
    if state is None:
        state = jnp.zeros((seq.shape[0], kk - 1, seq.shape[2]), seq.dtype)
    full = jnp.concatenate([state, seq], axis=1)          # (B, K-1+S, C)
    stacked = jnp.stack(
        [full[:, i:i + seq.shape[1], :] for i in range(kk)], axis=2)
    out = jnp.einsum("bskc,kc->bsc", stacked, w) + b
    new_state = full[:, -(kk - 1):, :]
    return jax.nn.silu(out), new_state


def mamba_forward(params: dict, x: Array, cfg: ModelConfig, *,
                  cache: Optional[dict] = None
                  ) -> Tuple[Array, Optional[dict]]:
    """x (B, S, d) -> (out (B, S, d), cache').  cache given => stateful."""
    s_cfg = cfg.ssm
    b, s, d = x.shape
    z, xc, bc, cc, dt_raw, (di, g, n, h) = _split_proj(
        x @ params["in_proj"], cfg)
    conv_in = jnp.concatenate([xc, bc, cc], axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    conv_out, new_conv = _causal_conv(conv_in, params["conv_w"],
                                      params["conv_b"], conv_state)
    xc = conv_out[..., :di]
    bc = conv_out[..., di:di + g * n]
    cc = conv_out[..., di + g * n:]

    p = s_cfg.head_dim
    xh = xc.reshape(b, s, h, p)
    # groups broadcast to heads (n_groups == 1 typical)
    rep = h // g
    bh = jnp.repeat(bc.reshape(b, s, g, n), rep, axis=2)
    ch = jnp.repeat(cc.reshape(b, s, g, n), rep, axis=2)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"])             # (B,S,H)
    A = -jnp.exp(params["A_log"])                         # (H,)

    if cache is not None and s == 1:
        hstate, y = ssd_decode_step(
            cache["ssm"], xh[:, 0].astype(jnp.float32), dt[:, 0], A,
            bh[:, 0].astype(jnp.float32), ch[:, 0].astype(jnp.float32))
        y = y[:, None]                                    # (B,1,H,P)
        new_cache = {"conv": new_conv, "ssm": hstate,
                     "len": cache["len"] + 1}
    elif cache is not None:
        y, hstate = ssd(xh, dt, A, bh, ch, chunk=s_cfg.chunk,
                        return_final_state=True)
        new_cache = {"conv": new_conv, "ssm": hstate,
                     "len": cache["len"] + s}
    else:
        y = ssd(xh, dt, A, bh, ch, chunk=s_cfg.chunk)
        new_cache = None

    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, s, di)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = rmsnorm((y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype),
                params["norm_w"], cfg.norm_eps)
    return y @ params["out_proj"], new_cache
