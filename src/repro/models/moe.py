"""Mixture-of-Experts FFN with RMW-semantics dispatch + expert parallelism.

The token->expert dispatch is the paper's contended-RMW workload (README
"RMW engine"): each token's (expert, slot) assignment is a Fetch-and-Add on
the expert's arrival counter.  The hot path runs on the unified atomics
front-end (`repro.atomics.arrival_rank`, a sort-free one-hot FAA fetch — no
argsort); gate-priority ranking uses ONE fused lexicographic `lax.sort`
instead of the previous triple argsort.  The *overflow policy* is a choice
of RMW semantics:

  * ``swp_drop_newest``     — arrival order wins (SWP: late colliders lose)
  * ``cas_keep_top_gate``   — gate priority wins (CAS: highest-priority
                              collider keeps the slot, later/lower fail)

Distribution: experts are sharded over the ``model`` mesh axis (EP); the
dispatch all_to_all runs inside shard_map.  Expert weights are additionally
ZeRO-3 sharded over ("pod","data") and all-gathered per layer inside the
shard (explicit FSDP).  Without a mesh the same routing runs in-process
(smoke tests).

The cross-device statistics run on the *sharded* RMW tier of
`repro.atomics.execute` instead of raw collectives: expert counts are a pure
sharded FAA onto an expert-count table sharded over ``model`` (the
``psum_scatter`` degenerate path — what used to be a `psum` of dense
one-hot sums), and the capacity-overflow decision for the arrival-order
policy uses the *fetched* values of a sharded FAA — each assignment's global
arrival rank across every writer in the documented (fsdp-major, model-minor)
device order, compared against the global capacity exactly like the
single-device dispatch compares its local FAA fetch.  The gate-priority
policy keeps local ranks: priority order is not an FAA.  (Per-op-expected
CAS — the primitive a cross-shard priority resolution needs — is now
available through `repro.atomics.execute` with ``Cas(expected=array)``;
wiring the gate policy onto it is a behavioural change gated on a future
quality study, not an API limitation anymore.)
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import atomics
from repro.core.rmw import segmented_scan
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, mlp_apply, mlp_init
from repro.sharding import active_mesh, shard_map_compat as _shard_map

Array = jax.Array


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def moe_init(key, cfg: ModelConfig, dtype) -> dict:
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "w1": (jax.random.normal(ks[1], (e, d, f), jnp.float32)
               * d ** -0.5).astype(dtype),
        "w3": (jax.random.normal(ks[2], (e, d, f), jnp.float32)
               * d ** -0.5).astype(dtype),
        "w2": (jax.random.normal(ks[3], (e, f, d), jnp.float32)
               * f ** -0.5).astype(dtype),
    }
    if m.n_shared_experts:
        p["shared"] = mlp_init(ks[4], d, m.d_ff_expert * m.n_shared_experts,
                               cfg.mlp_act, dtype)
    return p


# ---------------------------------------------------------------------------
# routing with RMW semantics
# ---------------------------------------------------------------------------

def _route(x2d: Array, router_w: Array, m) -> Tuple[Array, Array, Array]:
    """Returns (gates (T,k), expert_ids (T,k), aux_loss scalar-parts).

    aux parts returned as (mean_prob_per_expert (E,), counts (E,)) so the
    caller can psum them across shards before forming the load-balance loss.
    """
    logits = (x2d.astype(jnp.float32) @ router_w)           # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, m.top_k)              # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(ids[:, 0], m.n_experts, dtype=jnp.float32)
    counts = onehot.sum(0)                                  # top-1 counts
    mean_probs = probs.mean(0)
    return gates, ids, (mean_probs, counts)


def _priority_rank(expert_ids: Array, gates: Array, policy: str,
                   num_experts: Optional[int] = None) -> Array:
    """Slot rank of each assignment within its expert — the FAA counter.

    swp_drop_newest:    rank by arrival (flattened token order) — sort-free
                        via the RMW engine's one-hot FAA fetch when
                        ``num_experts`` is known (no argsort at all).
    cas_keep_top_gate:  rank by descending gate; the CAS 'winner' is the
                        highest-gate collider.  ONE fused lexicographic
                        ``lax.sort`` on (expert, -gate) replaces the previous
                        triple argsort (gate argsort -> expert argsort ->
                        argsort inside arrival_rank).
    """
    flat_e = expert_ids.reshape(-1)
    n = flat_e.shape[0]
    if policy == "swp_drop_newest":
        # sort-free with num_experts, argsort fallback without
        return atomics.arrival_rank(flat_e, num_experts)
    # ranks are discrete routing decisions: no gradient flows through the
    # sort (grads reach the router through the gate weights only)
    flat_g = jax.lax.stop_gradient(gates.reshape(-1)).astype(jnp.float32)
    iota = jnp.arange(n, dtype=jnp.int32)
    _, _, order = jax.lax.sort((flat_e, -flat_g, iota), num_keys=2,
                               is_stable=True)
    sorted_e = flat_e[order]                    # grouped by expert, gate desc
    seg_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_e[1:] != sorted_e[:-1]])
    ranks_sorted = segmented_scan(
        jnp.ones((n,), jnp.int32), seg_start, jnp.add) - 1
    # `order` is a sort permutation of arange(n): collision-free by
    # construction, so tell XLA (and the A001 race lint) so
    return jnp.zeros((n,), jnp.int32).at[order].set(ranks_sorted,
                                                    unique_indices=True)


# ---------------------------------------------------------------------------
# the local (per-shard) dispatch->compute->combine pipeline
# ---------------------------------------------------------------------------

def _dispatch_compute(x2d: Array, params_local: dict, cfg: ModelConfig,
                      n_shards: int, capacity: int, axis: Optional[str],
                      act: str, replica_axes: Tuple[str, ...] = (),
                      global_capacity: Optional[int] = None):
    """x2d: (T, d) local tokens.  params_local hold E_loc experts.  When
    `axis` is set, runs the EP all_to_all over that mesh axis.

    `replica_axes` are data-parallel axes whose devices hold *distinct*
    tokens (writers into the shared expert counters); `global_capacity`
    enables the sharded-FAA overflow filter for the arrival-order policy
    (None = local-only capacity, e.g. when token replication would make
    global ranks meaningless).
    """
    m = cfg.moe
    t, d = x2d.shape
    e, e_loc = m.n_experts, m.n_experts // n_shards
    k = m.top_k

    gates, ids, aux = _route(x2d, params_local["router"], m)
    flat_ids = ids.reshape(-1)                              # (T*k,)
    rank = _priority_rank(ids, gates, m.overflow_policy, m.n_experts)
    keep = rank < capacity

    if axis is not None:
        # expert counts: a pure-FAA table-only batch against the count table
        # sharded over the EP axis — the dense psum_scatter degenerate path.
        # Replaces the old `psum` of one-hot sums; the aux-loss value is
        # unchanged (replicated writers are excluded instead of the psum's
        # uniform over-count, which the frac normalization cancelled).
        mean_probs, _ = aux
        cnt_table = atomics.AtomicTable(jnp.zeros((e_loc,), jnp.float32),
                                        axis=axis, replica_axes=replica_axes)
        cnt = atomics.execute(cnt_table, atomics.Faa(
            ids[:, 0], jnp.ones((t,), jnp.float32)),
            strategy="dense", need_fetched=False)
        counts = jax.lax.all_gather(cnt.table.data, axis, tiled=True)
        aux = (mean_probs, counts)
        if global_capacity is not None \
                and m.overflow_policy == "swp_drop_newest":
            # capacity overflow, globally: each assignment's FAA fetch is its
            # arrival rank across ALL writers (fsdp-major, model-minor device
            # order) — the mesh-wide version of the local FAA-fetch rank.
            rank_table = atomics.AtomicTable(jnp.zeros((e_loc,), jnp.int32),
                                             axis=axis,
                                             replica_axes=replica_axes)
            gres = atomics.execute(rank_table, atomics.Faa(
                flat_ids, jnp.ones((t * k,), jnp.int32)), need_fetched=True)
            keep = keep & (gres.fetched < global_capacity)

    # slot in the send buffer: (dest shard, expert-local row, capacity slot)
    dest = flat_ids // e_loc
    e_local = flat_ids % e_loc
    slot = dest * (e_loc * capacity) + e_local * capacity + rank
    buf_rows = n_shards * e_loc * capacity
    slot = jnp.where(keep, slot, buf_rows)                  # scratch row
    xk = jnp.repeat(x2d, k, axis=0)                         # (T*k, d)
    # kept slots are pairwise distinct by construction — (dest, expert row,
    # rank) is injective under rank < capacity — so the only colliding
    # writes land on the discarded scratch row `buf_rows`, where any write
    # order yields the same sliced-away result
    # atomics-lint: disable=A001
    send = jnp.zeros((buf_rows + 1, d), x2d.dtype).at[slot].set(xk)[:-1]

    # bf16 wire format for the dispatch when the model runs bf16 (halves
    # a2a bytes; fp32 smoke/consistency tests keep exact dtype)
    wire_dt = jnp.bfloat16 if x2d.dtype == jnp.bfloat16 else x2d.dtype
    if axis is not None:
        send = send.reshape(n_shards, e_loc * capacity, d).astype(wire_dt)
        recv = jax.lax.all_to_all(send, axis, split_axis=0, concat_axis=0,
                                  tiled=False)
    else:
        recv = send.reshape(1, e_loc * capacity, d).astype(wire_dt)

    # expert FFN on (n_src, E_loc, C, d)
    h_in = recv.reshape(n_shards, e_loc, capacity, d)
    w1, w3, w2 = params_local["w1"], params_local["w3"], params_local["w2"]
    if act in ("swiglu", "geglu"):
        g = jnp.einsum("secd,edf->secf", h_in, w1)
        u = jnp.einsum("secd,edf->secf", h_in, w3)
        hidden = (jax.nn.silu(g) if act == "swiglu"
                  else jax.nn.gelu(g, approximate=True)) * u
    else:
        hidden = jax.nn.gelu(jnp.einsum("secd,edf->secf", h_in, w1),
                             approximate=True)
    out = jnp.einsum("secf,efd->secd", hidden, w2)

    out = out.astype(wire_dt)
    if axis is not None:
        back = jax.lax.all_to_all(out.reshape(n_shards, e_loc * capacity, d),
                                  axis, split_axis=0, concat_axis=0,
                                  tiled=False)
    else:
        back = out.reshape(1, e_loc * capacity, d)
    back = back.reshape(buf_rows, d)
    back = jnp.concatenate([back, jnp.zeros((1, d), back.dtype)], axis=0)

    expert_out = back[slot]                                 # (T*k, d)
    weights = (gates.reshape(-1) * keep).astype(expert_out.dtype)
    combined = (expert_out * weights[:, None]).reshape(t, k, d).sum(axis=1)
    return combined, aux


def _aux_loss(mean_probs: Array, counts: Array, m) -> Array:
    total = jnp.maximum(counts.sum(), 1.0)
    frac = counts / total
    return m.n_experts * jnp.sum(frac * mean_probs) * m.router_aux_weight


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------

def moe_ffn(params: dict, x: Array, cfg: ModelConfig
            ) -> Tuple[Array, Array]:
    """x: (B, S, d) -> (out (B,S,d), aux_loss scalar)."""
    m = cfg.moe
    mesh = active_mesh()
    b, s, d = x.shape
    ep = 1
    axis = None
    if mesh is not None and "model" in mesh.shape \
            and m.n_experts % mesh.shape["model"] == 0 \
            and mesh.shape["model"] > 1:
        ep = mesh.shape["model"]
        axis = "model"

    if axis is None:
        t = b * s
        cap = _capacity(t, m, 1)
        out2d, aux = _dispatch_compute(x.reshape(t, d), params, cfg, 1, cap,
                                       None, cfg.mlp_act)
        out = out2d.reshape(b, s, d)
        loss = _aux_loss(*aux, m)
    else:
        dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        dp_size = _axes_size(mesh, dp_axes)
        # tiny decode batches can't split over data: replicate instead
        b_split = dp_size > 1 and b % dp_size == 0
        # split tokens over the model axis too when seq allows (prefill/train)
        seq_split = s % ep == 0 and s >= ep
        x_spec = P(dp_axes if b_split else None,
                   "model" if seq_split else None, None)
        b_loc = b // dp_size if b_split else b
        t_loc = b_loc * (s // ep if seq_split else s)
        cap = _capacity(t_loc, m, ep)
        fsdp_spec = dp_axes

        # distinct-token writers: dp shards when the batch splits, model
        # shards when the sequence splits; replicated tokens are excluded so
        # the shared counters aren't double-counted
        replica_axes = dp_axes if b_split else ()
        cap_global = (_capacity(t_loc * ep * (dp_size if b_split else 1),
                                m, 1) if seq_split else None)

        def shard_fn(xs, router, w1, w3, w2):
            w1 = jax.lax.all_gather(w1, fsdp_spec, axis=1, tiled=True)
            w3 = jax.lax.all_gather(w3, fsdp_spec, axis=1, tiled=True)
            w2 = jax.lax.all_gather(w2, fsdp_spec, axis=1, tiled=True)
            p_local = {"router": router, "w1": w1, "w3": w3, "w2": w2}
            bl, sl, dl = xs.shape
            out2d, (mp, cnt) = _dispatch_compute(
                xs.reshape(bl * sl, dl), p_local, cfg, ep, cap, "model",
                cfg.mlp_act, replica_axes=replica_axes,
                global_capacity=cap_global)
            mp = jax.lax.pmean(mp, ("model",) + fsdp_spec)
            # cnt comes back already global: the sharded-FAA count table is
            # psum_scatter-combined over every distinct-token writer
            return out2d.reshape(bl, sl, dl), mp, cnt

        out, mp, cnt = _shard_map(
            shard_fn, mesh,
            (x_spec, P(), P("model", fsdp_spec, None),
             P("model", fsdp_spec, None), P("model", fsdp_spec, None)),
            (x_spec, P(), P()),
        )(x, params["router"], params["w1"], params["w3"], params["w2"])
        loss = _aux_loss(mp, cnt, m)

    if m.n_shared_experts:
        out = out + mlp_apply(x, params["shared"], cfg.mlp_act)
    return out, loss


def _capacity(t_local: int, m, ep: int) -> int:
    per_expert = t_local * m.top_k / m.n_experts
    return max(1, int(per_expert * m.capacity_factor + 0.999))


def _axes_size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n
