"""Attention blocks: GQA/MQA, DeepSeek MLA, cross-attention; KV caching.

Three compute paths:
  * ``ref``     — full-score einsum (smoke-test scale oracle)
  * ``chunked`` — lax.scan over query blocks, O(block*S) score memory; this is
                  what train/prefill lower in the dry-run (differentiable,
                  XLA-fusable, shardable)
  * ``pallas``  — kernels/flash_attention on TPU (selected by ops.py backend
                  check; numerically validated against ``ref`` in tests)

Cache contract: dict(k=(B, S_max, Hkv, Dh), v=..., len=int32 scalar); decode
writes the new token at position ``len`` and attends to [0, len].
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import (dense_init, mrope_apply, norm, norm_init,
                                 rope_apply)

Array = jax.Array

DEFAULT_Q_CHUNK = 256
DEFAULT_Q_CHUNK_OVERRIDE = None  # set by the dry-run perf iterations


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig, dtype, cross: bool = False) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if cfg.mla is not None and not cross:
        m = cfg.mla
        ks = jax.random.split(key, 6)
        return {
            "wq_a": dense_init(ks[0], d, m.q_lora_rank, dtype),
            "q_norm": norm_init(m.q_lora_rank, "rmsnorm", dtype),
            "wq_b": dense_init(ks[1], m.q_lora_rank,
                               h * (m.qk_nope_head_dim + m.qk_rope_head_dim),
                               dtype),
            "wkv_a": dense_init(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim,
                                dtype),
            "kv_norm": norm_init(m.kv_lora_rank, "rmsnorm", dtype),
            "wkv_b": dense_init(ks[3], m.kv_lora_rank,
                                h * (m.qk_nope_head_dim + m.v_head_dim), dtype),
            "wo": dense_init(ks[4], h * m.v_head_dim, d, dtype),
        }
    ks = jax.random.split(key, 4)
    p = {"wq": dense_init(ks[0], d, h * hd, dtype),
         "wk": dense_init(ks[1], d, hkv * hd, dtype),
         "wv": dense_init(ks[2], d, hkv * hd, dtype),
         "wo": dense_init(ks[3], h * hd, d, dtype)}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    return p


def make_kv_cache(cfg: ModelConfig, batch: int, s_max: int, dtype) -> dict:
    if cfg.mla is not None:
        m = cfg.mla
        return {"ckv": jnp.zeros((batch, s_max, m.kv_lora_rank), dtype),
                "krope": jnp.zeros((batch, s_max, m.qk_rope_head_dim), dtype),
                "len": jnp.zeros((), jnp.int32)}
    return {"k": jnp.zeros((batch, s_max, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((batch, s_max, cfg.n_kv_heads, cfg.head_dim), dtype),
            "len": jnp.zeros((), jnp.int32)}


# ---------------------------------------------------------------------------
# core attention math (q: (B,S,H,D) already rotated)
# ---------------------------------------------------------------------------

def _sdpa(q: Array, k: Array, v: Array, *, causal: bool, kv_len,
          q_offset, scale: float, impl: str,
          q_chunk: int = 0) -> Array:
    """q (B,Sq,H,D); k/v (B,Skv,Hkv,D); kv_len: valid kv prefix (dynamic ok);
    q_offset: global position of q[0] (dynamic ok).  Returns (B,Sq,H,D)."""
    if q_chunk == 0:
        q_chunk = DEFAULT_Q_CHUNK_OVERRIDE or DEFAULT_Q_CHUNK
    b, sq, hq, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]                      # may differ from dh (MLA)
    group = hq // hkv
    # bf16 operands + f32 accumulation: no materialized f32 copies of q/k/v
    # (matches MXU practice; softmax stats stay f32)
    kf, vf = k, v
    # fold GQA: (B,S,Hkv,group,D)
    qg = q.reshape(b, sq, hkv, group, dh)

    def block(qb, q_pos):
        # qb (B,bq,Hkv,g,D); scores (B,Hkv,g,bq,Skv)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kf,
                       preferred_element_type=jnp.float32) * scale
        kpos = jnp.arange(skv)
        valid = kpos[None, :] < kv_len
        if causal:
            valid = valid & (kpos[None, :] <= (q_pos + q_offset)[:, None])
        s = jnp.where(valid[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(kf.dtype), vf,
                          preferred_element_type=jnp.float32)

    if impl == "tri" and causal and sq == skv:
        return _sdpa_tri(q, k, v, kv_len=kv_len, scale=scale)
    if impl == "ref" or sq <= q_chunk:
        out = block(qg, jnp.arange(sq))
    else:
        pad = (-sq) % q_chunk
        qp = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        nblk = qp.shape[1] // q_chunk
        qb = qp.reshape(b, nblk, q_chunk, hkv, group, dh).transpose(
            1, 0, 2, 3, 4, 5)
        pos = (jnp.arange(nblk * q_chunk).reshape(nblk, q_chunk))

        def body(_, inp):
            qx, px = inp
            return None, block(qx, px)

        # remat: never save the (bq, Skv) score tensors for backward —
        # recompute per q-block (this recompute IS the flash-attention trick)
        from repro.models import runtime_flags
        _, ob = jax.lax.scan(jax.checkpoint(body, prevent_cse=False),
                             None, (qb, pos),
                             unroll=runtime_flags.scan_unroll_arg(nblk))
        out = ob.transpose(1, 0, 2, 3, 4, 5).reshape(
            b, nblk * q_chunk, hkv, group, dv)[:, :sq]
    return out.reshape(b, sq, hq, dv).astype(q.dtype)


def _sdpa_tri(q: Array, k: Array, v: Array, *, kv_len, scale: float,
              block: int = 512) -> Array:
    """Block-triangular causal attention (beyond-paper §Perf optimization).

    Causal attention with sq == skv computed as nb diagonal bands: band d
    batches the (q-block i, kv-block i-d) pairs for all i >= d into ONE
    einsum with static shapes, so above-diagonal blocks are never computed —
    the dot FLOPs are exactly the triangular half (+ the masked diagonal),
    unlike `where`-masked full-score implementations.  Streaming-softmax
    merges bands, so score memory stays O(S * block).
    """
    b, sq, hq, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    assert sq == skv, "triangular path needs square attention"
    group = hq // hkv
    pad = (-sq) % block
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = sq + pad
    nb = sp // block
    from repro.sharding import hint
    qb = q.reshape(b, nb, block, hkv, group, dh)
    kb = k.reshape(b, nb, block, hkv, dh)
    vb = v.reshape(b, nb, block, hkv, dv)
    qb = hint(qb, "batch", None, None, "kv_heads", None, None)
    kb = hint(kb, "batch", None, None, "kv_heads", None)
    vb = hint(vb, "batch", None, None, "kv_heads", None)

    m = jnp.full((b, nb, block, hkv, group), -1e30, jnp.float32)
    l = jnp.zeros((b, nb, block, hkv, group), jnp.float32)
    acc = jnp.zeros((b, nb, block, hkv, group, dv), jnp.float32)

    kpos_in = jnp.arange(block)
    for d in range(nb):
        qs = qb[:, d:]                          # (b, nb-d, blk, hkv, g, dh)
        ks = kb[:, :nb - d]
        vs = vb[:, :nb - d]
        s = jnp.einsum("bnqhgd,bnkhd->bnqhgk", qs, ks,
                       preferred_element_type=jnp.float32) * scale
        # masks: diagonal band is causal-within-block; all bands respect
        # kv_len (padded tail)
        kpos = (jnp.arange(nb - d) * block)[None, :, None, None, None, None] \
            + kpos_in[None, None, None, None, None, :]
        valid = kpos < kv_len
        if d == 0:
            qpos = kpos_in[None, None, :, None, None, None]
            valid = valid & (kpos_in[None, None, None, None, None, :]
                             <= qpos)
        s = jnp.where(valid, s, -1e30)
        m_new = jnp.maximum(m[:, d:], jnp.max(s, axis=-1).transpose(
            0, 1, 2, 3, 4))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m[:, d:] - m_new)
        l = l.at[:, d:].set(l[:, d:] * alpha + p.sum(-1))
        acc = acc.at[:, d:].set(
            acc[:, d:] * alpha[..., None]
            + jnp.einsum("bnqhgk,bnkhd->bnqhgd", p.astype(vs.dtype), vs,
                         preferred_element_type=jnp.float32))
        m = m.at[:, d:].set(m_new)

    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.reshape(b, sp, hq, dv)[:, :sq]
    return out.astype(q.dtype)


def _positions(cache_len, batch: int, seq: int) -> Array:
    base = jnp.arange(seq, dtype=jnp.int32)[None, :] + cache_len
    return jnp.broadcast_to(base, (batch, seq))


def _apply_pos(q: Array, k: Array, cfg: ModelConfig, positions: Array,
               positions3: Optional[Array]) -> Tuple[Array, Array]:
    if cfg.pos_emb == "rope":
        q = rope_apply(q, positions, cfg.rope_theta, cfg.rope_fraction)
        k = rope_apply(k, positions, cfg.rope_theta, cfg.rope_fraction)
    elif cfg.pos_emb == "mrope":
        p3 = positions3 if positions3 is not None else jnp.broadcast_to(
            positions[None], (3,) + positions.shape)
        q = mrope_apply(q, p3, cfg.rope_theta, cfg.mrope_sections)
        k = mrope_apply(k, p3, cfg.rope_theta, cfg.mrope_sections)
    return q, k


# ---------------------------------------------------------------------------
# GQA / MQA attention
# ---------------------------------------------------------------------------

def gqa_forward(params: dict, x: Array, cfg: ModelConfig, *,
                causal: bool = True, cache: Optional[dict] = None,
                positions3: Optional[Array] = None,
                impl: str = "chunked") -> Tuple[Array, Optional[dict]]:
    b, s, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)

    cache_len = cache["len"] if cache is not None else jnp.zeros((), jnp.int32)
    pos = _positions(cache_len, b, s)
    q, k = _apply_pos(q, k, cfg, pos, positions3)

    if cache is not None:
        kc = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, cache_len, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, cache_len, 0, 0))
        new_cache = {"k": kc, "v": vc, "len": cache_len + s}
        out = _sdpa(q, kc, vc, causal=causal, kv_len=cache_len + s,
                    q_offset=cache_len, scale=hd ** -0.5, impl=impl)
    else:
        new_cache = None
        out = _sdpa(q, k, v, causal=causal, kv_len=s,
                    q_offset=jnp.zeros((), jnp.int32), scale=hd ** -0.5,
                    impl=impl)
    return out.reshape(b, s, h * hd) @ params["wo"], new_cache


# ---------------------------------------------------------------------------
# DeepSeek MLA
# ---------------------------------------------------------------------------

def mla_forward(params: dict, x: Array, cfg: ModelConfig, *,
                causal: bool = True, cache: Optional[dict] = None,
                impl: str = "chunked") -> Tuple[Array, Optional[dict]]:
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.n_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim

    ql = norm(x @ params["wq_a"], params["q_norm"], "rmsnorm", cfg.norm_eps)
    q = (ql @ params["wq_b"]).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]

    kv_a = x @ params["wkv_a"]
    ckv_new = norm(kv_a[..., :m.kv_lora_rank], params["kv_norm"], "rmsnorm",
                   cfg.norm_eps)
    krope_new = kv_a[..., m.kv_lora_rank:]                # (B,S,dr) shared

    cache_len = cache["len"] if cache is not None else jnp.zeros((), jnp.int32)
    pos = _positions(cache_len, b, s)
    q_rope = rope_apply(q_rope, pos, cfg.rope_theta)
    krope_new = rope_apply(krope_new[:, :, None, :], pos, cfg.rope_theta
                           )[:, :, 0, :]

    if cache is not None:
        ckv = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv_new.astype(cache["ckv"].dtype), (0, cache_len, 0))
        krope = jax.lax.dynamic_update_slice(
            cache["krope"], krope_new.astype(cache["krope"].dtype),
            (0, cache_len, 0))
        new_cache = {"ckv": ckv, "krope": krope, "len": cache_len + s}
        kv_len = cache_len + s
        q_offset = cache_len
    else:
        ckv, krope = ckv_new, krope_new
        new_cache = None
        kv_len = jnp.asarray(s, jnp.int32)
        q_offset = jnp.zeros((), jnp.int32)

    # expand latent kv per head (baseline; absorbed-matmul is a §Perf item)
    kv = (ckv @ params["wkv_b"]).reshape(b, ckv.shape[1], h, dn + dv)
    k_nope, vv = kv[..., :dn], kv[..., dn:]
    # concat rope part (shared across heads) into keys and queries
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope[:, :, None, :],
                                  k_nope.shape[:3] + (dr,))], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = _sdpa(q_full, k_full, vv, causal=causal, kv_len=kv_len,
                q_offset=q_offset, scale=(dn + dr) ** -0.5, impl=impl)
    return out.reshape(b, s, h * dv) @ params["wo"], new_cache


# ---------------------------------------------------------------------------
# cross attention (whisper decoder); kv from encoder output, no causal mask
# ---------------------------------------------------------------------------

def cross_attn_init(key, cfg: ModelConfig, dtype) -> dict:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {"wq": dense_init(ks[0], d, h * hd, dtype),
            "wk": dense_init(ks[1], d, h * hd, dtype),
            "wv": dense_init(ks[2], d, h * hd, dtype),
            "wo": dense_init(ks[3], h * hd, d, dtype)}


def cross_attn_forward(params: dict, x: Array, enc_out: Array,
                       cfg: ModelConfig, impl: str = "chunked") -> Array:
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    se = enc_out.shape[1]
    q = (x @ params["wq"]).reshape(b, s, h, hd)
    k = (enc_out @ params["wk"]).reshape(b, se, h, hd)
    v = (enc_out @ params["wv"]).reshape(b, se, h, hd)
    out = _sdpa(q, k, v, causal=False, kv_len=jnp.asarray(se, jnp.int32),
                q_offset=jnp.zeros((), jnp.int32), scale=hd ** -0.5, impl=impl)
    return out.reshape(b, s, h * hd) @ params["wo"]


def attn_forward(params: dict, x: Array, cfg: ModelConfig, **kw):
    if cfg.mla is not None:
        kw.pop("positions3", None)
        return mla_forward(params, x, cfg, **kw)
    return gqa_forward(params, x, cfg, **kw)
