"""Contention estimator: measured `distinct_slots` per repeated call site.

``distinct_slots`` — the exchange selector's contention knob (how many
distinct table slots a batch actually touches) — was a static,
caller-supplied hint.  Lightweight Contention Management (arxiv 1305.5800)
argues contention policy must be *measured and adaptive*; the measurement
is already free: every `execute_until` round knows exactly which slots it
issued (host numpy), so the combine pass's collision count is one
``np.unique`` away, and the round histogram's resolved-in-one-attempt
count is the same quantity seen through CAS-failure feedback (one winner
per contended slot per round).

This module folds both observations into an EWMA per **call site** —
keyed by ``(op kind, tier, size-bucket(m), size-bucket(n))``, the same
power-of-two bucketing the drift tracker uses — and serves it back as the
``distinct_slots`` hint for the *next* batch of the same shape
(`hint` rounds to a power of two so the hint feeds jit cache keys without
recompile churn).  `execute_until` consults it automatically whenever a
`repro.tuning.SpecController` is running and the caller passed no explicit
hint; the keyword remains an override, never a requirement.

The estimator only ever shapes *selection* (exchange-strategy caps); like
the live spec itself it can never change results.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

from repro.telemetry import drift

#: call-site key: (op kind, tier, size_bucket(m), size_bucket(n))
SiteKey = Tuple[str, str, str, str]


def site_key(kind: str, tier: str, m: int, n: int) -> SiteKey:
    """The call-site identity two batches share iff the estimator may pool
    their contention observations: same op kind, tier, and power-of-two
    table/batch size buckets."""
    return (str(kind), str(tier), drift.size_bucket(m),
            drift.size_bucket(n))


class ContentionEstimator:
    """EWMA of observed distinct-slot counts per call site.

    ``alpha`` is the EWMA smoothing weight of each new observation; the
    default 0.25 converges in a handful of batches while riding out one
    skewed batch.  Thread-unsafe by design — updates come from the host
    retry loop, reads from the next dispatch on the same thread.
    """

    def __init__(self, alpha: float = 0.25):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = float(alpha)
        self._ewma: Dict[SiteKey, float] = {}
        self.n_updates = 0
        self.n_updates_host = 0
        self.n_updates_device = 0

    def update(self, key: SiteKey, distinct: int, *,
               source: str = "host") -> None:
        """Fold one observed distinct-slot count into the site's EWMA.
        Counts below 1 carry no signal (nothing was issued) and are
        ignored.  ``source`` tags where the count came from (``"host"``:
        the retry loop's np.unique; ``"device"``: a ContentionStats
        ``distinct_slots`` computed inside the combine pass) — same EWMA
        and site keys either way, the tag only feeds the per-source
        counters observability reads."""
        d = float(distinct)
        if not math.isfinite(d) or d < 1.0:
            return
        prev = self._ewma.get(key)
        self._ewma[key] = d if prev is None else \
            prev + self.alpha * (d - prev)
        self.n_updates += 1
        if source == "device":
            self.n_updates_device += 1
        else:
            self.n_updates_host += 1

    def hint(self, key: SiteKey) -> Optional[int]:
        """The site's `distinct_slots` hint: the EWMA rounded to the
        nearest power of two (selection caps only need the order of
        magnitude, and a quantized hint keeps the jit/decision cache key
        space bounded as the EWMA drifts).  None until the site has been
        observed."""
        v = self._ewma.get(key)
        if v is None:
            return None
        return 1 << max(0, int(round(math.log2(max(1.0, v)))))

    def raw(self, key: SiteKey) -> Optional[float]:
        """The unquantized EWMA (observability/tests)."""
        return self._ewma.get(key)

    def sites(self) -> Dict[SiteKey, float]:
        return dict(self._ewma)

    def __len__(self) -> int:
        return len(self._ewma)

    # --- persistence (rides in the controller's state file) ---------------
    def snapshot(self) -> Dict[str, Any]:
        return {"alpha": self.alpha,
                "sites": {"|".join(k): v for k, v in self._ewma.items()}}

    def restore(self, payload: Dict[str, Any]) -> int:
        """Load a `snapshot`; malformed entries are dropped (restores must
        never poison the estimator).  Returns the number of sites kept."""
        kept = 0
        for key_s, v in (payload.get("sites") or {}).items():
            parts = tuple(str(key_s).split("|"))
            if len(parts) != 4 or not isinstance(v, (int, float)) \
                    or isinstance(v, bool) or not math.isfinite(v) \
                    or v < 1.0:
                continue
            self._ewma[parts] = float(v)
            kept += 1
        return kept
