"""Guarded self-tuning: the live `HardwareSpec` controller.

PR 7 built the measurement half of the ROADMAP's self-tuning loop
(`telemetry.drift.aggregate` + `fit_spec_update`): every selector decision
carries its ``predicted_s``, every measured call site its wall time, and
the fitter turns persistent drift into a corrected-spec *proposal*.  This
module closes the loop: a :class:`SpecController` folds the live drift
window into the active spec on a cadence and swaps it into **all three
selector tiers** at once through `rmw_engine.set_live_spec` (the
process-wide indirection `default_spec()` honors — `select_backend`,
`select_exchange`, and `select_migration` all default their spec through
it, and the atomics decision caches key on the spec epoch so a swap takes
effect immediately).

An unguarded feedback loop is a new failure mode — the paper's warning
about performance depending on "unclear and not thoroughly analyzed"
architectural state cuts both ways — so every update passes hard
guardrails:

* **clamp** — no constant moves more than ``max_update_factor`` per
  update; big corrections are walked over several confirmed windows;
* **hysteresis** — no update below ``min_events`` drift samples
  (``min_samples`` per field, per-field floors supported) and none within
  ``cooldown_updates`` windows of the last swap; sub-``deadband`` moves
  are not worth a cache/jit invalidation and are held;
* **rollback** — every swap pushes the previous spec onto a last-good
  stack and arms a post-swap check: if the next window's drift *score*
  (sample-weighted mean ``|log(measured/predicted)|``) worsens by more
  than ``rollback_margin``, the previous spec is reinstalled
  (``tuning.rollback``), else the swap is confirmed (``tuning.confirm``);
* **quarantine** — pathological proposals (NaN / non-positive / outside
  ``envelope_factor`` of the *calibrated* spec) are never installed: the
  field falls back to its calibrated value and a ``tuning.quarantine``
  event names it — never silent, like every other controller outcome
  (``tuning.skip`` carries the reason and any fields below their sample
  floor);
* **validated persistence** — `state_path` persists the tuned spec (and
  the contention estimator) across restarts; restore re-validates every
  field against the calibrated envelope and the current jax backend, and
  quarantines anything suspect instead of installing it.

Chaos coverage (`spec_perturb` site, `runtime.chaos.FaultPlan`): when the
site fires inside an update cycle the deterministic parameter draw either
**skews** the window's measured walls by a log-uniform factor in
[1/8, 8) — poisoning the live spec through its own feedback loop — or
**poisons** the fitted proposal outright (NaN / negated), which the
quarantine guardrail must absorb.  tests/test_tuning.py asserts the
controller converges back, rolls back on induced regression, and — the
load-bearing invariant — that tuned runs stay **bit-identical** to
untuned runs: the spec steers *selection* only, and every backend and
strategy is bit-identical to the serialized oracle by construction.
"""

from __future__ import annotations

import collections
import dataclasses
import json
import math
import os
import threading
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

import jax

from repro import telemetry
from repro.core import perf_model, rmw_engine
from repro.runtime.chaos import FaultPlan
from repro.telemetry import drift
from repro.tuning.estimator import ContentionEstimator

#: env var: truthy enables a default controller in `launch.train`
#: (a path value additionally persists/restores the tuned state there)
TUNING_ENV = "REPRO_TUNING"

#: the spec constants the controller may ever touch — exactly the fields
#: the drift fitter maps drift pools onto (everything else in HardwareSpec
#: is structural: tier tables, tile geometry, names)
TUNABLE_FIELDS: Tuple[str, ...] = tuple(sorted(
    {field for field, _sense in drift.SPEC_FIELD_OF.values()}))


@dataclasses.dataclass(frozen=True)
class TuningConfig:
    """Guardrail knobs of one :class:`SpecController` (defaults are the
    benchmarked configuration in ``benchmarks/results/tuning.json``)."""

    #: drift-bearing events per update window (hysteresis floor)
    min_events: int = 32
    #: per-field sample floor handed to `fit_spec_update`
    min_samples: int = 4
    #: per-field overrides of ``min_samples`` (e.g. demand more evidence
    #: for high-blast-radius constants); None = uniform floor
    min_samples_per_field: Optional[Mapping[str, int]] = None
    #: max multiplicative move of any constant per update (clamp)
    max_update_factor: float = 2.0
    #: quarantine envelope around the *calibrated* spec: proposals outside
    #: [cal/envelope, cal*envelope] are pathological by definition
    envelope_factor: float = 64.0
    #: |log(new/current)| below this is held, not applied (no churn)
    deadband: float = 0.05
    #: update windows to sit out after a swap/rollback before fitting again
    #: (the post-swap window still runs the rollback check)
    cooldown_updates: int = 1
    #: rollback when the post-swap drift score worsens by more than this
    #: (additive in mean-|log-ratio| units; 0.2 ~ geometric drift +22%)
    rollback_margin: float = 0.2
    #: last-good stack depth (consecutive bad swaps roll back that far)
    history_depth: int = 8
    #: EWMA weight of the contention estimator
    ewma_alpha: float = 0.25
    #: enable telemetry sync so eager execute walls measure device time —
    #: the controller's drift diet; disable to tune from retry/migration
    #: events only
    sync: bool = True
    #: drift-window retention cap (oldest events drop past this)
    window_cap: int = 4096


class _ControllerSink(telemetry.Sink):
    """The controller's tap on the event stream.  ``emit`` runs under the
    telemetry lock: buffer only, never record (re-entering the stream from
    a sink would deadlock)."""

    def __init__(self, controller: "SpecController"):
        self._controller = controller

    def emit(self, event: Dict[str, Any]) -> None:
        self._controller._observe(event)


#: the running controller (at most one per process — it owns the
#: process-wide live spec); `execute_until` reads its estimator
_ACTIVE: Optional["SpecController"] = None


def active_controller() -> Optional["SpecController"]:
    return _ACTIVE


def active_estimator() -> Optional[ContentionEstimator]:
    """The running controller's contention estimator, if any — the hook
    `atomics.execute_until` polls for estimator-backed ``distinct_slots``."""
    return _ACTIVE.estimator if _ACTIVE is not None else None


class SpecController:
    """Lifecycle: ``start()`` (attach to the stream, restore+validate any
    persisted state, install the tuned spec) → ``step()`` once per outer
    step (cheap no-op until a window fills) → ``stop()`` (detach, clear
    the live spec, persist).  Context-manager sugar covers all three::

        with SpecController(state_path="tuned.json") as ctrl:
            for i in range(steps):
                state = train_step(i, state)
                ctrl.step()

    or wrap the step function once: ``step = ctrl.wrap_step(step)``.
    """

    def __init__(self, config: Optional[TuningConfig] = None, *,
                 base_spec: Optional[perf_model.HardwareSpec] = None,
                 chaos: Optional[FaultPlan] = None,
                 state_path: Optional[str] = None):
        self.cfg = config or TuningConfig()
        self.base = base_spec if base_spec is not None \
            else rmw_engine.calibrated_spec()
        self.active = self.base
        self.chaos = chaos
        self.state_path = state_path
        self.estimator = ContentionEstimator(alpha=self.cfg.ewma_alpha)
        self._sink = _ControllerSink(self)
        self._wlock = threading.Lock()
        self._window: collections.deque = collections.deque(
            maxlen=self.cfg.window_cap)
        self._stack: List[Tuple[perf_model.HardwareSpec, float]] = []
        self._pre_swap_score: Optional[float] = None
        self._cooldown = 0
        self._started = False
        self.last_score: Optional[float] = None
        self.last_outcome: Optional[str] = None
        self.n_updates = 0
        self.n_applied = 0
        self.n_rollbacks = 0
        self.n_quarantined = 0
        self.n_perturbs = 0

    # --- lifecycle --------------------------------------------------------
    def start(self) -> "SpecController":
        global _ACTIVE
        if self._started:
            return self
        if _ACTIVE is not None:
            raise RuntimeError(
                "another SpecController is already running — it owns the "
                "process-wide live spec; stop() it first")
        telemetry.add_sink(self._sink, sync=self.cfg.sync)
        if self.state_path and os.path.exists(self.state_path):
            self._restore_state()
        if self.active != self.base:
            self._install()
        self._started = True
        _ACTIVE = self
        return self

    def stop(self) -> None:
        global _ACTIVE
        if not self._started:
            return
        telemetry.remove_sink(self._sink)
        rmw_engine.clear_live_spec()
        if self.state_path:
            self._save_state()
        self._started = False
        if _ACTIVE is self:
            _ACTIVE = None

    def __enter__(self) -> "SpecController":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.stop()
        return False

    def wrap_step(self, step_fn: Callable) -> Callable:
        """``step_fn`` with ``self.step()`` appended — the one-line way to
        put a training/serving loop under tuning.  Donation metadata
        (`declare_donation`) is preserved so the recovery/lint contracts
        still see it."""
        def tuned_step(*args, **kwargs):
            out = step_fn(*args, **kwargs)
            self.step()
            return out
        donated = getattr(step_fn, "donate_argnums", None)
        if donated:
            from repro.runtime.fault_tolerance import declare_donation
            return declare_donation(tuned_step, tuple(donated))
        return tuned_step

    # --- stream tap -------------------------------------------------------
    def _observe(self, ev: Dict[str, Any]) -> None:
        # called under the telemetry lock: filter + buffer only
        if ev.get("event") not in drift.DRIFT_EVENTS:
            return
        pred, meas = ev.get("predicted_s"), ev.get("measured_s")
        if not isinstance(pred, (int, float)) or isinstance(pred, bool) \
                or not isinstance(meas, (int, float)) \
                or isinstance(meas, bool) or pred <= 0 or meas <= 0:
            return
        with self._wlock:
            self._window.append(ev)

    def window_size(self) -> int:
        with self._wlock:
            return len(self._window)

    # --- the update cycle -------------------------------------------------
    def step(self) -> Optional[str]:
        """Run one update cycle if a full drift window has accumulated.
        Returns the cycle outcome (``"apply"`` / ``"confirm"`` /
        ``"rollback"`` / ``"cooldown"`` / ``"quarantine"`` / ``"hold"``)
        or None when the window is still filling (the per-step fast path:
        one lock + one length check)."""
        if not self._started:
            return None
        with self._wlock:
            if len(self._window) < self.cfg.min_events:
                return None
            window = list(self._window)
            self._window.clear()
        outcome = self._update(window)
        self.last_outcome = outcome
        return outcome

    def _update(self, window: List[Dict[str, Any]]) -> str:
        self.n_updates += 1
        window = self._maybe_perturb(window)
        stats = drift.aggregate(window)
        n_samples = sum(st.n for st in stats.values())
        score = self._score(stats)
        self.last_score = score

        # post-swap evaluation first — rollback outranks everything,
        # including cooldown (the cooldown window IS the evaluation window)
        if self._pre_swap_score is not None and self._stack:
            pre = self._pre_swap_score
            if score > pre + self.cfg.rollback_margin:
                prev_spec, _prev_score = self._stack.pop()
                self.active = prev_spec
                self._install()
                self._pre_swap_score = None
                self._cooldown = self.cfg.cooldown_updates
                self.n_rollbacks += 1
                telemetry.record("tuning.rollback", score=score,
                                 pre_swap_score=pre, n=n_samples,
                                 depth=len(self._stack))
                return "rollback"
            self._pre_swap_score = None
            telemetry.record("tuning.confirm", score=score,
                             pre_swap_score=pre, n=n_samples)

        if self._cooldown > 0:
            self._cooldown -= 1
            telemetry.record("tuning.skip", reason="cooldown", score=score,
                             n=n_samples)
            return "cooldown"

        fitted = drift.fit_spec_update(stats, self.active,
                                       min_samples=self._sample_floors())
        proposals = {name: f["proposed"]
                     for name, f in fitted["fields"].items()}
        proposals = self._maybe_poison(proposals)
        applied, clamped, quarantined = self._guard(proposals)
        if quarantined:
            self.n_quarantined += len(quarantined)
            telemetry.record("tuning.quarantine", fields=quarantined,
                             score=score, n=n_samples)
        if not applied:
            if not quarantined:
                telemetry.record(
                    "tuning.skip",
                    reason="deadband" if proposals else "no_fields",
                    skipped=fitted["skipped"], score=score, n=n_samples)
            return "quarantine" if quarantined else "hold"

        self._stack.append((self.active, score))
        if len(self._stack) > self.cfg.history_depth:
            self._stack.pop(0)
        changes = {name: {"from": float(getattr(self.active, name)),
                          "to": float(val)}
                   for name, val in applied.items()}
        self.active = dataclasses.replace(self.active, **applied)
        self._install()
        self._pre_swap_score = score
        self._cooldown = self.cfg.cooldown_updates
        self.n_applied += 1
        telemetry.record("tuning.apply", fields=changes, clamped=clamped,
                         skipped=fitted["skipped"], score=score,
                         n=n_samples, depth=len(self._stack))
        return "apply"

    def _guard(self, proposals: Dict[str, Any]):
        """The per-field guardrail ladder: quarantine (pathological →
        calibrated fallback), clamp (bounded move), deadband (hold)."""
        applied: Dict[str, float] = {}
        clamped: Dict[str, Dict[str, float]] = {}
        quarantined: Dict[str, Dict[str, Any]] = {}
        env = self.cfg.envelope_factor
        for name, prop in proposals.items():
            if name not in TUNABLE_FIELDS:
                quarantined[name] = {"value": repr(prop),
                                     "reason": "not a tunable field"}
                continue
            cur = float(getattr(self.active, name, 0.0) or 0.0)
            cal = float(getattr(self.base, name, 0.0) or 0.0)
            if cur <= 0.0 or cal <= 0.0:
                quarantined[name] = {"value": repr(prop),
                                     "reason": "field unset on spec"}
                continue
            bad = not isinstance(prop, (int, float)) \
                or isinstance(prop, bool) or not math.isfinite(prop) \
                or prop <= 0.0
            if bad or not cal / env <= prop <= cal * env:
                quarantined[name] = {
                    "value": repr(prop),
                    "reason": ("non-finite or non-positive" if bad
                               else "outside calibrated envelope"),
                    "envelope": [cal / env, cal * env]}
                if cur != cal:
                    applied[name] = cal    # fall back to the calibrated value
                continue
            val = min(max(float(prop), cur / self.cfg.max_update_factor),
                      cur * self.cfg.max_update_factor)
            if val != prop:
                clamped[name] = {"proposed": float(prop), "applied": val}
            if abs(math.log(val / cur)) < self.cfg.deadband:
                continue
            applied[name] = val
        return applied, clamped, quarantined

    # --- chaos (spec_perturb site) ---------------------------------------
    def _maybe_perturb(self, window):
        if self.chaos is None or not self.chaos.fire("spec_perturb"):
            return window
        self.n_perturbs += 1
        u = self.chaos.param("spec_perturb")
        if u < 0.5:
            # skew: scale the window's measured walls by a log-uniform
            # factor in [1/8, 8) — the live spec gets poisoned through its
            # own feedback loop, and honest windows must walk it back
            factor = 8.0 ** (4.0 * u - 1.0)
            telemetry.record("tuning.perturb", kind="skew", factor=factor)
            self._poison_kind = None
            return [dict(ev, measured_s=ev["measured_s"] * factor)
                    for ev in window]
        # poison: corrupt the fitted proposal outright — quarantine must
        # absorb it (asserted by tests/test_tuning.py and the benchmark)
        kind = "nan" if u < 0.75 else "negative"
        telemetry.record("tuning.perturb", kind="poison", poison=kind)
        self._poison_kind = kind
        return window

    _poison_kind: Optional[str] = None

    def _maybe_poison(self, proposals: Dict[str, Any]) -> Dict[str, Any]:
        kind = self._poison_kind
        if kind is None:
            return proposals
        self._poison_kind = None
        bad = float("nan") if kind == "nan" else -1e-6
        if not proposals:
            # nothing fit this window: poison a tunable field anyway so
            # the quarantine path is exercised, not silently skipped
            return {TUNABLE_FIELDS[0]: bad}
        return {name: bad for name in proposals}

    # --- internals --------------------------------------------------------
    @staticmethod
    def _score(stats) -> float:
        """Sample-weighted mean |log(measured/predicted)| over the window —
        0 means the cost model is calibrated; the rollback check compares
        this across the swap."""
        n = sum(st.n for st in stats.values())
        if n == 0:
            return 0.0
        return sum(abs(st.log_sum) for st in stats.values()) / n

    def _sample_floors(self):
        if self.cfg.min_samples_per_field:
            return {"*": self.cfg.min_samples,
                    **dict(self.cfg.min_samples_per_field)}
        return self.cfg.min_samples

    def _install(self) -> None:
        rmw_engine.set_live_spec(self.active)

    def stats(self) -> Dict[str, Any]:
        """Controller observability: counters + the active tuned fields."""
        return {"updates": self.n_updates, "applied": self.n_applied,
                "rollbacks": self.n_rollbacks,
                "quarantined": self.n_quarantined,
                "perturbs": self.n_perturbs,
                "stack_depth": len(self._stack),
                "last_score": self.last_score,
                "last_outcome": self.last_outcome,
                "estimator_sites": len(self.estimator),
                "tuned_fields": {
                    f: {"calibrated": float(getattr(self.base, f)),
                        "active": float(getattr(self.active, f))}
                    for f in TUNABLE_FIELDS
                    if getattr(self.active, f) != getattr(self.base, f)}}

    # --- persistence ------------------------------------------------------
    def _save_state(self) -> None:
        payload = {"version": 1, "jax_backend": jax.default_backend(),
                   "spec": perf_model.spec_to_dict(self.active),
                   "estimator": self.estimator.snapshot(),
                   "counters": {"applied": self.n_applied,
                                "rollbacks": self.n_rollbacks,
                                "quarantined": self.n_quarantined}}
        tmp = f"{self.state_path}.tmp"
        try:
            with open(tmp, "w") as f:
                json.dump(payload, f, indent=2)
            os.replace(tmp, self.state_path)
        except OSError:
            telemetry.record("tuning.restore", accepted=False,
                             direction="save", reason="unwritable path",
                             path=self.state_path)

    def _restore_state(self) -> None:
        """Load + validate a persisted tuned spec.  Every failure mode —
        unreadable file, backend mismatch, out-of-envelope or non-finite
        constants — quarantines to the calibrated value and says so
        (``tuning.restore`` event); a stale state file must never install
        a pathological spec."""
        try:
            with open(self.state_path) as f:
                payload = json.load(f)
        except (OSError, ValueError):
            telemetry.record("tuning.restore", accepted=False,
                             reason="unreadable state file",
                             path=self.state_path)
            return
        backend = jax.default_backend()
        if payload.get("jax_backend") != backend:
            telemetry.record(
                "tuning.restore", accepted=False,
                reason=f"backend mismatch: tuned on "
                       f"{payload.get('jax_backend')!r}, running {backend!r}",
                path=self.state_path)
            return
        try:
            spec = perf_model.spec_from_dict(
                payload.get("spec") or {}, base=self.base)
        except Exception:  # noqa: BLE001 — corrupt payloads quarantine
            telemetry.record("tuning.restore", accepted=False,
                             reason="malformed spec payload",
                             path=self.state_path)
            return
        env = self.cfg.envelope_factor
        quarantined: Dict[str, str] = {}
        resets: Dict[str, float] = {}
        for name in TUNABLE_FIELDS:
            cal = float(getattr(self.base, name, 0.0) or 0.0)
            val = getattr(spec, name, None)
            ok = isinstance(val, (int, float)) \
                and not isinstance(val, bool) and math.isfinite(val) \
                and val > 0.0 and (cal <= 0.0
                                   or cal / env <= val <= cal * env)
            if not ok:
                quarantined[name] = repr(val)
                resets[name] = cal
        if resets:
            spec = dataclasses.replace(spec, **resets)
        self.active = spec
        self.estimator.restore(payload.get("estimator") or {})
        telemetry.record("tuning.restore", accepted=True,
                         quarantined=quarantined, path=self.state_path,
                         estimator_sites=len(self.estimator))


def from_env() -> Optional[SpecController]:
    """The ``REPRO_TUNING`` hook: unset/falsy → None; ``"1"/"on"/"true"``
    → a default controller; any other value is a state path the controller
    persists/restores the tuned spec through."""
    val = os.environ.get(TUNING_ENV, "").strip()
    if not val or val.lower() in ("0", "off", "false", "no"):
        return None
    if val.lower() in ("1", "on", "true", "yes"):
        return SpecController()
    return SpecController(state_path=val)
