"""`repro.tuning` — guarded self-tuning of the HardwareSpec cost model.

The feedback loop the ROADMAP's self-tuning item asked for, in its robust
form:

* :class:`SpecController` — folds the live telemetry drift window into the
  active `HardwareSpec` on a cadence and swaps it into all three selector
  tiers through `rmw_engine.set_live_spec`, behind clamp / hysteresis /
  rollback / quarantine guardrails and validated persistence
  (`repro.tuning.controller`).
* :class:`ContentionEstimator` — EWMA ``distinct_slots`` inference per
  repeated call site, fed by `execute_until`'s collision counts and round
  histograms, consulted automatically when the caller passes no hint
  (`repro.tuning.estimator`).
* ``spec_perturb`` — the chaos site (`runtime.chaos`) that poisons the
  live spec / skews drift samples inside the update cycle; the chaos suite
  asserts convergence-back, rollback, and tuned-vs-untuned bit-identity.

The one invariant everything here leans on: the spec and the estimator
steer **selection only** — every backend/strategy is bit-identical to the
serialized oracle, so a tuned run's results are bit-equal to an untuned
run's, always.
"""

from repro.tuning.controller import (TUNABLE_FIELDS, TUNING_ENV,
                                     SpecController, TuningConfig,
                                     active_controller, active_estimator,
                                     from_env)
from repro.tuning.estimator import ContentionEstimator, SiteKey, site_key

__all__ = [
    "TUNABLE_FIELDS", "TUNING_ENV", "SpecController", "TuningConfig",
    "active_controller", "active_estimator", "from_env",
    "ContentionEstimator", "SiteKey", "site_key",
]
