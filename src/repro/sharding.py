"""Logical-axis sharding hints (MaxText-style), divisibility-aware.

Models annotate tensors with *logical* axis names ("batch", "seq", "embed",
"ffn", "heads", "kv_heads", "experts", "vocab", "layers", ...).  The launcher
installs a mapping logical-name -> mesh axes; `hint()` applies a
`jax.lax.with_sharding_constraint` **only for dimensions whose size divides
the mesh axes** (e.g. 40 heads on a 16-way model axis stay unsharded — the
framework's divisibility-aware TP policy, DESIGN.md §7).

Without an installed mesh all hints are no-ops, so the same model code runs
single-device (smoke tests) and multi-pod (dry-run/train).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

AxisVal = Union[str, Tuple[str, ...], None]


def _current() -> Tuple[Optional[Mesh], Dict[str, AxisVal]]:
    return (getattr(_state, "mesh", None), getattr(_state, "rules", {}))


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: Dict[str, AxisVal]):
    """Install mesh + logical->physical rules for hint()/axis lookup."""
    prev = _current()
    _state.mesh, _state.rules = mesh, dict(rules)
    try:
        with mesh:
            yield
    finally:
        _state.mesh, _state.rules = prev


def mesh_axis_size(*names: str) -> int:
    mesh, _ = _current()
    if mesh is None:
        return 1
    size = 1
    for n in names:
        size *= mesh.shape.get(n, 1)
    return size


def active_mesh() -> Optional[Mesh]:
    return _current()[0]


def shard_map_compat(fn, mesh: Mesh, in_specs, out_specs):
    """jax.shard_map with a fallback for older jax (experimental module,
    `check_rep` instead of `check_vma`).  The single home for this
    version-dependent compat logic — use it instead of re-wrapping."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def logical_to_physical(logical: Sequence[Optional[str]],
                        shape: Sequence[int]) -> P:
    """Resolve logical names to a PartitionSpec, dropping non-divisible axes."""
    mesh, rules = _current()
    if mesh is None:
        return P()
    spec = []
    used: set = set()
    for name, dim in zip(logical, shape):
        phys = rules.get(name) if name else None
        if phys is None:
            spec.append(None)
            continue
        axes = (phys,) if isinstance(phys, str) else tuple(phys)
        axes = tuple(a for a in axes if a not in used and a in mesh.shape)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if size <= 1 or dim % size != 0:
            spec.append(None)
            continue
        used.update(axes)
        spec.append(axes[0] if len(axes) == 1 else axes)
    while spec and spec[-1] is None:
        spec.pop()
    return P(*spec)


def hint(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Apply a sharding constraint by logical axis names (no-op without mesh,
    and per-dimension no-op when sizes don't divide)."""
    mesh, _ = _current()
    if mesh is None:
        return x
    if len(logical) != x.ndim:
        raise ValueError(f"hint rank mismatch: {logical} vs {x.shape}")
    spec = logical_to_physical(logical, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(logical: Sequence[Optional[str]],
                   shape: Sequence[int]) -> Optional[NamedSharding]:
    mesh, _ = _current()
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_to_physical(logical, shape))


#: default logical->physical rules used by the launcher.  "fsdp" combines the
#: pod and data axes (params + optimizer state ZeRO-3 sharded across both).
DEFAULT_RULES: Dict[str, AxisVal] = {
    "batch": ("pod", "data"),
    "seq": None,                # sequence stays unsharded in activations
    "act_seq": None,            # residual-carry seq sharding (SP) — opt-in
                                # via rules override ("model") in the launcher
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "qkv": "model",
    "ffn": "model",
    "vocab": "model",
    "experts": "model",
    "expert_ffn": None,
    "fsdp": ("pod", "data"),
    "layers": None,
    "kv_seq": None,
    "state": None,
    # RMW tables (core/rmw_sharded.py): owner-major over the EP/model axis,
    # matching the subsystem's slot->shard layout (g // m_local)
    "rmw_table": "model",
}
