"""Deterministic, shardable data pipeline.

Restart-exactness is the fault-tolerance contract (DESIGN.md §7): batch
content is a pure function of (seed, step), so resuming from a checkpointed
step reproduces the exact token stream with no reader state to persist.
Two sources:
  * synthetic  — hash-based token generator (benchmarks, dry-runs, tests)
  * memmap     — flat binary token file (real corpora), sliced by (step,
                 shard) with the same determinism
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    source: str = "synthetic"          # "synthetic" | "memmap"
    path: Optional[str] = None         # memmap token file (uint16/uint32)
    mask_fraction: float = 0.0         # fraction of label positions masked


def synthetic_batch(cfg: DataConfig, step: int,
                    d_model: int = 0, with_embeds: bool = False,
                    with_frames: int = 0,
                    with_positions3: bool = False) -> Dict[str, Array]:
    """Pure function of (seed, step) -> batch dict (model.py contract).

    Tokens follow a seed-fixed bigram permutation with 20% uniform noise:
    IID-uniform streams have irreducible next-token loss ln(V) (nothing for
    the quickstart to learn), while a noisy bigram gives training a
    learnable signal yet stays a pure function of (seed, step).
    """
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    ks = jax.random.split(key, 6)
    b, s = cfg.global_batch, cfg.seq_len
    v = cfg.vocab_size
    perm = jax.random.permutation(jax.random.PRNGKey(cfg.seed ^ 0x5EED), v)
    first = jax.random.randint(ks[0], (b,), 0, v, jnp.int32)
    noise = jax.random.bernoulli(ks[4], 0.2, (b, s))
    resample = jax.random.randint(ks[5], (b, s), 0, v, jnp.int32)

    def chain(tok, inp):
        noisy, rand = inp
        nxt = jnp.where(noisy, rand, perm[tok])
        return nxt, nxt

    _, rest = jax.lax.scan(chain, first,
                           (noise[:, 1:].T, resample[:, 1:].T))
    tokens = jnp.concatenate([first[:, None], rest.T], axis=1)
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.full((b, 1), -100, jnp.int32)], axis=1)
    batch: Dict[str, Array] = {"tokens": tokens, "labels": labels}
    if with_embeds:
        batch["embeds"] = jax.random.normal(ks[1], (b, s, d_model),
                                            jnp.float32) * 0.02
        del batch["tokens"]
    if with_frames:
        batch["frames"] = jax.random.normal(ks[2], (b, with_frames, d_model),
                                            jnp.float32) * 0.02
    if with_positions3:
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))
        batch["positions3"] = jnp.broadcast_to(pos[None], (3, b, s))
    return batch


class MemmapSource:
    """Flat token file; batch (step, i) reads a deterministic window."""

    def __init__(self, cfg: DataConfig):
        assert cfg.path, "memmap source needs cfg.path"
        self.cfg = cfg
        self.tokens = np.memmap(cfg.path, dtype=np.uint16, mode="r")
        self.n = len(self.tokens)

    def batch(self, step: int) -> Dict[str, Array]:
        cfg = self.cfg
        b, s = cfg.global_batch, cfg.seq_len
        rng = np.random.default_rng(cfg.seed * 1_000_003 + step)
        starts = rng.integers(0, self.n - s - 1, size=b)
        toks = np.stack([self.tokens[st:st + s].astype(np.int32)
                         for st in starts])
        labels = np.stack([self.tokens[st + 1:st + s + 1].astype(np.int32)
                           for st in starts])
        return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}


def make_iterator(cfg: DataConfig, start_step: int = 0,
                  **synthetic_kw) -> Iterator[Dict[str, Array]]:
    """Resumable iterator: pass the checkpointed step as start_step."""
    src = MemmapSource(cfg) if cfg.source == "memmap" else None
    step = start_step
    while True:
        if src is not None:
            yield src.batch(step)
        else:
            yield synthetic_batch(cfg, step, **synthetic_kw)
        step += 1


def batch_kwargs_for(cfg_model) -> Dict:
    """synthetic_batch kwargs required by a ModelConfig's input contract."""
    kw: Dict = {}
    if cfg_model.embeds_input:
        kw.update(with_embeds=True, d_model=cfg_model.d_model)
    if cfg_model.encoder is not None:
        kw.update(with_frames=cfg_model.encoder.n_frames,
                  d_model=cfg_model.d_model)
    if cfg_model.pos_emb == "mrope":
        kw.update(with_positions3=True)
    return kw
