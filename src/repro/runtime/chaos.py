"""Deterministic, seedable fault injection — the chaos half of recovery.

The recovery state machine (`runtime.fault_tolerance`) used to be driven by
ad-hoc hand-written ``failure_injector`` callbacks: each test invented its
own crash schedule, nothing composed, and nothing could answer "does the
whole stack survive a *seeded storm* of chip loss, corrupt checkpoints, and
mid-reshard failures bit-identically?".  This module replaces that with a
:class:`FaultPlan`: one seed deterministically schedules faults at named
**sites** of the recovery loop, with per-site probability/count knobs.

Sites (the first five are visited by ``run_with_recovery`` in loop order;
``spec_perturb`` belongs to the tuning controller's update cycle)::

    straggler_delay   before a step: injected stall (sleeps, never raises)
    step              the step body: raises ChaosError (chip loss analogue)
    ckpt_save         before save_fn: a save that never lands
    ckpt_restore      before restore_fn: a restore attempt that dies
    reshard           before reshard_fn: elastic migration failure
    spec_perturb      tuning update cycle: poison the live HardwareSpec /
                      skew the drift window (`repro.tuning.SpecController`)

Determinism contract: whether visit ``k`` of site ``s`` fires is a pure
function of ``(seed, s, k)`` — every site draws from its own independent
stream, so adding visits at one site never perturbs another site's
schedule, and two runs with the same seed and the same control flow inject
the *same* faults.  (Control flow after a fault differs from the fault-free
run, of course — that is the point; the invariant under test is that the
**final state** is still bit-equal.)

Env hook: ``REPRO_CHAOS="seed=7,step=0.05,ckpt_save=0.1@2,delay=0.02"``
turns any benchmark, example, or training run into a chaos run without
code changes (`FaultPlan.from_env`, consulted by ``run_with_recovery``
when no explicit plan is passed).
"""

from __future__ import annotations

import logging
import os
import time
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Union

import numpy as np

log = logging.getLogger("repro.runtime")

#: the fault sites of the recovery loop, in `run_with_recovery` visit order
RECOVERY_SITES = ("straggler_delay", "step", "ckpt_save", "ckpt_restore",
                  "reshard")

#: all named fault sites: the recovery loop's plus the tuning controller's
#: spec-poisoning site (visited once per `SpecController` update cycle)
SITES = RECOVERY_SITES + ("spec_perturb",)

#: env var consumed by FaultPlan.from_env (see module docstring for syntax)
CHAOS_ENV = "REPRO_CHAOS"


class ChaosError(RuntimeError):
    """An *injected*, retryable fault.  Recovery must absorb it: the chaos
    suite asserts the final state is bit-equal to a fault-free run."""

    def __init__(self, site: str, occurrence: int, step: Optional[int] = None):
        self.site = site
        self.occurrence = occurrence
        self.step = step
        at = f" at step {step}" if step is not None else ""
        super().__init__(f"injected fault #{occurrence} at site "
                         f"{site!r}{at}")


@dataclass(frozen=True)
class SiteSpec:
    """Per-site knobs: fire with ``prob`` per visit, at most ``count`` times
    total (None = unbounded), skipping the first ``after`` visits.
    ``delay_s`` is the injected stall for the ``straggler_delay`` site."""

    prob: float = 0.0
    count: Optional[int] = None
    after: int = 0
    delay_s: float = 0.0


class FaultPlan:
    """A seed-derived fault schedule over the named recovery-loop sites.

    ``sites`` maps site name -> :class:`SiteSpec` (a bare float is shorthand
    for ``SiteSpec(prob=...)``).  The plan is stateful only in its visit
    counters: the fire decision itself is the pure function
    ``hash(seed, site, visit) < prob`` (counter-mode PRNG per draw), so two
    plans with the same seed replay identically.
    """

    def __init__(self, seed: int = 0,
                 sites: Optional[Dict[str, Union[float, SiteSpec]]] = None,
                 *, sleep_fn: Callable[[float], None] = time.sleep):
        self.seed = int(seed)
        self.sites: Dict[str, SiteSpec] = {}
        for name, spec in (sites or {}).items():
            if name not in SITES:
                raise ValueError(f"unknown fault site {name!r}; "
                                 f"have {SITES}")
            if not isinstance(spec, SiteSpec):
                spec = SiteSpec(prob=float(spec))
            self.sites[name] = spec
        self._sleep = sleep_fn
        self._visits = {s: 0 for s in SITES}
        self._fired = {s: 0 for s in SITES}

    # --- constructors -----------------------------------------------------
    @classmethod
    def null(cls) -> "FaultPlan":
        """A plan that never fires (the no-chaos default)."""
        return cls(0, {})

    @classmethod
    def from_spec(cls, text: str, *,
                  sleep_fn: Callable[[float], None] = time.sleep
                  ) -> "FaultPlan":
        """Parse ``"seed=7,step=0.05,ckpt_save=0.1@2,delay=0.02"``:
        ``seed=<int>``; ``delay=<sec>`` (stall length for the
        ``straggler_delay`` site); ``<site>=<prob>[@<count>]`` per site."""
        seed, delay_s, sites = 0, 0.01, {}
        for token in text.split(","):
            token = token.strip()
            if not token:
                continue
            if "=" not in token:
                raise ValueError(f"bad {CHAOS_ENV} token {token!r} "
                                 f"(want key=value)")
            key, _, val = token.partition("=")
            key, val = key.strip(), val.strip()
            if key == "seed":
                seed = int(val)
            elif key == "delay":
                delay_s = float(val)
            else:
                prob, _, count = val.partition("@")
                sites[key] = SiteSpec(prob=float(prob),
                                      count=int(count) if count else None)
        sites = {name: (SiteSpec(spec.prob, spec.count, spec.after, delay_s)
                        if name == "straggler_delay" else spec)
                 for name, spec in sites.items()}
        return cls(seed, sites, sleep_fn=sleep_fn)

    @classmethod
    def from_env(cls) -> "FaultPlan":
        """The ``REPRO_CHAOS`` hook: a plan parsed from the env var, or the
        null plan when unset/empty."""
        text = os.environ.get(CHAOS_ENV, "").strip()
        return cls.from_spec(text) if text else cls.null()

    # --- the schedule -----------------------------------------------------
    def _draw(self, site: str, visit: int) -> float:
        # counter-mode: one fresh generator per (seed, site, visit) makes
        # the decision history-free — sites never share a stream
        seq = np.random.SeedSequence([self.seed, SITES.index(site), visit])
        return float(np.random.default_rng(seq).random())

    def fire(self, site: str) -> bool:
        """Advance site's visit counter; True iff this visit faults."""
        if site not in SITES:
            raise ValueError(f"unknown fault site {site!r}; have {SITES}")
        visit = self._visits[site]
        self._visits[site] = visit + 1
        spec = self.sites.get(site)
        if spec is None or spec.prob <= 0.0 or visit < spec.after:
            return False
        if spec.count is not None and self._fired[site] >= spec.count:
            return False
        hit = self._draw(site, visit) < spec.prob
        if hit:
            self._fired[site] += 1
        return hit

    def param(self, site: str) -> float:
        """Deterministic fault *parameter* in [0, 1) for the most recent
        visit of ``site`` — an independent stream from the fire decision
        (tag 1 vs the implicit fire draw), so reading a parameter never
        perturbs the schedule.  The tuning controller maps it onto the
        perturbation shape (skew factor vs poison kind) for the
        ``spec_perturb`` site."""
        if site not in SITES:
            raise ValueError(f"unknown fault site {site!r}; have {SITES}")
        visit = max(0, self._visits[site] - 1)
        seq = np.random.SeedSequence(
            [self.seed, SITES.index(site), visit, 1])
        return float(np.random.default_rng(seq).random())

    def visit(self, site: str, *, step: Optional[int] = None) -> None:
        """The recovery loop's hook: raise :class:`ChaosError` when the
        site fires — except ``straggler_delay``, which *stalls* instead
        (the straggler analogue: one slow participant, not a dead one)."""
        if not self.fire(site):
            return
        from repro import telemetry
        if site == "straggler_delay":
            delay = self.sites[site].delay_s
            telemetry.record("chaos.fire", site=site,
                             occurrence=self._fired[site], step=step,
                             kind="stall", delay_s=delay)
            log.info("chaos: injected %.3fs straggler stall at step %s",
                     delay, step)
            self._sleep(delay)
            return
        telemetry.record("chaos.fire", site=site,
                         occurrence=self._fired[site], step=step,
                         kind="raise")
        raise ChaosError(site, self._fired[site], step)

    # --- observability ----------------------------------------------------
    def stats(self) -> Dict[str, Dict[str, int]]:
        """Per-site ``{"visits": n, "fired": k}`` counters."""
        return {s: {"visits": self._visits[s], "fired": self._fired[s]}
                for s in SITES if self._visits[s] or s in self.sites}

    @property
    def total_fired(self) -> int:
        return sum(self._fired.values())

    def replay(self) -> "FaultPlan":
        """A fresh plan with the same seed/sites and zeroed counters —
        re-running the same program under it injects the same faults."""
        return FaultPlan(self.seed, dict(self.sites), sleep_fn=self._sleep)

    def __repr__(self):
        parts = ", ".join(f"{n}={s.prob:g}" +
                          (f"@{s.count}" if s.count is not None else "")
                          for n, s in self.sites.items())
        return f"FaultPlan(seed={self.seed}, {{{parts}}})"
