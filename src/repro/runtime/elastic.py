"""Elastic scaling: reshard a checkpoint onto a different mesh.

The fleet contract (DESIGN.md §7): when a pod is lost (or added), training
restarts on a new mesh whose `data` (or `pod`) extent changed.  Because
checkpoints store host arrays + logical metadata, restoring is a pure
device_put under the *new* mesh's shardings — no resharding collectives, no
dependence on the writer's topology.  The deterministic data pipeline then
resumes from the checkpointed step with the new shard count.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding

from repro.checkpoint import ckpt as ckpt_lib
from repro.launch import shardings as sh
from repro.models.config import ModelConfig
from repro.sharding import use_mesh


def reshard_restore(ckpt_dir: str, step: int, like: Any, cfg: ModelConfig,
                    new_mesh: Mesh, rules: Optional[Dict] = None,
                    shape_kind: str = "train"):
    """Restore `like`-structured state under `new_mesh` shardings.

    `like` must contain a "params" entry (model parameters); every params
    leaf gets its divisibility-aware NamedSharding computed against the NEW
    mesh; other entries ("opt" moments/master) inherit the param shardings
    leaf-wise where shapes match, else replicate.
    """
    rules = rules if rules is not None else sh.arch_rules(cfg, new_mesh,
                                                          shape_kind)
    with use_mesh(new_mesh, rules):
        params_abs = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), like["params"])
        params_sh = sh.params_shardings(cfg, params_abs, new_mesh, rules)
        shard_by_shape: Dict[tuple, Any] = {}
        for leaf, s in zip(jax.tree.leaves(params_abs),
                           jax.tree.leaves(params_sh)):
            shard_by_shape.setdefault((leaf.shape, str(leaf.dtype)), s)

        flat_like, _ = jax.tree_util.tree_flatten(like)
        flat_sh = []
        for leaf in flat_like:
            key = (leaf.shape, str(leaf.dtype))
            alt = (leaf.shape, "float32")  # fp32 master of a bf16 param
            s = shard_by_shape.get(key) or shard_by_shape.get(alt)
            flat_sh.append(s if s is not None
                           else NamedSharding(new_mesh,
                                              jax.sharding.PartitionSpec()))
        it = iter(flat_sh)

        def sharding_fn(key, ref):
            return next(it)

        state, extra = ckpt_lib.restore(ckpt_dir, step, like,
                                        sharding_fn=sharding_fn)
    return state, extra


def survivors_mesh(axis_sizes: Dict[str, int], lost_data_shards: int = 0):
    """Build the post-failure mesh: shrink the data axis by the lost shards
    (straggler/failed hosts are excluded; see runtime.fault_tolerance)."""
    sizes = dict(axis_sizes)
    sizes["data"] = sizes.get("data", 1) - lost_data_shards
    if sizes["data"] < 1:
        raise ValueError("no data shards left")
    names = tuple(sizes)
    return jax.make_mesh(tuple(sizes[n] for n in names), names)
