"""Elastic scaling: reshard a checkpoint onto a different mesh.

The fleet contract (DESIGN.md §7): when a pod is lost (or added), training
restarts on a new mesh whose `data` (or `pod`) extent changed.  Because
checkpoints store host arrays + logical metadata, restoring is a pure
device_put under the *new* mesh's shardings — no resharding collectives, no
dependence on the writer's topology.  The deterministic data pipeline then
resumes from the checkpointed step with the new shard count.

`AtomicTable` state rides the same contract: table leaves in `like` restore
through `repro.atomics.reshard` (the host-roundtrip migration path — the
old mesh is gone by definition here), re-deriving the owner-major layout
and arrival order under the new extents instead of replaying RMW history.
Live tables — no checkpoint in the loop — migrate with
:func:`reshard_tables` (`atomics.reshard.migrate` over a state tree), which
the recovery state machine (`runtime.fault_tolerance`) invokes on elastic
restarts.
"""

from __future__ import annotations

import logging
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

log = logging.getLogger("repro.runtime")

from repro.atomics.table import AtomicTable
from repro.checkpoint import ckpt as ckpt_lib
from repro.launch import shardings as sh
from repro.models.config import ModelConfig
from repro.sharding import use_mesh


def _is_table(x) -> bool:
    return isinstance(x, AtomicTable)


def reshard_restore(ckpt_dir: str, step: int, like: Any, cfg: ModelConfig,
                    new_mesh: Mesh, rules: Optional[Dict] = None,
                    shape_kind: str = "train"):
    """Restore `like`-structured state under `new_mesh` shardings.

    `like` must contain a "params" entry (model parameters); every params
    leaf gets its divisibility-aware NamedSharding computed against the NEW
    mesh; other entries ("opt" moments/master) inherit the param shardings
    leaf-wise where shapes match, else replicate.  `AtomicTable` leaves
    reshard through `atomics.reshard.restore_table` under the new mesh
    (their sharding comes from the handle's own axis contract, not the
    shape-matching heuristic).
    """
    rules = rules if rules is not None else sh.arch_rules(cfg, new_mesh,
                                                          shape_kind)
    with use_mesh(new_mesh, rules):
        params_abs = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), like["params"],
            is_leaf=_is_table)
        params_sh = sh.params_shardings(cfg, params_abs, new_mesh, rules)
        shard_by_shape: Dict[tuple, Any] = {}
        for leaf, s in zip(jax.tree.leaves(params_abs),
                           jax.tree.leaves(params_sh)):
            shard_by_shape.setdefault((leaf.shape, str(leaf.dtype)), s)

        flat_like, _ = jax.tree_util.tree_flatten(like, is_leaf=_is_table)
        flat_sh = []
        for leaf in flat_like:
            if _is_table(leaf):
                continue  # ckpt.restore never consults sharding_fn for these
            key = (leaf.shape, str(leaf.dtype))
            alt = (leaf.shape, "float32")  # fp32 master of a bf16 param
            s = shard_by_shape.get(key) or shard_by_shape.get(alt)
            flat_sh.append(s if s is not None
                           else NamedSharding(new_mesh,
                                              jax.sharding.PartitionSpec()))
        it = iter(flat_sh)

        def sharding_fn(key, ref):
            return next(it)

        state, extra = ckpt_lib.restore(ckpt_dir, step, like,
                                        sharding_fn=sharding_fn)
    return state, extra


def reshard_tables(state: Any, new_mesh: Mesh, *, path: str = "auto",
                   spec=None) -> Any:
    """Migrate every live `AtomicTable` in a state tree onto `new_mesh`.

    The no-checkpoint elastic route: tables move through
    `atomics.reshard.migrate` (cost-model-chosen path — the in-collective
    slot exchange when the fleet is unchanged, the host roundtrip when it
    grew or shrank), keeping their axis contract where the new mesh still
    carries those axes.  Non-table leaves pass through untouched.

    Degradation ladder: this runs *inside the recovery loop*, where a
    failure means another restore/replay cycle — so a broken migration
    path must degrade, not crash.  Per table: the requested path (the
    in-collective ``exchange`` under ``"auto"``) -> the host-roundtrip
    ``device_put`` (always topologically feasible) -> a plain **local
    handle** (host gather, contract dropped) as the floor.  Each
    degradation is logged; the data is bit-identical on every rung, only
    placement quality degrades.
    """
    from repro.atomics import reshard as reshard_lib

    def one(x):
        if not _is_table(x) or not x.is_sharded:
            return x
        try:
            return reshard_lib.migrate(x, new_mesh, path=path, spec=spec)
        except Exception as e:  # noqa: BLE001 — mid-recovery, degrade
            log.warning("table migration (path=%s) onto %s failed (%s: %s); "
                        "degrading to device_put", path, new_mesh,
                        type(e).__name__, e)
        if path != "device_put":
            try:
                return reshard_lib.migrate(x, new_mesh, path="device_put",
                                           spec=spec)
            except Exception as e:  # noqa: BLE001
                log.warning("device_put migration failed too (%s: %s); "
                            "degrading to a local handle",
                            type(e).__name__, e)
        return AtomicTable(jnp.asarray(np.asarray(x.data)))

    return jax.tree_util.tree_map(one, state, is_leaf=_is_table)


def survivors_mesh(axis_sizes: Dict[str, int], lost_data_shards: int = 0):
    """Build the post-failure mesh: shrink the data axis by the lost shards
    (straggler/failed hosts are excluded; see runtime.fault_tolerance)."""
    sizes = dict(axis_sizes)
    sizes["data"] = sizes.get("data", 1) - lost_data_shards
    if sizes["data"] < 1:
        raise ValueError("no data shards left")
    names = tuple(sizes)
    return jax.make_mesh(tuple(sizes[n] for n in names), names)
