"""Fault tolerance: retrying step executor, straggler detection, elasticity.

On a real multi-pod deployment, chip/host loss surfaces as a Python exception
from the collective runtime; the recovery sequence is: tear down, re-init the
mesh (possibly smaller — elastic), restore the latest checkpoint, reshard
live `AtomicTable` state onto the new mesh (`reshard_fn`, normally
`runtime.elastic.reshard_tables` — layout re-derivation, not history
replay), and resume from the checkpointed step (the deterministic data
pipeline makes the resume bit-exact).  This module implements that state
machine; the CPU tests drive it with injected failures.
"""

from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

log = logging.getLogger("repro.runtime")


@dataclass
class FaultConfig:
    max_failures: int = 3
    checkpoint_every: int = 50
    straggler_window: int = 20
    straggler_threshold: float = 2.0     # x median step time


class StragglerMonitor:
    """Per-host step-time tracker (paper §5.4 analogue: one slow participant
    serializes the collective, like one contended owner serializes the RMW).

    flag() returns hosts whose recent mean step time exceeds
    threshold x fleet median — the launcher reassigns their data shards and
    excludes them at the next elastic restart.
    """

    def __init__(self, n_hosts: int, cfg: FaultConfig):
        self.cfg = cfg
        self.times: List[List[float]] = [[] for _ in range(n_hosts)]

    def record(self, host: int, seconds: float) -> None:
        w = self.times[host]
        w.append(seconds)
        if len(w) > self.cfg.straggler_window:
            w.pop(0)

    def flag(self) -> List[int]:
        means = [sum(w) / len(w) if w else 0.0 for w in self.times]
        active = sorted(m for m in means if m > 0)
        if not active:
            return []
        median = active[len(active) // 2]
        return [i for i, m in enumerate(means)
                if m > self.cfg.straggler_threshold * median]


@dataclass
class RunResult:
    steps_done: int
    failures: int
    restored_from: List[int] = field(default_factory=list)


def run_with_recovery(step_fn: Callable[[int, Any], Any],
                      init_state: Any,
                      n_steps: int,
                      cfg: FaultConfig,
                      save_fn: Callable[[int, Any], None],
                      restore_fn: Callable[[], Optional[tuple]],
                      failure_injector: Optional[Callable[[int], None]] = None,
                      reshard_fn: Optional[Callable[[Any], Any]] = None
                      ) -> RunResult:
    """Drive `step_fn(step, state) -> state` with checkpoint/restart recovery.

    `restore_fn() -> (step, state) | None` returns the latest checkpoint.
    `failure_injector(step)` may raise to simulate chip loss (tests).
    `reshard_fn(state) -> state`, when given, is applied to every restored
    state before stepping resumes — the elastic-restart hook: the launcher
    wires it to `runtime.elastic.reshard_tables` (itself
    `atomics.reshard.migrate` over the state tree) so live `AtomicTable`s
    land on the post-failure mesh with their owner-major layout re-derived
    instead of their RMW history replayed.
    """
    state = init_state
    step = 0
    failures = 0
    restored: List[int] = []

    def _adopt(s):
        return s if reshard_fn is None else reshard_fn(s)

    restored_ck = restore_fn()
    if restored_ck is not None:
        step, state = restored_ck
        state = _adopt(state)
        restored.append(step)
        log.info("resumed from checkpoint at step %d", step)
    while step < n_steps:
        try:
            if failure_injector is not None:
                failure_injector(step)
            state = step_fn(step, state)
            step += 1
            if step % cfg.checkpoint_every == 0 or step == n_steps:
                save_fn(step, state)
        except Exception as e:  # noqa: BLE001 — chip loss shows up as generic
            failures += 1
            log.warning("step %d failed (%s); recovery %d/%d", step, e,
                        failures, cfg.max_failures)
            if failures > cfg.max_failures:
                raise
            ck = restore_fn()
            if ck is None:
                # restart from scratch still crosses the mesh change: the
                # initial state's live tables need adopting too
                step, state = 0, _adopt(init_state)
            else:
                step, state = ck
                state = _adopt(state)
                restored.append(step)
            time.sleep(0)  # backoff hook
    return RunResult(steps_done=step, failures=failures,
                     restored_from=restored)
