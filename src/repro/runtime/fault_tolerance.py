"""Fault tolerance: retrying step executor, straggler detection, elasticity.

On a real multi-pod deployment, chip/host loss surfaces as a Python exception
from the collective runtime; the recovery sequence is: tear down, re-init the
mesh (possibly smaller — elastic), restore the latest VALID checkpoint
(`checkpoint.ckpt.restore_latest_valid` walks back past corrupt ones),
reshard live `AtomicTable` state onto the new mesh (`reshard_fn`, normally
`runtime.elastic.reshard_tables` — layout re-derivation, not history
replay), and resume from the checkpointed step (the deterministic data
pipeline makes the resume bit-exact).  This module implements that state
machine.

Recovery pacing follows Lightweight Contention Management
(arxiv 1305.5800): failure feedback drives an **explicit policy** —
exponential backoff with deterministic jitter between recovery attempts
(so a fleet of restarting hosts does not re-stampede the same resource),
a wall-clock ``deadline_s`` budget after which recovery gives up, and a
retryable/fatal split (`FatalFault`, ``FaultConfig.fatal_types``) so
misconfiguration is never retried like chip loss.

Faults are injected by the deterministic chaos subsystem
(`runtime.chaos.FaultPlan`) at the named sites of the loop —
``straggler_delay`` / ``step`` / ``ckpt_save`` / ``ckpt_restore`` /
``reshard`` — seeded and replayable; the legacy ``failure_injector``
callback is kept as a thin shim for hand-written step-site crashes.  Set
``REPRO_CHAOS`` (e.g. ``"seed=7,step=0.05,ckpt_save=0.1@2"``) to run any
caller under faults without code changes.
"""

from __future__ import annotations

import logging
import random
import time
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Tuple

from repro import telemetry
from repro.runtime.chaos import FaultPlan

log = logging.getLogger("repro.runtime")


class FatalFault(Exception):
    """A failure recovery must NOT absorb (misconfiguration, corrupted
    source of truth, operator abort).  Raising it — or any class listed in
    ``FaultConfig.fatal_types`` — propagates immediately, no retry."""


@dataclass
class FaultConfig:
    max_failures: int = 3
    checkpoint_every: int = 50
    straggler_window: int = 20
    straggler_threshold: float = 2.0     # x median step time

    # recovery pacing (arxiv 1305.5800: explicit backoff, not blind retry)
    backoff_base_s: float = 0.01         # first retry delay
    backoff_factor: float = 2.0          # growth per consecutive failure
    backoff_max_s: float = 2.0           # delay ceiling
    backoff_jitter: float = 0.1          # ± fraction, de-stampedes a fleet
    backoff_seed: int = 0                # deterministic jitter stream
    deadline_s: Optional[float] = None   # wall-clock recovery budget
    fatal_types: Tuple[type, ...] = ()   # never retried (FatalFault always)


def backoff_delay(cfg: FaultConfig, failures: int) -> float:
    """Delay before recovery attempt ``failures`` (1-based): capped
    exponential with deterministic jitter — a pure function of
    ``(cfg, failures)``, so a replayed chaos run paces identically."""
    base = min(cfg.backoff_max_s,
               cfg.backoff_base_s * cfg.backoff_factor ** max(0, failures - 1))
    u = random.Random(cfg.backoff_seed * 1_000_003 + failures).uniform(-1.0,
                                                                       1.0)
    return max(0.0, base * (1.0 + cfg.backoff_jitter * u))


class StragglerMonitor:
    """Per-host step-time tracker (paper §5.4 analogue: one slow participant
    serializes the collective, like one contended owner serializes the RMW).

    flag() returns hosts whose recent mean step time exceeds
    threshold x fleet median — the launcher reassigns their data shards and
    excludes them at the next elastic restart.
    """

    def __init__(self, n_hosts: int, cfg: FaultConfig):
        self.cfg = cfg
        self.times: List[List[float]] = [[] for _ in range(n_hosts)]

    def record(self, host: int, seconds: float) -> None:
        w = self.times[host]
        w.append(seconds)
        if len(w) > self.cfg.straggler_window:
            w.pop(0)

    def flag(self) -> List[int]:
        means = [sum(w) / len(w) if w else 0.0 for w in self.times]
        active = sorted(m for m in means if m > 0)
        if not active:
            return []
        median = active[len(active) // 2]
        return [i for i, m in enumerate(means)
                if m > self.cfg.straggler_threshold * median]


class _DonatingStep:
    """A step callable carrying machine-readable donation metadata.

    jit's C++ ``PjitFunction`` rejects attribute assignment, so the
    metadata lives on this thin wrapper instead; `declare_donation`
    constructs it.  The static analyzer (`repro.analysis.check_recovery`,
    rule A004) and `run_with_recovery`'s startup check read
    ``donate_argnums`` without tracing.
    """

    __slots__ = ("fn", "donate_argnums")

    def __init__(self, fn: Callable, donate_argnums: Tuple[int, ...]):
        self.fn = fn
        self.donate_argnums = tuple(donate_argnums)

    def __call__(self, *args, **kwargs):
        return self.fn(*args, **kwargs)

    def __repr__(self) -> str:
        return (f"_DonatingStep({self.fn!r}, "
                f"donate_argnums={self.donate_argnums})")


def declare_donation(fn: Callable, argnums) -> "_DonatingStep":
    """Annotate a (jitted) step function with the argnums it donates.

    Purely metadata — the wrapper calls ``fn`` unchanged.  Declaring
    donation lets rule A004 check the donation/state-factory contract
    statically instead of at the first post-failure restart.
    """
    if isinstance(argnums, int):
        argnums = (argnums,)
    return _DonatingStep(fn, tuple(argnums))


@dataclass
class RunResult:
    """Outcome of :func:`run_with_recovery`.

    ``events`` is the run's structured recovery trace — one dict per
    ``recovery.fault`` / ``recovery.backoff`` / ``recovery.restore``
    occurrence, in order, always populated (telemetry enabled or not) so
    tests and callers assert on fields instead of parsing log text.

    ``telemetry_ring`` is the last-N global event stream at run end when a
    `telemetry.RingBuffer` sink is installed (``REPRO_TELEMETRY=ring``) —
    empty otherwise.  The same snapshot is flushed to disk on the fatal
    fault path (`telemetry.flush_ring`), so ring captures no longer vanish
    exactly when the run dies.
    """

    steps_done: int
    failures: int
    restored_from: List[int] = field(default_factory=list)
    backoff_total_s: float = 0.0
    events: List[dict] = field(default_factory=list)
    telemetry_ring: List[dict] = field(default_factory=list)

    def event_counts(self) -> dict:
        counts: dict = {}
        for e in self.events:
            counts[e["event"]] = counts.get(e["event"], 0) + 1
        return counts


def run_with_recovery(step_fn: Callable[[int, Any], Any],
                      init_state: Any,
                      n_steps: int,
                      cfg: FaultConfig,
                      save_fn: Callable[[int, Any], None],
                      restore_fn: Callable[[], Optional[tuple]],
                      failure_injector: Optional[Callable[[int], None]] = None,
                      reshard_fn: Optional[Callable[[Any], Any]] = None,
                      chaos: Optional[FaultPlan] = None,
                      sleep_fn: Callable[[float], None] = time.sleep
                      ) -> RunResult:
    """Drive `step_fn(step, state) -> state` with checkpoint/restart recovery.

    `init_state` is the starting state, or a ZERO-ARG FACTORY returning a
    fresh one — pass a factory whenever `step_fn` donates its input
    buffers (jit ``donate_argnums``): a post-failure scratch restart must
    rebuild state, because the original buffers were consumed by step 0.
    `restore_fn() -> (step, state) | None` returns the latest *valid*
    checkpoint (wire it to `ckpt.restore_latest_valid` so a corrupt newest
    step costs one checkpoint interval, not the run).
    `reshard_fn(state) -> state`, when given, is applied to every restored
    state before stepping resumes — the elastic-restart hook: the launcher
    wires it to `runtime.elastic.reshard_tables` (itself
    `atomics.reshard.migrate` over the state tree) so live `AtomicTable`s
    land on the post-failure mesh with their owner-major layout re-derived
    instead of their RMW history replayed.

    `chaos` is the fault schedule (`runtime.chaos.FaultPlan`); None reads
    ``REPRO_CHAOS`` from the environment (null plan when unset).
    `failure_injector(step)` is the legacy hand-written step-site hook,
    kept as a thin shim — prefer a seeded plan.

    Every failure is classified: ``FatalFault`` / ``cfg.fatal_types``
    propagate untouched; anything else is retried behind
    :func:`backoff_delay` (logged, accumulated in
    ``RunResult.backoff_total_s``) until ``max_failures`` or the
    ``deadline_s`` wall-clock budget is exhausted.  A failure during
    restore itself is retryable the same way.
    """
    plan = chaos if chaos is not None else FaultPlan.from_env()
    donated = getattr(step_fn, "donate_argnums", None)
    if donated and not callable(init_state):
        # the PR-6 bug class, caught at startup: a donating step consumes
        # the captured buffers on step 0, so every scratch restart would
        # replay aliased garbage.  Deliberately NOT in the run-local
        # events trace (RunResult.event_counts is API) — it is a static
        # property of the call, not a recovery occurrence.
        telemetry.record("recovery.donation_hazard",
                         donate_argnums=tuple(donated))
        log.warning(
            "step_fn declares donate_argnums=%s but init_state is a "
            "captured value — pass a zero-arg factory so post-failure "
            "scratch restarts rebuild fresh buffers (lint rule A004)",
            tuple(donated))
    t_start = time.monotonic()
    failures = 0
    backoff_total = 0.0
    restored: List[int] = []
    events: List[dict] = []

    def _emit(event: str, **fields) -> None:
        # the run-local trace is ALWAYS kept (RunResult.events is API);
        # the global stream only sees it when telemetry is enabled
        events.append({"event": event, **fields})
        telemetry.record(event, **fields)

    def _flush_ring(reason: str) -> None:
        # the fault is about to propagate out of the recovery loop: land
        # the last-N ring events (REPRO_TELEMETRY=ring) on disk next to
        # the recovery.fault event before the process likely dies.
        # flush_ring is a no-op without a ring sink and never raises.
        n = telemetry.flush_ring()
        if n:
            log.error("flushed %d telemetry ring events (%s)", n, reason)

    def _absorb(e: BaseException, what: str) -> None:
        """Count a failure; re-raise fatal/over-budget, else back off."""
        nonlocal failures, backoff_total
        if isinstance(e, FatalFault) or isinstance(e, cfg.fatal_types):
            _emit("recovery.fault", site=what, error=type(e).__name__,
                  message=str(e), attempt=failures + 1, fatal=True)
            log.error("%s failed with fatal %s: %s — not retrying",
                      what, type(e).__name__, e)
            _flush_ring(f"fatal fault at {what}")
            raise e
        failures += 1
        _emit("recovery.fault", site=what, error=type(e).__name__,
              message=str(e), attempt=failures, fatal=False,
              budget=cfg.max_failures)
        log.warning("%s failed (%s: %s); recovery %d/%d", what,
                    type(e).__name__, e, failures, cfg.max_failures)
        if failures > cfg.max_failures:
            _flush_ring(f"failure budget exhausted at {what}")
            raise e
        elapsed = time.monotonic() - t_start
        if cfg.deadline_s is not None and elapsed > cfg.deadline_s:
            _flush_ring(f"recovery deadline exceeded at {what}")
            raise TimeoutError(
                f"recovery deadline {cfg.deadline_s:.3f}s exceeded "
                f"({elapsed:.3f}s elapsed, {failures} failures); "
                f"last error: {type(e).__name__}: {e}") from e
        delay = backoff_delay(cfg, failures)
        backoff_total += delay
        _emit("recovery.backoff", attempt=failures, backoff_s=delay)
        log.info("recovery backoff: sleeping %.4fs before attempt %d",
                 delay, failures + 1)
        sleep_fn(delay)

    def _adopt(s):
        if reshard_fn is None:
            return s
        plan.visit("reshard")
        return reshard_fn(s)

    def _initial():
        return init_state() if callable(init_state) else init_state

    def _restore_and_adopt(scratch_adopts: bool) -> Tuple[int, Any]:
        plan.visit("ckpt_restore")
        ck = restore_fn()
        if ck is None:
            # a POST-FAILURE restart from scratch still crosses the mesh
            # change, so the initial state's live tables need adopting;
            # scratch at startup does not — init_state was built under
            # the current mesh (tests/test_reshard.py pins both halves)
            _emit("recovery.restore", step=0, scratch=True,
                  resharded=scratch_adopts and reshard_fn is not None)
            return 0, _adopt(_initial()) if scratch_adopts else _initial()
        s, st = ck
        st = _adopt(st)
        restored.append(s)
        _emit("recovery.restore", step=s, scratch=False,
              resharded=reshard_fn is not None)
        return s, st

    def _recover(what: str, scratch_adopts: bool = True) -> Tuple[int, Any]:
        while True:
            try:
                return _restore_and_adopt(scratch_adopts)
            except Exception as e:  # noqa: BLE001 — restore is retryable too
                _absorb(e, what)

    step, state = _recover("initial restore", scratch_adopts=False)
    if restored:
        log.info("resumed from checkpoint at step %d", step)
    while step < n_steps:
        try:
            plan.visit("straggler_delay", step=step)
            if failure_injector is not None:   # legacy step-site shim
                failure_injector(step)
            plan.visit("step", step=step)
            state = step_fn(step, state)
            step += 1
            if step % cfg.checkpoint_every == 0 or step == n_steps:
                plan.visit("ckpt_save", step=step)
                save_fn(step, state)
        except Exception as e:  # noqa: BLE001 — chip loss shows up as generic
            _absorb(e, f"step {step}")
            step, state = _recover("restore")
    return RunResult(steps_done=step, failures=failures,
                     restored_from=restored,
                     backoff_total_s=backoff_total, events=events,
                     telemetry_ring=telemetry.ring_events())
