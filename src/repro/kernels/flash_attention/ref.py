"""Pure-jnp oracle for block-wise (flash) attention with GQA."""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def attention_ref(q: Array, k: Array, v: Array, *, causal: bool = True,
                  scale: float | None = None) -> Array:
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Skv, D); Hq % Hkv == 0."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    assert hq % hkv == 0
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    kq = jnp.repeat(k, group, axis=1)
    vq = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kq.astype(jnp.float32)) * scale
    if causal:
        qpos = jnp.arange(sq)[:, None] + (skv - sq)
        kpos = jnp.arange(skv)[None, :]
        s = jnp.where(kpos <= qpos, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vq.astype(jnp.float32)
                      ).astype(q.dtype)
