"""Pallas TPU kernel: block-wise causal/full attention with GQA.

Standard streaming-softmax (FlashAttention) schedule adapted to the TPU
memory hierarchy: one (block_q x d) query tile stays VMEM-resident while
(block_k x d) key/value tiles stream HBM->VMEM along the inner ("arbitrary")
grid axis; running max / normalizer / accumulator live in VMEM scratch.
Matmul dims are kept multiples of the 128-lane MXU width by ops.py padding.

GQA is handled in the BlockSpec index maps: query head h reads KV head
h // (Hq // Hkv) — no repeated KV materialization.

Causal blocks strictly above the diagonal are skipped via pl.when (the
block-level analogue of not issuing the read-for-ownership at all).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128

_NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale: float, causal: bool, block_q: int, block_k: int,
               num_k_blocks: int, kv_offset: int, kv_valid: int):
    iq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # skip kv tiles entirely past the valid region, and (causal) tiles
    # strictly above the diagonal of this query tile
    needed = jk * block_k < kv_valid
    if causal:
        needed &= jk * block_k <= iq * block_q + (block_q - 1) + kv_offset

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0].astype(jnp.float32)                  # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        kpos = jk * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = kpos < kv_valid
        if causal:
            qpos = iq * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0) + kv_offset
            mask &= kpos <= qpos
        s = jnp.where(mask, s, _NEG_INF)
        m_prev = m_ref[...]                               # (bq, 1)
        l_prev = l_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_cur)                            # (bq, bk)
        alpha = jnp.exp(m_prev - m_cur)                   # (bq, 1)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = m_cur
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(jk == num_k_blocks - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "scale", "block_q", "block_k", "kv_valid", "kv_offset",
    "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: float | None = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    kv_valid: int | None = None,
                    kv_offset: int | None = None,
                    interpret: bool = True) -> jax.Array:
    """q: (B, Hq, Sq, D), k/v: (B, Hkv, Skv, D); Sq % block_q == 0,
    Skv % block_k == 0 (ops.py pads).  ``kv_valid`` masks trailing padded kv
    rows; ``kv_offset`` is the causal diagonal shift (real_skv - real_sq).
    Returns (B, Hq, Sq, D)."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    assert sq % block_q == 0 and skv % block_k == 0, (sq, skv)
    nq, nk = sq // block_q, skv // block_k
    if kv_valid is None:
        kv_valid = skv
    if kv_offset is None:
        kv_offset = skv - sq  # causal alignment when kv longer (cached decode)

    qf = q.reshape(b * hq, sq, d)
    kf = k.reshape(b * hkv, skv, d)
    vf = v.reshape(b * hkv, skv, d)

    def kv_row(bh, i, j):
        del i
        batch = bh // hq
        head = bh % hq
        return batch * hkv + head // group, j, 0

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, num_k_blocks=nk, kv_offset=kv_offset,
        kv_valid=kv_valid)

    out = pl.pallas_call(
        kernel,
        grid=(b * hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, block_k, d), kv_row),
            pl.BlockSpec((1, block_k, d), kv_row),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running normalizer
            pltpu.VMEM((block_q, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, sq, d)
