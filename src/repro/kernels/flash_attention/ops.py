"""Public attention entry point: pads to block multiples, picks backend."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as _k
from repro.kernels.flash_attention import ref as _ref

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "scale", "block_q",
                                             "block_k", "use_kernel"))
def attention(q: Array, k: Array, v: Array, *, causal: bool = True,
              scale: float | None = None,
              block_q: int = _k.DEFAULT_BLOCK_Q,
              block_k: int = _k.DEFAULT_BLOCK_K,
              use_kernel: bool = True) -> Array:
    """Flash attention with padding to block multiples.

    Q and KV are back-padded to block multiples; the kernel masks padded kv
    rows via ``kv_valid`` and keeps the causal diagonal anchored to the real
    lengths via ``kv_offset``; padded query rows are sliced off on exit.
    """
    if not use_kernel:
        return _ref.attention_ref(q, k, v, causal=causal, scale=scale)
    b, hq, sq, d = q.shape
    skv = k.shape[2]
    pad_q = (-sq) % block_q
    pad_k = (-skv) % block_k
    kv_offset = skv - sq
    if pad_q or pad_k:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    out = _k.flash_attention(q, k, v, causal=causal, scale=scale,
                             block_q=block_q, block_k=block_k,
                             kv_valid=skv, kv_offset=kv_offset,
                             interpret=not _on_tpu())
    return out[:, :, :sq, :]
