"""Pallas TPU kernel: per-chunk SSD block (the quadratic hot spot).

The SSD chunked algorithm splits the sequence into chunks of length Q.  The
*within-chunk* work is attention-shaped (two (Q,N)/(Q,P) matmuls through a
decay-masked (Q,Q) score matrix — MXU work) and is what this kernel computes;
the *cross-chunk* state recurrence is a cheap log-depth associative scan done
in jnp by ops.py.

Per grid cell (one batch-head, one chunk) the kernel emits:
  y_intra (Q,P)  — contribution of in-chunk tokens,
  state  (N,P)   — this chunk's end-state contribution  Σ_s exp(lQ-l_s)·dt_s·B_s⊗x_s
All inputs are pre-scaled by ops.py: xdt = x*dt, adt = A*dt.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_CHUNK = 128


def _ssd_kernel(xdt_ref, adt_ref, b_ref, c_ref, y_ref, state_ref, *,
                chunk: int):
    xdt = xdt_ref[0].astype(jnp.float32)      # (Q, P)
    adt = adt_ref[0].astype(jnp.float32)      # (1, Q) row layout
    bmat = b_ref[0].astype(jnp.float32)       # (Q, N)
    cmat = c_ref[0].astype(jnp.float32)       # (Q, N)

    l = jnp.cumsum(adt.reshape(chunk), axis=0)            # (Q,) inclusive
    # decay mask M[t, s] = exp(l_t - l_s) for s <= t else 0
    lt = l.reshape(chunk, 1)
    ls = l.reshape(1, chunk)
    tpos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    spos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    mask = spos <= tpos
    m = jnp.where(mask, jnp.exp(lt - ls), 0.0)            # (Q, Q)

    scores = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q,Q)
    y_ref[0] = jax.lax.dot_general(scores * m, xdt,
                                   (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32
                                   ).astype(y_ref.dtype)

    # chunk state: B^T @ (xdt * exp(l_Q - l_s))
    decay_to_end = jnp.exp(l[chunk - 1] - l).reshape(chunk, 1)  # (Q,1)
    state_ref[0, 0] = jax.lax.dot_general(
        bmat, xdt * decay_to_end, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(state_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunk(xdt: jax.Array, adt: jax.Array, B: jax.Array, C: jax.Array, *,
              chunk: int = DEFAULT_CHUNK,
              interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Compute per-chunk intra outputs and chunk states.

    xdt (BH, S, P), adt (BH, S), B/C (BH, S, N); S % chunk == 0.
    Returns y_intra (BH, S, P), states (BH, NC, N, P).
    """
    bh, s, p = xdt.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, states = pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda i, c: (i, 0, c)),
            pl.BlockSpec((1, chunk, n), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, c: (i, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, 1, n, p), lambda i, c: (i, c, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, p), jnp.float32),
            jax.ShapeDtypeStruct((bh, nc, n, p), jnp.float32),
        ],
        interpret=interpret,
    )(xdt, adt.reshape(bh, 1, s), B, C)
    return y, states
