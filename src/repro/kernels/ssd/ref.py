"""Pure-jnp oracle for the Mamba-2 SSD (state-space duality) scan.

Sequential recurrence (the definition, arXiv:2405.21060 §3):
    h_t = exp(A * dt_t) * h_{t-1} + dt_t * (B_t ⊗ x_t)     h: (N, P)
    y_t = C_t^T h_t
Layouts: x (B, S, H, P), dt (B, S, H), A (H,), B/C (B, S, H, N).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def ssd_ref(x: Array, dt: Array, A: Array, B: Array, C: Array) -> Array:
    b, s, h, p = x.shape
    n = B.shape[-1]

    def per_bh(xbh, dtbh, a, bbh, cbh):
        # xbh (S,P), dtbh (S,), bbh/cbh (S,N), a scalar
        def step(hstate, inp):
            xt, dtt, bt, ct = inp
            hstate = jnp.exp(a * dtt) * hstate + dtt * jnp.outer(bt, xt)
            return hstate, ct @ hstate

        h0 = jnp.zeros((n, p), jnp.float32)
        _, y = jax.lax.scan(step, h0, (xbh.astype(jnp.float32),
                                       dtbh.astype(jnp.float32),
                                       bbh.astype(jnp.float32),
                                       cbh.astype(jnp.float32)))
        return y

    f = jax.vmap(jax.vmap(per_bh, in_axes=(1, 1, 0, 1, 1), out_axes=1),
                 in_axes=(0, 0, None, 0, 0), out_axes=0)
    return f(x, dt, A, B, C).astype(x.dtype)


def ssd_decode_ref(hstate: Array, x: Array, dt: Array, A: Array, B: Array,
                   C: Array) -> tuple[Array, Array]:
    """One decode step.  hstate (B,H,N,P), x (B,H,P), dt (B,H), B/C (B,H,N)."""
    decay = jnp.exp(A[None, :] * dt)[..., None, None]
    hstate = decay * hstate + dt[..., None, None] * jnp.einsum(
        "bhn,bhp->bhnp", B, x)
    y = jnp.einsum("bhn,bhnp->bhp", C, hstate)
    return hstate, y
