"""Public SSD entry points: chunked scan (train/prefill) + decode step.

Composition (ops layer):
  1. kernel: per-chunk intra output + chunk states       (quadratic, MXU)
  2. jnp:    log-depth associative scan over chunk states (cross-chunk)
  3. jnp:    y += C·exp(l)·H_prev  inter-chunk term       (two einsums)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd import kernel as _k
from repro.kernels.ssd import ref as _ref

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "return_final_state"))
def ssd_chunked_jnp(x: Array, dt: Array, A: Array, B: Array, C: Array, *,
                    chunk: int = _k.DEFAULT_CHUNK,
                    return_final_state: bool = False):
    """Differentiable chunked SSD in pure jnp (same math as the kernel).

    Keeps the head axis explicit throughout so TP sharding of heads over the
    `model` mesh axis propagates (no (B*H) merges that would force gathers).
    Used for the train path (pallas_call has no vjp) and on CPU.
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    nc = sp // chunk

    def r(t):  # (B, S, H, ...) -> (B, NC, Q, H, ...)
        return t.reshape(b, nc, chunk, *t.shape[2:])

    xdt = r((x * dt[..., None]).astype(jnp.float32))          # (b,c,q,h,p)
    adt = r((dt * A[None, None, :]).astype(jnp.float32))      # (b,c,q,h)
    Br, Cr = r(B.astype(jnp.float32)), r(C.astype(jnp.float32))

    l = jnp.cumsum(adt, axis=2)                               # (b,c,q,h)
    lt = l[:, :, :, None, :]                                  # (b,c,q,1,h)
    ls = l[:, :, None, :, :]                                  # (b,c,1,k,h)
    tpos = jnp.arange(chunk)
    mask = tpos[None, :] <= tpos[:, None]                     # (q,k) s<=t
    m = jnp.where(mask[None, None, :, :, None], jnp.exp(lt - ls), 0.0)
    scores = jnp.einsum("bcqhn,bckhn->bcqkh", Cr, Br)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", scores * m, xdt)

    decay_end = jnp.exp(l[:, :, -1:, :] - l)                  # (b,c,q,h)
    states = jnp.einsum("bckhn,bckhp->bchnp", Br, xdt * decay_end[..., None])
    ltot = l[:, :, -1, :]                                     # (b,c,h)
    decay = jnp.exp(ltot)[..., None, None]                    # (b,c,h,1,1)

    def comb(a2, c2):
        d1, s1 = a2
        d2, s2 = c2
        return d1 * d2, d2 * s1 + s2

    _, h_incl = jax.lax.associative_scan(comb, (decay, states), axis=1)
    h_prev = jnp.concatenate(
        [jnp.zeros_like(h_incl[:, :1]), h_incl[:, :-1]], axis=1)
    y_inter = jnp.einsum("bcqhn,bchnp->bcqhp", Cr * jnp.exp(l)[..., None],
                         h_prev)
    y = (y_intra + y_inter).reshape(b, sp, h, p)[:, :s].astype(x.dtype)
    if return_final_state:
        return y, h_incl[:, -1]                               # (b,h,n,p)
    return y


@functools.partial(jax.jit, static_argnames=("chunk", "use_kernel",
                                             "return_final_state"))
def ssd(x: Array, dt: Array, A: Array, B: Array, C: Array, *,
        chunk: int = _k.DEFAULT_CHUNK, use_kernel: bool | None = None,
        return_final_state: bool = False):
    """Chunked SSD.  x (B,S,H,P), dt (B,S,H), A (H,), B/C (B,S,H,N).
    With return_final_state, also returns h_final (B,H,N,P) for decode.
    use_kernel: None = auto (Pallas kernel on TPU, jnp elsewhere/for grad)."""
    if use_kernel is None:
        use_kernel = _on_tpu()
    if not use_kernel:
        return ssd_chunked_jnp(x, dt, A, B, C, chunk=chunk,
                               return_final_state=return_final_state)
    b, s, h, p = x.shape
    n = B.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))   # dt=0 => identity step
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    nc = sp // chunk

    # (B,S,H,*) -> (B*H, S, *)
    def flat(t):
        return t.transpose(0, 2, 1, *range(3, t.ndim)).reshape(
            b * h, sp, *t.shape[3:])

    xdt = flat(x * dt[..., None]).astype(jnp.float32)
    adt = (dt * A[None, None, :]).transpose(0, 2, 1).reshape(b * h, sp) \
        .astype(jnp.float32)
    Bf, Cf = flat(B).astype(jnp.float32), flat(C).astype(jnp.float32)

    y_intra, states = _k.ssd_chunk(xdt, adt, Bf, Cf, chunk=chunk,
                                   interpret=not _on_tpu())

    # cross-chunk recurrence: H_c = exp(Ltot_c) * H_{c-1} + S_c
    l = jnp.cumsum(adt.reshape(b * h, nc, chunk), axis=-1)     # (BH,NC,Q)
    ltot = l[..., -1]                                          # (BH,NC)
    decay = jnp.exp(ltot)[..., None, None]                     # (BH,NC,1,1)

    def comb(a, c):
        d1, s1 = a
        d2, s2 = c
        return d1 * d2, d2 * s1 + s2

    _, h_incl = jax.lax.associative_scan(comb, (decay, states), axis=1)
    h_prev = jnp.concatenate(
        [jnp.zeros_like(h_incl[:, :1]), h_incl[:, :-1]], axis=1)  # entering st.

    # inter-chunk: y_t += (C_t * exp(l_t)) @ H_prev(chunk(t))
    cdecay = Cf.reshape(b * h, nc, chunk, n) * jnp.exp(l)[..., None]
    y_inter = jnp.einsum("zcqn,zcnp->zcqp", cdecay, h_prev)
    y = y_intra.reshape(b * h, nc, chunk, p) + y_inter
    y = y.reshape(b * h, sp, p).reshape(b, h, sp, p).transpose(0, 2, 1, 3)
    y = y[:, :s].astype(x.dtype)
    if return_final_state:
        # NOTE: with padding, padded steps have dt=0 => exp(0)*h + 0 = h, so
        # the final inclusive state equals the state after the real prefix.
        h_final = h_incl[:, -1].reshape(b, h, n, p)
        return y, h_final
    return y


def _final_state_ref(x, dt, A, B, C):
    b, s, h, p = x.shape
    n = B.shape[-1]

    def per_bh(xbh, dtbh, a, bbh, cbh):
        def step(hs, inp):
            xt, dtt, bt, _ = inp
            return jnp.exp(a * dtt) * hs + dtt * jnp.outer(bt, xt), None
        h0 = jnp.zeros((n, p), jnp.float32)
        hf, _ = jax.lax.scan(step, h0, (xbh.astype(jnp.float32),
                                        dtbh.astype(jnp.float32),
                                        bbh.astype(jnp.float32),
                                        cbh.astype(jnp.float32)))
        return hf

    f = jax.vmap(jax.vmap(per_bh, in_axes=(1, 1, 0, 1, 1), out_axes=0),
                 in_axes=(0, 0, None, 0, 0), out_axes=0)
    return f(x, dt, A, B, C)


@jax.jit
def ssd_decode_step(hstate: Array, x: Array, dt: Array, A: Array, B: Array,
                    C: Array) -> tuple[Array, Array]:
    """One-token decode: carries hstate (B,H,N,P) — O(1) in context length.

    This is why the SSM archs run the `long_500k` cell (DESIGN.md §5)."""
    return _ref.ssd_decode_ref(hstate, x, dt, A, B, C)
