"""jit'd public wrappers for the combining-RMW kernel.

Handles padding (table to the tile multiple, batch to the block multiple),
dtype management, and backend selection: on TPU the Mosaic kernel runs
compiled; elsewhere ``interpret=True`` executes the same kernel body (the
validation mode used by this container's tests/benchmarks).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rmw import kernel as _k
from repro.kernels.rmw import ref as _ref

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: Array, multiple: int, fill) -> Array:
    n = x.shape[0]
    rem = (-n) % multiple
    if rem == 0:
        return x
    return jnp.concatenate([x, jnp.full((rem,), fill, x.dtype)])


@functools.partial(jax.jit, static_argnames=("op", "table_tile", "block",
                                             "use_kernel"))
def rmw_apply(table: Array, indices: Array, values: Array, op: str = "faa",
              *, table_tile: int = _k.DEFAULT_TABLE_TILE,
              block: int = _k.DEFAULT_BLOCK, use_kernel: bool = True) -> Array:
    """Combining-RMW a batch into a 1-D table.  Returns the updated table.

    Out-of-range indices are dropped (padding / masked tokens use index = n).
    """
    if not use_kernel:
        return _ref.rmw_table_ref(table, indices, values, op)
    n = table.shape[0]
    values = values.astype(table.dtype)
    tab_p = _pad_to(table, table_tile, 0)
    # padded table slots must not capture ops: point padding indices past even
    # the padded table
    idx_p = _pad_to(indices.astype(jnp.int32), block, jnp.int32(tab_p.shape[0]))
    val_p = _pad_to(values, block, 0)
    out = _k.rmw_table(tab_p, idx_p, val_p, op, table_tile=table_tile,
                       block=block, interpret=not _on_tpu())
    return out[:n]


def histogram(indices: Array, num_bins: int, **kw) -> Array:
    """Expert-load histogram — FAA with unit values (MoE routing's counter)."""
    return rmw_apply(jnp.zeros((num_bins,), jnp.float32), indices,
                     jnp.ones(indices.shape, jnp.float32), "faa", **kw)
