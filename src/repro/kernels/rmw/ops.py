"""jit'd public wrappers for the combining-RMW kernel.

Handles padding (table to the tile multiple, batch to the block multiple),
dtype management, and platform dispatch: on TPU the Mosaic kernel runs
compiled; elsewhere ``interpret`` (auto-selected, no longer hardcoded)
executes the same kernel body — the validation mode used by this container's
tests/benchmarks.

`rmw_apply` returns the updated table only; `rmw_apply_fetched` additionally
returns per-op serialized-order fetch results and CAS success flags — this is
the entry the RMW engine's ``pallas`` backend (`core.rmw_engine`) calls.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.rmw import RmwResult
from repro.kernels.rmw import kernel as _k
from repro.kernels.rmw import ref as _ref

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: Array, multiple: int, fill) -> Array:
    n = x.shape[0]
    rem = (-n) % multiple
    if rem == 0:
        return x
    return jnp.concatenate([x, jnp.full((rem,), fill, x.dtype)])


@functools.partial(jax.jit, static_argnames=("op", "table_tile", "block",
                                             "use_kernel"))
def rmw_apply(table: Array, indices: Array, values: Array, op: str = "faa",
              *, table_tile: int = _k.DEFAULT_TABLE_TILE,
              block: int = _k.DEFAULT_BLOCK, use_kernel: bool = True) -> Array:
    """Combining-RMW a batch into a 1-D table.  Returns the updated table.

    Out-of-range indices are dropped (padding / masked tokens use index = n).
    """
    if not use_kernel:
        return _ref.rmw_table_ref(table, indices, values, op)
    n = table.shape[0]
    values = values.astype(table.dtype)
    tab_p = _pad_to(table, table_tile, 0)
    # padded table slots must not capture ops: point padding indices past even
    # the padded table
    idx_p = _pad_to(indices.astype(jnp.int32), block, jnp.int32(tab_p.shape[0]))
    val_p = _pad_to(values, block, 0)
    out = _k.rmw_table(tab_p, idx_p, val_p, op, table_tile=table_tile,
                       block=block, interpret=not _on_tpu())
    return out[:n]


@functools.partial(jax.jit, static_argnames=("op", "table_tile", "block"))
def rmw_apply_fetched(table: Array, indices: Array, values: Array,
                      op: str = "faa", *, expected: Optional[Array] = None,
                      table_tile: int = _k.DEFAULT_TABLE_TILE,
                      block: int = _k.DEFAULT_BLOCK) -> RmwResult:
    """Combining RMW with per-op fetched values (and CAS success flags).

    Pads like :func:`rmw_apply`; fetched/success are sliced back to the
    caller's batch.  Out-of-range indices are dropped (fetched 0, success
    False).  CAS takes one uniform ``expected`` value.
    """
    n = table.shape[0]
    n_ops = indices.shape[0]
    values = values.astype(table.dtype)
    tab_p = _pad_to(table, table_tile, 0)
    # out-of-range ops must not observe table-padding slots: route them (and
    # the batch padding) past even the padded table so no one-hot row matches
    idx = indices.astype(jnp.int32)
    idx = jnp.where((idx < 0) | (idx >= n), jnp.int32(tab_p.shape[0]), idx)
    idx_p = _pad_to(idx, block, jnp.int32(tab_p.shape[0]))
    val_p = _pad_to(values, block, 0)
    out, fetched, success = _k.rmw_table_fetched(
        tab_p, idx_p, val_p, op, expected=expected, table_tile=table_tile,
        block=block, interpret=not _on_tpu())
    return RmwResult(out[:n], fetched[:n_ops], success[:n_ops])


def histogram(indices: Array, num_bins: int, **kw) -> Array:
    """Expert-load histogram — FAA with unit values (MoE routing's counter)."""
    return rmw_apply(jnp.zeros((num_bins,), jnp.float32), indices,
                     jnp.ones(indices.shape, jnp.float32), "faa", **kw)


@functools.partial(jax.jit, static_argnames=("m", "table_tile", "block"))
def slot_occupancy(indices: Array, m: int, *,
                   table_tile: int = _k.DEFAULT_TABLE_TILE,
                   block: int = _k.DEFAULT_BLOCK) -> Array:
    """(m,) int32 exact per-slot occupancy via the counters kernel output ref.

    Integer-exact companion of :func:`histogram` (whose fp32 FAA path would
    lose counts past 2^24): the contention observatory's occupancy source
    when the ``pallas`` backend executed the batch.  Same padding/drop
    contract as :func:`rmw_apply`.
    """
    tile = min(table_tile, max(128, ((m + 127) // 128) * 128))
    m_p = ((m + tile - 1) // tile) * tile
    idx = indices.astype(jnp.int32)
    idx = jnp.where((idx < 0) | (idx >= m), jnp.int32(m_p), idx)
    idx_p = _pad_to(idx, block, jnp.int32(m_p))
    out = _k.slot_counts(idx_p, m_p, table_tile=tile, block=block,
                         interpret=not _on_tpu())
    return out[:m]
