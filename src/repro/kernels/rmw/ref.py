"""Pure-jnp oracle for the combining-RMW kernel.

Semantics contract (shared with kernels/rmw/kernel.py):
given a 1-D ``table`` (padded to the kernel's table-tile multiple), ``indices``
and ``values`` batches, return the table after applying the whole batch with
the selected combiner:

  faa — table[i] += sum of colliding values            (order-free)
  min/max — combine with minimum / maximum             (order-free)
  swp — last collider (by batch position) wins         (order-dependent)

Out-of-range indices (>= table size) are dropped — the kernel uses this to
implement masking/padding, and MoE dispatch uses it for token dropping.

`rmw_table_fetched_ref` is the serialized oracle for the kernel's
fetched-value/CAS outputs (kernel.py `rmw_table_fetched`): op-at-a-time in
batch order, dropped ops observing fetched = 0 / success = False.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def rmw_table_ref(table: Array, indices: Array, values: Array, op: str) -> Array:
    n = table.shape[0]
    valid = indices < n
    safe_idx = jnp.where(valid, indices, 0)
    if op == "faa":
        contrib = jnp.where(valid, values, jnp.zeros_like(values))
        return table.at[safe_idx].add(contrib)
    if op == "min":
        big = jnp.asarray(jnp.finfo(values.dtype).max
                          if jnp.issubdtype(values.dtype, jnp.floating)
                          else jnp.iinfo(values.dtype).max, values.dtype)
        return table.at[safe_idx].min(jnp.where(valid, values, big))
    if op == "max":
        small = jnp.asarray(jnp.finfo(values.dtype).min
                            if jnp.issubdtype(values.dtype, jnp.floating)
                            else jnp.iinfo(values.dtype).min, values.dtype)
        return table.at[safe_idx].max(jnp.where(valid, values, small))
    if op == "swp":
        # last-wins: iterate in order via scatter of the *last* collider only
        pos = jnp.arange(indices.shape[0], dtype=jnp.int32)
        last_pos = jnp.full((n,), -1, jnp.int32).at[safe_idx].max(
            jnp.where(valid, pos, -1))
        written = last_pos >= 0
        gathered = values[jnp.clip(last_pos, 0, None)]
        return jnp.where(written, gathered, table)
    raise ValueError(f"unknown op {op!r}")


@partial(jax.jit, static_argnames=("op",))
def rmw_table_fetched_ref(table: Array, indices: Array, values: Array,
                          op: str, expected: Optional[Array] = None
                          ) -> Tuple[Array, Array, Array]:
    """Order-faithful (table, fetched, success) with drop semantics.

    Matches `core.rmw.rmw_serialized` for in-range ops; indices outside
    [0, table size) are skipped entirely (fetched 0, success False).
    """
    n = table.shape[0]
    e = jnp.asarray(0 if expected is None else expected, table.dtype)

    def step(tab, inp):
        i, v = inp
        valid = (i >= 0) & (i < n)
        safe = jnp.clip(i, 0, n - 1)
        old = tab[safe]
        if op == "faa":
            new, ok = old + v, jnp.array(True)
        elif op == "swp":
            new, ok = v, jnp.array(True)
        elif op == "min":
            new, ok = jnp.minimum(old, v), jnp.array(True)
        elif op == "max":
            new, ok = jnp.maximum(old, v), jnp.array(True)
        elif op == "cas":
            ok = old == e
            new = jnp.where(ok, v, old)
        else:
            raise ValueError(f"unknown op {op!r}")
        tab = tab.at[safe].set(jnp.where(valid, new, old))
        return tab, (jnp.where(valid, old, jnp.zeros_like(old)), valid & ok)

    table, (fetched, success) = jax.lax.scan(
        step, table, (indices.astype(jnp.int32), values.astype(table.dtype)))
    return table, fetched, success


def histogram_ref(indices: Array, num_bins: int) -> Array:
    """FAA special case: the expert-load histogram MoE routing needs."""
    return rmw_table_ref(jnp.zeros((num_bins,), jnp.float32), indices,
                         jnp.ones(indices.shape, jnp.float32), "faa")
