"""Pallas TPU kernel: combining RMW (the paper's atomics, MXU-native).

TPU adaptation (DESIGN.md §2): a batch of atomic RMWs against a table is
re-expressed as a **one-hot matmul reduction** so that the combine runs on the
MXU/VPU instead of serializing, realizing the paper's proposed relaxed
atomics (§6.2.3).  For a table tile T (kept in VMEM across the inner grid
axis) and an index/value block B:

    one_hot[b, t] = (indices[b] == tile_start + t)
    faa:  tile += values @ one_hot              (1xB @ BxT matmul -> MXU)
    min/max: tile = combine(tile, masked col-reduce of values)
    swp:  tile = value of the *latest* collider per slot (last-wins)

Grid = (table_tiles, index_blocks); the index-block axis is the reduction
("arbitrary") axis, the table-tile axis is parallel.  The index/value blocks
stream HBM->VMEM once per table tile; the table tile stays resident in VMEM —
this is the paper's Eq. (10) amortization with the VMEM tile in the
cache-line role.

Alignment: TABLE_TILE is a multiple of 128 (lane width) — the benchmark
`benchmarks/unaligned.py` measures the penalty of violating this, the TPU
analogue of the paper's §5.7 line-spanning atomics.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TABLE_TILE = 512      # table slots per tile (multiple of 128)
DEFAULT_BLOCK = 1024          # index/value elements per block


def _rmw_kernel(idx_ref, val_ref, table_ref, out_ref, *, op: str,
                table_tile: int, block: int):
    tile_id = pl.program_id(0)
    blk_id = pl.program_id(1)

    # Initialize the output tile from the input table on the first block.
    @pl.when(blk_id == 0)
    def _init():
        out_ref[...] = table_ref[...]

    tile_start = tile_id * table_tile
    idx = idx_ref[...].astype(jnp.int32)            # (1, block)
    val = val_ref[...]                              # (1, block)
    slots = jax.lax.broadcasted_iota(jnp.int32, (block, table_tile), 1)
    local = idx.reshape(block, 1) - tile_start
    one_hot = (local == slots)                      # (block, table_tile)

    acc = out_ref[...]                              # (1, table_tile)
    if op == "faa":
        # MXU path: (1, block) @ (block, tile) — the combining reduction.
        upd = jnp.dot(val, one_hot.astype(val.dtype),
                      preferred_element_type=jnp.float32)
        out_ref[...] = acc + upd.astype(acc.dtype)
    elif op in ("min", "max"):
        neutral = (jnp.asarray(jnp.finfo(val.dtype).max, val.dtype) if op == "min"
                   else jnp.asarray(jnp.finfo(val.dtype).min, val.dtype))
        masked = jnp.where(one_hot, val.reshape(block, 1), neutral)
        red = (jnp.min(masked, axis=0) if op == "min"
               else jnp.max(masked, axis=0)).reshape(1, table_tile)
        comb = jnp.minimum if op == "min" else jnp.maximum
        out_ref[...] = comb(acc, red)
    elif op == "swp":
        # last-wins: the collider with the highest global batch position.
        pos = jax.lax.broadcasted_iota(jnp.int32, (block, table_tile), 0) \
            + blk_id * block
        masked_pos = jnp.where(one_hot, pos, -1)
        best = jnp.max(masked_pos, axis=0).reshape(1, table_tile)  # (1, tile)
        # gather the winning value via a second one-hot contraction
        sel = (masked_pos == best) & one_hot & (best >= 0)
        winner = jnp.dot(val, sel.astype(val.dtype),
                         preferred_element_type=jnp.float32)
        out_ref[...] = jnp.where(best >= 0, winner.astype(acc.dtype), acc)
    else:
        raise ValueError(f"unknown op {op!r}")


@functools.partial(jax.jit,
                   static_argnames=("op", "table_tile", "block", "interpret"))
def rmw_table(table: jax.Array, indices: jax.Array, values: jax.Array,
              op: str = "faa", *, table_tile: int = DEFAULT_TABLE_TILE,
              block: int = DEFAULT_BLOCK, interpret: bool = True) -> jax.Array:
    """Apply a combining-RMW batch to a 1-D fp32 table.

    Requires table size % table_tile == 0 and batch % block == 0 (ops.py pads).
    Out-of-range indices never match a slot and are dropped (mask tokens).
    """
    n = table.shape[0]
    nb = indices.shape[0]
    assert n % table_tile == 0, (n, table_tile)
    assert nb % block == 0, (nb, block)
    grid = (n // table_tile, nb // block)

    kernel = functools.partial(_rmw_kernel, op=op, table_tile=table_tile,
                               block=block)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block), lambda t, b: (0, b)),       # indices
            pl.BlockSpec((1, block), lambda t, b: (0, b)),       # values
            pl.BlockSpec((1, table_tile), lambda t, b: (0, t)),  # table in
        ],
        out_specs=pl.BlockSpec((1, table_tile), lambda t, b: (0, t)),
        out_shape=jax.ShapeDtypeStruct((1, n), table.dtype),
        interpret=interpret,
    )(indices.reshape(1, nb), values.reshape(1, nb), table.reshape(1, n))
    return out.reshape(n)
