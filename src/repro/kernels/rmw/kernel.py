"""Pallas TPU kernel: combining RMW (the paper's atomics, MXU-native).

TPU adaptation (DESIGN.md §2): a batch of atomic RMWs against a table is
re-expressed as a **one-hot matmul reduction** so that the combine runs on the
MXU/VPU instead of serializing, realizing the paper's proposed relaxed
atomics (§6.2.3).  For a table tile T (kept in VMEM across the inner grid
axis) and an index/value block B:

    one_hot[b, t] = (indices[b] == tile_start + t)
    faa:  tile += values @ one_hot              (1xB @ BxT matmul -> MXU)
    min/max: tile = combine(tile, masked col-reduce of values)
    swp:  tile = value of the *latest* collider per slot (last-wins)
    cas:  tile = first value != expected per live slot (uniform expected)

Grid = (table_tiles, index_blocks); the index-block axis is the reduction
("arbitrary") axis, the table-tile axis is parallel.  The index/value blocks
stream HBM->VMEM once per table tile; the table tile stays resident in VMEM —
this is the paper's Eq. (10) amortization with the VMEM tile in the
cache-line role.

**Fetched values** (`rmw_table_fetched`, used by the engine's `pallas`
backend): each op's serialized-order fetch result is the carried tile value
combined with the *exclusive per-slot prefix* of earlier colliders in its
block, computed as a strict-lower-triangular-masked one-hot contraction
``(L ∘ (oh @ oh^T)) @ v`` — another MXU matmul, no sort.  The tile axis
lives OUTSIDE the grid (one ``pallas_call`` per table tile, 1-D grid over
index blocks): each op's index lands in exactly one tile, so the disjoint
per-tile fetched/success contributions sum outside the kernel, and no
output block is ever revisited non-consecutively (the only revisit is the
tile accumulator along the single grid axis — the reduction pattern
compiled Pallas TPU guarantees).

``interpret`` now defaults to auto (`None` -> compiled on TPU, interpreter
elsewhere) instead of the old hardcoded ``True``.

Alignment: TABLE_TILE is a multiple of 128 (lane width) — the benchmark
`benchmarks/unaligned.py` measures the penalty of violating this, the TPU
analogue of the paper's §5.7 line-spanning atomics.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TABLE_TILE = 512      # table slots per tile (multiple of 128)
DEFAULT_BLOCK = 1024          # index/value elements per block


def _resolve_interpret(interpret: Optional[bool]) -> bool:
    """Auto-select the Pallas interpreter off-TPU (old default: always True)."""
    return jax.default_backend() != "tpu" if interpret is None else interpret


def _rmw_kernel(idx_ref, val_ref, table_ref, out_ref, *, op: str,
                table_tile: int, block: int):
    tile_id = pl.program_id(0)
    blk_id = pl.program_id(1)

    # Initialize the output tile from the input table on the first block.
    @pl.when(blk_id == 0)
    def _init():
        out_ref[...] = table_ref[...]

    tile_start = tile_id * table_tile
    idx = idx_ref[...].astype(jnp.int32)            # (1, block)
    val = val_ref[...]                              # (1, block)
    slots = jax.lax.broadcasted_iota(jnp.int32, (block, table_tile), 1)
    local = idx.reshape(block, 1) - tile_start
    one_hot = (local == slots)                      # (block, table_tile)

    acc = out_ref[...]                              # (1, table_tile)
    if op == "faa":
        # MXU path: (1, block) @ (block, tile) — the combining reduction.
        upd = jnp.dot(val, one_hot.astype(val.dtype),
                      preferred_element_type=jnp.float32)
        out_ref[...] = acc + upd.astype(acc.dtype)
    elif op in ("min", "max"):
        neutral = (jnp.asarray(jnp.finfo(val.dtype).max, val.dtype) if op == "min"
                   else jnp.asarray(jnp.finfo(val.dtype).min, val.dtype))
        masked = jnp.where(one_hot, val.reshape(block, 1), neutral)
        red = (jnp.min(masked, axis=0) if op == "min"
               else jnp.max(masked, axis=0)).reshape(1, table_tile)
        comb = jnp.minimum if op == "min" else jnp.maximum
        out_ref[...] = comb(acc, red)
    elif op == "swp":
        # last-wins: the collider with the highest global batch position.
        pos = jax.lax.broadcasted_iota(jnp.int32, (block, table_tile), 0) \
            + blk_id * block
        masked_pos = jnp.where(one_hot, pos, -1)
        best = jnp.max(masked_pos, axis=0).reshape(1, table_tile)  # (1, tile)
        # gather the winning value via a second one-hot contraction
        sel = (masked_pos == best) & one_hot & (best >= 0)
        winner = jnp.dot(val, sel.astype(val.dtype),
                         preferred_element_type=jnp.float32)
        out_ref[...] = jnp.where(best >= 0, winner.astype(acc.dtype), acc)
    else:
        raise ValueError(f"unknown op {op!r}")


@functools.partial(jax.jit,
                   static_argnames=("op", "table_tile", "block", "interpret"))
def rmw_table(table: jax.Array, indices: jax.Array, values: jax.Array,
              op: str = "faa", *, table_tile: int = DEFAULT_TABLE_TILE,
              block: int = DEFAULT_BLOCK,
              interpret: Optional[bool] = None) -> jax.Array:
    """Apply a combining-RMW batch to a 1-D fp32 table.

    Requires table size % table_tile == 0 and batch % block == 0 (ops.py pads).
    Out-of-range indices never match a slot and are dropped (mask tokens).
    ``interpret=None`` auto-selects from the platform.
    """
    n = table.shape[0]
    nb = indices.shape[0]
    assert n % table_tile == 0, (n, table_tile)
    assert nb % block == 0, (nb, block)
    grid = (n // table_tile, nb // block)

    kernel = functools.partial(_rmw_kernel, op=op, table_tile=table_tile,
                               block=block)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block), lambda t, b: (0, b)),       # indices
            pl.BlockSpec((1, block), lambda t, b: (0, b)),       # values
            pl.BlockSpec((1, table_tile), lambda t, b: (0, t)),  # table in
        ],
        out_specs=pl.BlockSpec((1, table_tile), lambda t, b: (0, t)),
        out_shape=jax.ShapeDtypeStruct((1, n), table.dtype),
        interpret=_resolve_interpret(interpret),
    )(indices.reshape(1, nb), values.reshape(1, nb), table.reshape(1, n))
    return out.reshape(n)


# ---------------------------------------------------------------------------
# Contention counters kernel (PR 10 observatory)
# ---------------------------------------------------------------------------

def _slot_count_kernel(idx_ref, count_ref, *, table_tile: int, block: int):
    """Per-slot occupancy counts via the same one-hot contraction as the RMW.

    The counters output ref accumulates column sums of the one-hot matrix
    across index blocks — the combine pass's collision counts emitted as a
    first-class output instead of being discarded after the reduction.
    """
    tile_id = pl.program_id(0)
    blk_id = pl.program_id(1)

    @pl.when(blk_id == 0)
    def _init():
        count_ref[...] = jnp.zeros_like(count_ref)

    tile_start = tile_id * table_tile
    idx = idx_ref[...].astype(jnp.int32)            # (1, block)
    slots = jax.lax.broadcasted_iota(jnp.int32, (block, table_tile), 1)
    local = idx.reshape(block, 1) - tile_start
    one_hot = (local == slots)                      # (block, table_tile)
    upd = jnp.sum(one_hot.astype(jnp.int32), axis=0).reshape(1, table_tile)
    count_ref[...] = count_ref[...] + upd


@functools.partial(jax.jit,
                   static_argnames=("m", "table_tile", "block", "interpret"))
def slot_counts(indices: jax.Array, m: int, *,
                table_tile: int = DEFAULT_TABLE_TILE,
                block: int = DEFAULT_BLOCK,
                interpret: Optional[bool] = None) -> jax.Array:
    """(m,) int32 occupancy counts for a slot-index batch.

    Same padding contract as `rmw_table`: m % table_tile == 0 and
    batch % block == 0 (ops.py pads); out-of-range indices match no slot.
    """
    nb = indices.shape[0]
    assert m % table_tile == 0, (m, table_tile)
    assert nb % block == 0, (nb, block)
    grid = (m // table_tile, nb // block)

    kernel = functools.partial(_slot_count_kernel, table_tile=table_tile,
                               block=block)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, block), lambda t, b: (0, b))],
        out_specs=pl.BlockSpec((1, table_tile), lambda t, b: (0, t)),
        out_shape=jax.ShapeDtypeStruct((1, m), jnp.int32),
        interpret=_resolve_interpret(interpret),
    )(indices.reshape(1, nb))
    return out.reshape(m)


# ---------------------------------------------------------------------------
# Fetched-value kernel (serialized-order fetch results + uniform-expected CAS)
# ---------------------------------------------------------------------------

def _rmw_fetched_kernel(idx_ref, val_ref, table_ref, exp_ref, out_ref,
                        fetched_ref, success_ref, *, op: str,
                        table_tile: int, block: int, tile_start: int):
    # 1-D grid over index blocks; the table tile this call owns is fixed
    # (``tile_start`` is static — the tile axis lives OUTSIDE the grid, one
    # pallas_call per tile).  This keeps every output block's revisit pattern
    # within what compiled Pallas TPU guarantees: the table-tile out block is
    # constant across the (only) grid axis — the standard minor-axis
    # reduction — and each fetched/success block is written exactly once.
    blk_id = pl.program_id(0)

    @pl.when(blk_id == 0)
    def _init_tile():
        out_ref[...] = table_ref[...]

    idx = idx_ref[...].astype(jnp.int32)            # (1, block)
    val = val_ref[...]                              # (1, block)
    slots = jax.lax.broadcasted_iota(jnp.int32, (block, table_tile), 1)
    local = idx.reshape(block, 1) - tile_start
    one_hot = (local == slots)                      # (block, table_tile)
    in_tile = (idx >= tile_start) & (idx < tile_start + table_tile)  # (1, B)

    acc = out_ref[...]                              # tile BEFORE this block
    ohf = one_hot.astype(val.dtype)
    # base[i] = acc[idx[i]] — gather as a one-hot contraction (MXU)
    base = jnp.dot(acc, ohf.T, preferred_element_type=jnp.float32
                   ).astype(val.dtype)              # (1, block)

    pos_i = jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
    pos_j = jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
    # strict-lower-triangular same-slot mask: j precedes i, same table slot.
    # (equality on idx restricted to this tile via the row mask below)
    same = (idx.reshape(block, 1) == idx.reshape(1, block)) & (pos_i > pos_j)

    ones = jnp.ones((1, block), val.dtype)
    if op == "faa":
        # exclusive per-slot prefix: the lower-triangular-masked one-hot matmul
        prefix = jnp.dot(val, same.astype(val.dtype).T,
                         preferred_element_type=jnp.float32).astype(val.dtype)
        fetched = base + prefix
        ok = ones
        upd = jnp.dot(val, ohf, preferred_element_type=jnp.float32)
        out_ref[...] = acc + upd.astype(acc.dtype)
    elif op in ("min", "max"):
        neutral = (jnp.asarray(jnp.finfo(val.dtype).max, val.dtype)
                   if op == "min"
                   else jnp.asarray(jnp.finfo(val.dtype).min, val.dtype))
        comb = jnp.minimum if op == "min" else jnp.maximum
        masked = jnp.where(same, val.reshape(1, block), neutral)   # (B, B)
        prefix = (jnp.min(masked, axis=1) if op == "min"
                  else jnp.max(masked, axis=1)).reshape(1, block)
        fetched = comb(base, prefix)
        ok = ones
        colmask = jnp.where(one_hot, val.reshape(block, 1), neutral)
        red = (jnp.min(colmask, axis=0) if op == "min"
               else jnp.max(colmask, axis=0)).reshape(1, table_tile)
        out_ref[...] = comb(acc, red)
    elif op == "swp":
        mpos = jnp.where(same, pos_j, -1).max(axis=1).reshape(1, block)
        sel = same & (pos_j == mpos.reshape(block, 1))
        prev = jnp.dot(val, sel.astype(val.dtype).T,
                       preferred_element_type=jnp.float32).astype(val.dtype)
        fetched = jnp.where(mpos >= 0, prev, base)
        ok = ones
        gpos = jax.lax.broadcasted_iota(jnp.int32, (block, table_tile), 0) \
            + blk_id * block
        masked_pos = jnp.where(one_hot, gpos, -1)
        best = jnp.max(masked_pos, axis=0).reshape(1, table_tile)
        wsel = (masked_pos == best) & one_hot & (best >= 0)
        winner = jnp.dot(val, wsel.astype(val.dtype),
                         preferred_element_type=jnp.float32)
        out_ref[...] = jnp.where(best >= 0, winner.astype(acc.dtype), acc)
    else:  # cas (uniform expected): first value != expected wins a live slot
        e = exp_ref[0, 0].astype(val.dtype)
        ne = val != e                                              # (1, B)
        big = jnp.int32(block)
        fpos = jnp.where(same & ne.reshape(1, block), pos_j, big
                         ).min(axis=1).reshape(1, block)
        xsel = same & ne.reshape(1, block) \
            & (pos_j == fpos.reshape(block, 1))
        xval = jnp.dot(val, xsel.astype(val.dtype).T,
                       preferred_element_type=jnp.float32).astype(val.dtype)
        x_excl = jnp.where(fpos < big, xval, e)
        v_before = jnp.where(base == e, x_excl, base)
        fetched = v_before
        ok = (v_before == e).astype(val.dtype)
        # tile update: per slot, the first op with value != expected
        opos = jax.lax.broadcasted_iota(jnp.int32, (block, table_tile), 0)
        fslot = jnp.where(one_hot & ne.reshape(block, 1), opos, big
                          ).min(axis=0).reshape(1, table_tile)
        fsel = one_hot & (opos == fslot.reshape(1, table_tile)) \
            & ne.reshape(block, 1)
        first_val = jnp.dot(val, fsel.astype(val.dtype),
                            preferred_element_type=jnp.float32
                            ).astype(acc.dtype)
        out_ref[...] = jnp.where((acc == e) & (fslot < big), first_val, acc)

    # each op's index lives in exactly one tile: this call's contribution is
    # zero elsewhere, and the caller sums the per-tile outputs.
    itf = in_tile.astype(val.dtype)
    fetched_ref[...] = (fetched * itf).astype(fetched_ref.dtype)
    success_ref[...] = (ok * itf).astype(success_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("op", "table_tile", "block", "interpret"))
def rmw_table_fetched(table: jax.Array, indices: jax.Array,
                      values: jax.Array, op: str = "faa", *,
                      expected: Optional[jax.Array] = None,
                      table_tile: int = DEFAULT_TABLE_TILE,
                      block: int = DEFAULT_BLOCK,
                      interpret: Optional[bool] = None):
    """Combining RMW returning ``(table, fetched, success)``.

    Semantics match `core.rmw.rmw_serialized` per-op fetch results; CAS takes
    one uniform ``expected`` value (the combinable form).  Out-of-range
    indices are dropped: fetched = 0, success = False for those ops.
    Alignment contract as :func:`rmw_table` (ops.py pads).

    One ``pallas_call`` per table tile, each with a 1-D grid over index
    blocks (the tile stays VMEM-resident for the whole sweep); per-tile
    fetched/success contributions are disjoint and summed outside the
    kernel.  This costs one launch per tile but never revisits an output
    block non-consecutively — the pattern compiled Pallas TPU supports.
    """
    n = table.shape[0]
    nb = indices.shape[0]
    assert n % table_tile == 0, (n, table_tile)
    assert nb % block == 0, (nb, block)
    if op == "cas" and expected is None:
        raise ValueError("cas requires `expected`")
    interp = _resolve_interpret(interpret)
    exp = jnp.full((1, 1), 0 if expected is None else expected, table.dtype)
    idx2 = indices.reshape(1, nb)
    val2 = values.reshape(1, nb)
    tab2 = table.reshape(1, n)

    out_tiles = []
    fetched = jnp.zeros((1, nb), table.dtype)
    success = jnp.zeros((1, nb), table.dtype)
    for ti in range(n // table_tile):
        kernel = functools.partial(_rmw_fetched_kernel, op=op,
                                   table_tile=table_tile, block=block,
                                   tile_start=ti * table_tile)
        out_t, f_t, s_t = pl.pallas_call(
            kernel,
            grid=(nb // block,),
            in_specs=[
                pl.BlockSpec((1, block), lambda b: (0, b)),       # indices
                pl.BlockSpec((1, block), lambda b: (0, b)),       # values
                pl.BlockSpec((1, table_tile), lambda b: (0, 0)),  # table tile
                pl.BlockSpec((1, 1), lambda b: (0, 0)),           # expected
            ],
            out_specs=[
                pl.BlockSpec((1, table_tile), lambda b: (0, 0)),  # tile out
                pl.BlockSpec((1, block), lambda b: (0, b)),       # fetched
                pl.BlockSpec((1, block), lambda b: (0, b)),       # success
            ],
            out_shape=[
                jax.ShapeDtypeStruct((1, table_tile), table.dtype),
                jax.ShapeDtypeStruct((1, nb), table.dtype),
                jax.ShapeDtypeStruct((1, nb), table.dtype),
            ],
            interpret=interp,
        )(idx2, val2, tab2[:, ti * table_tile:(ti + 1) * table_tile], exp)
        out_tiles.append(out_t)
        fetched = fetched + f_t
        success = success + s_t
    out = jnp.concatenate(out_tiles, axis=1)
    return out.reshape(n), fetched.reshape(nb), success.reshape(nb) > 0.5
