"""Render a telemetry JSONL capture: ``python -m repro.telemetry.report``.

Three sections — event counts with numeric-field aggregates (a replayed
:class:`~repro.telemetry.core.Counters` sink), the cost-model drift table
(`telemetry.drift.summarize`), and the proposed `HardwareSpec` correction
(`fit_spec_update`) when any selector tier shows enough drift samples.
``--json`` emits the same content as one machine-readable object (the
format ``benchmarks/results/telemetry_drift.json`` is committed in).
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List

from repro.telemetry import drift as drift_lib
from repro.telemetry.core import Counters, read_jsonl


def build_report(events: List[Dict[str, Any]], *, spec=None,
                 fit: bool = True) -> Dict[str, Any]:
    """The report as data: ``{events: Counters.summary(), drift: [rows],
    spec_update: {field: {...}}}`` — the JSON the CLI prints/renders."""
    counters = Counters()
    for ev in events:
        counters.emit(ev)
    stats = drift_lib.aggregate(events)
    out: Dict[str, Any] = {"n_events": len(events),
                           "events": counters.summary(),
                           "drift": drift_lib.summarize(stats),
                           "analysis": _analysis_rows(events)}
    if fit:
        fitted = drift_lib.fit_spec_update(stats, spec)
        out["spec_update"] = fitted["fields"]
        out["spec_update_skipped"] = fitted["skipped"]
    return out


def _analysis_rows(events: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """``analysis.finding`` events -> lint-result rows (repro.analysis)."""
    rows = []
    for ev in events:
        if ev.get("event") != "analysis.finding":
            continue
        rows.append({k: ev.get(k) for k in
                     ("rule", "severity", "file", "line", "entry",
                      "suppressed", "message")})
    return rows


def _fmt_s(v: float) -> str:
    if v != v:                       # NaN
        return "-"
    for unit, scale in (("s", 1.0), ("ms", 1e-3), ("us", 1e-6)):
        if abs(v) >= scale:
            return f"{v / scale:.3g}{unit}"
    return f"{v / 1e-9:.3g}ns"


def render_text(report: Dict[str, Any]) -> str:
    lines = [f"telemetry report — {report['n_events']} events", ""]
    lines.append(f"{'event':<28}{'count':>8}  numeric fields (mean)")
    for name in sorted(report["events"]):
        info = report["events"][name]
        means = "  ".join(
            f"{k}={_fmt_s(v['mean']) if k.endswith('_s') else round(v['mean'], 3)}"
            for k, v in sorted(info["fields"].items()))
        lines.append(f"{name:<28}{info['count']:>8}  {means}")
    rows = report["drift"]
    lines += ["", "cost-model drift (measured / predicted, geometric mean)"]
    if rows:
        lines.append(f"{'tier':<11}{'choice':<14}{'op':<6}{'size':<7}"
                     f"{'n':>5}{'ratio':>10}{'min':>10}{'max':>10}"
                     f"{'pred':>9}{'meas':>9}")
        for r in rows:
            lines.append(
                f"{r['tier']:<11}{r['choice']:<14}{r['op']:<6}"
                f"{r['size_bucket']:<7}{r['n']:>5}{r['ratio']:>10.3g}"
                f"{r['min_ratio']:>10.3g}{r['max_ratio']:>10.3g}"
                f"{_fmt_s(r['mean_predicted_s']):>9}"
                f"{_fmt_s(r['mean_measured_s']):>9}")
    else:
        lines.append("  (no (predicted_s, measured_s) pairs in the capture)")
    lint = report.get("analysis") or []
    lines += ["", "static analysis (analysis.finding events)"]
    if lint:
        for r in lint:
            where = (f"{r['file']}:{r['line']}" if r.get("file")
                     else "<unknown>")
            sup = " [suppressed]" if r.get("suppressed") else ""
            entry = f" [{r['entry']}]" if r.get("entry") else ""
            sev = (r.get("severity") or "?").upper()
            lines.append(f"  {where}: {sev} {r.get('rule')}{sup}{entry}")
    else:
        lines.append("  (no analysis.finding events in the capture)")
    upd = report.get("spec_update") or {}
    lines += ["", "proposed HardwareSpec correction (fit_spec_update)"]
    if upd:
        for name, f in sorted(upd.items()):
            lines.append(f"  {name}: {f['current']:.3g} -> "
                         f"{f['proposed']:.3g}  (drift x{f['ratio']:.2f}, "
                         f"n={f['n']})")
    else:
        lines.append("  (not enough drift samples)")
    skipped = report.get("spec_update_skipped") or {}
    if skipped:
        # no silent caps: fields with drift evidence below their sample
        # floor are listed, not dropped
        for name, s in sorted(skipped.items()):
            why = s.get("reason") or (f"n={s['n']} < "
                                      f"min_samples={s['min_samples']}")
            lines.append(f"  {name}: skipped ({why})")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry.report",
        description="Render a repro.telemetry JSONL capture.")
    ap.add_argument("capture", help="JSONL file written by JsonlWriter "
                                    "(e.g. REPRO_TELEMETRY=out.jsonl)")
    ap.add_argument("--json", action="store_true",
                    help="emit the report as one JSON object")
    ap.add_argument("--no-fit", action="store_true",
                    help="skip the HardwareSpec correction section")
    args = ap.parse_args(argv)
    events = read_jsonl(args.capture)
    report = build_report(events, fit=not args.no_fit)
    if args.json:
        json.dump(report, sys.stdout, indent=2)
        sys.stdout.write("\n")
    else:
        print(render_text(report))
    return 0


if __name__ == "__main__":
    sys.exit(main())
