"""Render a telemetry JSONL capture: ``python -m repro.telemetry.report``.

Three sections — event counts with numeric-field aggregates (a replayed
:class:`~repro.telemetry.core.Counters` sink), the cost-model drift table
(`telemetry.drift.summarize`), and the proposed `HardwareSpec` correction
(`fit_spec_update`) when any selector tier shows enough drift samples.
``--json`` emits the same content as one machine-readable object (the
format ``benchmarks/results/telemetry_drift.json`` is committed in).
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List

from repro.telemetry import drift as drift_lib
from repro.telemetry.core import Counters, read_jsonl


def build_report(events: List[Dict[str, Any]], *, spec=None,
                 fit: bool = True) -> Dict[str, Any]:
    """The report as data: ``{events: Counters.summary(), drift: [rows],
    spec_update: {field: {...}}}`` — the JSON the CLI prints/renders."""
    counters = Counters()
    for ev in events:
        counters.emit(ev)
    stats = drift_lib.aggregate(events)
    out: Dict[str, Any] = {"n_events": len(events),
                           "events": counters.summary(),
                           "drift": drift_lib.summarize(stats),
                           "contention": _contention_rows(events),
                           "analysis": _analysis_rows(events)}
    if fit:
        fitted = drift_lib.fit_spec_update(stats, spec)
        out["spec_update"] = fitted["fields"]
        out["spec_update_skipped"] = fitted["skipped"]
    return out


def _contention_rows(events: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """``contention.stats`` events (the `collect_stats=` observatory)
    aggregated by (tier, op): batch count, mean distinct slots, the worst
    max-occupancy, the summed log2-bucket occupancy histogram, the hottest
    slots merged across batches, and per-exchange-level combining
    efficiency (total ops in vs representatives out)."""
    agg: Dict[tuple, Dict[str, Any]] = {}
    for ev in events:
        if ev.get("event") != "contention.stats":
            continue
        key = (str(ev.get("tier")), str(ev.get("op")))
        a = agg.setdefault(key, {
            "tier": key[0], "op": key[1], "batches": 0, "n_ops": 0,
            "distinct_sum": 0, "max_occupancy": 0, "occupancy_hist": [],
            "hot": {}, "level_ops_in": [], "level_ops_out": []})
        a["batches"] += 1
        a["n_ops"] += int(ev.get("n_ops") or 0)
        a["distinct_sum"] += int(ev.get("distinct_slots") or 0)
        a["max_occupancy"] = max(a["max_occupancy"],
                                 int(ev.get("max_occupancy") or 0))
        hist = [int(h) for h in (ev.get("occupancy_hist") or [])]
        if len(hist) > len(a["occupancy_hist"]):
            a["occupancy_hist"] += [0] * (len(hist) - len(a["occupancy_hist"]))
        for i, h in enumerate(hist):
            a["occupancy_hist"][i] += h
        for s, c in zip(ev.get("topk_slots") or [],
                        ev.get("topk_counts") or []):
            if int(s) >= 0:
                a["hot"][int(s)] = max(a["hot"].get(int(s), 0), int(c))
        for fld in ("level_ops_in", "level_ops_out"):
            lv = [int(x) for x in (ev.get(fld) or [])]
            if len(lv) > len(a[fld]):
                a[fld] += [0] * (len(lv) - len(a[fld]))
            for i, x in enumerate(lv):
                a[fld][i] += x
    rows = []
    for a in agg.values():
        hot = sorted(a.pop("hot").items(), key=lambda kv: -kv[1])[:8]
        a["mean_distinct"] = round(a.pop("distinct_sum")
                                   / max(1, a["batches"]), 1)
        a["hot_slots"] = [{"slot": s, "count": c} for s, c in hot]
        a["level_efficiency"] = [
            round(o / i, 4) if i else None
            for i, o in zip(a["level_ops_in"], a["level_ops_out"])]
        rows.append(a)
    rows.sort(key=lambda r: (r["tier"], r["op"]))
    return rows


def _analysis_rows(events: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """``analysis.finding`` events -> lint-result rows (repro.analysis)."""
    rows = []
    for ev in events:
        if ev.get("event") != "analysis.finding":
            continue
        rows.append({k: ev.get(k) for k in
                     ("rule", "severity", "file", "line", "entry",
                      "suppressed", "message")})
    return rows


def _fmt_s(v: float) -> str:
    if v != v:                       # NaN
        return "-"
    for unit, scale in (("s", 1.0), ("ms", 1e-3), ("us", 1e-6)):
        if abs(v) >= scale:
            return f"{v / scale:.3g}{unit}"
    return f"{v / 1e-9:.3g}ns"


def render_text(report: Dict[str, Any]) -> str:
    lines = [f"telemetry report — {report['n_events']} events", ""]
    lines.append(f"{'event':<28}{'count':>8}  numeric fields (mean)")
    for name in sorted(report["events"]):
        info = report["events"][name]
        means = "  ".join(
            f"{k}={_fmt_s(v['mean']) if k.endswith('_s') else round(v['mean'], 3)}"
            for k, v in sorted(info["fields"].items()))
        lines.append(f"{name:<28}{info['count']:>8}  {means}")
    rows = report["drift"]
    lines += ["", "cost-model drift (measured / predicted, geometric mean)"]
    if rows:
        lines.append(f"{'tier':<11}{'choice':<14}{'op':<6}{'size':<7}"
                     f"{'n':>5}{'ratio':>10}{'min':>10}{'max':>10}"
                     f"{'pred':>9}{'meas':>9}")
        for r in rows:
            lines.append(
                f"{r['tier']:<11}{r['choice']:<14}{r['op']:<6}"
                f"{r['size_bucket']:<7}{r['n']:>5}{r['ratio']:>10.3g}"
                f"{r['min_ratio']:>10.3g}{r['max_ratio']:>10.3g}"
                f"{_fmt_s(r['mean_predicted_s']):>9}"
                f"{_fmt_s(r['mean_measured_s']):>9}")
    else:
        lines.append("  (no (predicted_s, measured_s) pairs in the capture)")
    cont = report.get("contention") or []
    lines += ["", "contention (contention.stats events, collect_stats=)"]
    if cont:
        lines.append(f"{'tier':<11}{'op':<6}{'batches':>8}{'ops':>8}"
                     f"{'distinct':>9}{'max_occ':>8}  occupancy 2^k hist"
                     f" | hot slots | level in->out")
        for r in cont:
            hist = r["occupancy_hist"]
            top = max((i for i, h in enumerate(hist) if h), default=0)
            hist_s = " ".join(str(h) for h in hist[:top + 1])
            hot_s = ",".join(f"{h['slot']}x{h['count']}"
                             for h in r["hot_slots"][:4]) or "-"
            lvl_s = " ".join(
                f"{i}->{o}" for i, o in zip(r["level_ops_in"],
                                            r["level_ops_out"])) or "-"
            lines.append(
                f"{r['tier']:<11}{r['op']:<6}{r['batches']:>8}"
                f"{r['n_ops']:>8}{r['mean_distinct']:>9}"
                f"{r['max_occupancy']:>8}  [{hist_s}] | {hot_s} | {lvl_s}")
    else:
        lines.append("  (no contention.stats events in the capture)")
    lint = report.get("analysis") or []
    lines += ["", "static analysis (analysis.finding events)"]
    if lint:
        for r in lint:
            where = (f"{r['file']}:{r['line']}" if r.get("file")
                     else "<unknown>")
            sup = " [suppressed]" if r.get("suppressed") else ""
            entry = f" [{r['entry']}]" if r.get("entry") else ""
            sev = (r.get("severity") or "?").upper()
            lines.append(f"  {where}: {sev} {r.get('rule')}{sup}{entry}")
    else:
        lines.append("  (no analysis.finding events in the capture)")
    upd = report.get("spec_update") or {}
    lines += ["", "proposed HardwareSpec correction (fit_spec_update)"]
    if upd:
        for name, f in sorted(upd.items()):
            lines.append(f"  {name}: {f['current']:.3g} -> "
                         f"{f['proposed']:.3g}  (drift x{f['ratio']:.2f}, "
                         f"n={f['n']})")
    else:
        lines.append("  (not enough drift samples)")
    skipped = report.get("spec_update_skipped") or {}
    if skipped:
        # no silent caps: fields with drift evidence below their sample
        # floor are listed, not dropped
        for name, s in sorted(skipped.items()):
            why = s.get("reason") or (f"n={s['n']} < "
                                      f"min_samples={s['min_samples']}")
            lines.append(f"  {name}: skipped ({why})")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry.report",
        description="Render a repro.telemetry JSONL capture.")
    ap.add_argument("capture", help="JSONL file written by JsonlWriter "
                                    "(e.g. REPRO_TELEMETRY=out.jsonl)")
    ap.add_argument("--json", action="store_true",
                    help="emit the report as one JSON object")
    ap.add_argument("--no-fit", action="store_true",
                    help="skip the HardwareSpec correction section")
    args = ap.parse_args(argv)
    events = read_jsonl(args.capture)
    report = build_report(events, fit=not args.no_fit)
    if args.json:
        json.dump(report, sys.stdout, indent=2)
        sys.stdout.write("\n")
    else:
        print(render_text(report))
    return 0


if __name__ == "__main__":
    sys.exit(main())
