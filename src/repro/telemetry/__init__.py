"""`repro.telemetry` — structured events, drift tracking, profiler hooks.

The observability layer every tier of the atomics stack reports into:

* `record` / `span` / `annotation` — the instrumentation primitives
  (near-zero cost disabled; see `repro.telemetry.core`).
* `enable` / `disable` / `capture` / `enable_from_env` — stream control.
* `RingBuffer` / `JsonlWriter` / `Counters` — the pluggable sinks.
* `repro.telemetry.drift` — predicted-vs-measured aggregation over the
  event stream and the `fit_spec_update` HardwareSpec-correction hook.
* ``python -m repro.telemetry.report capture.jsonl`` — render a capture.

Event catalogue (the schema table lives in README "Observability"):

====================  =====================================================
``atomics.execute``   one per `repro.atomics.execute` op batch: tier,
                      backend/strategy chosen, op, n, m, distinct_slots,
                      predicted_s (+ measured_s eager under ``sync``)
``atomics.retry.round``  one per `execute_until` round: pending/issued/
                      resolved counts, strategy, predicted_s, measured_s
``atomics.retry.done``   end of an `execute_until` call: round-count
                      histogram (the contention signal), unresolved count
``contention.stats``  one per ``collect_stats`` batch at a sync boundary:
                      n_ops, distinct_slots, max_occupancy, log2-bucketed
                      occupancy_hist, topk_slots/topk_counts, per-exchange-
                      level level_ops_in/level_ops_out (sharded tier)
``atomics.reshard.migrate``  one per table migration: path chosen,
                      predicted_s per path, measured_s
``recovery.fault``    one per absorbed/raised failure: site, error type,
                      attempt number, fatal flag
``recovery.backoff``  one per recovery backoff sleep: attempt, backoff_s
``recovery.restore``  one per restore: step resumed from (or scratch)
``chaos.fire``        one per injected fault: site, occurrence, step
``train.step``        per-step span from `launch.train`: wall_s, step
``analysis.finding``  one per static-lint finding (`repro.analysis`):
                      rule, severity, file, line, entry, suppressed
``recovery.donation_hazard``  startup warning from `run_with_recovery`:
                      donating step_fn + captured init_state (rule A004)
``tuning.apply``      one per live-spec swap by `repro.tuning`: fields
                      changed (from/to/ratio), drift score, window size
``tuning.rollback``   controller reverted to the last-good spec: the
                      post-swap drift score that triggered it
``tuning.quarantine`` pathological proposal rejected (NaN/negative/
                      out-of-envelope): field, value, reason — never silent
``tuning.skip``       update cycle that applied nothing: reason
                      (cooldown/deadband/no_fields) + any skipped fields
``tuning.confirm``    post-swap window showed no regression: swap kept
``tuning.restore``    persisted tuned spec validated+reinstalled (or
                      rejected) at controller start
``tuning.perturb``    spec_perturb chaos fired inside the update cycle:
                      kind (skew/poison) + deterministic parameter
====================  =====================================================
"""

from repro.telemetry.core import (Counters, JsonlWriter, RingBuffer, Sink,
                                  Span, add_sink, annotation,
                                  annotations_enabled, capture, disable,
                                  enable, enable_from_env, enabled,
                                  flush_ring, read_jsonl, record,
                                  record_event, remove_sink, ring_events,
                                  sinks, span, sync_enabled, telemetry_dir,
                                  TELEMETRY_DIR_ENV, TELEMETRY_ENV)

__all__ = [
    "Counters", "JsonlWriter", "RingBuffer", "Sink", "Span",
    "add_sink", "annotation", "annotations_enabled", "capture", "disable",
    "enable", "enable_from_env", "enabled", "flush_ring", "read_jsonl",
    "record", "record_event", "remove_sink", "ring_events", "sinks",
    "span", "sync_enabled", "telemetry_dir",
    "TELEMETRY_DIR_ENV", "TELEMETRY_ENV",
]
