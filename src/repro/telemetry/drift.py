"""Cost-model drift: predicted-vs-measured aggregation over the event stream.

The selector tiers (`select_backend` / `select_exchange` /
`select_migration`) stamp every decision event with ``predicted_s``; the
host-side call sites stamp ``measured_s``.  This module folds those pairs
into per-``(tier, choice, op, size-bucket)`` drift statistics — the
*geometric* mean of ``measured / predicted`` (ratios are multiplicative:
a model off by 2x slow and 2x fast should average to 1, not 1.25) — and
turns persistent drift into a proposed `HardwareSpec` correction
(:func:`fit_spec_update`), closing the ROADMAP's self-tuning loop: the
constants the paper measured once per architecture (Table 2/3) become
constants the *stack* re-measures continuously in production.

Input is any iterable of event dicts — a live :class:`~repro.telemetry.core.
RingBuffer`'s ``.events``, or a JSONL capture via :func:`from_jsonl`.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Iterable, List, Optional, Tuple

from repro.telemetry.core import read_jsonl

#: events carrying a (predicted_s, measured_s) pair worth folding in
DRIFT_EVENTS = ("atomics.execute", "atomics.retry.round",
                "atomics.reshard.migrate")

#: drift-group key: (tier, choice, op, size_bucket)
Key = Tuple[str, str, str, str]


@dataclasses.dataclass
class DriftStat:
    """Running drift of one (tier, choice, op, size-bucket) group.

    ``ratio`` (the headline number) is the geometric mean of
    ``measured_s / predicted_s`` — 1.0 means the cost model is calibrated,
    2.0 means the hardware is 2x slower than the model thinks.
    """

    n: int = 0
    log_sum: float = 0.0
    min_ratio: float = math.inf
    max_ratio: float = -math.inf
    predicted_sum: float = 0.0
    measured_sum: float = 0.0

    def add(self, predicted: float, measured: float) -> None:
        r = measured / predicted
        self.n += 1
        self.log_sum += math.log(r)
        self.min_ratio = min(self.min_ratio, r)
        self.max_ratio = max(self.max_ratio, r)
        self.predicted_sum += predicted
        self.measured_sum += measured

    @property
    def ratio(self) -> float:
        return math.exp(self.log_sum / self.n) if self.n else float("nan")

    def as_dict(self) -> Dict[str, Any]:
        return {"n": self.n, "ratio": self.ratio,
                "min_ratio": self.min_ratio, "max_ratio": self.max_ratio,
                "mean_predicted_s": self.predicted_sum / max(1, self.n),
                "mean_measured_s": self.measured_sum / max(1, self.n)}


def size_bucket(n: Optional[int]) -> str:
    """Power-of-two bucket label for a batch/table size (``"2^k"``)."""
    if n is None or n < 1:
        return "?"
    return f"2^{max(0, int(n) - 1).bit_length()}"


def _choice(ev: Dict[str, Any]) -> Optional[str]:
    if ev.get("event") == "atomics.reshard.migrate":
        return ev.get("path")
    return ev.get("backend") or ev.get("strategy")


def _size(ev: Dict[str, Any]) -> Optional[int]:
    for k in ("n_exec", "n", "n_slots"):
        v = ev.get(k)
        if isinstance(v, (int, float)) and not isinstance(v, bool):
            return int(v)
    return None


def aggregate(events: Iterable[Dict[str, Any]]) -> Dict[Key, DriftStat]:
    """Fold an event stream into per-group drift statistics.

    Only events with a *positive* predicted and measured time contribute —
    traced decision events (no wall time) and oracle-path events (no
    prediction) are informative elsewhere but carry no drift signal.
    """
    out: Dict[Key, DriftStat] = {}
    for ev in events:
        if ev.get("event") not in DRIFT_EVENTS:
            continue
        pred, meas = ev.get("predicted_s"), ev.get("measured_s")
        if not isinstance(pred, (int, float)) or isinstance(pred, bool) \
                or not isinstance(meas, (int, float)) \
                or isinstance(meas, bool) or pred <= 0 or meas <= 0:
            continue
        key: Key = (str(ev.get("tier", "?")), str(_choice(ev) or "?"),
                    str(ev.get("op", "-")), size_bucket(_size(ev)))
        out.setdefault(key, DriftStat()).add(float(pred), float(meas))
    return out


def from_jsonl(path: str) -> Dict[Key, DriftStat]:
    return aggregate(read_jsonl(path))


def summarize(stats: Dict[Key, DriftStat]) -> List[Dict[str, Any]]:
    """Flat row-per-group view, most-drifted first (|log ratio| descending)."""
    rows = []
    for (tier, choice, op, bucket), st in stats.items():
        rows.append({"tier": tier, "choice": choice, "op": op,
                     "size_bucket": bucket, **st.as_dict()})
    rows.sort(key=lambda r: abs(math.log(r["ratio"])), reverse=True)
    return rows


# ---------------------------------------------------------------------------
# Spec correction: drift -> proposed HardwareSpec constants
# ---------------------------------------------------------------------------

#: which spec constant each (tier, choice) drift pool scales, and in which
#: direction: "direct" constants are latencies (2x-slow hardware -> 2x the
#: constant), "inverse" are bandwidths (2x-slow -> HALF the Bps)
SPEC_FIELD_OF = {
    ("local", "serialized"): ("loop_step_s", "direct"),
    ("local", "sort"): ("sort_elem_pass_s", "direct"),
    ("local", "onehot"): ("gather_elem_s", "direct"),
    ("sharded", "oneshot"): ("collective_launch_s", "direct"),
    ("sharded", "hierarchical"): ("collective_launch_s", "direct"),
    ("sharded", "naive"): ("collective_launch_s", "direct"),
    ("sharded", "dense"): ("collective_launch_s", "direct"),
    ("migration", "exchange"): ("collective_launch_s", "direct"),
    ("migration", "device_put"): ("host_roundtrip_Bps", "inverse"),
}

#: don't propose a correction from fewer samples than this per field
MIN_SAMPLES = 3


def _min_samples_for(name: str, min_samples) -> int:
    """Per-field sample floor: an int applies to every field; a mapping is
    consulted per field name with ``"*"`` as its default (falling back to
    `MIN_SAMPLES`)."""
    if isinstance(min_samples, int):
        return min_samples
    return int(min_samples.get(name, min_samples.get("*", MIN_SAMPLES)))


def fit_spec_update(stats: Dict[Key, DriftStat], spec=None, *,
                    min_samples=MIN_SAMPLES) -> Dict[str, Any]:
    """Turn per-group drift into proposed `HardwareSpec` constants.

    Groups mapping to the same field pool their log-ratios (sample-count
    weighted) into one field-level geometric drift; the proposal scales the
    current constant by it ("inverse" fields — bandwidths — divide instead).
    The dominant-term assumption is deliberate: each backend's cost is
    linear in exactly one spec constant at the sizes the selector's
    crossover points care about, so a multiplicative residual on the total
    is (to first order) a multiplicative residual on that constant — the
    same reasoning the paper uses to read Table 2 constants off median
    latencies.

    ``min_samples`` is either one int floor for every field, or a mapping
    ``{field_name: floor}`` (key ``"*"`` sets the default) — the tuning
    controller uses per-field floors to demand more evidence for
    high-blast-radius constants.  Fields *below* their floor are no longer
    silently dropped: they come back under ``"skipped"`` so reports and the
    controller can surface them.  Returns::

        {"fields": {name: {"current", "proposed", "ratio", "n"}},
         "skipped": {name: {"n", "min_samples"} | {"reason": ...}},
         "spec": <HardwareSpec with proposals applied>}
    """
    if spec is None:
        from repro.core import rmw_engine
        spec = rmw_engine.default_spec()
    pools: Dict[Tuple[str, str], List[float]] = {}   # field -> [log r] pool
    for (tier, choice, _op, _bucket), st in stats.items():
        target = SPEC_FIELD_OF.get((tier, choice))
        if target is None or st.n == 0:
            continue
        pools.setdefault(target, []).extend([st.log_sum / st.n] * st.n)
    fields: Dict[str, Dict[str, float]] = {}
    skipped: Dict[str, Dict[str, Any]] = {}
    updates: Dict[str, float] = {}
    for (name, sense), logs in pools.items():
        floor = _min_samples_for(name, min_samples)
        if len(logs) < floor:
            skipped[name] = {"n": len(logs), "min_samples": floor}
            continue
        ratio = math.exp(sum(logs) / len(logs))
        current = float(getattr(spec, name, 0.0) or 0.0)
        if current <= 0.0:
            skipped[name] = {"n": len(logs), "min_samples": floor,
                             "reason": "field unset on spec"}
            continue
        proposed = current * ratio if sense == "direct" else current / ratio
        fields[name] = {"current": current, "proposed": proposed,
                        "ratio": ratio, "n": len(logs)}
        updates[name] = proposed
    new_spec = dataclasses.replace(spec, **updates) if updates else spec
    return {"fields": fields, "skipped": skipped, "spec": new_spec}
