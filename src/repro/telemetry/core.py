"""Structured event stream: the measurement layer under the cost models.

The paper's methodology is *check every prediction against the hardware*;
the stack's three selector tiers (`select_backend`, `select_exchange`,
`select_migration`) make those predictions at every dispatch — this module
is where the predictions and the measurements meet.  Every layer reports
into one process-wide event stream:

* ``record(event, **fields)`` — one structured event (a flat dict), routed
  to every installed sink.  **Near-zero cost when disabled**: the hot-path
  guard is a single module-global boolean (`enabled()`), so instrumented
  code pays one branch per call site when telemetry is off.
* ``span(name, **fields)`` — timing context manager.  It *always* measures
  (``perf_counter`` on enter/exit, exposing ``.wall_s``) so benchmarks can
  use it as their one clock, and records an event only when enabled.  This
  is the single warmup-free timing convention shared by ``benchmarks/``
  and the production paths.
* sinks — :class:`RingBuffer` (bounded in-memory, tests), ``JsonlWriter``
  (one JSON object per line, offline analysis / the report CLI),
  ``Counters`` (streaming aggregation, no retention).

Jit discipline: events are recorded at **trace/dispatch boundaries only**.
Inside ``jit``/``shard_map``, instrumentation runs at *trace* time — once
per compilation, not once per executed call (and once per call *site*, not
once per device: ``shard_map`` traces its body a single time).  Such
events carry ``traced=True`` and no measured wall time; cached executions
of a jitted function emit nothing, so repeated calls never duplicate
events.  Measured wall times come from the host-side call sites (eager
`atomics.execute` under ``sync=True``, the retry combinator's per-round
dispatch, migration, train steps).

Thread safety: sink dispatch holds one module lock; sinks themselves need
no internal locking.  Enabling/disabling swaps the sink tuple atomically.
"""

from __future__ import annotations

import collections
import contextlib
import io
import json
import os
import threading
import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

#: env var: a JSONL path (or "ring") enabling telemetry at process start
#: for unmodified callers — the observability sibling of ``REPRO_CHAOS``
TELEMETRY_ENV = "REPRO_TELEMETRY"

_lock = threading.Lock()
_sinks: Tuple["Sink", ...] = ()
_enabled: bool = False          # the one hot-path guard
_sync: bool = False             # block_until_ready around measured calls
_annotate: bool = False         # jax.profiler.TraceAnnotation at dispatch


# ---------------------------------------------------------------------------
# Sinks
# ---------------------------------------------------------------------------

class Sink:
    """One consumer of the event stream.  ``emit`` is called under the
    module lock with a flat dict (the caller owns the dict; copy if you
    retain it past the call — the built-in sinks retain it as-is since
    instrumentation never mutates an emitted event)."""

    def emit(self, event: Dict[str, Any]) -> None:
        raise NotImplementedError

    def close(self) -> None:
        pass


class RingBuffer(Sink):
    """Bounded in-memory sink — the test/inspection default."""

    def __init__(self, capacity: int = 4096):
        self._buf: collections.deque = collections.deque(maxlen=capacity)

    def emit(self, event: Dict[str, Any]) -> None:
        self._buf.append(event)

    @property
    def events(self) -> List[Dict[str, Any]]:
        return list(self._buf)

    def __len__(self) -> int:
        return len(self._buf)

    def clear(self) -> None:
        self._buf.clear()


def _jsonable(x):
    """Best-effort scalar coercion: numpy scalars/arrays -> python, other
    non-JSON types -> repr.  Events must never make a sink raise."""
    if x is None or isinstance(x, (bool, int, float, str)):
        return x
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if isinstance(x, dict):
        return {str(k): _jsonable(v) for k, v in x.items()}
    item = getattr(x, "item", None)
    if item is not None:
        try:
            return _jsonable(item())
        except Exception:  # noqa: BLE001 — non-scalar arrays etc.
            pass
    tolist = getattr(x, "tolist", None)
    if tolist is not None:
        try:
            return _jsonable(tolist())
        except Exception:  # noqa: BLE001
            pass
    return repr(x)


class JsonlWriter(Sink):
    """One JSON object per line — the capture format the report CLI and
    `telemetry.drift` read back (`read_jsonl`)."""

    def __init__(self, path: str):
        self.path = path
        self._f: Optional[io.TextIOBase] = open(path, "w")

    def emit(self, event: Dict[str, Any]) -> None:
        if self._f is None:
            return
        self._f.write(json.dumps(
            {k: _jsonable(v) for k, v in event.items()}) + "\n")

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


def read_jsonl(path: str) -> List[Dict[str, Any]]:
    """Load a `JsonlWriter` capture back into a list of event dicts."""
    out: List[Dict[str, Any]] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


class Counters(Sink):
    """Streaming aggregation, no event retention: per event name a count,
    and per numeric field a running (count, sum, min, max)."""

    def __init__(self):
        self.counts: Dict[str, int] = collections.defaultdict(int)
        self._num: Dict[Tuple[str, str], List[float]] = {}

    def emit(self, event: Dict[str, Any]) -> None:
        name = str(event.get("event"))
        self.counts[name] += 1
        for k, v in event.items():
            if k in ("event", "t") or isinstance(v, bool) \
                    or not isinstance(v, (int, float)):
                continue
            agg = self._num.get((name, k))
            if agg is None:
                self._num[(name, k)] = [1, float(v), float(v), float(v)]
            else:
                agg[0] += 1
                agg[1] += v
                agg[2] = min(agg[2], v)
                agg[3] = max(agg[3], v)

    def summary(self) -> Dict[str, Dict[str, Any]]:
        """``{event: {count, fields: {field: {n, sum, mean, min, max}}}}``"""
        out: Dict[str, Dict[str, Any]] = {}
        for name, c in self.counts.items():
            out[name] = {"count": c, "fields": {}}
        for (name, k), (n, s, lo, hi) in self._num.items():
            out[name]["fields"][k] = {"n": n, "sum": s, "mean": s / n,
                                      "min": lo, "max": hi}
        return out


# ---------------------------------------------------------------------------
# The stream
# ---------------------------------------------------------------------------

def enabled() -> bool:
    """The hot-path guard instrumented code checks before doing any work."""
    return _enabled


def sync_enabled() -> bool:
    """True when measured call sites should ``block_until_ready`` so wall
    times mean device time, not dispatch time (drift captures need this)."""
    return _enabled and _sync


def annotations_enabled() -> bool:
    """True when dispatch sites should open `jax.profiler.TraceAnnotation`
    scopes (named regions in a profiler trace)."""
    return _enabled and _annotate


def enable(*sinks: Sink, sync: bool = False, annotate: bool = False) -> None:
    """Install ``sinks`` (replacing any current set) and turn the stream on.

    ``sync=True`` makes instrumented dispatch sites block until results are
    ready before reading the clock — accurate measured-vs-predicted events
    at the price of de-pipelining; leave False in production.
    ``annotate=True`` additionally opens ``jax.profiler.TraceAnnotation``
    regions around engine dispatch / exchange collectives / train steps.
    """
    global _sinks, _enabled, _sync, _annotate
    with _lock:
        _sinks = tuple(sinks) or (RingBuffer(),)
        _sync = bool(sync)
        _annotate = bool(annotate)
        _enabled = True


def disable() -> None:
    """Turn the stream off and close the installed sinks."""
    global _sinks, _enabled, _sync, _annotate
    with _lock:
        for s in _sinks:
            try:
                s.close()
            except Exception:  # noqa: BLE001 — teardown must not raise
                pass
        _sinks = ()
        _enabled = False
        _sync = False
        _annotate = False


def add_sink(sink: Sink, *, sync: Optional[bool] = None,
             annotate: Optional[bool] = None) -> None:
    """Attach ``sink`` *alongside* any installed sinks and turn the stream
    on (contrast `enable`, which replaces the sink set).  ``sync``/
    ``annotate`` only ever widen the current flags — a live consumer (the
    tuning controller) must not silently strip another consumer's settings.
    Pair with `remove_sink`."""
    global _sinks, _enabled, _sync, _annotate
    with _lock:
        if sink not in _sinks:
            _sinks = _sinks + (sink,)
        if sync is not None:
            _sync = _sync or bool(sync)
        if annotate is not None:
            _annotate = _annotate or bool(annotate)
        _enabled = True


def remove_sink(sink: Sink, *, close: bool = False) -> bool:
    """Detach one sink installed via `add_sink`/`enable`.  When the last
    sink goes, the stream turns fully off (flags reset).  Returns True if
    the sink was installed."""
    global _sinks, _enabled, _sync, _annotate
    with _lock:
        had = any(s is sink for s in _sinks)
        _sinks = tuple(s for s in _sinks if s is not sink)
        if not _sinks:
            _enabled = False
            _sync = False
            _annotate = False
    if had and close:
        try:
            sink.close()
        except Exception:  # noqa: BLE001 — teardown must not raise
            pass
    return had


def sinks() -> Tuple[Sink, ...]:
    return _sinks


@contextlib.contextmanager
def capture(sink: Optional[Sink] = None, *, sync: bool = False,
            annotate: bool = False):
    """Scoped enable: install ``sink`` (default: a fresh :class:`RingBuffer`)
    *in addition to* any already-installed sinks, yield it, and restore the
    previous state on exit.  The standard test/benchmark spelling::

        with telemetry.capture(sync=True) as buf:
            atomics.execute(...)
        events = buf.events
    """
    global _sinks, _enabled, _sync, _annotate
    target = sink if sink is not None else RingBuffer()
    with _lock:
        prev = (_sinks, _enabled, _sync, _annotate)
        _sinks = prev[0] + (target,)
        _sync = bool(sync) or _sync
        _annotate = bool(annotate) or _annotate
        _enabled = True
    try:
        yield target
    finally:
        with _lock:
            _sinks, _enabled, _sync, _annotate = prev
        if sink is None:
            pass                      # caller keeps the buffer; nothing to close
        # an explicitly passed sink stays open — its owner closes it


def record(event: str, **fields) -> None:
    """Record one structured event.  No-op (one boolean check) when the
    stream is disabled; never raises."""
    if not _enabled:
        return
    ev: Dict[str, Any] = {"event": event, "t": time.time()}
    ev.update(fields)
    record_event(ev)


def record_event(ev: Dict[str, Any]) -> None:
    """Hot-path variant of :func:`record`: the caller hands over a prebuilt
    event dict (must contain ``"event"``; ``"t"`` is stamped here if
    absent).  Ownership transfers to the stream — don't mutate after."""
    if not _enabled:
        return
    if "t" not in ev:
        ev["t"] = time.time()
    with _lock:
        for s in _sinks:
            try:
                s.emit(ev)
            except Exception:  # noqa: BLE001 — a broken sink must not take
                pass           # down the instrumented path


class Span:
    """Timing scope: measures wall seconds between enter and exit (always —
    ``.wall_s`` is valid whether or not the stream is on) and records one
    ``{event: name, wall_s: ...}`` event when enabled."""

    __slots__ = ("name", "fields", "wall_s", "_t0")

    def __init__(self, name: str, fields: Dict[str, Any]):
        self.name = name
        self.fields = fields
        self.wall_s: Optional[float] = None

    def __enter__(self) -> "Span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.wall_s = time.perf_counter() - self._t0
        if _enabled:
            record(self.name, wall_s=self.wall_s,
                   ok=exc_type is None, **self.fields)
        return False


def span(name: str, **fields) -> Span:
    """``with telemetry.span("train.step", step=i) as sp: ...`` — see
    :class:`Span`.  ``sp.wall_s`` is the one clock benchmarks and
    production paths share."""
    return Span(name, fields)


def annotation(name: str):
    """A `jax.profiler.TraceAnnotation` scope when annotations are enabled,
    else a no-op context — cheap enough to leave on dispatch sites."""
    if not (_enabled and _annotate):
        return contextlib.nullcontext()
    import jax.profiler
    return jax.profiler.TraceAnnotation(name)


# ---------------------------------------------------------------------------
# Ring crash-flush: REPRO_TELEMETRY=ring keeps the last N events in memory —
# which used to mean they vanished exactly when they mattered (a crash).
# `enable_from_env` now registers an atexit flush (atexit runs on unhandled-
# exception exits too), and `runtime.fault_tolerance` calls `flush_ring`
# on the fatal-fault path so the last-N events land next to the
# `recovery.fault` event.
# ---------------------------------------------------------------------------

#: env var naming the directory run artifacts (ring flushes) land in
TELEMETRY_DIR_ENV = "REPRO_TELEMETRY_DIR"

#: default ring-flush filename; lands under `telemetry_dir()` (it used to
#: land bare in the CWD, strewing ``repro_telemetry_ring.jsonl`` wherever
#: the process happened to run); override the full path with
#: ``REPRO_TELEMETRY=ring:/path/to/flush.jsonl``
RING_FLUSH_DEFAULT = "repro_telemetry_ring.jsonl"

_ring_flush_path: Optional[str] = None   # set by enable_from_env("ring[:p]")
_atexit_registered = False


def telemetry_dir() -> str:
    """The run's telemetry artifact directory: ``REPRO_TELEMETRY_DIR`` when
    set, else ``artifacts/telemetry`` under the working directory.  Not
    created until something is written into it."""
    return os.environ.get(TELEMETRY_DIR_ENV, "").strip() or \
        os.path.join("artifacts", "telemetry")


def _default_flush_target() -> str:
    return _ring_flush_path or os.path.join(telemetry_dir(),
                                            RING_FLUSH_DEFAULT)


def ring_events() -> List[Dict[str, Any]]:
    """Snapshot of every installed RingBuffer sink's events (oldest first,
    concatenated across rings).  Empty when no ring sink is installed —
    callers (`run_with_recovery` attaching the tail to `RunResult`) need no
    mode check."""
    return [ev for s in _sinks if isinstance(s, RingBuffer)
            for ev in s.events]


def flush_ring(path: Optional[str] = None) -> int:
    """Write the current ring snapshot to ``path`` (default: the
    ``ring:<path>`` target from ``REPRO_TELEMETRY``, else
    ``RING_FLUSH_DEFAULT`` under `telemetry_dir`) as JSONL readable by
    `read_jsonl`.  Returns the number of events written; 0 (and no file
    touched) when no ring sink is installed or the ring is empty.  Never
    raises — this runs on crash paths."""
    evs = ring_events()
    if not evs:
        return 0
    target = path or _default_flush_target()
    try:
        parent = os.path.dirname(target)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(target, "w") as f:
            for ev in evs:
                f.write(json.dumps(
                    {k: _jsonable(v) for k, v in ev.items()}) + "\n")
    except Exception:  # noqa: BLE001 — a failing flush must not mask the
        return 0       # fault that triggered it
    return len(evs)


def _flush_ring_atexit() -> None:
    n = flush_ring()
    if n:
        import logging
        logging.getLogger("repro.telemetry").info(
            "flushed %d ring events to %s", n, _default_flush_target())


def enable_from_env() -> bool:
    """The ``REPRO_TELEMETRY`` hook: ``"ring"`` installs a RingBuffer
    (``"ring:/path.jsonl"`` names where the crash/atexit flush lands —
    default `RING_FLUSH_DEFAULT` under `telemetry_dir`), anything else is
    treated as a JSONL
    output path.  Ring mode registers an atexit flush so the last-N events
    survive a crash.  Returns True when the stream was enabled.  Called by
    `launch.train` so unmodified training invocations can be instrumented
    from the environment."""
    global _ring_flush_path, _atexit_registered
    target = os.environ.get(TELEMETRY_ENV, "").strip()
    if not target:
        return False
    if target == "ring" or target.startswith("ring:"):
        _, _, flush_to = target.partition(":")
        _ring_flush_path = flush_to.strip() or None
        enable(RingBuffer())
        if not _atexit_registered:
            import atexit
            atexit.register(_flush_ring_atexit)
            _atexit_registered = True
    else:
        enable(JsonlWriter(target))
    return True
