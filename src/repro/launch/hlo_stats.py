"""Parse post-SPMD HLO text for collective operations and wire bytes.

cost_analysis() does not report collective traffic, so the roofline's
collective term comes from here.  Collectives that live inside scanned layer
stacks appear *once* in the HLO text but execute once per loop trip, so the
parser is computation-aware: it builds the while-loop call graph, extracts
trip counts from loop-condition constants, and multiplies nested collective
bytes accordingly.

Wire-byte conventions (per participant, ring schedules — matching
core/collective_model.py):
  all-gather:         out_bytes * (n-1)/n
  reduce-scatter:     in_bytes  * (n-1)/n
  all-reduce:         2 * in_bytes * (n-1)/n
  all-to-all:         in_bytes  * (n-1)/n
  collective-permute: in_bytes
"""

from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[\w\[\],{}]+)?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_WHILE_RE = re.compile(
    r"=.*\bwhile\(.*condition=%?([\w.\-]+).*body=%?([\w.\-]+)", re.DOTALL)
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(type_str: str) -> int:
    m = _SHAPE_RE.match(type_str.strip())
    if not m:
        return 0
    dt, dims = m.group(1), m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def _line_result_bytes(line: str) -> int:
    try:
        rhs = line.split("=", 1)[1].strip()
    except IndexError:
        return 0
    if rhs.startswith("("):
        inner = rhs[1:rhs.index(")")]
        # shapes contain commas — findall, don't split
        return sum(_shape_bytes(p)
                   for p in re.findall(r"\w+\[[\d,]*\]", inner))
    return _shape_bytes(rhs)


def _line_operand_bytes(line: str, opname: str) -> int:
    m = _OP_RE.search(line)
    if not m:
        return 0
    start = line.index("(", m.end() - 1)
    depth, i = 0, start
    while i < len(line):
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
            if depth == 0:
                break
        i += 1
    args = line[start + 1:i]
    return sum(_shape_bytes(p) for p in re.findall(r"\w+\[[\d,]*\]", args))


def _group_size(line: str, world: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return world


def split_computations(hlo: str) -> Tuple[Dict[str, List[str]], str]:
    """-> ({computation name: lines}, entry_name)."""
    comps: Dict[str, List[str]] = {}
    entry = ""
    cur: List[str] = []
    cur_name = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        m = _COMP_START_RE.match(line) if (line and not line[0].isspace()) \
            else None
        if m and stripped.endswith("{"):
            cur_name = m.group(1)
            cur = []
            comps[cur_name] = cur
            if line.startswith("ENTRY"):
                entry = cur_name
        elif stripped == "}":
            cur_name = None
        elif cur_name is not None:
            cur.append(stripped)
    return comps, entry


def _trip_count(cond_lines: List[str]) -> int:
    """Heuristic: largest integer constant in the loop condition."""
    best = 1
    for line in cond_lines:
        for m in _CONST_RE.finditer(line):
            best = max(best, int(m.group(1)))
    return best


_DOT_RE = re.compile(
    r"=\s*(\w+\[[\d,]*\])[^=]*?\bdot\(%?([\w.\-]+),\s*%?([\w.\-]+)\)(.*)$")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\w+\[[\d,]*\])")
_RHS_CDIMS_RE = re.compile(r"rhs_contracting_dims=\{([\d,]*)\}")
_CALLS_RE = re.compile(r"\b(?:calls|body)=%?([\w.\-]+)")


def _dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.match(type_str.strip())
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


def analyze_hlo(hlo: str, world: int = 512) -> Dict[str, Dict]:
    """Full expanded analysis: collectives + dot flops + result-byte traffic.

    Equivalent to `collective_bytes_from_hlo` plus:
      dot_flops     — 2 * result_elems * contraction, expanded by loop trips
      result_bytes  — sum of all op result bytes (≈ bytes written), expanded
    """
    return _analyze(hlo, world)


def collective_bytes_from_hlo(hlo: str, world: int = 512) -> Dict[str, Dict]:
    return _analyze(hlo, world)


def _analyze(hlo: str, world: int) -> Dict[str, Dict]:
    comps, entry = split_computations(hlo)
    if not entry:
        # fallback: flat scan of all lines
        comps = {"__all__": [ln.strip() for ln in hlo.splitlines()]}
        entry = "__all__"

    memo: Dict[str, Dict[str, Dict]] = {}

    def eval_comp(name: str, seen=()) -> Dict[str, Dict]:
        if name in memo:
            return memo[name]
        if name in seen or name not in comps:
            return {}
        lines = comps[name]
        # symbol table: op name -> result type (for dot contraction sizes)
        sym: Dict[str, str] = {}
        for line in lines:
            dm = _DEF_RE.match(line)
            if dm:
                sym[dm.group(1)] = dm.group(2)
        stats: Dict[str, Dict] = {}

        def add(kind: str, count: float, wire: float):
            s = stats.setdefault(kind, {"count": 0.0, "wire_bytes": 0.0})
            s["count"] += count
            s["wire_bytes"] += wire

        def add_flops(count: float, flops: float, bytes_: float,
                      dbytes: float = 0.0):
            s = stats.setdefault("__compute__",
                                 {"count": 0.0, "dot_flops": 0.0,
                                  "result_bytes": 0.0, "dot_bytes": 0.0})
            s["count"] += count
            s["dot_flops"] += flops
            s["result_bytes"] += bytes_
            s["dot_bytes"] += dbytes

        for line in lines:
            # result-byte traffic of every op (upper-bound bytes-written)
            rb = _line_result_bytes(line)
            if rb:
                add_flops(0, 0.0, float(rb))
            dm = _DOT_RE.search(line)
            if dm:
                res_t, lhs, rhs, attrs = dm.groups()
                res_elems = 1
                for d in _dims(res_t):
                    res_elems *= d
                cm = _RHS_CDIMS_RE.search(attrs)
                contraction = 1
                if cm and cm.group(1):
                    rdims = _dims(sym.get(rhs, ""))
                    for ax in cm.group(1).split(","):
                        ax = int(ax)
                        if ax < len(rdims):
                            contraction *= rdims[ax]
                # matmul-touched bytes: lhs + rhs + out (the HBM-traffic
                # proxy — fused elementwise rides along with these)
                dbytes = (_shape_bytes(sym.get(lhs, ""))
                          + _shape_bytes(sym.get(rhs, ""))
                          + _shape_bytes(res_t))
                add_flops(1, 2.0 * res_elems * contraction, 0.0, dbytes)
            om = _OP_RE.search(line)
            if om:
                kind = om.group(1)
                n = max(2, _group_size(line, world))
                # operands print without type annotations in this dialect, so
                # wire bytes derive from the RESULT type (in==out for
                # all-reduce/all-to-all/permute; out = n*in for all-gather;
                # in = n*out for reduce-scatter)
                outb = _line_result_bytes(line)
                if kind == "all-gather":
                    wire = outb * (n - 1) / n
                elif kind == "reduce-scatter":
                    wire = outb * (n - 1)
                elif kind == "all-reduce":
                    wire = 2 * outb * (n - 1) / n
                elif kind == "all-to-all":
                    wire = outb * (n - 1) / n
                else:
                    wire = outb
                add(kind, 1, wire)
                continue
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trips = _trip_count(comps.get(cond, []))
                child = eval_comp(body, seen + (name,))
                for kind, s in child.items():
                    if kind == "__compute__":
                        add_flops(s["count"] * trips,
                                  s["dot_flops"] * trips,
                                  s["result_bytes"] * trips,
                                  s["dot_bytes"] * trips)
                    else:
                        add(kind, s["count"] * trips,
                            s["wire_bytes"] * trips)
                continue
            cmm = _CALLS_RE.search(line)
            if cmm and "while(" not in line:
                child = eval_comp(cmm.group(1), seen + (name,))
                for kind, s in child.items():
                    if kind == "__compute__":
                        add_flops(s["count"], s["dot_flops"],
                                  s["result_bytes"], s["dot_bytes"])
                    else:
                        add(kind, s["count"], s["wire_bytes"])
        memo[name] = stats
        return stats

    stats = dict(eval_comp(entry))
    total = sum(s["wire_bytes"] for s in stats.values()
                if isinstance(s, dict) and "wire_bytes" in s)
    stats["total_wire_bytes"] = total  # type: ignore[assignment]
    compute = stats.pop("__compute__", {"count": 0, "dot_flops": 0.0,
                                        "result_bytes": 0.0,
                                        "dot_bytes": 0.0})
    stats["dot_flops"] = compute["dot_flops"]  # type: ignore[assignment]
    stats["result_bytes"] = compute["result_bytes"]  # type: ignore
    stats["dot_bytes"] = compute["dot_bytes"]  # type: ignore[assignment]
    stats["dot_count"] = compute["count"]  # type: ignore[assignment]
    return stats
