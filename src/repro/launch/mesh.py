"""Production mesh builders (functions, not constants — importing this module
never touches jax device state)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the `pod` axis rides
    DCN, `data`/`model` ride ICI."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (possibly fake) local devices exist —
    used by tests that set XLA_FLAGS=--xla_force_host_platform_device_count."""
    return jax.make_mesh((data, model), ("data", "model"))
