import os
if not os.environ.get("REPRO_DRYRUN_NO_DEVICE_OVERRIDE"):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=512")
# ^ MUST run before any other import so jax sees 512 placeholder devices.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces (written incrementally to --out as JSON):
  * compiled.memory_analysis()  — bytes per device (proves it fits)
  * compiled.cost_analysis()    — HLO FLOPs / bytes for §Roofline
  * collective_bytes            — parsed from the post-SPMD HLO text
  * wall seconds spent lowering / compiling

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma_2b \
      --shape train_4k --mesh single --out experiments/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import (ARCH_IDS, ShapeCell, cells_for, get_config,
                           SHAPES)
from repro.launch import shardings as sh
from repro.launch.hlo_stats import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (abstract_train_state, make_decode_step,
                                make_prefill_step, make_train_step)
from repro.models.model import build_model
from repro.optim.adamw import AdamWConfig
from repro.sharding import use_mesh


def input_specs(cfg, shape: ShapeCell) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    b = shape.global_batch
    s = shape.seq_len if shape.kind != "decode" else 1
    f32, i32 = jnp.float32, jnp.int32
    batch: Dict[str, Any] = {}
    if cfg.embeds_input:
        batch["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                               jnp.bfloat16)
    else:
        batch["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
    if shape.kind == "train":
        batch["labels"] = jax.ShapeDtypeStruct((b, s), i32)
    if cfg.encoder is not None:
        batch["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder.n_frames, cfg.d_model), jnp.bfloat16)
    if cfg.pos_emb == "mrope":
        batch["positions3"] = jax.ShapeDtypeStruct((3, b, s), i32)
    return batch


def _moment_dtype(cfg) -> str:
    return "bfloat16" if cfg.param_count() > 2e11 else "float32"


def default_microbatches(cfg, shape: ShapeCell, n_data: int) -> int:
    """Grad-accumulation factor sized so the per-chip saved residual carry
    (L x T_mb x d x ~6B: bf16 + the XLA-CPU f32 duplicate) stays ~<= 6GB."""
    if shape.kind != "train":
        return 1
    t_loc = shape.global_batch * shape.seq_len // max(n_data, 1)
    batch_loc = max(1, shape.global_batch // max(n_data, 1))
    mb = 1
    while mb < batch_loc:
        carry = cfg.n_layers * (t_loc / mb) * cfg.d_model * 6
        if carry <= 6e9:
            break
        mb *= 2
    return mb


def run_cell(arch: str, shape: ShapeCell, multi_pod: bool,
             overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    overrides = overrides or {}
    cfg = get_config(arch)
    if "capacity_factor" in overrides and cfg.moe is not None:
        import dataclasses as _dc
        cfg = cfg.replace(moe=_dc.replace(
            cfg.moe, capacity_factor=float(overrides["capacity_factor"])))
    if "q_chunk" in overrides:
        from repro.models import attention as _attn
        _attn.DEFAULT_Q_CHUNK_OVERRIDE = int(overrides["q_chunk"])
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    seq_shard = shape.name == "long_500k"
    rules = sh.arch_rules(cfg, mesh, shape.kind, seq_shard_carry=seq_shard)
    rules.update(overrides.get("rules", {}))
    model = build_model(
        cfg,
        attn_impl=overrides.get("attn_impl", "chunked"),
        remat_policy=overrides.get("remat_policy", "full"),
        loss_chunk=overrides.get("loss_chunk", 2048))
    opt_cfg = AdamWConfig(moment_dtype=_moment_dtype(cfg))
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape.name, "mesh": "multi" if multi_pod
        else "single", "chips": n_chips, "rules": {k: str(v) for k, v
                                                   in rules.items()},
        "overrides": {k: str(v) for k, v in overrides.items()},
    }

    from contextlib import ExitStack
    from repro.models.runtime_flags import set_unroll_scans
    stack = ExitStack()
    if overrides.get("unroll", False):
        # optional: unrolled scans => XLA's own cost_analysis counts every
        # layer once (used to validate the rolled-program HLO parser)
        stack.enter_context(set_unroll_scans(True))
    with stack, use_mesh(mesh, rules):
        batch_abs = input_specs(cfg, shape)
        batch_sh = sh.batch_shardings(batch_abs, mesh, rules)
        t0 = time.time()
        if shape.kind == "train":
            params_abs, opt_abs = abstract_train_state(model, opt_cfg)
            params_sh = sh.params_shardings(cfg, params_abs, mesh, rules)
            opt_sh = sh.opt_state_shardings(opt_abs, params_sh, mesh)
            n_data = n_chips // mesh.shape.get("model", 1)
            mb = overrides.get("microbatches",
                               default_microbatches(cfg, shape, n_data))
            rec["microbatches"] = mb
            step = make_train_step(model, opt_cfg, microbatches=mb)
            jitted = jax.jit(step,
                             in_shardings=(params_sh, opt_sh, batch_sh),
                             out_shardings=(params_sh, opt_sh, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_abs, opt_abs, batch_abs)
        elif shape.kind == "prefill":
            params_abs = jax.eval_shape(
                lambda: model.init(jax.random.PRNGKey(0)))
            params_sh = sh.params_shardings(cfg, params_abs, mesh, rules)
            step = make_prefill_step(model, s_max=shape.seq_len)
            # cache outputs carry explicit shardings (seq-sharded kv)
            cache_out_abs = jax.eval_shape(step, params_abs, batch_abs)[0]
            cache_out_sh = sh.cache_shardings(cache_out_abs, mesh, rules)
            jitted = jax.jit(step, in_shardings=(params_sh, batch_sh),
                             out_shardings=(cache_out_sh, None))
            lowered = jitted.lower(params_abs, batch_abs)
        else:  # decode
            params_abs = jax.eval_shape(
                lambda: model.init(jax.random.PRNGKey(0)))
            params_sh = sh.params_shardings(cfg, params_abs, mesh, rules)
            cache_abs = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            if cfg.encoder is not None:
                cache_abs["enc_out"] = jax.ShapeDtypeStruct(
                    (shape.global_batch, cfg.encoder.n_frames, cfg.d_model),
                    jnp.bfloat16)
            cache_sh = sh.cache_shardings(cache_abs, mesh, rules)
            step = make_decode_step(model)
            jitted = jax.jit(step,
                             in_shardings=(params_sh, cache_sh, batch_sh),
                             out_shardings=(cache_sh, None),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_abs, cache_abs, batch_abs)
        rec["lower_s"] = round(time.time() - t0, 2)

        t0 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 2)

        mem = compiled.memory_analysis()
        if mem is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                v = getattr(mem, k, None)
                if v is not None:
                    rec[k] = int(v)
            args_b = rec.get("argument_size_in_bytes", 0)
            alias_b = rec.get("alias_size_in_bytes", 0)
            peak = (args_b + rec.get("output_size_in_bytes", 0)
                    + rec.get("temp_size_in_bytes", 0) - alias_b)
            rec["per_device_peak_bytes"] = int(peak)
        cost = compiled.cost_analysis()
        if cost:
            c = cost if isinstance(cost, dict) else cost[0]
            rec["hlo_flops"] = float(c.get("flops", -1))
            rec["hlo_bytes"] = float(c.get("bytes accessed", -1))
            rec["cost_keys"] = sorted(k for k in c.keys())[:40]
        hlo = compiled.as_text()
        stats = analyze_hlo(hlo, world=n_chips)
        rec["collectives"] = {k: v for k, v in stats.items()
                              if isinstance(v, dict)}
        rec["total_wire_bytes"] = stats["total_wire_bytes"]
        rec["dot_flops"] = stats["dot_flops"]          # expanded, per chip
        rec["result_bytes"] = stats["result_bytes"]    # expanded, per chip
        rec["dot_bytes"] = stats["dot_bytes"]          # HBM-traffic proxy
        rec["hlo_len"] = len(hlo)
        # MODEL_FLOPS: 6*N_active*D for train (fwd+bwd), 2*N_active*D for
        # forward-only prefill/decode
        mult = 6.0 if shape.kind == "train" else 2.0
        tokens = shape.global_batch * (shape.seq_len
                                       if shape.kind != "decode" else 1)
        rec["model_flops_global"] = mult * cfg.active_param_count() * tokens
        if overrides.get("save_hlo", True):
            import gzip
            out_dir = overrides.get("hlo_dir", "experiments/hlo")
            os.makedirs(out_dir, exist_ok=True)
            tag = overrides.get("tag", "baseline")
            fname = (f"{arch}.{shape.name}."
                     f"{'multi' if multi_pod else 'single'}.{tag}.hlo.gz")
            with gzip.open(os.path.join(out_dir, fname), "wt") as f:
                f.write(hlo)
            rec["hlo_path"] = os.path.join(out_dir, fname)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=("single", "multi",
                                                       "both"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--overrides", default=None,
                    help="JSON dict of overrides (perf iterations)")
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args()

    overrides = json.loads(args.overrides) if args.overrides else {}
    os.makedirs(args.out, exist_ok=True)

    if args.all:
        todo = [(a, s) for a in ARCH_IDS for s in cells_for(a)]
    else:
        assert args.arch and args.shape
        cell = next(s for s in SHAPES if s.name == args.shape)
        todo = [(args.arch, cell)]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    n_fail = 0
    for arch, cell in todo:
        for multi in meshes:
            mesh_name = "multi" if multi else "single"
            name = f"{arch}.{cell.name}.{mesh_name}.{args.tag}"
            path = os.path.join(args.out, name + ".json")
            if os.path.exists(path):
                print(f"[skip] {name} (exists)")
                continue
            print(f"[run ] {name}", flush=True)
            try:
                rec = run_cell(arch, cell, multi, overrides)
                rec["status"] = "ok"
            except Exception as e:  # noqa: BLE001
                rec = {"arch": arch, "shape": cell.name, "mesh": mesh_name,
                       "status": "fail", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]}
                n_fail += 1
                print(f"[FAIL] {name}: {e}", flush=True)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            if rec["status"] == "ok":
                print(f"[ ok ] {name} lower={rec['lower_s']}s "
                      f"compile={rec['compile_s']}s "
                      f"flops={rec.get('hlo_flops', 0):.3g} "
                      f"peakB={rec.get('per_device_peak_bytes', 0):.3g}",
                      flush=True)
    print(f"done; failures={n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
