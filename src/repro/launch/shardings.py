"""Parameter / batch / cache sharding rules (logical axes -> mesh).

Every parameter leaf gets logical axis names from its tree path; the mapping
logical->physical is divisibility-aware (repro.sharding), which implements
the per-arch TP policy automatically: e.g. gemma's 8 q-heads on a 16-way
model axis simply stay replicated while its 16384-wide d_ff shards.

Per-shape overrides:
  * long-context decode ("long_500k") shards the KV-cache sequence over the
    data axis (split-KV decode) since batch=1 leaves data idle otherwise.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.sharding import DEFAULT_RULES, logical_to_physical, use_mesh

# logical axes per param name (applied to the trailing dims; stacked stage
# params get a leading "layers"=None axis automatically)
_PARAM_AXES: Dict[str, Tuple[Optional[str], ...]] = {
    "embed": ("vocab", "embed"),
    "lm_head": ("vocab", "embed"),
    "pos_embed": (None, "embed"),
    "enc_pos": (None, "embed"),
    # attention
    "wq": ("fsdp", "qkv"),
    "wk": ("fsdp", "kv_qkv"),
    "wv": ("fsdp", "kv_qkv"),
    "wo": ("qkv", "fsdp"),
    "bq": ("qkv",), "bk": ("kv_qkv",), "bv": ("kv_qkv",),
    # MLA
    "wq_a": ("fsdp", None),
    "wq_b": (None, "qkv"),
    "wkv_a": ("fsdp", None),
    "wkv_b": (None, "qkv"),
    # MLP
    "w1": ("fsdp", "ffn"),
    "w3": ("fsdp", "ffn"),
    "w2": ("ffn", "fsdp"),
    "b1": ("ffn",), "b2": (None,),
    # MoE (leading experts dim; shard_map expects P("model", fsdp, None))
    "router": ("fsdp", None),
    # mamba
    "in_proj": ("fsdp", "ffn"),
    "out_proj": ("ffn", "fsdp"),
    "conv_w": (None, None), "conv_b": (None,),
    "A_log": (None,), "D": (None,), "dt_bias": (None,), "norm_w": (None,),
    # norms
    "w": (None,), "b": (None,),
}

_MOE_AXES: Dict[str, Tuple[Optional[str], ...]] = {
    "w1": ("experts", "fsdp", None),
    "w3": ("experts", "fsdp", None),
    "w2": ("experts", "fsdp", None),
}


def _leaf_axes(path: Tuple, leaf) -> Tuple[Optional[str], ...]:
    names = [getattr(k, "key", getattr(k, "name", None)) for k in path
             if not hasattr(k, "idx")]
    name = names[-1] if names else None
    in_moe = "moe" in names
    in_stages = any(n in ("stages", "enc_stages") for n in names)
    if in_moe and name in _MOE_AXES:
        axes = _MOE_AXES[name]
    elif name in _PARAM_AXES:
        axes = _PARAM_AXES[name]
    else:
        axes = (None,) * leaf.ndim
    lead = leaf.ndim - len(axes)
    if in_stages and lead >= 1:
        axes = ("layers",) * lead + axes
    elif lead > 0:
        axes = (None,) * lead + axes
    if len(axes) != leaf.ndim:
        axes = (None,) * leaf.ndim
    return axes


def arch_rules(cfg: ModelConfig, mesh: Mesh, shape_kind: str = "train",
               seq_shard_carry: bool = False) -> Dict[str, Any]:
    """Per-(arch, shape) logical->physical rules."""
    rules = dict(DEFAULT_RULES)
    tp = mesh.shape.get("model", 1)
    # attention TP only when head counts divide (replicated otherwise)
    if cfg.n_heads % max(tp, 1) != 0:
        rules["qkv"] = None
    if cfg.n_kv_heads % max(tp, 1) != 0:
        rules["kv_qkv"] = None
    else:
        rules["kv_qkv"] = "model"
    if cfg.mla is not None:
        # MLA q/kv up-projections are (lora, H*dim): shard over heads dim
        rules["qkv"] = "model" if cfg.n_heads % max(tp, 1) == 0 else None
    if shape_kind in ("decode", "prefill"):
        # none of the assigned archs' kv-head counts divide a 16-way model
        # axis, so the cache's big axis is SEQUENCE: shard it over model
        # (split-KV attention; XLA combines the partial softmaxes)
        rules["kv_seq"] = "model"
    if shape_kind == "decode" and seq_shard_carry:
        # long-context (batch=1): data is idle too — put it on the sequence
        rules["kv_seq"] = ("data", "model")
        rules["batch"] = None
    return rules


def params_shardings(cfg: ModelConfig, params_abstract, mesh: Mesh,
                     rules: Dict[str, Any]):
    """Pytree of NamedShardings matching params_abstract."""
    with use_mesh(mesh, rules):
        def one(path, leaf):
            axes = _leaf_axes(path, leaf)
            return NamedSharding(mesh, logical_to_physical(axes, leaf.shape))
        return jax.tree_util.tree_map_with_path(one, params_abstract)


def batch_shardings(batch_abstract, mesh: Mesh, rules: Dict[str, Any]):
    with use_mesh(mesh, rules):
        def one(path, leaf):
            name = getattr(path[-1], "key", None)
            if name == "positions3":
                axes = (None, "batch", None)
            elif leaf.ndim == 2:
                axes = ("batch", None)
            else:
                axes = ("batch",) + (None,) * (leaf.ndim - 1)
            return NamedSharding(mesh, logical_to_physical(axes, leaf.shape))
        return jax.tree_util.tree_map_with_path(one, batch_abstract)


_CACHE_AXES: Dict[str, Tuple[Optional[str], ...]] = {
    # stacked over the stage's repeat dim ("layers") by stage_cache
    "k": ("layers", "batch", "kv_seq", "kv_heads", None),
    "v": ("layers", "batch", "kv_seq", "kv_heads", None),
    "ckv": ("layers", "batch", "kv_seq", None),
    "krope": ("layers", "batch", "kv_seq", None),
    "ssm": ("layers", "batch", "heads", None, None),
    "conv": ("layers", "batch", None, None),
    "len": ("layers",),
    "enc_out": ("batch", None, None),
}


def cache_shardings(cache_abstract, mesh: Mesh, rules: Dict[str, Any]):
    """KV caches: (layers, B, S, H, D) / (layers, B, S, C) / ssm states."""
    with use_mesh(mesh, rules):
        def one(path, leaf):
            names = [getattr(k, "key", getattr(k, "name", None))
                     for k in path]
            name = next((n for n in reversed(names) if n in _CACHE_AXES),
                        None)
            axes = _CACHE_AXES.get(name, (None,) * leaf.ndim)
            if len(axes) != leaf.ndim:
                axes = (None,) * leaf.ndim
            return NamedSharding(mesh, logical_to_physical(axes, leaf.shape))
        return jax.tree_util.tree_map_with_path(one, cache_abstract)


def opt_state_shardings(opt_abstract, params_shard_tree, mesh: Mesh):
    """m/v/master inherit the param shardings; step is replicated."""
    def like(p_sh):
        return p_sh

    return {
        "step": NamedSharding(mesh, P()),
        "master": jax.tree.map(like, params_shard_tree),
        "m": jax.tree.map(like, params_shard_tree),
        "v": jax.tree.map(like, params_shard_tree),
    }
