"""jit-able train / prefill / decode step factories shared by the trainer,
the server, and the dry-run."""

from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import LM
from repro.optim.adamw import AdamWConfig, apply_updates, init_state


def make_train_step(model: LM, opt_cfg: AdamWConfig,
                    microbatches: int = 1) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    With microbatches > 1, gradients are accumulated over a scan of
    microbatch slices (grad-accumulation in fp32 of the grad dtype)."""

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split(path, x):
                name = getattr(path[-1], "key", None)
                ax = 1 if name == "positions3" else 0  # (3, B, S) batch axis
                n = x.shape[ax] // microbatches
                moved = jnp.moveaxis(x, ax, 0)
                split_ = moved.reshape((microbatches, n) + moved.shape[1:])
                return jnp.moveaxis(split_, 1, ax + 1)

            mb = jax.tree_util.tree_map_with_path(split, batch)

            def acc(carry, b):
                tot, g = carry
                l, gi = jax.value_and_grad(loss_fn)(params, b)
                return (tot + l, jax.tree.map(jnp.add, g, gi)), None

            from repro.models import runtime_flags
            zero = jax.tree.map(jnp.zeros_like, params)
            (loss, grads), _ = jax.lax.scan(
                acc, (jnp.zeros((), jnp.float32), zero), mb,
                unroll=runtime_flags.scan_unroll_arg(microbatches))
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        new_params, new_state, metrics = apply_updates(params, grads,
                                                       opt_state, opt_cfg)
        metrics["loss"] = loss
        return new_params, new_state, metrics

    return step


def make_prefill_step(model: LM, s_max: int) -> Callable:
    def step(params, batch):
        return model.prefill(params, batch, s_max)
    return step


def make_decode_step(model: LM) -> Callable:
    def step(params, cache, batch):
        return model.decode_step(params, cache, batch)
    return step


def abstract_train_state(model: LM, opt_cfg: AdamWConfig
                         ) -> Tuple[Any, Any]:
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    opt = jax.eval_shape(lambda p: init_state(p, opt_cfg), params)
    return params, opt
