"""Training driver: --arch/--shape selectable, fault-tolerant, resumable.

On this container it runs real steps single-device at reduced scale
(examples/train_100m.py drives it); on a TPU fleet the same entry point runs
under the production mesh (launch/mesh.py) — the step function, checkpoint
layout, and data pipeline are identical.
"""

from __future__ import annotations

import argparse
import json
import logging
import os
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import telemetry
from repro.checkpoint import ckpt as ckpt_lib
from repro.checkpoint.ckpt import AsyncCheckpointer
from repro.configs import get_config, get_reduced
from repro.data.pipeline import DataConfig, batch_kwargs_for, synthetic_batch
from repro.launch import shardings as sh
from repro.launch.steps import make_train_step
from repro.models.model import build_model
from repro.optim.adamw import AdamWConfig, init_state
from repro.runtime.chaos import FaultPlan
from repro.runtime.fault_tolerance import (FaultConfig, StragglerMonitor,
                                           declare_donation,
                                           run_with_recovery)
from repro.sharding import use_mesh

log = logging.getLogger("repro.train")


def train(arch: str, *, steps: int = 100, seq_len: int = 256,
          global_batch: int = 8, reduced: bool = True,
          ckpt_dir: Optional[str] = None, checkpoint_every: int = 50,
          mesh=None, rules: Optional[Dict] = None, lr: float = 3e-4,
          microbatches: int = 1, log_every: int = 10,
          failure_injector=None, seed: int = 0,
          remat_policy: str = "none",
          chaos: Optional[FaultPlan] = None,
          tuning=None) -> Dict[str, Any]:
    """Returns final metrics dict.  Deterministic given (arch, seed, steps)
    — including under an injected fault schedule (`chaos`, or the
    ``REPRO_CHAOS`` env hook when None): recovery restores the latest
    *valid* checkpoint and replays, so the final state is bit-equal to a
    fault-free run.

    ``tuning``: a started-or-not `repro.tuning.SpecController`, True for a
    default one, or None to consult the ``REPRO_TUNING`` env hook.  The
    controller is stepped once per training step (guarded live-spec
    updates from the run's own drift telemetry) and stopped on exit; the
    spec steers dispatch selection only, so tuned metrics/losses stay
    bit-equal to untuned runs."""
    cfg = get_reduced(arch) if reduced else get_config(arch)
    model = build_model(cfg, attn_impl="chunked", remat_policy=remat_policy,
                        loss_chunk=2048)
    opt_cfg = AdamWConfig(lr=lr, warmup_steps=min(20, steps // 5 + 1),
                          total_steps=steps)
    data_cfg = DataConfig(seq_len=seq_len, global_batch=global_batch,
                          vocab_size=cfg.vocab_size, seed=seed)
    bkw = batch_kwargs_for(cfg)

    params = model.init(jax.random.PRNGKey(seed))
    opt_state = init_state(params, opt_cfg)
    step_fn = jax.jit(make_train_step(model, opt_cfg,
                                      microbatches=microbatches),
                      donate_argnums=(0, 1))

    # step_fn DONATES its inputs, so the initial buffers are consumed by
    # step 0 — a post-failure scratch restart must rebuild state, not
    # reuse them.  First call hands out the arrays built above; later
    # calls re-init deterministically from the same seed.
    _first_init = [(params, opt_state)]

    def fresh_state():
        if _first_init:
            return _first_init.pop()
        p = model.init(jax.random.PRNGKey(seed))
        return p, init_state(p, opt_cfg)

    saver = AsyncCheckpointer(ckpt_dir, keep=3) if ckpt_dir else None
    monitor = StragglerMonitor(n_hosts=1, cfg=FaultConfig())
    history = []

    def one_step(step: int, state):
        params, opt_state = state
        batch = synthetic_batch(data_cfg, step, **bkw)
        t0 = time.time()
        # the span is the per-step profiler hook: wall_s lands in the event
        # stream, and under enable(annotate=True) the step also shows up as
        # a named range in a jax.profiler trace
        with telemetry.annotation(f"train.step/{step}"), \
                telemetry.span("train.step", step=step, arch=arch):
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
        dt = time.time() - t0
        monitor.record(0, dt)
        if step % log_every == 0 or step == steps - 1:
            log.info("step %4d loss=%.4f lr=%.2e gnorm=%.3f %.2fs",
                     step, metrics["loss"], metrics["lr"],
                     metrics["grad_norm"], dt)
            history.append({"step": step, **metrics, "sec": dt})
        return params, opt_state

    # donation metadata travels with the callable: the state argument's
    # buffers are consumed each call (the inner jit donates params+opt), so
    # recovery and the static linter (rule A004) can verify that the
    # init_state handed over below is a factory, not a captured value
    one_step = declare_donation(one_step, (1,))

    controller = _resolve_tuning(tuning)
    if controller is not None:
        controller.start()
        # wrap_step preserves the donation metadata declared above
        one_step = controller.wrap_step(one_step)

    def save_fn(step: int, state):
        if saver is not None:
            saver.save_async(step, {"params": state[0], "opt": state[1]},
                             extra={"arch": arch, "seed": seed})

    def restore_fn():
        if not ckpt_dir:
            return None
        # if the saver's background thread died mid-write, surface it here
        # (and drop the torn step on the floor: restore_latest_valid walks
        # straight past it to the newest checkpoint that checksums clean)
        if saver is not None:
            try:
                saver.wait()
            except Exception as e:  # noqa: BLE001 — recovery handles it
                log.warning("async save failed (%s); restoring the newest "
                            "valid step instead", e)
        like = {"params": params, "opt": opt_state}
        got = ckpt_lib.restore_latest_valid(ckpt_dir, like)
        if got is None:
            return None
        last, tree, _extra = got
        return last, (tree["params"], tree["opt"])

    fault_cfg = FaultConfig(checkpoint_every=checkpoint_every)
    ctx = use_mesh(mesh, rules or {}) if mesh is not None else _null_ctx()
    # elastic adoption: every restored state re-lands its live AtomicTables
    # on the CURRENT mesh (layout re-derivation, not history replay); a
    # table-free state tree passes through untouched
    reshard_fn = None
    if mesh is not None:
        from repro.runtime.elastic import reshard_tables
        reshard_fn = lambda s: reshard_tables(s, mesh)  # noqa: E731
    try:
        with ctx:
            result = run_with_recovery(one_step, fresh_state, steps,
                                       fault_cfg, save_fn, restore_fn,
                                       failure_injector=failure_injector,
                                       reshard_fn=reshard_fn, chaos=chaos)
    finally:
        if controller is not None:
            controller.stop()        # detach, clear live spec, persist
    if saver is not None:
        saver.wait()
    out = {"history": history, "steps_done": result.steps_done,
           "failures": result.failures,
           "backoff_total_s": result.backoff_total_s,
           "final_loss": history[-1]["loss"] if history else None}
    if controller is not None:
        out["tuning"] = controller.stats()
    return out


def _resolve_tuning(tuning):
    """None → the REPRO_TUNING env hook; True → a default controller;
    a SpecController instance passes through."""
    if tuning is None:
        from repro.tuning import from_env
        return from_env()
    if tuning is True:
        from repro.tuning import SpecController
        return SpecController()
    return tuning


class _null_ctx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


def main() -> None:
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--full", action="store_true",
                    help="full-size config (TPU fleet); default reduced")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--chaos", default=None, metavar="SPEC",
                    help="fault-injection spec, e.g. 'seed=7,step=0.05,"
                         "ckpt_save=0.1@2' (same syntax as REPRO_CHAOS)")
    ap.add_argument("--telemetry", default=None, metavar="SINK",
                    help="'ring' or a JSONL path: enable the repro.telemetry "
                         "event stream (same as REPRO_TELEMETRY); render a "
                         "capture with `python -m repro.telemetry.report`")
    ap.add_argument("--tuning", nargs="?", const="on", default=None,
                    metavar="STATE",
                    help="run under a repro.tuning.SpecController (guarded "
                         "live HardwareSpec updates from the run's own "
                         "drift telemetry); optional value = state file the "
                         "tuned spec persists/restores through (same as "
                         "REPRO_TUNING)")
    ap.add_argument("--profile-annotations", action="store_true",
                    help="open jax.profiler.TraceAnnotation regions around "
                         "steps and atomics dispatch (needs --telemetry)")
    args = ap.parse_args()
    if args.telemetry:
        sink = (telemetry.RingBuffer() if args.telemetry == "ring"
                else telemetry.JsonlWriter(args.telemetry))
        telemetry.enable(sink, annotate=args.profile_annotations)
    else:
        telemetry.enable_from_env()
    chaos = FaultPlan.from_spec(args.chaos) if args.chaos else None
    tuning = None
    if args.tuning is not None:
        from repro.tuning import SpecController
        tuning = SpecController(
            state_path=None if args.tuning == "on" else args.tuning)
    try:
        out = train(args.arch, steps=args.steps, seq_len=args.seq_len,
                    global_batch=args.global_batch, reduced=not args.full,
                    ckpt_dir=args.ckpt_dir, lr=args.lr,
                    microbatches=args.microbatches, chaos=chaos,
                    tuning=tuning)
    finally:
        if telemetry.enabled():
            telemetry.disable()      # flush/close the JSONL capture
    print(json.dumps({k: v for k, v in out.items() if k != "history"}))


if __name__ == "__main__":
    main()
