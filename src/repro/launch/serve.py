"""Batched serving driver: continuous-batching loop over prefill + decode.

Requests arrive with different prompt lengths; the scheduler packs them into
a fixed-size decode batch (padding slots), prefills new requests into free
slots, and steps the whole batch one token at a time — the standard
batched-serving shape (decode_32k cell = one such step at scale).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.data.pipeline import batch_kwargs_for
from repro.models.model import build_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int = 16
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchServer:
    """Static-batch server (slots = batch size); greedy sampling."""

    def __init__(self, arch: str, *, reduced: bool = True, slots: int = 4,
                 s_max: int = 128, seed: int = 0):
        self.cfg = get_reduced(arch) if reduced else get_config(arch)
        self.model = build_model(self.cfg, attn_impl="ref",
                                 remat_policy="none", loss_chunk=1024)
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self.slots = slots
        self.s_max = s_max
        self.active: List[Optional[Request]] = [None] * slots
        self.caches: List[Any] = [None] * slots
        self._decode = jax.jit(self.model.decode_step)

    # one slot per request keeps per-request cache lengths exact; a
    # production deployment fuses slots into one batched cache (the
    # decode_32k dry-run cell models that shape)
    def submit(self, req: Request) -> bool:
        for i in range(self.slots):
            if self.active[i] is None:
                prompt = jnp.asarray([req.prompt], jnp.int32)
                cache, logits = self.model.prefill(
                    self.params, {"tokens": prompt}, self.s_max)
                tok = int(jnp.argmax(logits, -1)[0])
                req.out.append(tok)
                self.active[i] = req
                self.caches[i] = cache
                return True
        return False

    def step(self) -> int:
        """Advance every active request one token; returns #active."""
        n = 0
        for i, req in enumerate(self.active):
            if req is None:
                continue
            n += 1
            tok = jnp.asarray([[req.out[-1]]], jnp.int32)
            self.caches[i], logits = self._decode(self.params,
                                                  self.caches[i],
                                                  {"tokens": tok})
            nxt = int(jnp.argmax(logits, -1)[0])
            req.out.append(nxt)
            if len(req.out) >= req.max_new:
                req.done = True
                self.active[i] = None
                self.caches[i] = None
        return n

    def run(self, requests: List[Request]) -> Dict[str, Any]:
        t0 = time.time()
        pending = list(requests)
        done: List[Request] = []
        tokens = 0
        while pending or any(r is not None for r in self.active):
            while pending and self.submit(pending[0]):
                pending.pop(0)
            tokens += self.step()
            done = [r for r in requests if r.done]
        dt = time.time() - t0
        return {"requests": len(requests), "tokens": tokens,
                "wall_s": round(dt, 3),
                "tok_per_s": round(tokens / max(dt, 1e-9), 1),
                "completed": len(done)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma_2b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()
    server = BatchServer(args.arch)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, server.cfg.vocab_size,
                                        rng.integers(4, 16)).tolist(),
                    max_new=args.max_new)
            for i in range(args.requests)]
    print(json.dumps(server.run(reqs)))


if __name__ == "__main__":
    main()
