"""Typed RMW op constructors — the declarative half of the atomics API.

Each class is one *batch* of same-kind ops: ``indices[i]`` names the table
slot the i-th op targets and ``values[i]`` its operand.  Semantics (all
serialized-equivalent, in batch order):

``Faa``   fetched = old, slot += value
``Swp``   fetched = old, slot = value
``Min``   fetched = old, slot = min(old, value)
``Max``   fetched = old, slot = max(old, value)
``Cas``   fetched = old; slot = value iff old == expected (success), else
          unchanged (failure).  ``expected`` is either one shared scalar
          (the combinable form: BFS set-if-unvisited, dispatch claims) or a
          per-op array (the paper's "wasted work" case — priority CAS —
          which executes on the serialized oracle, locally and across
          shards).

Ops are registered pytrees, so they can cross ``jit``/``shard_map``
boundaries like any other JAX value.
"""

from __future__ import annotations

from typing import ClassVar, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def _as_1d(name: str, x) -> Array:
    x = jnp.asarray(x)
    if x.ndim != 1:
        raise ValueError(f"{name} must be 1-D, got shape {x.shape}")
    return x


class AtomicOp:
    """Base class: one batch of same-kind RMW ops against one table."""

    kind: ClassVar[str] = ""
    #: Herlihy consensus number of the primitive (arxiv 1802.03844): FAA /
    #: SWP / MIN / MAX solve 2-process consensus, CAS solves n-process
    #: (``inf``).  Machine-readable contract annotation the strength lint
    #: (repro.analysis rule A002) cites: when a CAS batch's update pattern
    #: is expressible by a consensus-2 primitive, the downgrade is free
    #: correctness margin — the paper's "pick the simplest correct one".
    CONSENSUS_NUMBER: ClassVar[float] = 2
    __slots__ = ("indices", "values")

    def __init__(self, indices, values):
        self.indices = _as_1d("indices", indices)
        self.values = _as_1d("values", values)
        if self.indices.shape[0] != self.values.shape[0]:
            raise ValueError(
                f"indices and values disagree on batch size: "
                f"{self.indices.shape[0]} vs {self.values.shape[0]}")

    def __repr__(self):
        return (f"{type(self).__name__}(n={self.indices.shape[0]}, "
                f"dtype={self.values.dtype})")

    # --- contract hooks the executor reads -------------------------------
    @property
    def expected(self) -> Optional[Array]:
        return None

    @property
    def uniform_expected(self) -> bool:
        """True when the op batch is combinable (non-CAS, scalar expected)."""
        return True

    # --- pytree protocol --------------------------------------------------
    def tree_flatten(self) -> Tuple[tuple, None]:
        return (self.indices, self.values), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        obj = object.__new__(cls)
        obj.indices, obj.values = children
        return obj


@jax.tree_util.register_pytree_node_class
class Faa(AtomicOp):
    """Fetch-and-add: slot += value, fetched = pre-op value."""

    kind: ClassVar[str] = "faa"
    __slots__ = ()


@jax.tree_util.register_pytree_node_class
class Swp(AtomicOp):
    """Swap: slot = value, fetched = pre-op value (last collider wins)."""

    kind: ClassVar[str] = "swp"
    __slots__ = ()


@jax.tree_util.register_pytree_node_class
class Min(AtomicOp):
    """Atomic min: slot = min(slot, value), fetched = pre-op value."""

    kind: ClassVar[str] = "min"
    __slots__ = ()


@jax.tree_util.register_pytree_node_class
class Max(AtomicOp):
    """Atomic max: slot = max(slot, value), fetched = pre-op value."""

    kind: ClassVar[str] = "max"
    __slots__ = ()


@jax.tree_util.register_pytree_node_class
class Cas(AtomicOp):
    """Compare-and-swap: slot = value iff slot == expected.

    ``expected`` may be a scalar (one shared expected value — the combinable
    first-wins form every backend supports) or a per-op array of the same
    length as ``values`` (serialized-oracle semantics; supported locally and
    across shards via the owner-side oracle pass).
    """

    kind: ClassVar[str] = "cas"
    CONSENSUS_NUMBER: ClassVar[float] = float("inf")
    __slots__ = ("_expected",)

    def __init__(self, indices, values, *, expected):
        super().__init__(indices, values)
        if expected is None:
            raise ValueError("Cas requires `expected`")
        exp = jnp.asarray(expected)
        if exp.ndim not in (0, 1):
            raise ValueError(
                f"expected must be a scalar or 1-D, got shape {exp.shape}")
        if exp.ndim == 1 and exp.shape[0] != self.values.shape[0]:
            raise ValueError(
                f"per-op expected disagrees with batch size: "
                f"{exp.shape[0]} vs {self.values.shape[0]}")
        self._expected = exp

    @property
    def expected(self) -> Array:
        return self._expected

    @property
    def uniform_expected(self) -> bool:
        return jnp.ndim(self._expected) == 0

    def tree_flatten(self):
        return (self.indices, self.values, self._expected), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        obj = object.__new__(cls)
        obj.indices, obj.values, obj._expected = children
        return obj


#: canonical op-kind -> constructor map (the single home for it — benchmarks
#: and tests build ops from legacy op strings through this).  ``Cas`` takes
#: its extra ``expected=`` keyword; the rest are (indices, values).
OP_KINDS = {"faa": Faa, "swp": Swp, "min": Min, "max": Max, "cas": Cas}
