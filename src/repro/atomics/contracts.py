"""Contract observation hooks: how the static analyzer sees the atomics API.

A jaxpr records *primitives*, not API calls — by the time `execute` has
dispatched, the trace contains scatters and collectives with no marker
saying "this one went through the sanctioned front-end" or "this table
declared axis='model'".  This module is that marker: the atomics entry
points (`execute`, `execute_until`, `AtomicTable.__init__`) call
:func:`notify` with their call-site contract (table, op, tier arguments),
and an installed observer — `repro.analysis` during a `check()` trace —
records them alongside the jaxpr variables the arguments trace to.

Cost discipline (same pattern as `repro.telemetry`): the hot-path guard is
one module-global (``_observer is None``), so production dispatch pays a
single attribute read per call when no analysis is running.  Observer
exceptions are swallowed into :data:`_errors` — observation must never
change what the observed code does — and the analysis session surfaces
them as findings instead of crashing the trace.
"""

from __future__ import annotations

import contextlib
import traceback
from typing import Any, Callable, Dict, List, Optional, Tuple

#: the one hot-path guard; installed by :func:`observe`
_observer: Optional[Callable[[str, Dict[str, Any]], None]] = None

#: exceptions raised *by the observer* (never propagated into dispatch);
#: drained by the analysis session at the end of a trace
_errors: List[str] = []

#: path fragments naming the sanctioned RMW implementation modules — a
#: scatter whose source frames include one of these came from the engine
#: itself, not from user code bypassing it.  `repro.analysis.rules` is the
#: consumer; the list lives here because it IS the contract ("these modules
#: may touch table memory directly").
SANCTIONED_PATHS: Tuple[str, ...] = (
    "repro/core/rmw",          # rmw.py, rmw_engine.py, rmw_sharded.py
    "repro/atomics/",          # front-end, retry, reshard internals
    "repro/kernels/rmw",       # the Pallas kernel
)


def active() -> bool:
    """True while an analysis observer is installed."""
    return _observer is not None


def notify(site: str, **fields) -> None:
    """Report one contract event to the installed observer (if any).

    ``site`` ∈ {"table", "execute", "execute_until"}; ``fields`` carry the
    live API objects (the observer reads tracer→var mappings off them at
    trace time).  Never raises, never mutates its arguments.
    """
    cb = _observer
    if cb is None:
        return
    try:
        cb(site, fields)
    except Exception:  # noqa: BLE001 — observation must not break dispatch
        _errors.append(traceback.format_exc())


@contextlib.contextmanager
def observe(callback: Callable[[str, Dict[str, Any]], None]):
    """Install ``callback`` as the contract observer for the scope; yields
    the list collecting observer-side errors (drained on exit)."""
    global _observer, _site_counter
    prev = _observer
    _observer = callback
    _site_counter = 0
    _errors.clear()
    try:
        yield _errors
    finally:
        _observer = prev


#: name of the identity primitive :func:`mark` injects — the bridge between
#: an API-level observation ("this array is an AtomicTable's data", "these
#: are a Cas batch's operands") and the jaxpr the analyzer walks afterwards.
#: Trace-internal `Var` objects do NOT survive jax's literal-inlining clone
#: pass, so tagging lineage *in the dataflow itself* is the only identity
#: that reaches the final jaxpr.
MARKER = "atomics_lint_marker"

_marker_p = None
_site_counter = 0


def _get_marker():
    global _marker_p
    if _marker_p is None:
        from jax._src.core import Primitive
        from jax.interpreters import ad, batching, mlir

        p = Primitive(MARKER)
        p.def_impl(lambda x, **_: x)
        p.def_abstract_eval(lambda x, **_: x)
        # identity is linear: one rule covers both jvp and transpose, so
        # marked arrays pass through grad/vmap untouched
        ad.deflinear2(p, lambda ct, x, **kw: [ct])
        batching.defvectorized(p)
        try:
            mlir.register_lowering(p, lambda ctx, x, **kw: [x])
        except Exception:  # noqa: BLE001 — lowering never needed for trace
            pass
        _marker_p = p
    return _marker_p


def next_site() -> int:
    """Fresh id tying an `execute` observation to its marker equations."""
    global _site_counter
    _site_counter += 1
    return _site_counter


def mark(x, role: str, **params):
    """Pass ``x`` through the identity marker primitive (observer active
    only; no-op otherwise).  The resulting jaxpr equation carries ``role``
    (+ ``params``) so the rule engine identifies the array structurally —
    on concrete values the identity impl runs eagerly and nothing is
    recorded, which is exactly right: a constant is not trace dataflow."""
    if _observer is None or x is None:
        return x
    try:
        return _get_marker().bind(x, role=role, **params)
    except Exception:  # noqa: BLE001 — marking must never break dispatch
        _errors.append(traceback.format_exc())
        return x


def caller_site(skip: Tuple[str, ...] = ("repro/atomics/",
                                         "repro/analysis/",
                                         "/jax/", "/jax_", "jax/_src")
                ) -> Tuple[Optional[str], Optional[int]]:
    """(file, line) of the innermost stack frame outside the atomics /
    analysis / jax machinery — the user call site a finding should point
    at.  Best-effort: (None, None) when every frame is machinery."""
    for fr in reversed(traceback.extract_stack()):
        fname = fr.filename.replace("\\", "/")
        if any(s in fname for s in skip):
            continue
        if fname.startswith("<"):          # <string>, <stdin>
            continue
        return fr.filename, fr.lineno
    return None, None
