"""AtomicTable: the typed table handle the atomics executor operates on.

An :class:`AtomicTable` bundles the table array with its *distribution
contract*: which mesh axes shard it (owner-major: global slot ``g`` lives on
shard ``g // m_local``) and which axes replicate it (every replica holds the
same shard; writers on all replicas serialize replica-major).  ``axis=None``
means a purely local table.  The contract itself — owner arithmetic, replica
semantics, device-rank arrival order — is reified by
`repro.atomics.layout.TableLayout` (:meth:`AtomicTable.layout` derives it),
which is what checkpoints persist and `repro.atomics.reshard` re-derives
when the mesh changes.

The handle is a registered pytree whose only leaf is ``data``, so it passes
through ``jit`` / ``shard_map`` like a plain array while carrying the
sharding metadata in the (static) treedef — inside ``shard_map``, ``data``
is this device's local shard and ``axis`` still names the mesh axes, which
is exactly what the sharded executor needs.

:func:`make_table` is the sharding-aware constructor: with an active mesh
(``repro.sharding.use_mesh``) it places the array via the ``"rmw_table"``
logical-axis rule (`sharding.DEFAULT_RULES`) and records the resolved mesh
axes on the handle; without a mesh it returns a local table.
"""

from __future__ import annotations

from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro import sharding as shardlib
from repro.atomics import contracts as _contracts

Array = jax.Array
AxisNames = Union[str, Tuple[str, ...]]

#: the logical axis name RMW tables shard over (see sharding.DEFAULT_RULES)
TABLE_LOGICAL_AXIS = "rmw_table"


@jax.tree_util.register_pytree_node_class
class AtomicTable:
    """A 1-D table of atomic slots plus its mesh-distribution contract.

    Attributes:
      data:          the table array (inside ``shard_map``: the local shard).
      axis:          mesh axis name(s) the table is sharded over, or None
                     for a local table.
      replica_axes:  mesh axes over which the table is *replicated*; writers
                     on every replica serialize in replica-major order.
    """

    __slots__ = ("data", "axis", "replica_axes")

    def __init__(self, data: Array, *, axis: Optional[AxisNames] = None,
                 replica_axes: AxisNames = ()):
        data = jnp.asarray(data)
        if data.ndim != 1:
            raise ValueError(f"AtomicTable data must be 1-D, "
                             f"got shape {data.shape}")
        self.data = data
        self.axis = _norm_axes(axis)
        self.replica_axes = _norm_axes(replica_axes) or ()
        if _contracts._observer is not None:
            # fresh constructions only: with_data/tree_unflatten bypass
            # __init__, so each logical table announces itself once per
            # trace.  The data is routed through the identity marker
            # primitive so the final jaxpr carries the table lineage
            # structurally (trace-internal Vars do not survive jax's
            # literal-inlining clone); concrete data passes through
            # unchanged.
            self.data = _contracts.mark(self.data, role="table")
            _contracts.notify("table", table=self)
        if self.replica_axes and self.axis is None:
            # replica serialization is a property of the *sharded* executor;
            # accepting it on a local table would silently drop the
            # replica-major write contract (each replica would just apply
            # its own batch to its own copy).
            raise ValueError(
                "replica_axes requires axis: a table replicated over mesh "
                "axes must also name the axes it is sharded over (use "
                "axis=... ; for a purely local table drop replica_axes)")

    # --- conveniences -----------------------------------------------------
    @property
    def shape(self) -> Tuple[int, ...]:
        return self.data.shape

    @property
    def dtype(self):
        return self.data.dtype

    @property
    def is_sharded(self) -> bool:
        return self.axis is not None

    def with_data(self, data: Array) -> "AtomicTable":
        """Same distribution contract, new contents (functional update)."""
        new = object.__new__(AtomicTable)
        new.data = data
        new.axis = self.axis
        new.replica_axes = self.replica_axes
        return new

    def layout(self, mesh=None):
        """The table's :class:`~repro.atomics.layout.TableLayout` — the
        owner-major contract with concrete extents (``mesh`` defaults to
        the mesh of the array's sharding)."""
        from repro.atomics.layout import TableLayout
        return TableLayout.from_table(self, mesh=mesh)

    def __repr__(self):
        where = f"sharded over {self.axis!r}" if self.axis else "local"
        rep = f", replicated over {self.replica_axes!r}" \
            if self.replica_axes else ""
        return (f"AtomicTable({self.data.shape[0]} x {self.data.dtype}, "
                f"{where}{rep})")

    # --- pytree protocol --------------------------------------------------
    def tree_flatten(self):
        return (self.data,), (self.axis, self.replica_axes)

    @classmethod
    def tree_unflatten(cls, aux, children):
        new = object.__new__(cls)
        new.data = children[0]
        new.axis, new.replica_axes = aux
        return new


def _norm_axes(axis) -> Optional[AxisNames]:
    if axis is None:
        return None
    if isinstance(axis, str):
        return axis
    return tuple(axis)


def make_table(num_slots: int, dtype=jnp.int32, *, fill=0,
               logical: str = TABLE_LOGICAL_AXIS,
               replica_axes: AxisNames = ()) -> AtomicTable:
    """Build a table, sharded per the active mesh's ``"rmw_table"`` rule.

    With a mesh installed (``sharding.use_mesh``), the array is placed with
    ``named_sharding((logical,), ...)`` — owner-major over the mesh axes the
    rule resolves to (dropped when ``num_slots`` does not divide them, like
    every logical-axis hint) — and the handle records those axes so
    `repro.atomics.execute` can route through the sharded tier inside
    ``shard_map``.  Without a mesh this is a plain local table.
    """
    data = jnp.full((num_slots,), fill, dtype)
    mesh_axis = None
    if shardlib.active_mesh() is not None:
        ns = shardlib.named_sharding((logical,), (num_slots,))
        mesh_axis = ns.spec[0] if len(ns.spec) >= 1 else None
        if mesh_axis is not None:
            data = jax.device_put(data, ns)
    if replica_axes and mesh_axis is None:
        raise ValueError(
            f"replica_axes={replica_axes!r} cannot be honoured: the "
            f"{logical!r} rule resolved to no mesh axes here (no active "
            f"mesh, or {num_slots} does not divide them), so the table "
            f"would be local and the replica-major write contract lost")
    return AtomicTable(data, axis=mesh_axis, replica_axes=replica_axes)
