"""Device-side contention statistics for the RMW tiers (PR 10).

The paper's central claim is that atomic cost is governed by the *state* of
the accessed line — how many writers collide on it — not by the primitive's
consensus number.  This module is the observable for that state: a small
``ContentionStats`` pytree of device arrays computed *inside* the existing
combine passes (the onehot backend's bincount scatter locally, the
``psum_scatter`` owner reduction on the sharded tier), returned alongside
results when callers opt in with ``collect_stats=``.

Everything here is pure jnp on already-materialized occupancy vectors, so it
traces cleanly inside ``jit`` / ``shard_map`` (PR-7 jit discipline: stats
stay device arrays; hosts only look at them at sync boundaries).

Layout:

* ``n_ops``          — () int32, in-range ops in the batch
* ``distinct_slots`` — () int32, slots touched at least once
* ``max_occupancy``  — () int32, writers on the hottest slot
* ``occupancy_hist`` — (HIST_BINS,) int32, occupied slots bucketed by
  ``floor(log2(occupancy))`` (bucket 0 = exactly 1 writer, bucket 1 = 2-3,
  bucket 2 = 4-7, ...; the top bucket absorbs the tail)
* ``topk_slots`` / ``topk_counts`` — (TOPK,) int32, hottest slot ids (global
  ids on the sharded tier) and their occupancy; ``-1`` slot id where fewer
  than TOPK slots are occupied
* ``level_ops_in`` / ``level_ops_out`` — (L,) int32, sharded tier only: ops
  entering each exchange level vs. combined representatives leaving it — the
  measured two-phase dedup factor.  ``L = 0`` on the local tier.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "HIST_BINS", "TOPK", "ContentionStats", "occupancy_hist", "topk_hot",
    "stats_from_occupancy", "stats_to_fields",
]

HIST_BINS = 16
TOPK = 8


class ContentionStats(NamedTuple):
    """Per-batch contention observables; every field is a device array."""

    n_ops: Any
    distinct_slots: Any
    max_occupancy: Any
    occupancy_hist: Any
    topk_slots: Any
    topk_counts: Any
    level_ops_in: Any
    level_ops_out: Any


def occupancy_hist(occ: Any) -> Any:
    """(HIST_BINS,) histogram of occupied slots by log2(occupancy) bucket.

    A (HIST_BINS, m) comparison matrix instead of a scatter: XLA CPU
    scatters serialize per element (~150ns each), while the dense mask sum
    vectorizes — ~2.3x cheaper at m=1024, and scatter-free inside the
    combine pass it rides in.
    """
    occ = occ.astype(jnp.int32)
    bucket = jnp.log2(jnp.maximum(occ, 1).astype(jnp.float32)).astype(jnp.int32)
    bucket = jnp.clip(bucket, 0, HIST_BINS - 1)
    # Unoccupied slots route to a sacrificial bin value that matches nothing.
    bucket = jnp.where(occ > 0, bucket, HIST_BINS)
    bins = jnp.arange(HIST_BINS, dtype=jnp.int32)
    return (bucket[None, :] == bins[:, None]).sum(axis=1, dtype=jnp.int32)


def topk_hot(occ: Any, slot_ids: Optional[Any] = None) -> Any:
    """Hottest TOPK slots of an occupancy vector.

    Returns ``(slots, counts)``: ``slots`` are positions in ``occ`` (or
    gathered from ``slot_ids`` when the vector carries non-trivial ids, e.g.
    owner-shard-local rows mapped to global slot numbers), ``-1`` where the
    corresponding count is zero.  TOPK unrolled argmax passes instead of
    ``lax.top_k`` — top_k sorts the whole vector (~4x the cost on CPU at
    m=1024) where eight masked reductions suffice.
    """
    occ = occ.astype(jnp.int32)
    if slot_ids is None:
        slot_ids = jnp.arange(occ.shape[0], dtype=jnp.int32)
    slot_ids = slot_ids.astype(jnp.int32)
    cur = occ
    slots, counts = [], []
    for _ in range(TOPK):
        p = jnp.argmax(cur)
        c = jnp.maximum(cur[p], 0)
        slots.append(jnp.where(c > 0, slot_ids[p], -1))
        counts.append(c)
        cur = cur.at[p].set(-1)
    return jnp.stack(slots), jnp.stack(counts).astype(jnp.int32)


def _level_array(levels: Optional[Sequence[Any]]) -> Any:
    if not levels:
        return jnp.zeros((0,), jnp.int32)
    return jnp.stack([jnp.asarray(v, jnp.int32) for v in levels])


def stats_from_occupancy(
    occ: Any,
    n_ops: Any,
    *,
    slot_ids: Optional[Any] = None,
    level_ops_in: Optional[Sequence[Any]] = None,
    level_ops_out: Optional[Sequence[Any]] = None,
) -> ContentionStats:
    """Build ``ContentionStats`` from a per-slot occupancy vector.

    ``occ`` is the full occupancy (one entry per table slot — locally the
    whole table, on the sharded tier the owner shard's rows with ``slot_ids``
    carrying global slot numbers).  Cross-device reductions are the caller's
    job; this function is purely local arithmetic so it composes with
    ``psum``/``pmax`` either side.
    """
    occ = occ.astype(jnp.int32)
    slots, counts = topk_hot(occ, slot_ids)
    return ContentionStats(
        n_ops=jnp.asarray(n_ops, jnp.int32),
        distinct_slots=(occ > 0).sum(dtype=jnp.int32),
        max_occupancy=jnp.max(occ).astype(jnp.int32),
        occupancy_hist=occupancy_hist(occ),
        topk_slots=slots,
        topk_counts=counts,
        level_ops_in=_level_array(level_ops_in),
        level_ops_out=_level_array(level_ops_out),
    )


def stats_to_fields(stats: ContentionStats, **extra: Any) -> Dict[str, Any]:
    """Convert device stats to a flat host-side telemetry event payload.

    Forces a device sync — only call at sync boundaries (eager sync mode or
    after the retry loop's host round trip), never under trace.
    """
    fields: Dict[str, Any] = {
        "event": "contention.stats",
        "n_ops": int(np.asarray(stats.n_ops)),
        "distinct_slots": int(np.asarray(stats.distinct_slots)),
        "max_occupancy": int(np.asarray(stats.max_occupancy)),
        "occupancy_hist": np.asarray(stats.occupancy_hist).tolist(),
        "topk_slots": np.asarray(stats.topk_slots).tolist(),
        "topk_counts": np.asarray(stats.topk_counts).tolist(),
        "level_ops_in": np.asarray(stats.level_ops_in).tolist(),
        "level_ops_out": np.asarray(stats.level_ops_out).tolist(),
    }
    fields.update(extra)
    return fields
