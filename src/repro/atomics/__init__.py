"""Unified atomics front-end: ONE typed API over every RMW execution tier.

The paper's central result is that FAA/SWP/CAS cost about the same on real
hardware, so the primitive should be chosen by *semantics* and the execution
strategy by *access pattern and coherence state* — never by the caller
hand-picking an implementation.  This package is that methodology as an API:
callers declare **what** they want done (a typed op batch against a typed
table) and the existing cost tiers decide **how**:

* local batches dispatch through the engine registry
  (`core.rmw_engine.select_backend`: serialized oracle / argsort combiner /
  sort-free blocked one-hot / Pallas MXU kernel);
* batches issued inside ``shard_map`` against a mesh-sharded table dispatch
  through the exchange strategies
  (`core.rmw_sharded.select_exchange`: one-shot / hierarchical per-pod
  combining / dense psum_scatter), including the owner-side oracle pass that
  executes **per-op-expected CAS across shards** (the un-combinable "wasted
  work" case, routed un-combined and resolved serially at the owner).

Public surface::

    from repro import atomics

    table = atomics.make_table(4096, jnp.int32)        # sharding-aware
    res = atomics.execute(table, atomics.Faa(idx, vals))
    res.table          # AtomicTable with the updated array in .data
    res.fetched        # per-op value observed before the op (serialized order)
    res.success        # per-op bool (CAS: expected matched)

    atomics.execute(table, atomics.Cas(idx, vals, expected=-1),
                    need_fetched=False)                # table-only fast path
    atomics.execute(table, atomics.Cas(idx, vals, expected=exp_array))
                       # per-op expected: serialized-oracle semantics, local
                       # AND across shards

    atomics.arrival_rank(keys, num_keys)               # sort-free FAA-fetch

    atomics.execute_until(table, make_ops, max_rounds=8,
                          policy="immediate")          # bounded CAS-loop
                       # retry: failed ops re-batched with their fetched
                       # pre-images as the next expected (repro.atomics.retry)

Every result is bit-identical to `core.rmw.rmw_serialized` applied to the
same batch (on a mesh: to the device-rank-ordered concatenation of the
per-device batches — the arrival-order contract of `core.rmw_sharded`).

Tables survive mesh changes: `repro.atomics.layout.TableLayout` reifies the
owner-major slot->shard contract (and the device-rank arrival order), and
`repro.atomics.reshard` migrates a live table onto a new mesh by re-deriving
that contract under the new extents — an in-collective ``all_to_all`` slot
exchange when both meshes share the fleet, a host-roundtrip ``device_put``
when they don't — with post-migration `execute` results bit-identical to a
never-resharded run.  (The PR-3 legacy shims — ``rmw_run``,
``rmw_execute``, ``rmw_sharded``, the old ``arrival_rank`` spellings —
finished their deprecation window and are removed.)
"""

from repro.atomics.ops import (  # noqa: F401
    OP_KINDS, AtomicOp, Cas, Faa, Max, Min, Swp)
from repro.atomics.table import AtomicTable, make_table  # noqa: F401
from repro.atomics.layout import TableLayout  # noqa: F401
from repro.atomics.stats import ContentionStats  # noqa: F401
from repro.atomics.execute import (  # noqa: F401
    AtomicResult, arrival_rank, execute)
from repro.atomics.retry import (  # noqa: F401
    POLICIES, ExponentialBackoff, ImmediateRetry, RetryPolicy, RetryResult,
    ShrinkBatch, execute_until)
from repro.atomics.reshard import (  # noqa: F401
    ReshardPlan, cost_replay, migrate, plan_reshard, restore_table,
    select_migration)

__all__ = [
    "AtomicOp", "Faa", "Swp", "Min", "Max", "Cas", "OP_KINDS",
    "AtomicTable", "make_table", "TableLayout",
    "AtomicResult", "ContentionStats", "execute", "arrival_rank",
    "RetryPolicy", "RetryResult", "execute_until", "POLICIES",
    "ImmediateRetry", "ShrinkBatch", "ExponentialBackoff",
    "ReshardPlan", "plan_reshard", "migrate", "restore_table",
    "select_migration", "cost_replay",
]
