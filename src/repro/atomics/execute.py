"""`execute`: one entry point over every RMW execution tier.

Dispatch ladder (all decisions at trace time — shapes are static under jit):

1. **Tier** — an :class:`~repro.atomics.table.AtomicTable` with mesh axes
   (``table.axis``) executing *inside* ``shard_map`` routes to the sharded
   subsystem (`core.rmw_sharded`); a local table routes to the engine
   registry (`core.rmw_engine`).  A sharded table used outside ``shard_map``
   is an error (the collectives need bound axis names), caught with a
   guidance message instead of a cryptic NameError.
2. **Strategy/backend** — within the tier, the cost models pick the
   implementation: `select_backend` over the engine registry (serialized /
   sort / one-hot / Pallas), `select_exchange` over the exchange strategies
   (one-shot / hierarchical / dense), both overridable via the ``backend=``
   and ``strategy=`` keywords.  ``distinct_slots`` feeds the exchange
   selector's dynamic contention hint (an observed distinct-slot estimate)
   to sharpen the one-shot-vs-hierarchical crossover for skewed batches —
   estimator-backed when a `repro.tuning.SpecController` is active (the
   retry combinator's collision counts feed an EWMA per call site), with
   the explicit keyword remaining an optional caller override.
3. **Semantics** — per-op-expected CAS (non-uniform `Cas`) runs on the
   serialized oracle locally, and across shards via the owner-side oracle
   pass over un-combined ops (see `core.rmw_sharded`).

Every path returns results bit-identical to `core.rmw.rmw_serialized` on
the same batch (sharded: on the device-rank-ordered concatenation — the
arrival-order contract).
"""

from __future__ import annotations

import functools
import time
from typing import Any, NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro import telemetry
from repro.telemetry import core as _tcore
from repro.atomics import contracts as _contracts
from repro.atomics import stats as _cstats
from repro.atomics.ops import AtomicOp
from repro.atomics.table import AtomicTable
from repro.core import rmw as rmw_mod
from repro.core import rmw_engine

Array = jax.Array


class AtomicResult(NamedTuple):
    """Result of `execute`: the updated table handle + per-op outputs.

    ``fetched[i]`` is the value op ``i`` observed *before* executing
    (serialized order), ``success[i]`` its CAS outcome (always True for
    non-CAS ops).  With ``need_fetched=False`` both are zero placeholders —
    only ``table`` is meaningful.  When `execute` was given a *sequence* of
    op batches, ``fetched``/``success`` are tuples, one entry per batch.

    ``stats`` is ``None`` unless the call passed ``collect_stats=True``, in
    which case it holds the batch's device-side
    :class:`~repro.atomics.stats.ContentionStats` (a tuple of them for a
    sequence of op batches).
    """

    table: AtomicTable
    fetched: Any
    success: Any
    stats: Any = None


def _axis_names(table: AtomicTable) -> Tuple[str, ...]:
    names: Tuple[str, ...] = ()
    for group in (table.axis, table.replica_axes):
        if group:
            names += (group,) if isinstance(group, str) else tuple(group)
    return names


def _axes_bound(names: Tuple[str, ...]) -> bool:
    """True iff every mesh axis name is bound in the current trace — i.e.
    we are inside a ``shard_map`` (or pmap) that carries those axes."""
    try:
        for name in names:
            jax.lax.axis_index(name)
        return True
    except NameError:
        return False


@functools.partial(jax.jit, static_argnames=("op", "backend", "need_fetched"))
def _local_exec_stats(table: Array, indices: Array, values: Array, expected,
                      *, op: str, backend: str, need_fetched: bool):
    """Local execution + contention stats as ONE compiled program.

    The stats path must not add a second eager dispatch (on CPU that alone
    costs more than the gate allows), so the backend pass and the occupancy
    reduction compile together; `backend` arrives pre-resolved (static) so
    no spec object needs to cross the jit boundary.  Results are the same
    ops the eager path runs — bit-identity is asserted in tests and gated
    in benchmarks/contention_observe.py.
    """
    res = rmw_engine.execute_backend(table, indices, values, op, expected,
                                     backend=backend,
                                     need_fetched=need_fetched)
    m = table.shape[0]
    if backend == "pallas":
        # the kernel's counters output ref — same one-hot contraction the
        # Mosaic combine runs, emitted instead of discarded
        from repro.kernels.rmw import ops as _kops
        occ = _kops.slot_occupancy(indices, m)
    else:
        occ = rmw_engine.slot_occupancy(indices, m)
    idx = indices.astype(jnp.int32)
    n_ops = ((idx >= 0) & (idx < m)).sum(dtype=jnp.int32)
    return res, _cstats.stats_from_occupancy(occ, n_ops)


def _dispatch_one(table: AtomicTable, op: AtomicOp, *, need_fetched: bool,
                  backend: str, strategy: str, spec,
                  distinct_slots: Optional[int], reverse_ranks: bool,
                  collect_stats: bool = False):
    if not isinstance(op, AtomicOp):
        raise TypeError(
            f"ops must be atomics.Faa/Swp/Min/Max/Cas instances, "
            f"got {type(op).__name__}")
    stats = None
    if table.is_sharded:
        if not _axes_bound(_axis_names(table)):
            raise ValueError(
                f"AtomicTable is sharded over mesh axes {table.axis!r} but "
                f"execute() was called outside shard_map — wrap the call in "
                f"repro.sharding.shard_map_compat over those axes (the "
                f"sharded tier uses collectives), or build a local table")
        # deferred: core.rmw_sharded imports repro.atomics.layout at module
        # scope, so binding it here keeps the package import acyclic
        from repro.core.rmw_sharded import execute_sharded
        res = execute_sharded(
            table.data, op.indices, op.values, op.kind, op.expected,
            axis=table.axis, replica_axes=table.replica_axes,
            strategy=strategy, backend=backend, spec=spec,
            need_fetched=need_fetched, distinct_slots=distinct_slots,
            reverse_ranks=reverse_ranks, collect_stats=collect_stats)
        if collect_stats:
            res, stats = res
    else:
        if reverse_ranks:
            # on one device the caller owns the whole order: reversing is
            # just op[::-1].  Accepting the flag here would imply a
            # cross-device contract that does not exist on this tier.
            raise ValueError(
                "reverse_ranks reverses the device-rank arrival order of "
                "the sharded tier; for a local table reverse the batch "
                "itself (indices[::-1], values[::-1])")
        if strategy != "auto" or distinct_slots is not None:
            # exchange strategies/hints only exist on the sharded tier: a
            # caller naming one against a local table almost certainly
            # migrated an rmw_sharded call but forgot AtomicTable(axis=...)
            # — running locally would silently skip the exchange (global
            # indices past the local shard would just vanish as OOR drops).
            raise ValueError(
                f"strategy={strategy!r} / distinct_slots apply to the "
                f"sharded tier only, but the table is local — wrap it as "
                f"AtomicTable(data, axis=...) (and call inside shard_map) "
                f"or drop the sharded-tier arguments")
        if collect_stats:
            resolved = backend
            if resolved == "auto":
                resolved = rmw_engine.select_backend(
                    op.kind, int(op.indices.shape[0]),
                    int(table.data.shape[0]), spec,
                    uniform_expected=(op.kind != "cas")
                    or rmw_engine._is_uniform_expected(op.expected),
                    dtype=table.dtype, need_fetched=need_fetched)
            res, stats = _local_exec_stats(
                table.data, op.indices, op.values, op.expected,
                op=op.kind, backend=resolved, need_fetched=need_fetched)
        else:
            res = rmw_engine.execute_backend(
                table.data, op.indices, op.values, op.kind, op.expected,
                backend=backend, spec=spec, need_fetched=need_fetched)
    return table.with_data(res.table), res.fetched, res.success, stats


# ---------------------------------------------------------------------------
# Telemetry: one decision event per executed op batch
# ---------------------------------------------------------------------------

#: prebound — ``jax.core.Tracer`` goes through the deprecation-module
#: ``__getattr__`` on every lookup, measurable on the eager hot path
_TRACER = jax.core.Tracer


def _decision_fields(table: AtomicTable, op: AtomicOp, *, need_fetched: bool,
                     backend: str, strategy: str, spec,
                     distinct_slots: Optional[int]) -> dict:
    """Mirror the dispatch ladder's selection (same deterministic inputs ->
    same choice) into one flat event record: tier, choice, and the
    selector's predicted cost — the prediction half of the drift tracker.
    Never raises: a selection that cannot be priced records ``None``."""
    n = int(op.indices.shape[0])
    perop_cas = op.kind == "cas" and op.expected is not None \
        and jnp.ndim(op.expected) != 0
    fields = dict(op=op.kind, n=n, need_fetched=need_fetched,
                  distinct_slots=distinct_slots)
    try:
        if table.is_sharded:
            from repro.core import rmw_sharded as rs
            shard_axes = rs._axes_tuple(table.axis)
            sizes = [rs._axis_size(a) for a in shard_axes]
            m_global = int(table.data.shape[0]) * _prod(sizes)
            fields.update(tier="sharded", m=m_global,
                          n_shards=_prod(sizes), backend=backend)
            if perop_cas:
                # un-combined owner-oracle path: strategy does not apply
                # and the exchange cost model declines to price it
                fields.update(strategy="perop_oracle", predicted_s=None)
            elif strategy == "auto":
                n_rep = rs._axis_size(table.replica_axes) \
                    if table.replica_axes else 1
                sel = rs.select_exchange_with_cost(
                    op.kind, n, m_global,
                    rs._mesh_axes(shard_axes, sizes, None), spec=spec,
                    need_fetched=need_fetched, uniform_expected=True,
                    replicas=n_rep, distinct_slots=distinct_slots)
                fields.update(strategy=sel.choice,
                              predicted_s=sel.predicted_s)
            else:
                used = strategy
                if strategy == "hierarchical" and len(shard_axes) < 2:
                    used = "oneshot"    # the executor's documented demotion
                fields.update(strategy=used, predicted_s=rs.EXCHANGE_COSTS[
                    used](spec or rmw_engine.default_spec(), op.kind, n,
                          m_global, rs._mesh_axes(shard_axes, sizes, None),
                          need_fetched, distinct_slots=distinct_slots))
        else:
            m = int(table.data.shape[0])
            fields.update(tier="local", m=m, strategy=None)
            uniform = not perop_cas
            if backend == "auto":
                sel = rmw_engine.select_backend_with_cost(
                    op.kind, n, m, spec, uniform_expected=uniform,
                    dtype=table.dtype, need_fetched=need_fetched)
                fields.update(backend=sel.choice, predicted_s=sel.predicted_s)
            else:
                b = rmw_engine.BACKENDS.get(backend)
                fields.update(backend=backend, predicted_s=(
                    b.cost(spec or rmw_engine.default_spec(), op.kind, n, m,
                           need_fetched) if b is not None else None))
    except Exception:  # noqa: BLE001 — observability must not break dispatch
        fields.setdefault("tier", "sharded" if table.is_sharded else "local")
        fields.setdefault("predicted_s", None)
    return fields


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


#: decision fields are a pure function of (kind, n, m, backend, ...) — on
#: the local tier the same shapes recur every step, so the eager hot path
#: pays one dict lookup instead of re-running the cost model per call (the
#: <5% instrumentation-overhead budget).  Sharded fields stay uncached:
#: they are computed at trace time only, and axis sizes are trace-scoped.
_DECISION_CACHE: dict = {}
_DECISION_CACHE_MAX = 1024


def _execute_one(table: AtomicTable, op: AtomicOp, *, need_fetched: bool,
                 backend: str, strategy: str, spec,
                 distinct_slots: Optional[int], reverse_ranks: bool,
                 collect_stats: bool = False):
    if _contracts._observer is not None:
        # static analysis in progress: report this call site's contract
        # BEFORE dispatch (a sharded-outside-shard_map call raises below,
        # and the analyzer turns the recorded site into the finding), and
        # route the op's operands through the identity marker primitive so
        # the rule engine finds them in the final jaxpr — dispatch then
        # proceeds on the marked (semantically identical) copy
        sid = _contracts.next_site()
        roles = ("op_indices", "op_values", "op_expected")
        children, aux = op.tree_flatten()
        op = type(op).tree_unflatten(aux, tuple(
            _contracts.mark(c, role=r, kind=op.kind, site=sid)
            for c, r in zip(children, roles)))
        _contracts.notify(
            "execute", table=table, op=op, site_id=sid,
            need_fetched=need_fetched, backend=backend, strategy=strategy,
            distinct_slots=distinct_slots, reverse_ranks=reverse_ranks,
            axes_bound=(not table.is_sharded)
            or _axes_bound(_axis_names(table)))
    if not telemetry.enabled():
        return _dispatch_one(table, op, need_fetched=need_fetched,
                             backend=backend, strategy=strategy, spec=spec,
                             distinct_slots=distinct_slots,
                             reverse_ranks=reverse_ranks,
                             collect_stats=collect_stats)
    if not isinstance(op, AtomicOp) or \
            (table.is_sharded and not _axes_bound(_axis_names(table))):
        # let the dispatcher raise its guidance errors un-instrumented
        return _dispatch_one(table, op, need_fetched=need_fetched,
                             backend=backend, strategy=strategy, spec=spec,
                             distinct_slots=distinct_slots,
                             reverse_ranks=reverse_ranks,
                             collect_stats=collect_stats)
    data = table.data
    if table.is_sharded:
        # trace-time only (axis sizes are trace-scoped): never cached, and
        # the one-per-compilation cost is invisible
        fields = _decision_fields(table, op, need_fetched=need_fetched,
                                  backend=backend, strategy=strategy,
                                  spec=spec, distinct_slots=distinct_slots)
        fields["event"] = "atomics.execute"
    else:
        # inlined cache lookup — on the eager hot path the function-call
        # and kwargs overhead of a helper is itself a measurable slice of
        # the <5% instrumentation budget.  NB the raw dtype object in the
        # key: hashable, where str(dtype) costs ~10us/call.
        perop = op.kind == "cas" and op.expected is not None \
            and jnp.ndim(op.expected) != 0
        key = (op.kind, op.indices.shape[0], data.shape[0], backend,
               strategy, need_fetched, perop, id(spec), distinct_slots,
               data.dtype, rmw_engine._SPEC_EPOCH)
        fields = _DECISION_CACHE.get(key)
        if fields is None:
            fields = _decision_fields(
                table, op, need_fetched=need_fetched, backend=backend,
                strategy=strategy, spec=spec, distinct_slots=distinct_slots)
            fields["event"] = "atomics.execute"   # pre-stamped template
            if len(_DECISION_CACHE) >= _DECISION_CACHE_MAX:
                _DECISION_CACHE.clear()
            _DECISION_CACHE[key] = fields
        fields = dict(fields)        # the cached template stays pristine
    traced = isinstance(data, _TRACER) or isinstance(op.indices, _TRACER)
    # _tcore flag reads instead of the telemetry.*_enabled() accessors:
    # each saved call is ~0.15us against the overhead budget
    if traced or not _tcore._sync:
        if _tcore._annotate and not traced:
            with telemetry.annotation(
                    f"atomics.execute/{fields.get('tier')}"):
                out = _dispatch_one(table, op, need_fetched=need_fetched,
                                    backend=backend, strategy=strategy,
                                    spec=spec, distinct_slots=distinct_slots,
                                    reverse_ranks=reverse_ranks,
                                    collect_stats=collect_stats)
        else:
            out = _dispatch_one(table, op, need_fetched=need_fetched,
                                backend=backend, strategy=strategy,
                                spec=spec, distinct_slots=distinct_slots,
                                reverse_ranks=reverse_ranks,
                                collect_stats=collect_stats)
    else:
        t0 = time.perf_counter()
        if _tcore._annotate:
            with telemetry.annotation(
                    f"atomics.execute/{fields.get('tier')}"):
                out = _dispatch_one(table, op, need_fetched=need_fetched,
                                    backend=backend, strategy=strategy,
                                    spec=spec, distinct_slots=distinct_slots,
                                    reverse_ranks=reverse_ranks,
                                    collect_stats=collect_stats)
        else:
            out = _dispatch_one(table, op, need_fetched=need_fetched,
                                backend=backend, strategy=strategy,
                                spec=spec, distinct_slots=distinct_slots,
                                reverse_ranks=reverse_ranks,
                                collect_stats=collect_stats)
        sync = (out[0].data, out[1], out[2])
        if out[3] is not None:
            sync += (out[3],)
        jax.block_until_ready(sync)
        fields["measured_s"] = time.perf_counter() - t0
    # the cache-copy dict becomes the event itself (record_event skips the
    # kwargs rebuild that `record` pays — this is the hottest record site)
    fields["traced"] = traced
    telemetry.record_event(fields)
    if out[3] is not None and not traced and _tcore._sync:
        # PR-7 jit discipline: contention.* events only at sync boundaries —
        # the eager sync branch above already blocked on the stats leaves,
        # so the host readout below costs no extra device round trip.
        telemetry.record_event(_cstats.stats_to_fields(
            out[3], tier=fields.get("tier"), op=op.kind,
            n=fields.get("n"), m=fields.get("m"), traced=False))
    return out


def execute(table: Union[AtomicTable, Array],
            ops: Union[AtomicOp, Sequence[AtomicOp]], *,
            need_fetched: bool = True, backend: str = "auto",
            strategy: str = "auto", spec=None,
            distinct_slots: Optional[int] = None,
            reverse_ranks: bool = False,
            collect_stats: bool = False) -> AtomicResult:
    """Execute typed RMW op batches against a table, cost-model-routed.

    Args:
      table: an :class:`AtomicTable` (or a bare 1-D array, treated as a
        local table).  Inside ``shard_map``, a sharded table's ``data`` is
        the local shard and ``indices`` are *global* slot ids.
      ops: one op batch (``atomics.Faa(idx, vals)`` ...) or a sequence,
        applied in order against the running table.
      need_fetched: False lets backends skip the per-op fetch machinery
        (table-only fast paths); ``fetched``/``success`` are then zeros.
      backend: engine backend for local execution and the pre-combine /
        resolve passes of the sharded tier ("auto" = `select_backend`).
      strategy: exchange strategy for the sharded tier ("auto" =
        `select_exchange`); ignored for local tables.
      spec: `perf_model.HardwareSpec` override for the cost models.
      distinct_slots: optional observed estimate of distinct slots touched
        per batch — the dynamic contention hint for `select_exchange`.
        Optional: when a `repro.tuning.SpecController` is running, repeated
        `execute_until` call sites get this estimate from the contention
        estimator (EWMA over combine-pass collision counts) automatically;
        pass it explicitly only to override the measured estimate.
      reverse_ranks: sharded tier only — serialize devices in *descending*
        rank order (the arrival order reversed at every exchange level).
        Combined with locally reversed batches this realizes a globally
        reversed op stream, the second pass of the SWP+revert BFS scheme.
      collect_stats: True additionally computes the batch's device-side
        :class:`~repro.atomics.stats.ContentionStats` inside the combine
        pass (occupancy, distinct/max/histogram, top-k hot slots; sharded
        tier adds per-exchange-level combining efficiency) — returned as
        ``result.stats``.  Results are bit-identical either way; with the
        default False the stats code does not run at all.

    Returns:
      :class:`AtomicResult`, bit-identical to the serialized oracle.
    """
    if not isinstance(table, AtomicTable):
        table = AtomicTable(table)
    if isinstance(ops, AtomicOp):
        table, fetched, success, stats = _execute_one(
            table, ops, need_fetched=need_fetched, backend=backend,
            strategy=strategy, spec=spec, distinct_slots=distinct_slots,
            reverse_ranks=reverse_ranks, collect_stats=collect_stats)
        return AtomicResult(table, fetched, success, stats)
    ops = tuple(ops)
    if not ops:
        raise ValueError("ops is empty")
    fetched_l, success_l, stats_l = [], [], []
    for op in ops:
        table, fetched, success, stats = _execute_one(
            table, op, need_fetched=need_fetched, backend=backend,
            strategy=strategy, spec=spec, distinct_slots=distinct_slots,
            reverse_ranks=reverse_ranks, collect_stats=collect_stats)
        fetched_l.append(fetched)
        success_l.append(success)
        stats_l.append(stats)
    return AtomicResult(table, tuple(fetched_l), tuple(success_l),
                        tuple(stats_l) if collect_stats else None)


def arrival_rank(keys: Array, num_keys: Optional[int] = None, *,
                 block: int = rmw_engine.DEFAULT_ONEHOT_BLOCK) -> Array:
    """Per-element arrival order among equal keys (0-based) — canonical.

    The FAA-fetch identity: ``rank[i]`` equals the fetched value of
    ``FAA(counter[key[i]], 1)`` executed in element order — the primitive
    MoE dispatch uses to assign each token its slot within its expert's
    capacity buffer.

    With ``num_keys`` (the static key-space size) the rank is computed
    **sort-free**: a dense one-hot cumsum for small key spaces, the blocked
    one-hot engine backend beyond.  Without it, falls back to the stable
    argsort + segmented-scan path (the only remaining use of that
    implementation — pass ``num_keys`` on hot paths).

    The one spelling (the two legacy per-tier functions this replaced —
    argsort in ``core.rmw``, sort-free in ``core.rmw_engine`` — are gone;
    their implementations live on as the private functions dispatched here).
    """
    if num_keys is None:
        return rmw_mod._arrival_rank_argsort(keys)
    return rmw_engine._arrival_rank_sortfree(keys, num_keys, block=block)
