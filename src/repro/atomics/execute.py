"""`execute`: one entry point over every RMW execution tier.

Dispatch ladder (all decisions at trace time — shapes are static under jit):

1. **Tier** — an :class:`~repro.atomics.table.AtomicTable` with mesh axes
   (``table.axis``) executing *inside* ``shard_map`` routes to the sharded
   subsystem (`core.rmw_sharded`); a local table routes to the engine
   registry (`core.rmw_engine`).  A sharded table used outside ``shard_map``
   is an error (the collectives need bound axis names), caught with a
   guidance message instead of a cryptic NameError.
2. **Strategy/backend** — within the tier, the cost models pick the
   implementation: `select_backend` over the engine registry (serialized /
   sort / one-hot / Pallas), `select_exchange` over the exchange strategies
   (one-shot / hierarchical / dense), both overridable via the ``backend=``
   and ``strategy=`` keywords.  ``distinct_slots`` feeds the exchange
   selector's dynamic contention hint (an observed distinct-slot estimate,
   e.g. the previous step's counts) to sharpen the one-shot-vs-hierarchical
   crossover for skewed batches.
3. **Semantics** — per-op-expected CAS (non-uniform `Cas`) runs on the
   serialized oracle locally, and across shards via the owner-side oracle
   pass over un-combined ops (see `core.rmw_sharded`).

Every path returns results bit-identical to `core.rmw.rmw_serialized` on
the same batch (sharded: on the device-rank-ordered concatenation — the
arrival-order contract).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional, Sequence, Tuple, Union

import jax

from repro.atomics.ops import AtomicOp
from repro.atomics.table import AtomicTable
from repro.core import rmw as rmw_mod
from repro.core import rmw_engine

Array = jax.Array


class AtomicResult(NamedTuple):
    """Result of `execute`: the updated table handle + per-op outputs.

    ``fetched[i]`` is the value op ``i`` observed *before* executing
    (serialized order), ``success[i]`` its CAS outcome (always True for
    non-CAS ops).  With ``need_fetched=False`` both are zero placeholders —
    only ``table`` is meaningful.  When `execute` was given a *sequence* of
    op batches, ``fetched``/``success`` are tuples, one entry per batch.
    """

    table: AtomicTable
    fetched: Any
    success: Any


def _axis_names(table: AtomicTable) -> Tuple[str, ...]:
    names: Tuple[str, ...] = ()
    for group in (table.axis, table.replica_axes):
        if group:
            names += (group,) if isinstance(group, str) else tuple(group)
    return names


def _axes_bound(names: Tuple[str, ...]) -> bool:
    """True iff every mesh axis name is bound in the current trace — i.e.
    we are inside a ``shard_map`` (or pmap) that carries those axes."""
    try:
        for name in names:
            jax.lax.axis_index(name)
        return True
    except NameError:
        return False


def _execute_one(table: AtomicTable, op: AtomicOp, *, need_fetched: bool,
                 backend: str, strategy: str, spec,
                 distinct_slots: Optional[int], reverse_ranks: bool):
    if not isinstance(op, AtomicOp):
        raise TypeError(
            f"ops must be atomics.Faa/Swp/Min/Max/Cas instances, "
            f"got {type(op).__name__}")
    if table.is_sharded:
        if not _axes_bound(_axis_names(table)):
            raise ValueError(
                f"AtomicTable is sharded over mesh axes {table.axis!r} but "
                f"execute() was called outside shard_map — wrap the call in "
                f"repro.sharding.shard_map_compat over those axes (the "
                f"sharded tier uses collectives), or build a local table")
        # deferred: core.rmw_sharded imports repro.atomics.layout at module
        # scope, so binding it here keeps the package import acyclic
        from repro.core.rmw_sharded import execute_sharded
        res = execute_sharded(
            table.data, op.indices, op.values, op.kind, op.expected,
            axis=table.axis, replica_axes=table.replica_axes,
            strategy=strategy, backend=backend, spec=spec,
            need_fetched=need_fetched, distinct_slots=distinct_slots,
            reverse_ranks=reverse_ranks)
    else:
        if reverse_ranks:
            # on one device the caller owns the whole order: reversing is
            # just op[::-1].  Accepting the flag here would imply a
            # cross-device contract that does not exist on this tier.
            raise ValueError(
                "reverse_ranks reverses the device-rank arrival order of "
                "the sharded tier; for a local table reverse the batch "
                "itself (indices[::-1], values[::-1])")
        if strategy != "auto" or distinct_slots is not None:
            # exchange strategies/hints only exist on the sharded tier: a
            # caller naming one against a local table almost certainly
            # migrated an rmw_sharded call but forgot AtomicTable(axis=...)
            # — running locally would silently skip the exchange (global
            # indices past the local shard would just vanish as OOR drops).
            raise ValueError(
                f"strategy={strategy!r} / distinct_slots apply to the "
                f"sharded tier only, but the table is local — wrap it as "
                f"AtomicTable(data, axis=...) (and call inside shard_map) "
                f"or drop the sharded-tier arguments")
        res = rmw_engine.execute_backend(
            table.data, op.indices, op.values, op.kind, op.expected,
            backend=backend, spec=spec, need_fetched=need_fetched)
    return table.with_data(res.table), res.fetched, res.success


def execute(table: Union[AtomicTable, Array],
            ops: Union[AtomicOp, Sequence[AtomicOp]], *,
            need_fetched: bool = True, backend: str = "auto",
            strategy: str = "auto", spec=None,
            distinct_slots: Optional[int] = None,
            reverse_ranks: bool = False) -> AtomicResult:
    """Execute typed RMW op batches against a table, cost-model-routed.

    Args:
      table: an :class:`AtomicTable` (or a bare 1-D array, treated as a
        local table).  Inside ``shard_map``, a sharded table's ``data`` is
        the local shard and ``indices`` are *global* slot ids.
      ops: one op batch (``atomics.Faa(idx, vals)`` ...) or a sequence,
        applied in order against the running table.
      need_fetched: False lets backends skip the per-op fetch machinery
        (table-only fast paths); ``fetched``/``success`` are then zeros.
      backend: engine backend for local execution and the pre-combine /
        resolve passes of the sharded tier ("auto" = `select_backend`).
      strategy: exchange strategy for the sharded tier ("auto" =
        `select_exchange`); ignored for local tables.
      spec: `perf_model.HardwareSpec` override for the cost models.
      distinct_slots: optional observed estimate of distinct slots touched
        per batch — the dynamic contention hint for `select_exchange`.
      reverse_ranks: sharded tier only — serialize devices in *descending*
        rank order (the arrival order reversed at every exchange level).
        Combined with locally reversed batches this realizes a globally
        reversed op stream, the second pass of the SWP+revert BFS scheme.

    Returns:
      :class:`AtomicResult`, bit-identical to the serialized oracle.
    """
    if not isinstance(table, AtomicTable):
        table = AtomicTable(table)
    if isinstance(ops, AtomicOp):
        table, fetched, success = _execute_one(
            table, ops, need_fetched=need_fetched, backend=backend,
            strategy=strategy, spec=spec, distinct_slots=distinct_slots,
            reverse_ranks=reverse_ranks)
        return AtomicResult(table, fetched, success)
    ops = tuple(ops)
    if not ops:
        raise ValueError("ops is empty")
    fetched_l, success_l = [], []
    for op in ops:
        table, fetched, success = _execute_one(
            table, op, need_fetched=need_fetched, backend=backend,
            strategy=strategy, spec=spec, distinct_slots=distinct_slots,
            reverse_ranks=reverse_ranks)
        fetched_l.append(fetched)
        success_l.append(success)
    return AtomicResult(table, tuple(fetched_l), tuple(success_l))


def arrival_rank(keys: Array, num_keys: Optional[int] = None, *,
                 block: int = rmw_engine.DEFAULT_ONEHOT_BLOCK) -> Array:
    """Per-element arrival order among equal keys (0-based) — canonical.

    The FAA-fetch identity: ``rank[i]`` equals the fetched value of
    ``FAA(counter[key[i]], 1)`` executed in element order — the primitive
    MoE dispatch uses to assign each token its slot within its expert's
    capacity buffer.

    With ``num_keys`` (the static key-space size) the rank is computed
    **sort-free**: a dense one-hot cumsum for small key spaces, the blocked
    one-hot engine backend beyond.  Without it, falls back to the stable
    argsort + segmented-scan path (the only remaining use of that
    implementation — pass ``num_keys`` on hot paths).

    The one spelling (the two legacy per-tier functions this replaced —
    argsort in ``core.rmw``, sort-free in ``core.rmw_engine`` — are gone;
    their implementations live on as the private functions dispatched here).
    """
    if num_keys is None:
        return rmw_mod._arrival_rank_argsort(keys)
    return rmw_engine._arrival_rank_sortfree(keys, num_keys, block=block)
