"""Elastic table migration: reshard live `AtomicTable`s across mesh changes.

The paper's finding — atomic cost is set by where the line lives, not which
atomic you issue — has a sharp corollary for the distributed tier: because
ownership is a *pure function of (slot, extent)* (owner-major: ``g //
m_local``), changing the mesh never requires replaying the RMW history that
produced the table.  Re-derive the layout under the new extents, move each
slot to its new owner once, and every subsequent `atomics.execute` is
bit-identical to a never-resharded run (the arrival-order contract is a
property of the *current* mesh, re-derived the same way).  This is the
Big Atomics view of migration: relocating the metadata word sets the price,
not the operation stream.

Two executable paths, chosen by the **migration tier** of the
`HardwareSpec` cost model (`select_migration`, the sibling of
`select_backend` / `select_exchange`):

``"exchange"``     in-collective slot exchange: both meshes are live and
                   cover the SAME device set (axis re-arrangement, replica-
                   contract change, shard-count change across a fixed fleet).
                   Each device's old shard is re-wrapped zero-copy onto the
                   new mesh and ONE padded ``all_to_all`` moves every slot
                   directly to its new owner — no host traffic.
``"device_put"``   host-roundtrip: gather the global table to host, place it
                   under the new layout with one ``device_put`` — the
                   `runtime.elastic.reshard_restore` route, and the only
                   path when the old mesh is gone (fleet grew/shrank, or the
                   table came from a checkpoint).

Entry points:

* :func:`plan_reshard` — build a :class:`ReshardPlan` (path + predicted
  costs) without touching data.
* :func:`ReshardPlan.execute` — run the plan on a live table (or host
  array) and return the migrated `AtomicTable`.
* :func:`migrate` — plan + execute in one call (the runtime hook
  `runtime.fault_tolerance` / `runtime.elastic` use).
* :func:`restore_table` — the checkpoint half: rebuild a handle from host
  data under the active mesh (`checkpoint.ckpt.restore` calls this).
* :func:`cost_replay` — what migration is priced against: re-executing an
  op history through the sharded tier (benchmarks/reshard.py validates
  predicted-vs-measured on the 8-fake-device harness).
"""

from __future__ import annotations

import dataclasses
import functools
import math
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import telemetry
from repro.atomics.layout import TableLayout, norm_axes
from repro.atomics.table import AtomicTable

Array = jax.Array

PATHS = ("exchange", "device_put")


# ---------------------------------------------------------------------------
# Cost model: the migration tier (HardwareSpec constants, like the others)
# ---------------------------------------------------------------------------

def _mesh_axes_of(layout: TableLayout):
    """Price the layout's mesh with the default topology heuristic (outermost
    axis crosses pods when there is more than one level)."""
    from repro.core.rmw_sharded import _mesh_axes
    names = [n for n, _ in layout.mesh_axes]
    sizes = [s for _, s in layout.mesh_axes]
    return _mesh_axes(names, sizes, None)


def _itemsize(layout: TableLayout) -> int:
    return jnp.dtype(layout.dtype).itemsize


def cost_migrate_exchange(spec, src: TableLayout, dst: TableLayout) -> float:
    """One padded all_to_all over the destination mesh: per-device payload is
    ``n_dev`` lanes of ``min(m_local_src, m_local_dst)`` slots."""
    from repro.core.rmw_sharded import _a2a_s
    n_dev = math.prod(s for _, s in dst.mesh_axes) or 1
    cap = min(src.m_local, dst.m_local)
    return _a2a_s(spec, n_dev * cap * _itemsize(dst), _mesh_axes_of(dst))


def cost_migrate_device_put(spec, src: TableLayout,
                            dst: TableLayout) -> float:
    """Host roundtrip: the whole table crosses the host link twice (gather
    down, scatter up) plus one placement dispatch per shard copy."""
    from repro.core.placement import Tier
    nbytes = dst.num_slots * _itemsize(dst)
    host_bw = getattr(spec, "host_roundtrip_Bps", 0.0) \
        or spec.tier_bandwidth_Bps[Tier.HOST]
    launch = getattr(spec, "device_put_launch_s", 0.0) or 1e-4
    copies = max(1, dst.n_shards * dst.n_replicas)
    return 2.0 * nbytes / host_bw + launch * (1 + math.log2(max(2, copies)))


MIGRATION_COSTS = {
    "exchange": cost_migrate_exchange,
    "device_put": cost_migrate_device_put,
}


def cost_replay(spec, dst: TableLayout, n_ops_total: int, *,
                op: str = "faa", n_batches: int = 1,
                need_fetched: bool = True) -> float:
    """Price of the alternative migration strategy: start from the initial
    table on the new mesh and re-execute the recorded op history through the
    sharded tier (one-shot exchange per batch).  Migration must beat this
    for any history that touched the table more than trivially — the
    acceptance gate of ``benchmarks/reshard.py``."""
    from repro.core.rmw_sharded import cost_exchange_oneshot
    axes = _mesh_axes_of(dst)
    n_dev = math.prod(s for _, s in dst.mesh_axes) or 1
    n_per = max(1, -(-n_ops_total // max(1, n_batches) // n_dev))
    per_batch = cost_exchange_oneshot(spec, op, n_per, dst.num_slots, axes,
                                      need_fetched)
    return n_batches * per_batch


def select_migration(src: TableLayout, dst: TableLayout, *,
                     exchange_feasible: bool, spec=None) -> str:
    """Cheapest feasible migration path — the migration tier of the paper's
    L(A, S) decision procedure (`select_backend` / `select_exchange`'s
    sibling).  ``exchange_feasible`` is topology truth (both meshes live on
    one device set), not a preference; the model only arbitrates when both
    paths can run."""
    if not exchange_feasible:
        return "device_put"
    from repro.core import rmw_engine
    spec = spec or rmw_engine.default_spec()
    return min(MIGRATION_COSTS,
               key=lambda p: MIGRATION_COSTS[p](spec, src, dst))


# ---------------------------------------------------------------------------
# The plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ReshardPlan:
    """One planned migration: layouts, chosen path, predicted costs.

    Build with :func:`plan_reshard`; run with :meth:`execute`.  The plan is
    data-independent — the same plan can migrate any table matching ``src``
    (the benchmark reuses one plan across timing reps).
    """

    src: TableLayout
    dst: TableLayout
    path: str                      # "exchange" | "device_put"
    predicted_s: Dict[str, float]  # per-path model predictions (inf = infeasible)
    dst_mesh: object = dataclasses.field(repr=False, default=None)
    src_mesh: object = dataclasses.field(repr=False, default=None)

    def execute(self, table) -> AtomicTable:
        """Migrate ``table`` (an `AtomicTable`, live array, or host array in
        the ``src`` layout) onto the destination mesh.  Returns the handle
        carrying the re-derived contract; contents are bit-identical slot
        for slot."""
        data = table.data if isinstance(table, AtomicTable) else table
        if int(data.shape[0]) != self.src.num_slots:
            raise ValueError(f"table has {data.shape[0]} slots; plan expects "
                             f"{self.src.num_slots}")
        if self.path == "exchange":
            out = _exchange_slots(data, self.src, self.dst,
                                  self.src_mesh, self.dst_mesh)
        else:
            out = _device_put_slots(data, self.dst, self.dst_mesh)
        return AtomicTable(out, axis=self.dst.axis or None,
                           replica_axes=self.dst.replica_axes)


def _same_device_set(mesh_a, mesh_b) -> bool:
    if mesh_a is None or mesh_b is None:
        return False
    return set(mesh_a.devices.flat) == set(mesh_b.devices.flat)


def plan_reshard(src: TableLayout, dst: TableLayout, *, dst_mesh,
                 src_mesh=None, live: bool = True, path: str = "auto",
                 spec=None) -> ReshardPlan:
    """Plan a migration from layout ``src`` to layout ``dst``.

    ``live`` says the source table still exists on devices of ``src_mesh``
    (False for checkpointed host data — only ``device_put`` can run).
    ``path`` forces a specific path ("auto" = `select_migration`).
    """
    if src.num_slots != dst.num_slots:
        raise ValueError(
            f"slot-count changes are not migrations ({src.num_slots} -> "
            f"{dst.num_slots}); grow the table first, then reshard")
    feasible = bool(live and dst.is_sharded and src.is_sharded
                    and _same_device_set(src_mesh, dst_mesh))
    from repro.core import rmw_engine
    spec = spec or rmw_engine.default_spec()
    predicted = {
        "exchange": (cost_migrate_exchange(spec, src, dst)
                     if feasible else float("inf")),
        "device_put": cost_migrate_device_put(spec, src, dst),
    }
    if path == "auto":
        # the plan's choice IS its stored predictions (infeasible = inf)
        path = min(predicted, key=predicted.get)
    elif path not in PATHS:
        raise ValueError(f"unknown path {path!r}; have {PATHS}")
    elif path == "exchange" and not feasible:
        raise ValueError(
            "path='exchange' needs both meshes live on the same device set "
            "(use 'device_put' when the fleet changed or the source is a "
            "checkpoint)")
    return ReshardPlan(src=src, dst=dst, path=path, predicted_s=predicted,
                       dst_mesh=dst_mesh, src_mesh=src_mesh)


# ---------------------------------------------------------------------------
# Path 1: in-collective slot exchange (same device set, both meshes live)
# ---------------------------------------------------------------------------

def _shards_by_device(data: Array) -> Dict:
    return {sh.device: sh.data for sh in data.addressable_shards}

def _wrap_on_mesh(shape, sharding, per_device) -> Array:
    """Zero-copy re-wrap of per-device buffers as one logical array."""
    return jax.make_array_from_single_device_arrays(shape, sharding,
                                                    per_device)


@functools.lru_cache(maxsize=64)
def _exchange_executable(src: TableLayout, dst: TableLayout,
                         src_mesh, dst_mesh):
    """Build (once per plan — layouts and meshes are hashable, so repeat
    migrations reuse the compiled collective) the jitted shard_map that
    moves every slot to its new owner with ONE padded all_to_all.

    Because both layouts are contiguous owner-major splits of the same
    ``[0, m)`` slot range, the rows any (old shard, new shard) pair
    exchanges form one contiguous run of at most ``min(m_a, m_b)`` slots —
    so a fixed-cap padded exchange is exact, never truncating.  Replicated
    source shards are deduplicated by a designated *primary* sender (lowest
    old device rank holding the shard); replicated destinations each
    receive their own copy because every lane is per-device.
    """
    n_dev = int(dst_mesh.devices.size)
    m_a, m_b = src.m_local, dst.m_local
    cap = min(m_a, m_b)
    flat_axes = tuple(dst_mesh.axis_names)

    # per-new-flat-rank constants (numpy, baked into the traced body)
    old_devs = list(src_mesh.devices.flat)
    old_flat_of = np.array([old_devs.index(d) for d in dst_mesh.devices.flat])
    src_shard = np.array([src.shard_of_device(int(f)) for f in old_flat_of])
    first_holder: Dict[int, int] = {}
    for f in range(len(old_devs)):   # lowest old device rank wins
        first_holder.setdefault(src.shard_of_device(f), f)
    src_primary = np.array([first_holder[int(s)] == int(f)
                            for s, f in zip(src_shard, old_flat_of)])
    dst_shard = np.array([dst.shard_of_device(j) for j in range(n_dev)])
    sizes = [s for _, s in dst.mesh_axes]

    def body(x):                     # x: (m_a,) — this device's old shard
        j = jnp.zeros((), jnp.int32)
        for name, size in zip(flat_axes, sizes):
            j = j * size + jax.lax.axis_index(name)
        r_me = jnp.asarray(src_shard)[j]
        prim_me = jnp.asarray(src_primary)[j]
        s_me = jnp.asarray(dst_shard)[j]
        lane = jnp.arange(n_dev)
        p = jnp.arange(cap)

        # send: lane k gets the run of my old shard owned by k's new shard
        s_k = jnp.asarray(dst_shard)[lane]
        o = jnp.maximum(r_me * m_a, s_k * m_b)
        ln = jnp.minimum((r_me + 1) * m_a, (s_k + 1) * m_b) - o
        rows = o[:, None] - r_me * m_a + p[None, :]
        send = jnp.where((p[None, :] < ln[:, None]) & prim_me,
                         x[jnp.clip(rows, 0, m_a - 1)],
                         jnp.zeros((), x.dtype))
        recv = jax.lax.all_to_all(send, flat_axes, split_axis=0,
                                  concat_axis=0)

        # receive: source i's run lands at its global offset in my new shard
        r_i = jnp.asarray(src_shard)[lane]
        prim_i = jnp.asarray(src_primary)[lane]
        o_i = jnp.maximum(r_i * m_a, s_me * m_b)
        ln_i = jnp.minimum((r_i + 1) * m_a, (s_me + 1) * m_b) - o_i
        rows_i = o_i[:, None] - s_me * m_b + p[None, :]
        valid = (p[None, :] < ln_i[:, None]) & prim_i[:, None]
        out = jnp.zeros((m_b + 1,), x.dtype).at[
            jnp.where(valid, rows_i, m_b)].set(recv)[:-1]
        return out

    from repro.sharding import shard_map_compat
    return jax.jit(shard_map_compat(body, dst_mesh,
                                    (P(flat_axes),), P(flat_axes)))


def _exchange_slots(data: Array, src: TableLayout, dst: TableLayout,
                    src_mesh, dst_mesh) -> Array:
    """Run the in-collective exchange: zero-copy re-wrap of the old
    per-device shards onto the new mesh, the cached jitted all_to_all, and
    a zero-copy re-wrap of the outputs under the destination sharding."""
    n_dev = int(dst_mesh.devices.size)
    view = _wrap_on_mesh(
        (n_dev * src.m_local,), NamedSharding(dst_mesh,
                                              P(tuple(dst_mesh.axis_names))),
        [_shards_by_device(data)[d] for d in dst_mesh.devices.flat])
    outv = _exchange_executable(src, dst, src_mesh, dst_mesh)(view)
    per_dev = _shards_by_device(outv)
    return _wrap_on_mesh((src.num_slots,), dst.named_sharding(dst_mesh),
                         [per_dev[d] for d in dst_mesh.devices.flat])


# ---------------------------------------------------------------------------
# Path 2: host roundtrip (the elastic.reshard_restore route)
# ---------------------------------------------------------------------------

def _device_put_slots(data, dst: TableLayout, dst_mesh) -> Array:
    host = np.asarray(data)          # gathers a live sharded array too
    if not dst.is_sharded or dst_mesh is None:
        return jnp.asarray(host)
    return jax.device_put(host, dst.named_sharding(dst_mesh))


# ---------------------------------------------------------------------------
# Front doors
# ---------------------------------------------------------------------------

def migrate(table: AtomicTable, dst_mesh, *, axis: object = "auto",
            replica_axes=None, path: str = "auto", spec=None,
            src_mesh=None) -> AtomicTable:
    """Reshard a live table onto ``dst_mesh``, re-deriving the owner-major
    layout, replica contract, and arrival order under the new extents.

    ``axis="auto"`` keeps the table's axis names that still exist on the
    new mesh (the grow/shrink case: same names, new extents); pass explicit
    ``axis=`` / ``replica_axes=`` to change the contract itself.  Results
    of every subsequent `atomics.execute` on the returned handle are
    bit-identical to a run that was never resharded.

    When the re-derived layout cannot be hosted — the slot count does not
    divide the new extents, or every sharding axis vanished — the table
    falls back to a *local* handle (host gather, one placement), the same
    divisibility-aware degradation `make_table` and `restore_table` apply,
    so an elastic restart onto an awkward fleet degrades instead of
    crashing the recovery loop.
    """
    src = TableLayout.from_table(table, mesh=src_mesh)
    if src_mesh is None and src.is_sharded:
        src_mesh = getattr(getattr(table.data, "sharding", None), "mesh",
                           None)
    names = set(dst_mesh.axis_names)
    if axis == "auto":
        axis = tuple(a for a in src.axis if a in names)
    rep = norm_axes(table.replica_axes if replica_axes is None
                    else replica_axes)
    rep = tuple(a for a in rep if a in names)
    try:
        dst = TableLayout.from_mesh(dst_mesh, num_slots=src.num_slots,
                                    dtype=src.dtype, axis=axis,
                                    replica_axes=rep)
    except ValueError:               # non-divisible extents -> local
        dst = TableLayout(num_slots=src.num_slots, dtype=src.dtype)
    plan = plan_reshard(src, dst, dst_mesh=dst_mesh, src_mesh=src_mesh,
                        live=True, path=path, spec=spec)
    if not telemetry.enabled():
        return plan.execute(table)
    with telemetry.annotation(f"atomics.reshard.migrate/{plan.path}"):
        t0 = time.perf_counter()
        out = plan.execute(table)
        jax.block_until_ready(out.data)
        dt = time.perf_counter() - t0
    telemetry.record(
        "atomics.reshard.migrate", path=plan.path,
        tier="migration", n_slots=src.num_slots,
        src_shards=src.n_shards, dst_shards=dst.n_shards,
        src_replicas=src.n_replicas, dst_replicas=dst.n_replicas,
        predicted_s=plan.predicted_s.get(plan.path),
        predicted_all={k: v for k, v in plan.predicted_s.items()
                       if math.isfinite(v)},
        measured_s=dt)
    return out


def restore_table(host_data, *, like: Optional[AtomicTable] = None,
                  meta: Optional[Dict] = None) -> AtomicTable:
    """Rebuild an `AtomicTable` from host data — the old-mesh-is-gone route.

    The *target* contract comes from ``like`` (the handle in the caller's
    ``like`` tree, built under the new mesh) when given, else from the
    checkpointed layout ``meta`` (axis names re-resolved against the active
    mesh — extents are re-derived, never trusted from the writer).  With no
    active mesh, or axes that no longer exist/divide, the table restores
    local — the same divisibility-aware fallback `make_table` applies.
    """
    from repro import sharding as shardlib
    axis = norm_axes(like.axis if like is not None
                     else tuple((meta or {}).get("axis") or ()))
    rep = norm_axes(like.replica_axes if like is not None
                    else tuple((meta or {}).get("replica_axes") or ()))
    mesh = shardlib.active_mesh()
    data = jnp.asarray(host_data)
    if axis and mesh is not None:
        try:
            dst = TableLayout.from_mesh(mesh, num_slots=int(data.shape[0]),
                                        dtype=data.dtype, axis=axis,
                                        replica_axes=rep)
        except ValueError:           # axis gone or non-divisible -> local
            return AtomicTable(data)
        plan = plan_reshard(
            TableLayout(num_slots=dst.num_slots, dtype=dst.dtype),
            dst, dst_mesh=mesh, live=False, path="device_put")
        return plan.execute(data)
    return AtomicTable(data)
