"""TableLayout: the owner-major slot->shard contract as a first-class object.

The paper's central finding is that an atomic's cost is set by *where the
cache line lives*, not by which atomic is issued; the distributed analogue is
that an RMW's cost is set by *which shard owns the slot*.  That ownership
contract used to live implicitly in two places — ``make_table``'s
sharding-rule resolution and ``rmw_sharded``'s inline ``g // m_local``
arithmetic — which made it impossible to reason about a table whose mesh is
*changing*.  This module makes the contract explicit:

* **owner-major layout**: global slot ``g`` lives on shard ``g // m_local``
  at local row ``g % m_local``; shards are laid out major-to-minor over the
  table's ``axis`` tuple (:func:`owner_shard`, :func:`local_row` are the
  single home for that arithmetic — the sharded executor imports them).
* **replica contract**: devices along ``replica_axes`` hold identical copies
  of their shard; writers on every replica serialize replica-major.
* **device-rank arrival order**: `atomics.execute` results equal the
  serialized oracle applied to the concatenation of per-device batches
  ordered by device rank — lexicographic over ``replica_axes + axis``
  (major to minor), each device's ops in local order
  (:func:`TableLayout.arrival_rank_of_device`).

A :class:`TableLayout` is derivable from a live table + mesh
(:meth:`TableLayout.from_table`), is JSON-serializable
(:meth:`~TableLayout.to_dict` / :meth:`~TableLayout.from_dict`) so
checkpoints can carry it, and is what `repro.atomics.reshard` re-derives
under a *new* mesh when the fleet grows or shrinks — ownership is a pure
function of (slot, extent), so migration never needs to replay history.

This module is import-light on purpose (jax/numpy only): both
`repro.atomics.table` and `repro.core.rmw_sharded` import it.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array
AxisNames = Union[str, Tuple[str, ...], None]


def norm_axes(axis: AxisNames) -> Tuple[str, ...]:
    """Normalize an axis spec (None / str / tuple) to a tuple of names."""
    if axis is None:
        return ()
    if isinstance(axis, str):
        return (axis,)
    return tuple(axis)


# ---------------------------------------------------------------------------
# Owner-major arithmetic (the single home; rmw_sharded imports these)
# ---------------------------------------------------------------------------

def owner_shard(gidx: Array, m_local: int, n_shards: int) -> Array:
    """Destination shard of each global slot id under owner-major layout.

    Valid ids map to ``g // m_local``; anything else (already remapped to
    ``>= m_global`` by the caller's OOR pass) clamps to the last shard,
    whose resolve pass drops it via the scratch row.
    """
    return jnp.minimum(gidx // m_local, n_shards - 1)


def local_row(gidx: Array, shard: Array, m_local: int, m_global: int) -> Array:
    """Local row of a global slot on its owner; OOR ids -> the scratch row
    (``m_local``), matching the engine's drop convention."""
    return jnp.where(gidx < m_global, gidx - shard * m_local, m_local)


# ---------------------------------------------------------------------------
# The layout record
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TableLayout:
    """One table's distribution contract, independent of live buffers.

    Attributes:
      num_slots:    global table length (slots are dense ``0..num_slots-1``).
      dtype:        slot dtype, as a string (JSON-safe).
      axis:         mesh axis name(s) the table shards over, major-to-minor
                    (empty tuple = local table).
      replica_axes: mesh axes holding identical shard copies.
      mesh_axes:    the full mesh shape as ``((name, size), ...)`` in mesh
                    order — the extents the owner-major layout was derived
                    under.  Re-deriving the same contract under different
                    extents is exactly what `reshard` does.
    """

    num_slots: int
    dtype: str
    axis: Tuple[str, ...] = ()
    replica_axes: Tuple[str, ...] = ()
    mesh_axes: Tuple[Tuple[str, int], ...] = ()

    # --- derived extents --------------------------------------------------
    @property
    def axis_sizes(self) -> Dict[str, int]:
        return dict(self.mesh_axes)

    def _size(self, names: Sequence[str]) -> int:
        sizes = self.axis_sizes
        return math.prod(sizes.get(n, 1) for n in names)

    @property
    def n_shards(self) -> int:
        return self._size(self.axis)

    @property
    def n_replicas(self) -> int:
        return self._size(self.replica_axes)

    @property
    def m_local(self) -> int:
        if self.num_slots % max(self.n_shards, 1):
            raise ValueError(
                f"{self.num_slots} slots do not divide over "
                f"{self.n_shards} shards ({self.axis!r} x {self.mesh_axes!r})")
        return self.num_slots // max(self.n_shards, 1)

    @property
    def is_sharded(self) -> bool:
        return bool(self.axis)

    # --- per-device derivations (numpy; device order = mesh C-order) ------
    def _coords(self, flat: int) -> Dict[str, int]:
        names = [n for n, _ in self.mesh_axes]
        sizes = [s for _, s in self.mesh_axes]
        return dict(zip(names, np.unravel_index(flat, sizes)))

    def _rank_over(self, names: Sequence[str], coords: Dict[str, int]) -> int:
        rank = 0
        for n in names:
            rank = rank * self.axis_sizes[n] + coords[n]
        return rank

    def shard_of_device(self, flat: int) -> int:
        """Owner-major shard id held by the device at mesh flat index."""
        return self._rank_over(self.axis, self._coords(flat))

    def replica_rank_of_device(self, flat: int) -> int:
        return self._rank_over(self.replica_axes, self._coords(flat))

    def arrival_rank_of_device(self, flat: int) -> int:
        """The device-rank arrival order: lexicographic over
        ``replica_axes + axis`` (major to minor) — the rank at which this
        device's local batch lands in the serialized-oracle concatenation."""
        return self._rank_over(self.replica_axes + self.axis,
                               self._coords(flat))

    def arrival_order(self) -> np.ndarray:
        """Mesh flat device indices sorted by arrival rank (the order a
        serialized oracle must concatenate per-device batches in)."""
        n = self._size([n for n, _ in self.mesh_axes])
        ranks = [self.arrival_rank_of_device(i) for i in range(n)]
        return np.argsort(np.asarray(ranks), kind="stable")

    def rows_of_shard(self, shard: int) -> Tuple[int, int]:
        """[start, end) global row range owned by a shard."""
        return shard * self.m_local, (shard + 1) * self.m_local

    # --- constructors / serialization -------------------------------------
    @classmethod
    def from_mesh(cls, mesh, *, num_slots: int, dtype,
                  axis: AxisNames, replica_axes: AxisNames = ()
                  ) -> "TableLayout":
        mesh_axes = tuple((str(n), int(s))
                          for n, s in zip(mesh.axis_names,
                                          mesh.devices.shape))
        lay = cls(num_slots=int(num_slots), dtype=str(jnp.dtype(dtype)),
                  axis=norm_axes(axis), replica_axes=norm_axes(replica_axes),
                  mesh_axes=mesh_axes)
        known = lay.axis_sizes
        for name in lay.axis + lay.replica_axes:
            if name not in known:
                raise ValueError(f"axis {name!r} not on mesh "
                                 f"{list(known)!r}")
        lay.m_local  # divisibility check
        return lay

    @classmethod
    def from_table(cls, table, mesh=None) -> "TableLayout":
        """Derive the layout of a live `AtomicTable` handle.

        ``mesh`` defaults to the mesh of the table's array sharding (a
        distributed array outside shard_map carries it); a local table needs
        no mesh.  Duck-typed on the handle (``data``/``axis``/
        ``replica_axes``) so this module stays import-light.
        """
        axis = norm_axes(table.axis)
        if not axis:
            return cls(num_slots=int(table.data.shape[0]),
                       dtype=str(table.data.dtype))
        if mesh is None:
            sharding = getattr(table.data, "sharding", None)
            mesh = getattr(sharding, "mesh", None)
        if mesh is None:
            raise ValueError(
                "cannot derive the layout of a sharded table without a "
                "mesh: pass mesh=..., or use an array placed with a "
                "NamedSharding")
        return cls.from_mesh(mesh, num_slots=int(table.data.shape[0]),
                             dtype=table.data.dtype, axis=axis,
                             replica_axes=table.replica_axes)

    def to_dict(self) -> Dict:
        return {"num_slots": self.num_slots, "dtype": self.dtype,
                "axis": list(self.axis),
                "replica_axes": list(self.replica_axes),
                "mesh_axes": [[n, s] for n, s in self.mesh_axes]}

    @classmethod
    def from_dict(cls, d: Dict) -> "TableLayout":
        return cls(num_slots=int(d["num_slots"]), dtype=str(d["dtype"]),
                   axis=tuple(d.get("axis") or ()),
                   replica_axes=tuple(d.get("replica_axes") or ()),
                   mesh_axes=tuple((str(n), int(s))
                                   for n, s in d.get("mesh_axes") or ()))

    def spec(self):
        """The PartitionSpec realizing this layout (owner-major over
        ``axis``, replicated elsewhere)."""
        from jax.sharding import PartitionSpec as P
        if not self.axis:
            return P()
        return P(self.axis if len(self.axis) > 1 else self.axis[0])

    def named_sharding(self, mesh) -> "jax.sharding.NamedSharding":
        from jax.sharding import NamedSharding
        return NamedSharding(mesh, self.spec())

    def __repr__(self):
        where = (f"sharded over {self.axis!r}" if self.axis else "local")
        rep = (f", replicated over {self.replica_axes!r}"
               if self.replica_axes else "")
        return (f"TableLayout({self.num_slots} x {self.dtype}, {where}{rep}, "
                f"mesh={dict(self.mesh_axes)!r})")
