"""`execute_until`: bounded-retry combinator for CAS loops, all tiers.

The paper's contention result (Fig. 8) and Lightweight Contention Management
(arxiv 1305.5800) agree on the fix for CAS storms: **failure feedback must
drive an explicit policy**, not blind retry.  A failed CAS already *fetched*
the winning value — that pre-image is exactly the next attempt's
``expected``, so a retry round never needs a separate read.  This module is
that loop as a combinator:

* each round executes one batched `atomics.execute` (local engine tier,
  or the sharded exchange tier when the table is mesh-sharded — the
  combinator launches its own ``shard_map``, scattering the round's ops
  over the devices in batch order);
* only the **failed** ops are re-batched, their fetched pre-images becoming
  the next round's per-op ``expected`` and their payloads recomputed by the
  caller's ``make_ops`` (the ``F`` in the lock-free ``CAS(x, v, F(v))``);
* a pluggable :class:`RetryPolicy` shapes the retry stream per
  arxiv 1305.5800 — retry everything at once (``immediate``), shrink the
  per-round batch so fewer ops collide (``shrink``), or space rounds with
  exponentially growing idle time (``exponential``);
* the result carries **per-op round counts** — the contention histogram a
  self-tuning policy needs is a free by-product of the loop.

Convergence: a fully-contended batch (every op targeting one slot) resolves
exactly one op per round — the serialized-equivalence contract means each
round's first arriving pending op sees its expected value and wins — so
``n`` ops need ``<= n`` rounds on every tier.  Uncontended batches resolve
in one.

Arrival-order caveat: *within* a round, ops execute in batch order (on a
mesh: the combinator scatters the round's batch contiguously over device
ranks, so device-rank concatenation re-creates batch order and local and
sharded tiers produce identical round histories).  *Across* rounds there is
no global order — a CAS loop is by construction order-free (each op commits
against whatever value it last observed), which is why `execute_until` may
be used where a single `execute` batch's serialized order matters not.
"""

from __future__ import annotations

import math
import time
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.telemetry import core as _tcore
from repro.atomics import contracts as _contracts
from repro.atomics import stats as _cstats
from repro.atomics.ops import OP_KINDS, AtomicOp, Cas
from repro.atomics.table import AtomicTable

Array = jax.Array


# ---------------------------------------------------------------------------
# Retry policies (arxiv 1305.5800: contention management as explicit policy)
# ---------------------------------------------------------------------------

class RetryPolicy:
    """How failures are re-offered: batch sizing + inter-round spacing.

    ``batch_size(n_pending, rnd)`` says how many of the pending ops round
    ``rnd`` may issue (the rest wait — fewer concurrent ops, less wasted
    work under contention); ``delay_s(rnd)`` is idle time *before* round
    ``rnd`` (0 for the first round).  Subclass to tune; the three classic
    shapes below are registered in :data:`POLICIES`.
    """

    name = "custom"

    def batch_size(self, n_pending: int, rnd: int) -> int:
        return n_pending

    def delay_s(self, rnd: int) -> float:
        return 0.0

    def __repr__(self):
        return f"{type(self).__name__}()"


class ImmediateRetry(RetryPolicy):
    """Re-offer every failed op next round, no spacing — optimal when the
    contention is *self-inflicted* (one batch against one table): each
    round's serialization resolves one winner per slot regardless."""

    name = "immediate"


class ShrinkBatch(RetryPolicy):
    """Halve (by default) the retry batch each consecutive failing round:
    the pending set still drains one winner per contended slot per round,
    but the losers that were going to fail anyway never hit the exchange —
    less wasted traffic, same round count."""

    name = "shrink"

    def __init__(self, factor: float = 0.5, min_batch: int = 1):
        if not 0.0 < factor <= 1.0:
            raise ValueError(f"factor must be in (0, 1], got {factor}")
        self.factor = factor
        self.min_batch = max(1, int(min_batch))

    def batch_size(self, n_pending: int, rnd: int) -> int:
        if rnd == 0:
            return n_pending
        return max(self.min_batch, math.ceil(n_pending * self.factor))


class ExponentialBackoff(RetryPolicy):
    """Full retry batches spaced by exponentially growing idle time —
    the classic shape when the contention is *external* (other writers
    between rounds), pointless when it is self-inflicted."""

    name = "exponential"

    def __init__(self, base_s: float = 1e-4, factor: float = 2.0,
                 max_s: float = 0.1):
        self.base_s = float(base_s)
        self.factor = float(factor)
        self.max_s = float(max_s)

    def delay_s(self, rnd: int) -> float:
        if rnd <= 0:
            return 0.0
        return min(self.max_s, self.base_s * self.factor ** (rnd - 1))


POLICIES: Dict[str, Callable[[], RetryPolicy]] = {
    "immediate": ImmediateRetry,
    "shrink": ShrinkBatch,
    "exponential": ExponentialBackoff,
}


def _resolve_policy(policy: Union[str, RetryPolicy]) -> RetryPolicy:
    if isinstance(policy, RetryPolicy):
        return policy
    try:
        return POLICIES[policy]()
    except KeyError:
        raise ValueError(f"unknown retry policy {policy!r}; have "
                         f"{tuple(POLICIES)} or a RetryPolicy instance")


# ---------------------------------------------------------------------------
# Result
# ---------------------------------------------------------------------------

class RetryResult(NamedTuple):
    """Outcome of :func:`execute_until` (host arrays, original batch order).

    ``fetched[i]`` is op i's *last observed pre-image* — for a resolved CAS,
    the value its winning attempt replaced; ``success[i]`` whether it
    resolved within the round budget; ``rounds[i]`` how many attempts it
    took (the per-op contention observable; 1 = first try); ``pending``
    the original positions still unresolved (empty on full convergence).

    ``stats`` is the round-0 device-side
    :class:`~repro.atomics.stats.ContentionStats` when the loop collected
    one (``collect_stats=True``, or None-auto with a tuning controller
    running), else None.  Round 0 is the full batch — the round whose
    contention spectrum characterizes the workload; later rounds only
    re-issue the losers.
    """

    table: AtomicTable
    fetched: np.ndarray
    success: np.ndarray
    rounds: np.ndarray
    n_rounds: int
    pending: np.ndarray
    stats: Any = None


# ---------------------------------------------------------------------------
# Sharded round execution: the combinator's own shard_map per round
# ---------------------------------------------------------------------------

_SHARDED_ROUND_CACHE: Dict[tuple, Any] = {}


def _norm_tuple(axes) -> Tuple[str, ...]:
    if axes is None:
        return ()
    return (axes,) if isinstance(axes, str) else tuple(axes)


def _sharded_round_fn(mesh, axis: Tuple[str, ...], rep: Tuple[str, ...],
                      kind: str, backend: str, strategy: str, spec,
                      distinct_slots, collect_stats: bool = False):
    """Build (and cache) the jitted shard_map executing ONE retry round on
    a mesh-sharded table: ops scattered contiguously over device ranks, so
    the device-rank arrival order re-creates the round's batch order."""
    from repro.core import rmw_engine
    # the spec epoch invalidates cached rounds when the tuning controller
    # swaps the live spec: the body bakes its strategy selection at trace
    # time, so a stale entry would keep dispatching the old choice
    key = (mesh, axis, rep, kind, backend, strategy, id(spec),
           distinct_slots, collect_stats, rmw_engine._SPEC_EPOCH)
    fn = _SHARDED_ROUND_CACHE.get(key)
    if fn is not None:
        return fn
    from jax.sharding import PartitionSpec as P

    from repro.atomics.execute import execute
    from repro.atomics.stats import ContentionStats
    from repro.sharding import shard_map_compat

    tab_spec, op_spec = P(axis), P(rep + axis)

    def body(t, i, v, e):
        tbl = AtomicTable(t, axis=axis if len(axis) > 1 else axis[0],
                          replica_axes=rep)
        if kind == "cas":
            op = Cas(i, v, expected=e)
        else:
            op = OP_KINDS[kind](i, v)
        res = execute(tbl, op, need_fetched=True, backend=backend,
                      strategy=strategy, spec=spec,
                      distinct_slots=distinct_slots,
                      collect_stats=collect_stats)
        if collect_stats:
            return res.table.data, res.fetched, res.success, res.stats
        return res.table.data, res.fetched, res.success

    out_specs = (tab_spec, op_spec, op_spec)
    if collect_stats:
        # stats leaves are already psum'd over every mesh axis inside the
        # exchange — replicated outputs, P() per ContentionStats field
        out_specs = out_specs + (
            ContentionStats(*([P()] * len(ContentionStats._fields))),)
    fn = jax.jit(shard_map_compat(body, mesh,
                                  (tab_spec, op_spec, op_spec, op_spec),
                                  out_specs))
    _SHARDED_ROUND_CACHE[key] = fn
    return fn


def _exec_round_sharded(table: AtomicTable, kind: str, idx: np.ndarray,
                        vals: np.ndarray, exp: Optional[np.ndarray], *,
                        backend: str, strategy: str, spec, distinct_slots,
                        collect_stats: bool = False):
    from repro import sharding as shardlib
    mesh = getattr(getattr(table.data, "sharding", None), "mesh", None)
    if mesh is None:
        mesh = shardlib.active_mesh()
    if mesh is None:
        raise ValueError(
            "execute_until on a sharded AtomicTable needs the mesh: place "
            "the table data with a NamedSharding (make_table under "
            "use_mesh) or call under sharding.use_mesh — the combinator "
            "launches its own shard_map per round, so unlike execute() it "
            "must be called OUTSIDE shard_map")
    axis, rep = _norm_tuple(table.axis), _norm_tuple(table.replica_axes)
    n_dev = math.prod(mesh.shape[a] for a in rep + axis)
    m = int(table.data.shape[0])
    k = len(idx)
    # pad per-device count to a power of two: bounded recompile count as
    # the pending set drains, padding ops target slot m (the OOR-drop
    # convention: no table effect, fetched 0, success False — sliced off)
    per = 1 << max(0, (max(1, -(-k // n_dev)) - 1)).bit_length()
    total = per * n_dev
    tbl_dtype = np.asarray(jnp.zeros((), table.data.dtype)).dtype
    idx_p = np.full(total, m, np.int32)
    idx_p[:k] = idx
    vals_p = np.zeros(total, tbl_dtype)
    vals_p[:k] = vals
    exp_p = np.zeros(total, tbl_dtype)
    if exp is not None:
        exp_p[:k] = exp
    fn = _sharded_round_fn(mesh, axis, rep, kind, backend, strategy, spec,
                           distinct_slots, collect_stats)
    from jax.sharding import NamedSharding, PartitionSpec as P
    op_sh = NamedSharding(mesh, P(rep + axis))
    args = [jax.device_put(jnp.asarray(a), op_sh)
            for a in (idx_p, vals_p, exp_p)]
    info = None
    if telemetry.enabled():
        # the prediction half of the round event: per-op CAS routes to the
        # owner-oracle pass (unpriced); everything else is a combinable
        # exchange the selector can price per strategy
        info = {"tier": "sharded", "n_exec": per, "m": m,
                "n_shards": n_dev // max(1, _rep_size(mesh, rep)),
                "strategy": "perop_oracle", "predicted_s": None}
        if kind != "cas":
            try:
                from repro.core import rmw_sharded as rs
                sizes = [int(mesh.shape[a]) for a in axis]
                sel = rs.select_exchange_with_cost(
                    kind, per, m, rs._mesh_axes(axis, sizes, None),
                    spec=spec, need_fetched=True,
                    distinct_slots=distinct_slots) if strategy == "auto" \
                    else None
                if sel is not None:
                    info.update(strategy=sel.choice,
                                predicted_s=sel.predicted_s)
                else:
                    info.update(strategy=strategy)
            except Exception:  # noqa: BLE001 — never break the round
                pass
    stats = None
    with telemetry.annotation("atomics.retry.exchange"):
        if collect_stats:
            tab, fetched, success, stats = fn(table.data, *args)
        else:
            tab, fetched, success = fn(table.data, *args)
    return (table.with_data(tab), np.asarray(fetched)[:k],
            np.asarray(success)[:k].astype(bool), info, stats)


def _rep_size(mesh, rep: Tuple[str, ...]) -> int:
    return math.prod(int(mesh.shape[a]) for a in rep) if rep else 1


def _exec_round(table: AtomicTable, kind: str, idx: np.ndarray,
                vals: np.ndarray, exp: Optional[np.ndarray], *,
                backend: str, strategy: str, spec, distinct_slots,
                collect_stats: bool = False):
    if table.is_sharded:
        return _exec_round_sharded(table, kind, idx, vals, exp,
                                   backend=backend, strategy=strategy,
                                   spec=spec, distinct_slots=distinct_slots,
                                   collect_stats=collect_stats)
    from repro.atomics.execute import execute
    if kind == "cas":
        op = Cas(jnp.asarray(idx), jnp.asarray(vals),
                 expected=jnp.asarray(exp))
    else:
        op = OP_KINDS[kind](jnp.asarray(idx), jnp.asarray(vals))
    info = None
    if telemetry.enabled():
        from repro.core import rmw_engine
        m = int(table.data.shape[0])
        info = {"tier": "local", "n_exec": len(idx), "m": m,
                "strategy": None, "predicted_s": None}
        try:
            sel = rmw_engine.select_backend_with_cost(
                kind, len(idx), m, spec,
                uniform_expected=kind != "cas", dtype=table.dtype) \
                if backend == "auto" else None
            if sel is not None:
                info.update(backend=sel.choice, predicted_s=sel.predicted_s)
            else:
                info.update(backend=backend)
        except Exception:  # noqa: BLE001 — never break the round
            pass
    res = execute(table, op, need_fetched=True, backend=backend, spec=spec,
                  collect_stats=collect_stats)
    return (res.table, np.asarray(res.fetched),
            np.asarray(res.success).astype(bool), info, res.stats)


# ---------------------------------------------------------------------------
# The combinator
# ---------------------------------------------------------------------------

def _host_distinct(x: np.ndarray) -> int:
    """Round-0 host-side distinct-slot count — the pre-observatory
    estimator observation, kept as the fallback when device-side stats are
    off (and monkeypatchable in tests to prove the hot path skips it)."""
    return int(np.unique(x).size)


def _active_estimator():
    """The running `repro.tuning` controller's contention estimator, or
    None.  sys.modules probing (not an import) keeps `repro.atomics` free
    of the tuning package unless a controller was actually started."""
    import sys
    mod = sys.modules.get("repro.tuning.controller")
    if mod is None:
        return None
    return mod.active_estimator()


def execute_until(table: Union[AtomicTable, Array],
                  make_ops: Callable, *,
                  max_rounds: int = 16,
                  policy: Union[str, RetryPolicy] = "immediate",
                  backend: str = "auto", strategy: str = "auto",
                  spec=None, distinct_slots: Optional[int] = None,
                  collect_stats: Optional[bool] = None,
                  sleep_fn: Callable[[float], None] = time.sleep
                  ) -> RetryResult:
    """Drive a batch of CAS loops to convergence in ``<= max_rounds`` rounds.

    ``make_ops`` is called twice per shape of the loop:

    * ``make_ops(None, None)`` (round 0) must return the initial
      :class:`~repro.atomics.ops.AtomicOp` batch — typically a ``Cas``
      (scalar or per-op ``expected``); any other op kind trivially resolves
      in one round.
    * ``make_ops(slots, observed)`` (later rounds) receives the still-
      pending ops' table slots and their latest fetched pre-images and
      returns the new *values* array for exactly those ops (the ``F`` in
      the lock-free ``CAS(x, v, F(v))``), or a full ``AtomicOp`` over them
      to also override ``expected``, or ``None`` to give up early.  The
      combinator supplies ``expected = observed`` — the CAS-failure
      feedback loop of arxiv 1305.5800.

    The table may be local or mesh-sharded; for a sharded table the
    combinator launches its own ``shard_map`` per round (call it *outside*
    ``shard_map``), scattering each round's pending ops contiguously over
    device ranks so both tiers produce identical round histories.

    Returns a :class:`RetryResult`; ``success`` is all-True iff every op
    resolved within the budget, and ``rounds`` is the per-op contention
    observable (attempts until success).

    ``distinct_slots`` (the exchange selector's contention hint) is
    estimator-backed: when a `repro.tuning.SpecController` is running and
    the caller passes None, the hint comes from the contention estimator's
    EWMA over this call site's observed collision counts (round-0 distinct
    slots + CAS round-histogram winners).  Passing an explicit value
    overrides the estimator; without a controller, None means no hint —
    exactly the pre-tuning behavior.

    ``collect_stats`` controls the round-0 device-side contention pass
    (:class:`~repro.atomics.stats.ContentionStats`, returned in
    ``result.stats``): True forces it, False forces it off, and the
    default None enables it exactly when an estimator is active — the
    estimator then reads ``distinct_slots`` straight from the combine
    pass instead of the host ``np.unique`` fallback, which is skipped
    entirely.  Results are bit-identical in every mode.
    """
    pol = _resolve_policy(policy)
    if max_rounds < 1:
        raise ValueError(f"max_rounds must be >= 1, got {max_rounds}")
    if _contracts._observer is not None:
        # contract annotation for the analyzer: this loop IS round-bounded
        # by construction (rule A003's recommended spelling)
        _contracts.notify("execute_until", table=table,
                          max_rounds=max_rounds, policy=pol.name)
    if not isinstance(table, AtomicTable):
        table = AtomicTable(table)
    op0 = make_ops(None, None)
    if not isinstance(op0, AtomicOp):
        raise TypeError(
            f"make_ops(None, None) must return an atomics op batch "
            f"(got {type(op0).__name__}) — e.g. "
            f"atomics.Cas(indices, values, expected=...)")
    kind = op0.kind
    n = int(op0.indices.shape[0])
    # contention estimator (repro.tuning): when a controller is running
    # and the caller passed no hint, serve the site's EWMA'd observed
    # distinct-slot count as the exchange selector's contention hint —
    # "estimator-backed, hint optional".  Selection-only, like the hint
    # itself: it can never change results.
    est = _active_estimator()
    est_key = None
    if est is not None:
        from repro.tuning.estimator import site_key
        est_key = site_key(kind,
                           "sharded" if table.is_sharded else "local",
                           int(table.data.shape[0]), n)
        if distinct_slots is None and table.is_sharded:
            distinct_slots = est.hint(est_key)
    # device-side stats default: on exactly when an estimator consumes
    # them (the ROADMAP follow-on: feed the EWMA from on-device counts)
    use_device = collect_stats if collect_stats is not None \
        else est is not None
    stats0 = None
    tbl_dtype = np.asarray(jnp.zeros((), table.data.dtype)).dtype
    slots = np.asarray(op0.indices, np.int32).copy()
    values = np.asarray(op0.values, tbl_dtype).copy()
    is_cas = kind == "cas"
    if is_cas:
        expected = np.broadcast_to(
            np.asarray(op0.expected, tbl_dtype), (n,)).copy()
    else:
        expected = None
    observed = (expected.copy() if is_cas
                else np.zeros(n, tbl_dtype))   # latest pre-image per op
    success = np.zeros(n, bool)
    rounds = np.zeros(n, np.int64)
    pending = np.arange(n)

    n_rounds = 0
    while len(pending) and n_rounds < max_rounds:
        rnd = n_rounds
        if rnd > 0:
            d = pol.delay_s(rnd)
            if d > 0:
                sleep_fn(d)
            made = make_ops(slots[pending], observed[pending])
            if made is None:
                break
            if isinstance(made, AtomicOp):
                if made.kind != kind or \
                        int(made.indices.shape[0]) != len(pending):
                    raise ValueError(
                        f"make_ops must re-batch exactly the pending ops: "
                        f"wanted {len(pending)} {kind!r} ops, got "
                        f"{int(made.indices.shape[0])} {made.kind!r}")
                slots[pending] = np.asarray(made.indices, np.int32)
                values[pending] = np.asarray(made.values, tbl_dtype)
                if is_cas:
                    expected[pending] = np.broadcast_to(
                        np.asarray(made.expected, tbl_dtype),
                        (len(pending),))
            else:
                vals_new = np.asarray(made, tbl_dtype)
                if vals_new.shape != (len(pending),):
                    raise ValueError(
                        f"make_ops returned values of shape "
                        f"{vals_new.shape}; want ({len(pending)},) — one "
                        f"value per pending op")
                values[pending] = vals_new
                if is_cas:
                    # the feedback loop: pre-image becomes next expected
                    expected[pending] = observed[pending]
        k = max(1, min(pol.batch_size(len(pending), rnd), len(pending)))
        issue, defer = pending[:k], pending[k:]
        collect_now = use_device and rnd == 0
        if rnd == 0 and not use_device and (est is not None
                                            or telemetry.enabled()):
            # host fallback for the combine pass's collision count: the
            # slots are host numpy already, so the round-0 distinct-slot
            # count is one np.unique away — skipped entirely when the
            # device pass supersedes it or nothing consumes it
            distinct_obs = _host_distinct(slots[issue])
            if est is not None:
                est.update(est_key, distinct_obs)
        else:
            distinct_obs = None
        t0 = time.perf_counter()
        table, fetched, ok, info, st = _exec_round(
            table, kind, slots[issue], values[issue],
            expected[issue] if is_cas else None,
            backend=backend, strategy=strategy, spec=spec,
            distinct_slots=distinct_slots, collect_stats=collect_now)
        if st is not None:
            stats0 = st
            # the round's fetched/success reads just blocked, so the stats
            # leaves are materialized — reading distinct here is one D2H
            # scalar copy, not a sync
            distinct_obs = int(np.asarray(st.distinct_slots))
            if est is not None:
                est.update(est_key, distinct_obs, source="device")
        if info is not None:
            if distinct_obs is not None:
                info["distinct_observed"] = distinct_obs
            # one event per retry round: the pending-count trajectory is
            # the contention signal the ROADMAP's adaptive estimator needs,
            # and (predicted_s, measured_s) feed the exchange-tier drift
            # tracker (the round's fetched/success reads block, so the
            # measured wall covers the full round dispatch+execute)
            telemetry.record(
                "atomics.retry.round", op=kind, policy=pol.name, round=rnd,
                pending=len(pending), issued=int(k),
                resolved=int(ok.sum()),
                measured_s=time.perf_counter() - t0, **info)
        observed[issue] = fetched
        rounds[issue] += 1
        success[issue] = ok
        # freshly failed ops lead the next round: their pre-images are
        # current, so a round issuing any of them always makes progress;
        # deferred ops (stale pre-images under a shrinking policy) trail
        pending = np.concatenate([issue[~ok], defer])
        n_rounds += 1

    if est is not None and is_cas and n_rounds >= 1:
        # the round histogram's second observation of the same quantity:
        # ops resolved on their FIRST attempt = one winner per contended
        # slot + every uncontended op = distinct slots among the issued
        # batch (CAS only — weaker ops resolve in one round regardless)
        est.update(est_key, int(((rounds == 1) & success).sum()))
    if telemetry.enabled():
        # rounds[i] = attempts op i took; bincount over it is the per-call
        # contention histogram (index = attempt count, 0 = never issued)
        hist = np.bincount(rounds.astype(np.int64),
                           minlength=n_rounds + 1).tolist()
        telemetry.record("atomics.retry.done", op=kind, policy=pol.name,
                         n=n, n_rounds=n_rounds,
                         tier="sharded" if table.is_sharded else "local",
                         resolved=int(success.sum()),
                         unresolved=int(len(pending)),
                         attempts=int(rounds.sum()), round_histogram=hist)
        if stats0 is not None and (table.is_sharded or not _tcore._sync):
            # the loop's own sync boundary; the local-tier + sync-mode
            # combination is the one case execute()'s eager sync branch
            # already emitted, so it is excluded to keep one event per
            # collected batch
            telemetry.record_event(_cstats.stats_to_fields(
                stats0, tier="sharded" if table.is_sharded else "local",
                op=kind, n=n, m=int(table.data.shape[0]), round=0))
    return RetryResult(table=table, fetched=observed, success=success,
                       rounds=rounds, n_rounds=n_rounds,
                       pending=np.sort(pending), stats=stats0)
