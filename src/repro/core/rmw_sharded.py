"""Mesh-wide sharded atomics: distributed RMW with hierarchical combining.

The paper's contention study (§5.4) shows aggregate atomic bandwidth
*collapsing* when many agents hammer one line, and §6.2 proposes combining
trees and remote-execution atomics as the fix.  This module is that fix at
mesh scale: a batch of FAA/SWP/MIN/MAX/uniform-CAS ops, issued by every
device of a ``shard_map`` against a table **sharded over mesh axes**, executes
as a two-phase *local-combine-then-owner-resolve* protocol whose results are
bit-identical to a single-device serialized oracle under a documented
cross-device arrival order.

Protocol (one exchange level)::

    phase 1 — pre-combine   each device sorts its local batch by global slot
                            and collapses every same-slot group into ONE
                            combined op using the PR-1 engine
                            (`rmw_engine.execute_backend` on an identity
                            table);
                            group combination is closed under every supported
                            op (FAA: sum, SWP: last, MIN/MAX: min/max,
                            uniform-CAS: first value != expected, else
                            expected).
    route                   combined reps are packed into a padded buffer,
                            one lane of `cap` slots per destination, and
                            exchanged with ONE `lax.all_to_all` over the axis.
    phase 2 — resolve       the owner shard applies the received per-device
                            groups (in source-rank order) with a second
                            engine pass; its fetched values are the *bases* —
                            the slot value each group observed.
    return                  bases flow back through the same `all_to_all`
                            and each device reconstructs exact per-op
                            fetched/success values from (base, local chain).

**Arrival-order contract**: results equal `rmw_serialized` applied to the
concatenation of per-device batches ordered by device rank — lexicographic
over ``replica_axes + axis`` (major to minor), each device's ops in local
order.  Every strategy below realizes the *same* order, so they are
interchangeable bit-for-bit.  The contract (and the owner-major slot->shard
arithmetic realizing it) is reified by `repro.atomics.layout.TableLayout`;
``reverse_ranks=True`` flips it to *descending* device rank — with locally
reversed batches that is a globally reversed op stream, which is what the
SWP+revert BFS scheme needs for its second pass.

Strategies (`strategy=`):

``"oneshot"``       one exchange over the flattened ``axis`` tuple.
``"hierarchical"``  two levels for ``axis=(outer, inner...)``: pre-combine
                    within the inner axes to a per-pod deputy (the owner's
                    inner-rank peer), deputies re-combine and exchange over
                    the outer (DCN) axis only — the paper's combining tree,
                    §6.2.3, spanning pods.  Cross-pod traffic shrinks from
                    ``n_devices·cap`` to ``n_pods·min(...)`` rows.
``"naive"``         no pre-combining: every op routed individually (the
                    paper's measured serialized regime; benchmark baseline).
``"dense"``         pure-FAA table-only degenerate path: local bincount +
                    `psum_scatter` (+ `psum` over replica axes).
``"auto"``          `select_exchange` picks the cheapest strategy from the
                    `HardwareSpec` ICI/DCN exchange terms + the PR-1 backend
                    cost models — the executable form of the paper's Fig. 8
                    crossover.

Out-of-range indices are dropped (fetched 0 / success False), matching the
engine's convention.  CAS supports both expected forms: the combinable
*uniform* scalar (all strategies above) and **per-op expected arrays**,
which cannot be pre-combined (the paper's "wasted work" case) and instead
route every op raw to its owner for a serialized-oracle pass
(`_execute_cas_perop` — the owner-side form of the paper's §6.2
remote-execution atomics).

All entry points must be called INSIDE `shard_map` (they use collectives
over the named axes); the public spelling is `repro.atomics.execute`, which
auto-detects that context.  `indices` are **global** slot ids; the table
argument is the caller's local shard (owner-major layout: global slot ``g``
lives on shard ``g // m_local`` at row ``g % m_local``).
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.atomics.layout import local_row, owner_shard
from repro.core import collective_model, perf_model, rmw_engine
from repro.core.collective_model import MeshAxis
from repro.core.placement import Tier
from repro.core.rmw import OPS, RmwResult, _identity

Array = jax.Array
AxisNames = Union[str, Tuple[str, ...]]

STRATEGIES = ("auto", "oneshot", "hierarchical", "naive", "dense")

#: bytes moved per routed op on the wire (int32 slot id + 4-byte value)
ROW_BYTES = 8


def _axes_tuple(axis: AxisNames) -> Tuple[str, ...]:
    return (axis,) if isinstance(axis, str) else tuple(axis)


def _axis_size(axis: AxisNames) -> int:
    """Static size of a (possibly tuple) mesh axis inside shard_map."""
    return int(jax.lax.psum(1, _axes_tuple(axis)))


# ---------------------------------------------------------------------------
# Phase 1 machinery: sort, pre-combine, pack, reconstruct
# ---------------------------------------------------------------------------

class _Combined(NamedTuple):
    """Bookkeeping of one local pre-combine (all arrays in sorted order)."""

    order: Array        # argsort of the input batch by global slot
    inv: Array          # inverse permutation
    sidx: Array         # sorted global slot ids (invalid == m_global)
    sval: Array         # sorted values
    seg_start: Array    # True at the first op of each same-slot group
    seg_id: Array       # compressed group index per op
    combined: Array     # (n,) combined value per group, dense by seg_id
    loc_fetched: Array  # per-op fetched vs the identity base (None if !need)
    loc_success: Array  # per-op success vs the identity base


def _identity_base(op: str, dtype, expected) -> Array:
    if op == "cas":
        return jnp.asarray(expected, dtype)
    if op in ("min", "max"):
        return _identity(op, dtype)
    return jnp.zeros((), dtype)  # faa, swp (swp base unused: seg_start flags)


def _combine(gidx: Array, vals: Array, op: str, expected, *,
             need_fetched: bool, backend: str, spec) -> _Combined:
    """Collapse a flat batch into one combined op per distinct slot.

    The per-group combine *and* the per-op local chain (fetched/success
    relative to an identity base) come from a single PR-1 engine pass against
    a dense identity table indexed by compressed group id — group combination
    is closed under every supported op, which is what makes the whole
    hierarchy self-similar.
    """
    n = gidx.shape[0]
    order = jnp.argsort(gidx, stable=True)
    inv = jnp.zeros_like(order).at[order].set(
        jnp.arange(n, dtype=order.dtype))
    sidx = gidx[order]
    sval = vals[order]
    seg_start = jnp.concatenate(
        [jnp.ones((1,), bool), sidx[1:] != sidx[:-1]])
    seg_id = jnp.cumsum(seg_start.astype(jnp.int32)) - 1
    ident = jnp.full((n,), _identity_base(op, vals.dtype, expected),
                     vals.dtype)
    exp = None if op != "cas" else jnp.asarray(expected, vals.dtype)
    res = rmw_engine.execute_backend(ident, seg_id, sval, op, exp,
                                     backend=backend, spec=spec,
                                     need_fetched=need_fetched)
    return _Combined(order=order, inv=inv, sidx=sidx, sval=sval,
                     seg_start=seg_start, seg_id=seg_id, combined=res.table,
                     loc_fetched=res.fetched, loc_success=res.success)


class _Stage(NamedTuple):
    """One routed exchange level (pack state kept for the return path)."""

    axis: AxisNames
    n_dest: int
    cap: int
    comb: _Combined
    slotpos: Array      # per-op packed buffer position (scratch if not rep)
    m_global: int
    reverse: bool = False


def _flip_lanes(x: Array, n_dest: int, cap: int) -> Array:
    """Reverse the per-source blocks of a routed flat buffer: the receiver
    processes sources in *descending* rank — the reversed arrival order.
    Involutive, so the return path applies the same flip to undo it."""
    return x.reshape(n_dest, cap)[::-1].reshape(-1)


def _rank_slotpos(dest: Array, valid: Array, n_dest: int, cap: int) -> Array:
    """Packed-exchange position per op: lane = destination rank, row = the
    op's arrival rank among same-destination valid ops (the engine's own
    sort-free FAA-fetch rank, so lanes fill densely in local order — the
    arrival-order contract), scratch (= n_dest * cap) for invalid ops.

    The single home for this packing: the combined (`_push`), naive
    (`_push_naive`) and per-op-CAS (`_push_uncombined`) paths all route
    through it, so the scratch/OOR convention cannot diverge between them.
    """
    key = jnp.where(valid, dest, n_dest)
    rank = rmw_engine._arrival_rank_sortfree(key, n_dest + 1)
    return jnp.where(valid, dest * cap + rank, n_dest * cap)


def _scatter_padded(fill, dtype, slotpos: Array, x: Array,
                    size: int) -> Array:
    """Scatter ``x`` to ``slotpos`` in a ``fill``-initialized (size,)
    buffer; position ``size`` is the dropped scratch row."""
    return jnp.full((size + 1,), fill, dtype).at[slotpos].set(x)[:-1]


def _route_pair(send_idx: Array, send_val: Array, axis: AxisNames,
                n_dest: int, cap: int) -> Tuple[Array, Array]:
    """Move (slot id, combined value) rows with ONE all_to_all.

    4-byte value dtypes ride in the same buffer as the int32 ids (bitcast),
    matching the cost model's single-launch ROW_BYTES pricing; wider dtypes
    fall back to a second collective."""
    if send_val.dtype.itemsize == 4:
        bits = jax.lax.bitcast_convert_type(send_val, jnp.int32)
        packed = jnp.stack([send_idx, bits], axis=-1).reshape(n_dest, cap, 2)
        recv = jax.lax.all_to_all(packed, axis, split_axis=0,
                                  concat_axis=0).reshape(-1, 2)
        return recv[:, 0], jax.lax.bitcast_convert_type(recv[:, 1],
                                                        send_val.dtype)
    recv_idx = jax.lax.all_to_all(send_idx.reshape(n_dest, cap), axis,
                                  split_axis=0, concat_axis=0).reshape(-1)
    recv_val = jax.lax.all_to_all(send_val.reshape(n_dest, cap), axis,
                                  split_axis=0, concat_axis=0).reshape(-1)
    return recv_idx, recv_val


def _push(gidx: Array, vals: Array, op: str, expected, *, axis: AxisNames,
          n_dest: int, dest: Array, cap: int, m_global: int,
          need_fetched: bool, backend: str, spec, reverse: bool = False
          ) -> Tuple[_Stage, Array, Array]:
    """Pre-combine + route one level.  `dest` gives, per op, the destination
    rank on `axis` (same for every op of a group).  Returns the stage record
    and the received flat batch (source-rank-major — the arrival order;
    descending source rank when ``reverse``)."""
    st = _combine(gidx, vals, op, expected, need_fetched=need_fetched,
                  backend=backend, spec=spec)
    dest_s = dest[st.order]
    valid = st.sidx < m_global
    is_rep = st.seg_start & valid
    scratch = n_dest * cap
    slotpos = _rank_slotpos(dest_s, is_rep, n_dest, cap)
    send_idx = _scatter_padded(m_global, jnp.int32, slotpos,
                               jnp.where(is_rep, st.sidx, m_global), scratch)
    send_val = _scatter_padded(0, vals.dtype, slotpos,
                               st.combined[st.seg_id], scratch)
    recv_idx, recv_val = _route_pair(send_idx, send_val, axis, n_dest, cap)
    if reverse:
        recv_idx = _flip_lanes(recv_idx, n_dest, cap)
        recv_val = _flip_lanes(recv_val, n_dest, cap)
    stage = _Stage(axis=axis, n_dest=n_dest, cap=cap, comb=st,
                   slotpos=slotpos, m_global=m_global, reverse=reverse)
    return stage, recv_idx, recv_val


def _pop(stage: _Stage, bases_recv: Array, op: str, expected
         ) -> Tuple[Array, Array]:
    """Return one level: route the resolver's bases back to the sources and
    reconstruct exact per-op fetched/success from (base, local chain)."""
    st = stage.comb
    n = st.sidx.shape[0]
    if stage.reverse:       # undo the receive-side flip before routing back
        bases_recv = _flip_lanes(bases_recv, stage.n_dest, stage.cap)
    ret = jax.lax.all_to_all(bases_recv.reshape(stage.n_dest, stage.cap),
                             stage.axis, split_axis=0,
                             concat_axis=0).reshape(-1)
    ret = jnp.concatenate([ret, jnp.zeros((1,), ret.dtype)])
    base_rep = ret[stage.slotpos]                     # scratch -> 0
    base_seg = jnp.zeros((n + 1,), ret.dtype).at[
        jnp.where(st.seg_start, st.seg_id, n)].set(base_rep)
    base = base_seg[st.seg_id]                        # per sorted op
    if op == "faa":
        fetched = base + st.loc_fetched
        success = jnp.ones((n,), bool)
    elif op in ("min", "max"):
        comb = jnp.minimum if op == "min" else jnp.maximum
        fetched = comb(base, st.loc_fetched)
        success = jnp.ones((n,), bool)
    elif op == "swp":
        fetched = jnp.where(st.seg_start, base, st.loc_fetched)
        success = jnp.ones((n,), bool)
    else:  # cas (uniform): the local chain assumed base == expected
        exp = jnp.asarray(expected, base.dtype)
        live = base == exp
        fetched = jnp.where(live, st.loc_fetched, base)
        success = live & st.loc_success
    valid = st.sidx < stage.m_global
    fetched = jnp.where(valid, fetched, jnp.zeros((), fetched.dtype))
    success = success & valid
    return fetched[st.inv], success[st.inv]


# ---------------------------------------------------------------------------
# Contention observatory (PR 10): stats from inside the combine passes
# ---------------------------------------------------------------------------

def _stage_level_counts(stages, m_global: int, all_axes: Tuple[str, ...]):
    """Per-exchange-level combining efficiency from the stage bookkeeping.

    Each `_Stage` already materializes the collision structure of its
    pre-combine (`comb.seg_start` marks group representatives, `comb.sidx`
    flags validity) — so ops-in / ops-out per level are free reductions over
    arrays the protocol computed anyway.  Every logical op lives on exactly
    one device at any level, so a psum over all participating axes counts
    each exactly once.
    """
    level_in, level_out = [], []
    for st_ in stages:
        v = st_.comb.sidx < m_global
        level_in.append(jax.lax.psum(v.sum(dtype=jnp.int32), all_axes))
        level_out.append(jax.lax.psum(
            (st_.comb.seg_start & v).sum(dtype=jnp.int32), all_axes))
    return level_in, level_out


def _contention_stats(gidx: Array, *, m_loc: int, m_global: int,
                      shard_axes: Tuple[str, ...],
                      rep_axes: Tuple[str, ...], level_in, level_out):
    """Mesh-global `ContentionStats` from per-device global slot ids.

    The occupancy reduction is the dense strategy's own psum_scatter pass
    run on unit values: each owner shard ends up holding the exact writer
    count for its rows, and the scalar observables reduce from there
    (replicated across the mesh, so shard_map out_specs use `P()`).
    """
    from repro.atomics import stats as _cstats

    occ = jnp.zeros((m_global + 1,), jnp.int32).at[gidx].add(1)[:-1]
    occ_own = jax.lax.psum_scatter(occ, shard_axes, scatter_dimension=0,
                                   tiled=True)
    if rep_axes:
        occ_own = jax.lax.psum(occ_own, rep_axes)
    all_axes = shard_axes + rep_axes
    n_ops = jax.lax.psum((gidx < m_global).sum(dtype=jnp.int32), all_axes)
    distinct = jax.lax.psum((occ_own > 0).sum(dtype=jnp.int32), shard_axes)
    max_occ = jax.lax.pmax(jnp.max(occ_own).astype(jnp.int32), shard_axes)
    hist = jax.lax.psum(_cstats.occupancy_hist(occ_own), shard_axes)
    # top-k: local candidates with global slot ids, re-ranked after a gather
    shard = jax.lax.axis_index(shard_axes).astype(jnp.int32)
    ids = shard * m_loc + jnp.arange(m_loc, dtype=jnp.int32)
    slots_l, counts_l = _cstats.topk_hot(occ_own, ids)
    slots_g = jax.lax.all_gather(slots_l, shard_axes, tiled=True)
    counts_g = jax.lax.all_gather(counts_l, shard_axes, tiled=True)
    slots_k, counts_k = _cstats.topk_hot(counts_g, slots_g)
    return _cstats.ContentionStats(
        n_ops=n_ops, distinct_slots=distinct, max_occupancy=max_occ,
        occupancy_hist=hist, topk_slots=slots_k, topk_counts=counts_k,
        level_ops_in=_cstats._level_array(level_in),
        level_ops_out=_cstats._level_array(level_out))


# ---------------------------------------------------------------------------
# The distributed executor
# ---------------------------------------------------------------------------

def execute_sharded(table: Array, indices: Array, values: Array, op: str,
                    expected: Optional[Array] = None, *, axis: AxisNames,
                    replica_axes: AxisNames = (), strategy: str = "auto",
                    backend: str = "auto",
                    spec: Optional[perf_model.HardwareSpec] = None,
                    axis_tiers: Optional[Sequence[Tier]] = None,
                    need_fetched: bool = True,
                    distinct_slots: Optional[int] = None,
                    reverse_ranks: bool = False,
                    collect_stats: bool = False):
    """Execute an RMW batch against a mesh-sharded table (inside shard_map).

    The distributed tier of the unified front-end — call it through
    `repro.atomics.execute`; this raw-array spelling is the internal entry.

    `table` is this device's shard (global slot ``g`` owned by shard
    ``g // m_local``, shards laid out major-to-minor over the ``axis``
    tuple); `indices` are global.  With `replica_axes`, the table is
    replicated over those axes (every replica holds the same shard) and
    writers on all replicas serialize replica-major; the updated shard is
    broadcast back so replicas stay identical.

    CAS accepts both expected forms: a scalar (uniform — pre-combinable,
    every strategy) or a per-op array, which cannot be pre-combined (the
    paper's "wasted work" case) and instead routes every op *un-combined*
    to its owner, which applies the serialized oracle over the received
    batch in device-rank order.  On that path ``strategy`` is ignored and
    ``backend`` must be "auto" or "serialized" (anything else raises, like
    the local tier).

    ``distinct_slots`` optionally feeds an observed distinct-slot estimate
    (e.g. the previous step's counts) to `select_exchange`, sharpening the
    one-shot-vs-hierarchical crossover for skewed batches; it never changes
    results, only the ``strategy="auto"`` choice.

    ``reverse_ranks`` flips the arrival-order contract to *descending*
    device rank (every exchange level processes sources in reverse): results
    then equal `rmw_serialized` on the batches concatenated in reverse
    device order.  Callers wanting a fully reversed global stream also
    reverse their local batch — see ``bfs_sharded(op="swp")``.

    Returns the PR-1 :class:`RmwResult` contract: results bit-identical to
    `rmw_serialized` on the device-rank-ordered concatenated batch (see
    module docstring), with `need_fetched=False` skipping the entire return
    path (fetched/success are zero placeholders).

    ``collect_stats=True`` (PR 10) additionally returns mesh-global
    :class:`repro.atomics.stats.ContentionStats` — the return becomes
    ``(RmwResult, ContentionStats)``.  Stats are read out of the combine
    passes' own bookkeeping (occupancy via the dense psum_scatter reduction,
    per-level efficiency from each `_Stage`'s seg_start flags), never change
    results, and stay device arrays (replicated: use `P()` out_specs).
    """
    if op not in OPS:
        raise ValueError(f"unknown op {op!r}")
    if op == "cas" and expected is None:
        raise ValueError("cas requires `expected`")
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}; have {STRATEGIES}")

    shard_axes = _axes_tuple(axis)
    rep_axes = _axes_tuple(replica_axes) if replica_axes else ()
    sizes = [_axis_size(a) for a in shard_axes]
    n_shards = math.prod(sizes)
    n_rep = _axis_size(rep_axes) if rep_axes else 1
    m_loc = int(table.shape[0])
    m_global = m_loc * n_shards
    n = int(indices.shape[0])

    if op == "cas" and jnp.ndim(expected) != 0:
        # the owner resolve is a serialized-oracle pass by construction —
        # mirror the local tier's error instead of silently ignoring an
        # explicit non-oracle backend override
        if backend not in ("auto", "serialized"):
            raise ValueError(
                f"backend {backend!r} supports CAS only with a scalar "
                f"(uniform) `expected`; per-op expected arrays execute on "
                f"the serialized oracle at the owner shard")
        return _execute_cas_perop(
            table, indices, values, expected, shard_axes=shard_axes,
            rep_axes=rep_axes, n_shards=n_shards, n_rep=n_rep, m_loc=m_loc,
            m_global=m_global, need_fetched=need_fetched, spec=spec,
            reverse=reverse_ranks, collect_stats=collect_stats)

    if strategy == "auto":
        strategy = select_exchange(
            op, n, m_global, _mesh_axes(shard_axes, sizes, axis_tiers),
            spec=spec, need_fetched=need_fetched,
            uniform_expected=True, replicas=n_rep,
            distinct_slots=distinct_slots)
    if strategy == "hierarchical" and len(shard_axes) < 2:
        strategy = "oneshot"
    if strategy == "dense" and not (op == "faa" and not need_fetched):
        raise ValueError("strategy='dense' is the pure-FAA table-only path")
    # dense is pure commutative FAA — every arrival order yields the same
    # table, so reverse_ranks is trivially satisfied there.

    gidx = indices.astype(jnp.int32)
    gidx = jnp.where((gidx < 0) | (gidx >= m_global), m_global, gidx)
    zero_f = jnp.zeros((n,), values.dtype)
    zero_s = jnp.zeros((n,), bool)

    if strategy == "dense":
        dense = jnp.zeros((m_global + 1,), values.dtype
                          ).at[gidx].add(values)[:-1]
        delta = jax.lax.psum_scatter(dense, shard_axes, scatter_dimension=0,
                                     tiled=True)
        if rep_axes:
            delta = jax.lax.psum(delta, rep_axes)
        result = RmwResult(table + delta, zero_f, zero_s)
        if collect_stats:  # dense has no exchange levels: L = 0
            return result, _contention_stats(
                gidx, m_loc=m_loc, m_global=m_global, shard_axes=shard_axes,
                rep_axes=rep_axes, level_in=(), level_out=())
        return result

    # --- build the exchange pipeline (innermost level first) --------------
    stages = []
    cur_idx, cur_vals = gidx, values
    if strategy == "naive":
        # route every op individually: pre-combining disabled by giving each
        # op a unique routing key... simpler: one stage with cap = n and no
        # combining is emulated by tagging ops with their position so no two
        # share a group.  The owner still resolves in arrival order.
        cur_idx, cur_vals, stages = _push_naive(
            gidx, vals=values, op=op, expected=expected,
            axis=shard_axes, n_shards=n_shards, m_loc=m_loc,
            m_global=m_global, need_fetched=need_fetched,
            reverse=reverse_ranks)
    elif strategy == "oneshot" or len(shard_axes) == 1:
        dest = owner_shard(cur_idx, m_loc, n_shards)
        cap = min(n, m_loc)
        stage, cur_idx, cur_vals = _push(
            cur_idx, cur_vals, op, expected, axis=shard_axes,
            n_dest=n_shards, dest=dest, cap=cap, m_global=m_global,
            need_fetched=need_fetched, backend=backend, spec=spec,
            reverse=reverse_ranks)
        stages.append(stage)
    else:  # hierarchical: inner axes to the deputy, outer axis to the owner
        inner = shard_axes[1:]
        n_inner = math.prod(sizes[1:])
        n_outer = sizes[0]
        dest1 = owner_shard(cur_idx, m_loc, n_shards) % n_inner
        cap1 = min(n, m_loc * n_outer)
        stage, cur_idx, cur_vals = _push(
            cur_idx, cur_vals, op, expected, axis=inner, n_dest=n_inner,
            dest=dest1, cap=cap1, m_global=m_global,
            need_fetched=need_fetched, backend=backend, spec=spec,
            reverse=reverse_ranks)
        stages.append(stage)
        dest2 = owner_shard(cur_idx, m_loc * n_inner, n_outer)
        cap2 = min(n_inner * cap1, m_loc)
        stage, cur_idx, cur_vals = _push(
            cur_idx, cur_vals, op, expected, axis=shard_axes[0],
            n_dest=n_outer, dest=dest2, cap=cap2, m_global=m_global,
            need_fetched=need_fetched, backend=backend, spec=spec,
            reverse=reverse_ranks)
        stages.append(stage)

    if rep_axes:  # serialize replica groups at replica rank 0
        dest_r = jnp.zeros(cur_idx.shape, jnp.int32)
        cap_r = min(int(cur_idx.shape[0]), m_loc)
        stage, cur_idx, cur_vals = _push(
            cur_idx, cur_vals, op, expected, axis=rep_axes, n_dest=n_rep,
            dest=dest_r, cap=cap_r, m_global=m_global,
            need_fetched=need_fetched, backend=backend, spec=spec,
            reverse=reverse_ranks)
        stages.append(stage)

    # --- resolve at the owner ---------------------------------------------
    shard = jax.lax.axis_index(shard_axes)
    row = local_row(cur_idx, shard, m_loc, m_global)
    res = rmw_engine.execute_backend(
        table, row, cur_vals, op,
        None if op != "cas" else jnp.asarray(expected, table.dtype),
        backend=backend, spec=spec, need_fetched=need_fetched)
    new_table = res.table
    if rep_axes:
        # only replica rank 0 received real ops; broadcast its shard update
        new_table = table + jax.lax.psum(new_table - table, rep_axes)

    stats = None
    if collect_stats:
        level_in, level_out = _stage_level_counts(
            stages, m_global, shard_axes + rep_axes)
        stats = _contention_stats(
            gidx, m_loc=m_loc, m_global=m_global, shard_axes=shard_axes,
            rep_axes=rep_axes, level_in=level_in, level_out=level_out)

    if not need_fetched:
        result = RmwResult(new_table, zero_f, zero_s)
        return (result, stats) if collect_stats else result

    # --- unwind: bases flow back down the tree ----------------------------
    bases = res.fetched.astype(values.dtype)
    for stage in reversed(stages):
        bases, success = _pop(stage, bases, op, expected)
    result = RmwResult(new_table, bases, success)
    return (result, stats) if collect_stats else result


def _push_naive(gidx, vals, op, expected, axis, n_shards, m_loc, m_global,
                need_fetched, reverse=False):
    """The no-combining baseline: each op is its own routed group.

    Packing is by per-destination arrival rank over *all* ops (cap = n), so
    the owner sees every individual op in source-rank-then-local order —
    the serialized ping-pong regime the paper measures (one line-ownership
    transfer per op), which the benchmark uses as the contention baseline.
    """
    n = gidx.shape[0]
    dest = owner_shard(gidx, m_loc, n_shards)
    valid = gidx < m_global
    cap = n
    scratch = n_shards * cap
    slotpos = _rank_slotpos(dest, valid, n_shards, cap)
    send_idx = _scatter_padded(m_global, jnp.int32, slotpos, gidx, scratch)
    send_val = _scatter_padded(0, vals.dtype, slotpos, vals, scratch)
    recv_idx, recv_val = _route_pair(send_idx, send_val, axis, n_shards, cap)
    if reverse:
        recv_idx = _flip_lanes(recv_idx, n_shards, cap)
        recv_val = _flip_lanes(recv_val, n_shards, cap)
    comb = _Combined(order=jnp.arange(n), inv=jnp.arange(n), sidx=gidx,
                     sval=vals, seg_start=jnp.ones((n,), bool),
                     seg_id=jnp.arange(n, dtype=jnp.int32),
                     combined=vals,
                     loc_fetched=jnp.full((n,), _identity_base(
                         op, vals.dtype, expected), vals.dtype),
                     loc_success=jnp.ones((n,), bool))
    stage = _Stage(axis=axis, n_dest=n_shards, cap=cap, comb=comb,
                   slotpos=slotpos, m_global=m_global, reverse=reverse)
    return recv_idx, recv_val, [stage]


# ---------------------------------------------------------------------------
# Per-op-expected CAS: owner-side oracle pass over un-combined ops
# ---------------------------------------------------------------------------

def _route_flat(buf: Array, axis: AxisNames, n_dest: int, cap: int) -> Array:
    """One padded all_to_all of a flat (n_dest * cap,) payload buffer."""
    return jax.lax.all_to_all(buf.reshape(n_dest, cap), axis, split_axis=0,
                              concat_axis=0).reshape(-1)


def _route_cols(cols, axis: AxisNames, n_dest: int, cap: int):
    """Move several same-length payload columns over one exchange.

    4-byte columns ride together as one bitcast-packed (n_dest, cap, k)
    buffer — ONE all_to_all launch total, the same single-launch pricing
    `_route_pair` gets for its (id, value) rows; any wider dtype falls back
    to one collective per column."""
    if all(c.dtype.itemsize == 4 for c in cols):
        bits = [jax.lax.bitcast_convert_type(c, jnp.int32) for c in cols]
        packed = jnp.stack(bits, axis=-1).reshape(n_dest, cap, len(cols))
        recv = jax.lax.all_to_all(packed, axis, split_axis=0,
                                  concat_axis=0).reshape(-1, len(cols))
        return tuple(jax.lax.bitcast_convert_type(recv[:, j], c.dtype)
                     for j, c in enumerate(cols))
    return tuple(_route_flat(c, axis, n_dest, cap) for c in cols)


def _push_uncombined(gidx: Array, vals: Array, exps: Array, *,
                     axis: AxisNames, n_dest: int, dest: Array,
                     m_global: int, reverse: bool = False):
    """Route (slot id, value, expected) rows with NO pre-combining.

    Like `_push_naive`, packing is by per-destination arrival rank over all
    valid ops (cap = n, the un-combinable worst case), so the receiver sees
    every individual op in source-rank-then-local order — exactly the
    arrival-order contract.  Returns (slotpos, recv_idx, recv_val, recv_exp).
    """
    n = gidx.shape[0]
    valid = gidx < m_global
    cap = n
    slotpos = _rank_slotpos(dest, valid, n_dest, cap)
    scratch = n_dest * cap
    send_idx = _scatter_padded(m_global, jnp.int32, slotpos, gidx, scratch)
    send_val = _scatter_padded(0, vals.dtype, slotpos, vals, scratch)
    send_exp = _scatter_padded(0, exps.dtype, slotpos, exps, scratch)
    recv_idx, recv_val, recv_exp = _route_cols(
        (send_idx, send_val, send_exp), axis, n_dest, cap)
    if reverse:
        recv_idx, recv_val, recv_exp = (
            _flip_lanes(c, n_dest, cap)
            for c in (recv_idx, recv_val, recv_exp))
    return slotpos, recv_idx, recv_val, recv_exp


def _execute_cas_perop(table: Array, indices: Array, values: Array,
                       expected: Array, *, shard_axes: Tuple[str, ...],
                       rep_axes: Tuple[str, ...], n_shards: int, n_rep: int,
                       m_loc: int, m_global: int, need_fetched: bool,
                       spec, reverse: bool = False,
                       collect_stats: bool = False):
    """Cross-shard CAS with per-op expected values (ROADMAP closure).

    Per-op expected CAS chains do not compose associatively (the combined
    effect of a group depends on each op's own expected value), so nothing
    can be pre-combined — the paper's "wasted work" regime.  Instead every
    op is routed raw to its owner shard (`_push_uncombined`, replica stage
    included), which applies the **serialized oracle** — the only
    general-CAS backend — over the received batch in device-rank order.
    The owner's per-op fetched values ARE the final fetched values (no
    local chain to recombine); success is recomputed at the source as
    ``fetched == expected``.  Results are bit-identical to `rmw_serialized`
    on the device-rank-ordered concatenated batch, same as every other op.
    """
    n = int(indices.shape[0])
    gidx = indices.astype(jnp.int32)
    gidx = jnp.where((gidx < 0) | (gidx >= m_global), m_global, gidx)
    exp = jnp.asarray(expected, table.dtype)

    stages = []                     # (axis, n_dest, cap, slotpos)
    cur_idx, cur_val, cur_exp = gidx, values, exp
    dest = owner_shard(cur_idx, m_loc, n_shards)
    slotpos, cur_idx, cur_val, cur_exp = _push_uncombined(
        cur_idx, cur_val, cur_exp, axis=shard_axes, n_dest=n_shards,
        dest=dest, m_global=m_global, reverse=reverse)
    stages.append((shard_axes, n_shards, n, slotpos))
    if rep_axes:                    # serialize replica groups at rank 0
        n2 = int(cur_idx.shape[0])
        dest_r = jnp.zeros((n2,), jnp.int32)
        slotpos, cur_idx, cur_val, cur_exp = _push_uncombined(
            cur_idx, cur_val, cur_exp, axis=rep_axes, n_dest=n_rep,
            dest=dest_r, m_global=m_global, reverse=reverse)
        stages.append((rep_axes, n_rep, n2, slotpos))

    shard = jax.lax.axis_index(shard_axes)
    row = local_row(cur_idx, shard, m_loc, m_global)
    res = rmw_engine.execute_backend(table, row, cur_val, "cas", cur_exp,
                                     backend="serialized", spec=spec,
                                     need_fetched=need_fetched)
    new_table = res.table
    if rep_axes:                    # broadcast replica rank 0's update
        new_table = table + jax.lax.psum(new_table - table, rep_axes)

    stats = None
    if collect_stats:
        # un-combinable by construction: every level moves each op raw, so
        # ops-in == ops-out at every level (the measured "wasted work").
        all_axes = shard_axes + rep_axes
        n_valid = jax.lax.psum((gidx < m_global).sum(dtype=jnp.int32),
                               all_axes)
        levels = [n_valid] * len(stages)
        stats = _contention_stats(
            gidx, m_loc=m_loc, m_global=m_global, shard_axes=shard_axes,
            rep_axes=rep_axes, level_in=levels, level_out=levels)

    zero_f = jnp.zeros((n,), values.dtype)
    zero_s = jnp.zeros((n,), bool)
    if not need_fetched:
        result = RmwResult(new_table, zero_f, zero_s)
        return (result, stats) if collect_stats else result

    bases = res.fetched.astype(values.dtype)
    for axis, n_dest, cap, slotpos in reversed(stages):
        if reverse:                 # undo the receive-side flip per level
            bases = _flip_lanes(bases, n_dest, cap)
        ret = _route_flat(bases, axis, n_dest, cap)
        ret = jnp.concatenate([ret, jnp.zeros((1,), ret.dtype)])
        bases = ret[slotpos]        # scratch -> 0
    valid = gidx < m_global
    fetched = jnp.where(valid, bases, zero_f)
    success = valid & (bases == exp.astype(values.dtype))
    result = RmwResult(new_table, fetched, success)
    return (result, stats) if collect_stats else result


# ---------------------------------------------------------------------------
# Cost model: the distributed tier of the paper's L(A, S) decision procedure
# ---------------------------------------------------------------------------

def _mesh_axes(names: Sequence[str], sizes: Sequence[int],
               tiers: Optional[Sequence[Tier]]) -> Tuple[MeshAxis, ...]:
    """Default topology: outermost axis crosses pods (DCN) when there is more
    than one level; everything else rides the ICI torus."""
    if tiers is None:
        tiers = [Tier.DCN_REMOTE_POD if (i == 0 and len(names) > 1)
                 else Tier.ICI_NEIGHBOR for i in range(len(names))]
    return tuple(MeshAxis(name=n, size=s, tier=t)
                 for n, s, t in zip(names, sizes, tiers))


def _cost_engine(spec, op: str, n: int, m: int, need_fetched: bool) -> float:
    """Cheapest local-backend prediction — phase-1/phase-2 engine passes."""
    cands = [b for b in rmw_engine.BACKENDS.values()
             if b.supports(op, uniform_expected=True)]
    return min(b.cost(spec, op, max(n, 1), max(m, 1), need_fetched)
               for b in cands)


def _level_sharing(axes: Sequence[MeshAxis], i: int, senders: int) -> int:
    """Concurrent senders squeezing through one link of level ``i``.

    ICI torus links are per-device (no sharing); the DCN uplink is one pipe
    per pod, shared by every in-pod device participating in the exchange —
    the inner axes' sizes (times any extra ``senders`` the caller knows
    about, e.g. deputies at a hierarchical outer level)."""
    if axes[i].tier is not Tier.DCN_REMOTE_POD:
        return 1
    return senders * math.prod(a.size for a in axes[i + 1:])


def _a2a_s(spec, nbytes: int, axes: Sequence[MeshAxis],
           senders: int = 1) -> float:
    """One padded all_to_all over (possibly flattened) axes.

    A flattened a2a decomposes into one transpose step per mesh axis, each
    carrying the full per-device payload (no combining between steps, so the
    payload does not shrink — that is exactly what the hierarchical strategy
    adds).  One software launch total; DCN levels pay the shared-uplink
    penalty of :func:`_level_sharing`.
    """
    t = spec.collective_launch_s
    for i, ax in enumerate(axes):
        if ax.size > 1:
            t += collective_model.collective_time_s(
                spec, "all_to_all", nbytes * _level_sharing(axes, i, senders),
                ax)
    return t


def _rs_s(spec, nbytes: int, axes: Sequence[MeshAxis]) -> float:
    """Hierarchical reduce_scatter over flattened axes: the inner level
    carries the full payload, each outer level 1/size of the previous."""
    t = spec.collective_launch_s
    share = float(nbytes)
    for i in reversed(range(len(axes))):  # inner (fast) first
        ax = axes[i]
        if ax.size > 1:
            t += collective_model.collective_time_s(
                spec, "reduce_scatter",
                int(share) * _level_sharing(axes, i, 1), ax)
            share /= ax.size
    return t


def _cap_hint(cap: int, distinct_slots: Optional[int]) -> int:
    """Tighten a worst-case exchange cap with an observed distinct-slot
    estimate (the dynamic contention hint): after pre-combining, at most one
    row per distinct slot survives, so the *expected* payload is bounded by
    the estimate even though the padded worst-case buffer is not.  Selection
    only — the executor's real caps stay worst-case correct."""
    if distinct_slots is None:
        return cap
    return max(1, min(cap, int(distinct_slots)))


def cost_exchange_oneshot(spec, op: str, n: int, m_global: int,
                          axes: Sequence[MeshAxis],
                          need_fetched: bool = True,
                          distinct_slots: Optional[int] = None) -> float:
    n_shards = math.prod(a.size for a in axes)
    m_loc = max(1, m_global // n_shards)
    cap = _cap_hint(min(n, m_loc), distinct_slots)
    t = _cost_engine(spec, op, n, n, need_fetched)           # pre-combine
    t += _a2a_s(spec, n_shards * cap * ROW_BYTES, axes)      # route
    t += _cost_engine(spec, op, n_shards * cap, m_loc, need_fetched)
    if need_fetched:
        t += _a2a_s(spec, n_shards * cap * 4, axes)          # bases back
        t += 3 * n * (spec.gather_elem_s or 2e-9)            # reconstruct
    return t


def cost_exchange_hierarchical(spec, op: str, n: int, m_global: int,
                               axes: Sequence[MeshAxis],
                               need_fetched: bool = True,
                               distinct_slots: Optional[int] = None) -> float:
    if len(axes) < 2:
        return float("inf")
    n_shards = math.prod(a.size for a in axes)
    n_outer = axes[0].size
    n_inner = n_shards // n_outer
    m_loc = max(1, m_global // n_shards)
    cap1 = _cap_hint(min(n, m_loc * n_outer), distinct_slots)
    cap2 = _cap_hint(min(n_inner * cap1, m_loc), distinct_slots)
    t = _cost_engine(spec, op, n, n, need_fetched)           # pre-combine
    t += _a2a_s(spec, n_inner * cap1 * ROW_BYTES, axes[1:])  # ICI to deputy
    t += _cost_engine(spec, op, n_inner * cap1, n_inner * cap1, need_fetched)
    t += _a2a_s(spec, n_outer * cap2 * ROW_BYTES, axes[:1],  # DCN to owner
                senders=n_inner)
    t += _cost_engine(spec, op, n_outer * cap2, m_loc, need_fetched)
    if need_fetched:
        t += _a2a_s(spec, n_outer * cap2 * 4, axes[:1], senders=n_inner)
        t += _a2a_s(spec, n_inner * cap1 * 4, axes[1:])
        t += 3 * (n + n_inner * cap1) * (spec.gather_elem_s or 2e-9)
    return t


def cost_exchange_naive(spec, op: str, n: int, m_global: int,
                        axes: Sequence[MeshAxis],
                        need_fetched: bool = True,
                        distinct_slots: Optional[int] = None) -> float:
    del distinct_slots              # no combining: every op ships regardless
    n_shards = math.prod(a.size for a in axes)
    m_loc = max(1, m_global // n_shards)
    t = _a2a_s(spec, n_shards * n * ROW_BYTES, axes)
    t += _cost_engine(spec, op, n_shards * n, m_loc, need_fetched)
    if need_fetched:
        t += _a2a_s(spec, n_shards * n * 4, axes)
    return t


def cost_exchange_dense(spec, op: str, n: int, m_global: int,
                        axes: Sequence[MeshAxis],
                        need_fetched: bool = True,
                        distinct_slots: Optional[int] = None) -> float:
    del distinct_slots              # dense path always moves the full table
    if op != "faa" or need_fetched:
        return float("inf")
    gather = spec.gather_elem_s or 2e-9
    return (n + m_global) * gather + _rs_s(spec, 4 * m_global, axes)


EXCHANGE_COSTS = {
    "oneshot": cost_exchange_oneshot,
    "hierarchical": cost_exchange_hierarchical,
    "naive": cost_exchange_naive,
    "dense": cost_exchange_dense,
}


def select_exchange(op: str, n: int, m_global: int,
                    axes: Sequence[MeshAxis], *,
                    spec: Optional[perf_model.HardwareSpec] = None,
                    need_fetched: bool = True, uniform_expected: bool = True,
                    replicas: int = 1, include_naive: bool = False,
                    distinct_slots: Optional[int] = None) -> str:
    """Cheapest distributed strategy for (op, n/device, table, topology).

    This is `select_backend`'s distributed tier: the same HardwareSpec
    constants, extended with the ICI/DCN exchange terms, decide one-shot vs
    hierarchical (per-pod then cross-pod) combining — the paper's Fig. 8
    crossover as a decision procedure.  `naive` (the measured per-op
    baseline) is priced in `EXCHANGE_COSTS` but excluded from auto selection
    unless `include_naive`: its padded exchange buffer is ``n_shards * n``
    rows, which is memory-hostile even in the cells where skipping the
    pre-combine pass would nominally win.

    ``distinct_slots`` is the **dynamic contention hint** (ROADMAP): an
    observed estimate of how many distinct slots the batch touches (e.g.
    the previous step's counts).  The static costs assume the worst-case
    exchange caps (bounded only by batch and table size); a skewed batch
    that actually touches few slots pre-combines to almost nothing, where
    the hierarchy's extra level of launches and engine passes no longer
    pays for its DCN savings — the hint shifts that crossover.  Selection
    only: results never depend on it.
    """
    return select_exchange_with_cost(
        op, n, m_global, axes, spec=spec, need_fetched=need_fetched,
        uniform_expected=uniform_expected, replicas=replicas,
        include_naive=include_naive, distinct_slots=distinct_slots).choice


def select_exchange_with_cost(op: str, n: int, m_global: int,
                              axes: Sequence[MeshAxis], *,
                              spec: Optional[perf_model.HardwareSpec] = None,
                              need_fetched: bool = True,
                              uniform_expected: bool = True,
                              replicas: int = 1,
                              include_naive: bool = False,
                              distinct_slots: Optional[int] = None
                              ) -> rmw_engine.Selection:
    """`select_exchange` returning the full predicted-cost record
    (`rmw_engine.Selection`) — persisted by the telemetry decision events
    so the exchange tier's drift is trackable per strategy."""
    if op not in OPS:
        raise ValueError(f"unknown op {op!r}")
    if op == "cas" and not uniform_expected:
        raise ValueError(
            "select_exchange prices pre-combined exchanges; per-op expected "
            "CAS always executes on the un-combined owner-oracle path")
    spec = spec or rmw_engine.default_spec()
    del replicas  # the replica stage cost is identical across strategies
    costs = {name: fn(spec, op, n, m_global, axes, need_fetched,
                      distinct_slots=distinct_slots)
             for name, fn in EXCHANGE_COSTS.items()
             if name != "naive" or include_naive}
    best = min(costs, key=costs.get)   # ties: EXCHANGE_COSTS order, as ever
    return rmw_engine.Selection(best, costs[best], costs)
