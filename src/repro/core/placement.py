"""Placement states — the TPU analogue of the paper's cache-coherency states.

The paper parameterizes the cost of an atomic by the coherency state S of the
accessed cache line (M/E/S/O) *and* its proximity (local L1/L2/L3, remote die,
remote socket, memory).  On a TPU there is no dynamic coherence protocol; the
authoritative copy of a datum lives where the sharding puts it.  What survives
of the paper's S axis is therefore a *placement* axis (which memory tier / how
many interconnect hops away the owner is) plus a *replica count* (how many
copies must be invalidated-or-updated — the paper's Shared-vs-Exclusive
distinction, Eq. (7)/(8)).
"""

from __future__ import annotations

import enum
from dataclasses import dataclass


class Tier(enum.Enum):
    """Memory tier holding the authoritative copy (proximity axis)."""

    VREG = "vreg"                 # vector registers        (paper: local L1 hit)
    VMEM = "vmem"                 # on-chip scratchpad      (paper: local L2)
    HBM_LOCAL = "hbm_local"       # chip-local HBM          (paper: local L3/mem)
    ICI_NEIGHBOR = "ici_neighbor" # 1 ICI hop               (paper: on-chip remote core)
    ICI_FAR = "ici_far"           # multi-hop ICI (torus)   (paper: remote die, same CPU)
    DCN_REMOTE_POD = "dcn_remote" # different pod over DCN  (paper: remote socket)
    HOST = "host"                 # host DRAM over PCIe     (paper: main memory)


class Ownership(enum.Enum):
    """Replica-count abstraction of the paper's M/E/S/O states.

    EXCLUSIVE  — single authoritative copy (paper E/M): read-for-ownership is a
                 plain transfer, no invalidations (paper Eq. (2)).
    SHARED     — ``n_replicas`` copies exist (paper S/O): acquiring ownership
                 must invalidate/update all replicas; replicas act in parallel so
                 the *max* latency dominates (paper Eq. (7)).
    """

    EXCLUSIVE = "exclusive"
    SHARED = "shared"


@dataclass(frozen=True)
class PlacementState:
    """Full placement state S of an operand: (tier, ownership, replica count)."""

    tier: Tier
    ownership: Ownership = Ownership.EXCLUSIVE
    n_replicas: int = 1
    # Hop count for ICI_FAR placements (torus distance); ignored otherwise.
    hops: int = 1

    def __post_init__(self) -> None:
        if self.ownership is Ownership.SHARED and self.n_replicas < 2:
            raise ValueError("SHARED placement requires n_replicas >= 2")
        if self.ownership is Ownership.EXCLUSIVE and self.n_replicas != 1:
            raise ValueError("EXCLUSIVE placement requires n_replicas == 1")
        if self.hops < 1:
            raise ValueError("hops must be >= 1")

    @property
    def short(self) -> str:
        own = "E" if self.ownership is Ownership.EXCLUSIVE else f"S{self.n_replicas}"
        return f"{self.tier.value}/{own}"


# Convenience constructors mirroring the paper's benchmark axes -------------

def local(tier: Tier = Tier.HBM_LOCAL) -> PlacementState:
    return PlacementState(tier=tier)


def remote_chip(hops: int = 1) -> PlacementState:
    t = Tier.ICI_NEIGHBOR if hops == 1 else Tier.ICI_FAR
    return PlacementState(tier=t, hops=hops)


def remote_pod() -> PlacementState:
    return PlacementState(tier=Tier.DCN_REMOTE_POD)


def shared(tier: Tier, n_replicas: int) -> PlacementState:
    return PlacementState(tier=tier, ownership=Ownership.SHARED, n_replicas=n_replicas)
