"""Model validation — the paper's NRMSE gate (Eq. 12, §5).

NRMSE = (1/x̄) * sqrt( (1/n) Σ (x̂_i - x_i)² )

The paper discusses every case where model-vs-data NRMSE exceeds 10%.  We use
the same metric and the same 10% gate in `benchmarks/model_validation.py` and
`tests/test_perf_model.py`.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence


def nrmse(predicted: Sequence[float], observed: Sequence[float]) -> float:
    if len(predicted) != len(observed) or not observed:
        raise ValueError("predicted and observed must be equal-length, non-empty")
    n = len(observed)
    mean = sum(observed) / n
    if mean == 0:
        raise ValueError("observed mean is zero; NRMSE undefined")
    se = sum((p - o) ** 2 for p, o in zip(predicted, observed)) / n
    return math.sqrt(se) / abs(mean)


NRMSE_GATE = 0.10  # the paper's 10% discussion threshold


@dataclass(frozen=True)
class ValidationRow:
    """One (op, placement) validation cell: prediction vs median measurement."""

    label: str
    predicted_s: float
    observed_s: float

    @property
    def rel_err(self) -> float:
        return abs(self.predicted_s - self.observed_s) / max(self.observed_s, 1e-30)


def validate(rows: Sequence[ValidationRow]) -> dict:
    """Aggregate a validation table the way §5 does: NRMSE + flagged cells."""
    preds = [r.predicted_s for r in rows]
    obs = [r.observed_s for r in rows]
    score = nrmse(preds, obs)
    flagged = [r.label for r in rows if r.rel_err > NRMSE_GATE]
    return {"nrmse": score, "passes": score <= NRMSE_GATE, "flagged": flagged,
            "n": len(rows)}
