"""Collective cost model built from the paper's per-hop R_O terms.

The paper models an atomic's cost as ownership-acquisition hops through the
memory hierarchy.  A mesh collective is the same object at scale: a schedule
of per-hop transfers, each costed as latency + bytes/bandwidth.  This module
prices the collectives the framework emits (ring all-reduce/all-gather/
reduce-scatter, bidirectional on the ICI torus; hierarchical over DCN) so that
`core/planner.py` can choose schedules analytically — the paper's
"use the model to pick the primitive" methodology (§6.1) applied to
distributed training.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.core.perf_model import HardwareSpec
from repro.core.placement import PlacementState, Tier

COLLECTIVES = ("all_reduce", "all_gather", "reduce_scatter", "all_to_all",
               "collective_permute")


@dataclass(frozen=True)
class MeshAxis:
    name: str
    size: int
    tier: Tier  # interconnect carrying this axis (ICI within pod, DCN across)


def _axis_link_Bps(spec: HardwareSpec, axis: MeshAxis) -> float:
    return spec.tier_bandwidth_Bps[axis.tier]


def _axis_hop_s(spec: HardwareSpec, axis: MeshAxis) -> float:
    return spec.tier_latency_s[axis.tier]


def collective_time_s(spec: HardwareSpec, kind: str, nbytes: int,
                      axis: MeshAxis, bidirectional: bool = True) -> float:
    """Time for one collective of `nbytes` (per-participant payload) on `axis`.

    Ring schedules (what XLA emits on ICI tori):
      all_gather / reduce_scatter: (n-1) steps, each moving nbytes/n.
      all_reduce: reduce_scatter + all_gather = 2(n-1) steps of nbytes/n.
      all_to_all: each chip exchanges nbytes*(n-1)/n total, bisection-limited.
      collective_permute: a single hop of nbytes.
    Bidirectional rings double effective link bandwidth (2 links per axis on
    a torus).
    """
    n = axis.size
    if n <= 1:
        return 0.0
    bw = _axis_link_Bps(spec, axis) * (2.0 if bidirectional else 1.0)
    hop = _axis_hop_s(spec, axis)
    if kind in ("all_gather", "reduce_scatter"):
        steps = n - 1
        return steps * (hop + (nbytes / n) / bw)
    if kind == "all_reduce":
        steps = 2 * (n - 1)
        return steps * (hop + (nbytes / n) / bw)
    if kind == "all_to_all":
        moved = nbytes * (n - 1) / n
        return hop * (n - 1) + moved / bw
    if kind == "collective_permute":
        return hop + nbytes / bw
    raise ValueError(f"unknown collective {kind!r}")


def collective_bytes_on_wire(kind: str, nbytes: int, n: int) -> int:
    """Bytes each participant puts on the wire (for the roofline term)."""
    if n <= 1:
        return 0
    if kind in ("all_gather", "reduce_scatter"):
        return int(nbytes * (n - 1) / n)
    if kind == "all_reduce":
        return int(2 * nbytes * (n - 1) / n)
    if kind == "all_to_all":
        return int(nbytes * (n - 1) / n)
    if kind == "collective_permute":
        return int(nbytes)
    raise ValueError(f"unknown collective {kind!r}")


def grad_sync_strategies(spec: HardwareSpec, grad_bytes: int,
                         axis: MeshAxis) -> Dict[str, float]:
    """Price the gradient-synchronization alternatives the planner considers.

    * ``all_reduce``      — replicate-everywhere baseline.
    * ``zero`` (RS+AG)    — reduce-scatter grads, all-gather updated params;
                            same wire bytes but the optimizer update runs on
                            1/n of the state (memory win; time shown is wire
                            time only).
    * ``zero_int8``       — RS+AG with int8 error-feedback compression on this
                            axis (4x fewer bytes for fp32 grads).
    """
    ar = collective_time_s(spec, "all_reduce", grad_bytes, axis)
    rs = collective_time_s(spec, "reduce_scatter", grad_bytes, axis)
    ag = collective_time_s(spec, "all_gather", grad_bytes, axis)
    zero = rs + ag
    zero_int8 = (collective_time_s(spec, "reduce_scatter", grad_bytes // 4, axis)
                 + collective_time_s(spec, "all_gather", grad_bytes // 4, axis))
    return {"all_reduce": ar, "zero": zero, "zero_int8": zero_int8}


def cross_pod_hierarchical(spec: HardwareSpec, nbytes: int, ici_axis: MeshAxis,
                           dcn_axis: MeshAxis) -> float:
    """Hierarchical all-reduce: reduce-scatter within pod (ICI), all-reduce the
    1/n shard across pods (DCN), all-gather within pod.  This is the multi-pod
    gradient path; DCN carries only nbytes/ici_n per chip."""
    rs = collective_time_s(spec, "reduce_scatter", nbytes, ici_axis)
    ar = collective_time_s(spec, "all_reduce", nbytes // max(1, ici_axis.size),
                           dcn_axis)
    ag = collective_time_s(spec, "all_gather", nbytes, ici_axis)
    return rs + ar + ag
